"""Paper Table I: per-exit top-1 accuracy on CIFAR-100, plus a live check
that joint early-exit training orders exit accuracies on a synthetic task
(reduced ResNets; CPU-sized)."""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import resnet_configs
from repro.core import ProfileTable
from repro.models import EarlyExitResNet, split_params
from repro.optim import AdamW
from repro.runtime.trainer import make_train_step
from benchmarks.common import Row, timed


def _short_train() -> "tuple[dict, float]":
    cfg = resnet_configs(smoke=True)["resnet50"]
    model = EarlyExitResNet(cfg)
    values, _ = split_params(model.init(jax.random.key(0)))
    opt = AdamW(lr=2e-3, weight_decay=0.0)
    state = opt.init(values)
    # tiny synthetic "dataset": class-dependent colour blobs
    key = jax.random.key(1)
    lbls = jax.random.randint(key, (64,), 0, 10)
    base = jax.nn.one_hot(lbls % 3, 3)[:, None, None, :]
    imgs = base + 0.3 * jax.random.normal(key, (64, 32, 32, 3))
    batch = {"images": imgs, "labels": lbls % 3}
    step = jax.jit(make_train_step(model, opt))
    metrics = {}
    for i in range(25):
        values, state, metrics = step(values, state, batch, i)
    return {k: float(v) for k, v in metrics.items()}, float(metrics["loss"])


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080()
    rows = []
    for mi, m in enumerate(table.model_names):
        acc = table.accuracy[mi]
        rows.append(Row(
            f"table1/{m}", 0.0,
            ";".join(f"{e}={a*100:.1f}%" for e, a in
                     zip(table.exit_names, acc)),
        ))
    (metrics, loss), us = timed(_short_train)
    rows.append(Row(
        "table1/joint-exit-training-live", us,
        f"final_loss={loss:.3f};"
        + ";".join(f"acc_exit{i}={metrics[f'acc_exit{i}']*100:.0f}%"
                   for i in range(4)),
    ))
    return rows
