"""Paper Fig. 2: profiled inference latency vs batch size for all models and
exit points. Emits the L(m, e, B) table and checks its three trends."""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core import ProfileTable
from benchmarks.common import Row, timed


def run() -> List[Row]:
    table, us = timed(ProfileTable.paper_rtx3080)
    rows = []
    for mi, m in enumerate(table.model_names):
        for ei, e in enumerate(table.exit_names):
            lat = table.latency[mi, ei]
            rows.append(Row(
                f"fig2/{m}/{e}", us / 12.0,
                f"L_b1_ms={lat[0]*1e3:.3f};L_b10_ms={lat[-1]*1e3:.3f};"
                f"growth={lat[-1]/lat[0]:.2f}x",
            ))
    # trend summary (paper Sec. IV-C)
    growth = table.latency[:, :, -1] / table.latency[:, :, 0]
    deep = table.latency[2, 3, :] / table.latency[2, 0, :]
    rows.append(Row(
        "fig2/trends", us,
        f"batch_growth_1_to_10={growth.min():.2f}-{growth.max():.2f}x"
        f"(paper:2-3x);r152_final_over_layer1={deep.mean():.1f}x(paper:6-8x);"
        f"ordering_r50<r101<r152={bool(np.all(np.diff(table.latency, axis=0) > 0))}",
    ))
    return rows
