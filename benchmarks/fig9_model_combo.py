"""Paper Fig. 9: model-combination robustness under equal traffic
(homogeneous 3xR50 / 3xR101 / 3xR152 and heterogeneous mixes)."""

from __future__ import annotations

from typing import List

from repro.core import ProfileTable
from benchmarks.common import Row, serving_row

COMBOS = {
    "3xR50": [0, 0, 0],
    "3xR101": [1, 1, 1],
    "3xR152": [2, 2, 2],
    "R50+R101+R152": [0, 1, 2],
    "2xR50+R152": [0, 0, 2],
    "R50+2xR152": [0, 2, 2],
}


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080()
    rows = []
    for name, mix in COMBOS.items():
        view = table.select_models(mix)
        for lam in (60, 120, 180):
            # equal rates (paper: 1:1:1 to unconfound heterogeneity)
            row, m = serving_row(
                f"fig9/{name}/lam{lam}", "edgeserving", view, lam,
                rates=[lam, lam, lam])
            rows.append(row)
    return rows
