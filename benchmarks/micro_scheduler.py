"""Scheduler-step microbenchmarks: the online decision must fit inside the
inter-quantum gap (sub-millisecond). Compares the paper's loop scheduler,
the vectorised NumPy variant, and the Pallas scoring kernel (interpret mode
on CPU — TPU numbers come from the same call with interpret=False)."""

from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core import (
    EdgeServingScheduler,
    LatticeEdgeServingScheduler,
    ProfileTable,
    QueueSnapshot,
    SchedulerConfig,
    VectorizedEdgeServingScheduler,
)
from repro.kernels.stability_score.ops import stability_scores
from benchmarks.common import Row


def _snapshot(m_count: int, qlen: int, seed: int = 0) -> QueueSnapshot:
    rng = np.random.default_rng(seed)
    waits = [np.sort(rng.uniform(0, 0.06, qlen))[::-1].copy()
             for _ in range(m_count)]
    return QueueSnapshot(0.0, waits)


def _time(fn, n=50):
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def run() -> List[Row]:
    rows = []
    table = ProfileTable.paper_rtx3080()
    cfg = SchedulerConfig(slo=0.05)
    lat_cfg = SchedulerConfig(slo=0.05, lattice=True)
    for m_count, qlen in [(3, 16), (3, 256), (3, 2048)]:
        snap = _snapshot(m_count, qlen)
        loop = EdgeServingScheduler(table, cfg)
        vec = VectorizedEdgeServingScheduler(table, cfg)
        lattice = LatticeEdgeServingScheduler(table, lat_cfg)
        us_loop = _time(lambda: loop.decide(snap))
        us_vec = _time(lambda: vec.decide(snap))
        us_lat = _time(lambda: lattice.decide(snap))
        n_cands = len(lattice.enumerate_candidates(snap)[0])
        rows.append(Row(f"micro/scheduler-loop/M{m_count}xQ{qlen}", us_loop,
                        f"decisions_per_s={1e6/us_loop:.0f}"))
        rows.append(Row(f"micro/scheduler-vec/M{m_count}xQ{qlen}", us_vec,
                        f"decisions_per_s={1e6/us_vec:.0f};"
                        f"speedup={us_loop/us_vec:.2f}x"))
        rows.append(Row(f"micro/scheduler-lattice/M{m_count}xQ{qlen}", us_lat,
                        f"decisions_per_s={1e6/us_lat:.0f};"
                        f"n_candidates={n_cands}"))

    # fused Pallas scoring (interpret mode: correctness-path timing only)
    m_count, qlen = 8, 512
    snap = _snapshot(m_count, qlen)
    w, mask = snap.padded()
    w = jnp.asarray(w, jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    lat = jnp.full((m_count,), 0.005, jnp.float32)
    bat = jnp.full((m_count,), 10, jnp.int32)
    fn = lambda: stability_scores(
        w, mask, lat, bat, tau=0.05, interpret=True).block_until_ready()
    us = _time(fn, n=10)
    rows.append(Row(f"micro/stability-kernel-interp/M{m_count}xQ{qlen}", us,
                    "pallas_interpret_cpu"))

    # flattened lattice layout: 5 ladder rungs per queue through the same
    # fused kernel via the candidate->queue index map
    n_cands = 5 * m_count
    cq = jnp.repeat(jnp.arange(m_count, dtype=jnp.int32), 5)
    lat_l = jnp.tile(jnp.asarray([1, 2, 3, 4, 5], jnp.float32) * 1e-3, m_count)
    bat_l = jnp.tile(jnp.asarray([1, 2, 4, 8, 10], jnp.int32), m_count)
    fn = lambda: stability_scores(
        w, mask, lat_l, bat_l, cq, tau=0.05, interpret=True
    ).block_until_ready()
    us = _time(fn, n=10)
    rows.append(Row(f"micro/stability-kernel-lattice/N{n_cands}xQ{qlen}", us,
                    "pallas_interpret_cpu"))
    return rows
