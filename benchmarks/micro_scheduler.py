"""Scheduler-step microbenchmarks: the online decision must fit inside the
inter-quantum gap (sub-millisecond). Three studies:

  * the classic loop-vs-vectorised-vs-lattice decision timing at edge scale
    (M = 3, growing queue depth);
  * the **scoring-backend study**: per-round stability-scoring latency of
    every ``repro.core.scoring`` backend (numpy / jnp / pallas-interpret)
    at M ∈ {4, 16, 64, 256} colocated queues, greedy and lattice layouts,
    with cross-backend decision-equivalence asserted on both scalar-SLO and
    heterogeneous-deadline snapshots before anything is timed. This is the
    many-tenant regime the kernel docstring anticipates: numpy wins at edge
    scale, jnp takes over from M ≳ 64. True-``pallas`` numbers come from
    the same call on a TPU host; interpret mode here is the
    correctness-path timing only.
  * the **sweep-speedup study**: a fig4-shaped grid (the paper's λ axis x
    several seeds) through the reference Python event loop versus one
    vmapped+jitted ``lax.scan`` launch (``repro.core.simfast``), greedy and
    lattice, with per-cell ``ServingMetrics`` equality asserted before the
    speedup is reported. Arrival generation is excluded on both sides
    (identical cost, shared input); timing is engine-only. Target: >= 50x.
    The scan side is a single XLA launch, so on multi-core hosts it also
    picks up intra-op parallelism that the serial Python loop cannot — the
    single-core ratio reported on a 1-CPU runner is the floor.

``REPRO_MICRO_SCHED_SMOKE=1`` (CI) restricts to M ∈ {4, 16} / a 2-cell
sweep grid with fewer repetitions so the studies run in seconds on
CPU-only runners.
"""

from __future__ import annotations

import os
import time
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core import (
    EdgeServingScheduler,
    LatticeEdgeServingScheduler,
    ProfileTable,
    QueueSnapshot,
    SchedulerConfig,
    ServingSimulator,
    VectorizedEdgeServingScheduler,
    make_scheduler,
    paper_rate_vector,
    poisson_arrivals,
    simulate_scan_batch,
)
from repro.kernels.stability_score.ops import stability_scores
from benchmarks.common import HORIZON, LAMBDAS, Row

BACKENDS = ("numpy", "jnp", "pallas-interpret")


def _snapshot(m_count: int, qlen: int, seed: int = 0,
              het_tau: bool = False) -> QueueSnapshot:
    rng = np.random.default_rng(seed)
    waits = [np.sort(rng.uniform(0, 0.06, qlen))[::-1].copy()
             for _ in range(m_count)]
    deadlines = None
    if het_tau:
        deadlines = [
            np.where(rng.uniform(size=qlen) < 0.5,
                     rng.uniform(0.02, 0.09, qlen), np.nan)
            for _ in range(m_count)
        ]
    return QueueSnapshot(0.0, waits, deadlines)


def _time(fn, n=50):
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def _wide_table(m_count: int) -> ProfileTable:
    """Tile the paper table out to ``m_count`` models with a deterministic
    per-model speed spread (breaks symmetry so argmins are meaningful)."""
    base = ProfileTable.paper_rtx3080()
    reps = -(-m_count // base.num_models)
    lat = np.tile(base.latency, (reps, 1, 1))[:m_count]
    acc = np.tile(base.accuracy, (reps, 1))[:m_count]
    scale = np.linspace(0.7, 1.3, m_count)[:, None, None]
    return ProfileTable(
        tuple(f"model{i}" for i in range(m_count)),
        base.exit_names, base.batch_sizes, lat * scale, acc,
        meta={"builder": "micro-wide", "platform": "synthetic"})


def _backend_study(smoke: bool) -> List[Row]:
    rows: List[Row] = []
    qlen = 16
    for m_count in ((4, 16) if smoke else (4, 16, 64, 256)):
        table = _wide_table(m_count)
        for lattice in (False, True):
            scheds = {
                be: (LatticeEdgeServingScheduler if lattice else
                     VectorizedEdgeServingScheduler)(
                         table,
                         SchedulerConfig(slo=0.05, lattice=lattice,
                                         backend=be))
                for be in BACKENDS
            }
            # decision-equivalence pin: every backend must pick the same
            # (model, exit, batch) on scalar-SLO *and* het-deadline state.
            for het in (False, True):
                s = _snapshot(m_count, qlen, seed=m_count + het, het_tau=het)
                picks = {
                    be: (d.model, d.exit_idx, d.batch_size)
                    for be, d in ((be, sc.decide(s))
                                  for be, sc in scheds.items())
                }
                assert len(set(picks.values())) == 1, (
                    f"backend decision mismatch at M={m_count} "
                    f"lattice={lattice} het={het}: {picks}")
            # scoring latency: one shared enumeration, timed scoring only
            snap = _snapshot(m_count, qlen, seed=m_count)
            ref = scheds["numpy"]
            cq, cb, _, cl, _ = ref.enumerate_candidates(snap)
            us_numpy = None
            for be in BACKENDS:
                sc = scheds[be]
                reps = (3 if smoke else 8) if be == "pallas-interpret" else \
                    (10 if smoke else 40)
                us = _time(
                    lambda sc=sc: sc.score_candidates(snap, cl, cb, cq),
                    n=reps)
                if be == "numpy":
                    us_numpy = us
                tag = "-lattice" if lattice else ""
                rows.append(Row(
                    f"micro/backend{tag}/{be}/M{m_count}", us,
                    f"n_candidates={len(cq)};match=yes;"
                    f"speedup_vs_numpy={us_numpy / us:.2f}x"))
    return rows


def _sweep_speedup_study(smoke: bool) -> List[Row]:
    """fig4-shaped sweep, Python engine vs one compiled scan launch.

    Both engines consume the same pre-generated arrival lanes (generation
    cost is identical and excluded); the Python side is the reference
    ``ServingSimulator`` loop run serially per cell, the scan side is one
    ``simulate_scan_batch`` call covering the whole grid. Every cell's
    ``ServingMetrics`` must compare equal across engines before the row is
    emitted — the speedup of a wrong simulation is not interesting.
    """
    table = ProfileTable.paper_rtx3080()
    lambdas = (60.0, 140.0) if smoke else tuple(float(x) for x in LAMBDAS)
    seeds = (7,) if smoke else (7, 8, 9, 10)
    horizon = 3.0 if smoke else HORIZON
    lanes = [poisson_arrivals(paper_rate_vector(lam), horizon, seed=s)
             for lam in lambdas for s in seeds]
    n_req = sum(len(l) for l in lanes)
    reps = 1 if smoke else 3

    rows: List[Row] = []
    for lattice in (False, True):
        def sched():
            return make_scheduler(
                "edgeserving-lattice" if lattice else "edgeserving",
                table, SchedulerConfig(slo=0.05))

        py_times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            py_res = [
                ServingSimulator(sched(), table, num_models=3).run(a, horizon)
                for a in lanes
            ]
            py_times.append(time.perf_counter() - t0)
        t_py = sorted(py_times)[len(py_times) // 2]

        t0 = time.perf_counter()
        sc_res = simulate_scan_batch(sched(), table, lanes, horizon,
                                     num_models=3)
        t_cold = time.perf_counter() - t0
        sc_times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            sc_res = simulate_scan_batch(sched(), table, lanes, horizon,
                                         num_models=3)
            sc_times.append(time.perf_counter() - t0)
        t_warm = sorted(sc_times)[len(sc_times) // 2]

        match = sum(p.metrics == s.metrics for p, s in zip(py_res, sc_res))
        assert match == len(lanes), (
            f"scan/python metrics diverged on {len(lanes) - match} of "
            f"{len(lanes)} cells (lattice={lattice})")
        tag = "lattice" if lattice else "greedy"
        rows.append(Row(
            f"micro/simfast-sweep/{tag}", t_warm * 1e6,
            f"cells={len(lanes)};requests={n_req};python_s={t_py:.2f};"
            f"scan_cold_s={t_cold:.2f};scan_warm_s={t_warm:.3f};"
            f"speedup={t_py / t_warm:.1f}x;target=50x;"
            f"match={match}/{len(lanes)}"))
    return rows


def run() -> List[Row]:
    smoke = bool(os.environ.get("REPRO_MICRO_SCHED_SMOKE"))
    rows = []
    table = ProfileTable.paper_rtx3080()
    cfg = SchedulerConfig(slo=0.05)
    lat_cfg = SchedulerConfig(slo=0.05, lattice=True)
    depths = [(3, 16), (3, 256)] if smoke else [(3, 16), (3, 256), (3, 2048)]
    for m_count, qlen in depths:
        snap = _snapshot(m_count, qlen)
        loop = EdgeServingScheduler(table, cfg)
        vec = VectorizedEdgeServingScheduler(table, cfg)
        lattice = LatticeEdgeServingScheduler(table, lat_cfg)
        n = 10 if smoke else 50
        us_loop = _time(lambda: loop.decide(snap), n=n)
        us_vec = _time(lambda: vec.decide(snap), n=n)
        us_lat = _time(lambda: lattice.decide(snap), n=n)
        n_cands = len(lattice.enumerate_candidates(snap)[0])
        rows.append(Row(f"micro/scheduler-loop/M{m_count}xQ{qlen}", us_loop,
                        f"decisions_per_s={1e6/us_loop:.0f}"))
        rows.append(Row(f"micro/scheduler-vec/M{m_count}xQ{qlen}", us_vec,
                        f"decisions_per_s={1e6/us_vec:.0f};"
                        f"speedup={us_loop/us_vec:.2f}x"))
        rows.append(Row(f"micro/scheduler-lattice/M{m_count}xQ{qlen}", us_lat,
                        f"decisions_per_s={1e6/us_lat:.0f};"
                        f"n_candidates={n_cands}"))

    # fused Pallas scoring (interpret mode: correctness-path timing only)
    m_count, qlen = 8, 512
    snap = _snapshot(m_count, qlen)
    w, mask = snap.padded()
    w = jnp.asarray(w, jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    lat = jnp.full((m_count,), 0.005, jnp.float32)
    bat = jnp.full((m_count,), 10, jnp.int32)
    fn = lambda: stability_scores(
        w, mask, lat, bat, tau=0.05, interpret=True).block_until_ready()
    us = _time(fn, n=10)
    rows.append(Row(f"micro/stability-kernel-interp/M{m_count}xQ{qlen}", us,
                    "pallas_interpret_cpu"))

    # flattened lattice layout: 5 ladder rungs per queue through the same
    # fused kernel via the candidate->queue index map
    n_cands = 5 * m_count
    cq = jnp.repeat(jnp.arange(m_count, dtype=jnp.int32), 5)
    lat_l = jnp.tile(jnp.asarray([1, 2, 3, 4, 5], jnp.float32) * 1e-3, m_count)
    bat_l = jnp.tile(jnp.asarray([1, 2, 4, 8, 10], jnp.int32), m_count)
    fn = lambda: stability_scores(
        w, mask, lat_l, bat_l, cq, tau=0.05, interpret=True
    ).block_until_ready()
    us = _time(fn, n=10)
    rows.append(Row(f"micro/stability-kernel-lattice/N{n_cands}xQ{qlen}", us,
                    "pallas_interpret_cpu"))

    rows.extend(_backend_study(smoke))
    rows.extend(_sweep_speedup_study(smoke))
    return rows
