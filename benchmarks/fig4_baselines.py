"""Paper Fig. 4: P95 latency + SLO violation ratio vs traffic intensity for
All-Final / All-Early / Symphony / EdgeServing."""

from __future__ import annotations

from typing import List

from repro.core import ProfileTable
from benchmarks.common import LAMBDAS, Row, serving_row


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080()
    rows = []
    for sched in ("edgeserving", "all-final", "all-early", "symphony"):
        for lam in LAMBDAS:
            row, _ = serving_row(f"fig4/{sched}/lam{lam}", sched, table, lam)
            rows.append(row)
    return rows
