"""Paper Fig. 4: P95 latency + SLO violation ratio vs traffic intensity for
All-Final / All-Early / Symphony / EdgeServing (parallel sweep)."""

from __future__ import annotations

from typing import List

from repro.core import ProfileTable, SweepRunner, SweepSpec
from benchmarks.common import HORIZON, LAMBDAS, Row, SEED, sweep_rows


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080()
    specs = [
        SweepSpec(policy=sched, rate=lam, seed=SEED, horizon=HORIZON,
                  label=f"fig4/{sched}/lam{lam:g}")
        for sched in ("edgeserving", "all-final", "all-early", "symphony")
        for lam in LAMBDAS
    ]
    return [row for row, _ in sweep_rows(SweepRunner(table), specs)]
