"""Beyond-paper Fig. 17: seed-band confidence intervals for the headline
cells, at a scale only the compiled engines can afford.

Every serving figure so far reports one seed per cell (the paper's own
protocol). This study re-runs the headline cells at 10^3 seeds through the
vmapped scan engines (``repro.core.seedband``) and reports mean ± 95%
normal-approximation CI per cell, answering two questions single-seed
sweeps cannot:

  * **grid** — the fig4 λ-grid (7 loads, plain RTX 3080 table, 10 s
    horizon) for EdgeServing, plus the strongest Algorithm-1 baseline
    ``allfinal-deadline-aware`` over its stable region (λ₁₅₂ <= 140 —
    fig4's own finding is that All-Final collapses past that knee; its
    post-collapse bands are ~97% violations with runaway queues that
    slow *both* engines ~20x, all noise and no signal): how much of each
    quoted violation/P95 number is seed noise? The per-λ rows carry the
    bands the docs can quote.
  * **fleet** — the fig14 heterogeneous-fleet headline cell (2 fast + 2
    Jetson-class, MMPP λ₁₅₂ = 640, 6 s horizon) for the two dispatchers
    the write-up compares: is the stability-aware-vs-JSQ violation gap
    statistically significant, or a lucky seed?  The ``gap`` row prints
    the two-sample 95% CI and the verdict (``compare_bands``).

Both parts also measure the reference Python engine on a small seed
subsample and report the honest study-level speedup (Python extrapolated
to all seeds / scan wall including compiles) in the ``speedup`` rows —
the acceptance bar is >= 10x per part on this container. The per-seed
metric columns are bitwise-reproducible (chunking is vmap-vs-loop
invariant; see ``tests/test_seedband.py``), so the bands themselves are
exact re-runnable numbers, not Monte-Carlo estimates of the engine.

``REPRO_FIG17_SMOKE=1`` (CI) shrinks to 2 grid cells × 8 seeds and a
6-seed fleet cell; the gap row still exercises ``compare_bands``.
"""

from __future__ import annotations

import os
import time
from typing import List

from repro.core import (
    ClusterSimulator,
    ProfileTable,
    SchedulerConfig,
    ServingSimulator,
    compare_bands,
    make_dispatcher,
    make_fleet,
    make_scenario,
    make_scheduler,
    paper_rate_vector,
    simulate_cluster_scan_seedband,
    simulate_scan_seedband,
)
from benchmarks.common import HORIZON, LAMBDAS, Row

SLO = 0.050
N_SEEDS = 1000
GRID_POLICIES = ("edgeserving", "allfinal-deadline-aware")
BASELINE_LAM_MAX = 140.0   # the baseline's pre-collapse region (fig4 knee)
GRID_CHUNK = 100
# fig14's het headline cell (benchmarks/fig14_cluster.py): 2 fast + 2
# Jetson-class devices under MMPP at ~1.5x weighted capacity.
FLEET_SIZE = 4
FLEET_LAM = 160.0 * 4
FLEET_HORIZON = 6.0
FLEET_DISPATCHERS = ("stability-aware", "jsq")
FLEET_CHUNK = 64
# ring width the het cell settles at; purely a shape hint (skips the
# Q-doubling re-runs), decisions are Q-invariant
FLEET_MAX_QUEUE = 128
PY_SAMPLE = 2          # Python-engine seeds per cell for the speedup rows


def _band_derived(band) -> str:
    v = band.band("violation_ratio")
    p = band.band("p95_latency")
    return (
        f"viol={v.mean * 100:.3f}%±{(v.ci_hi - v.mean) * 100:.3f}pp;"
        f"p95_ms={p.mean * 1e3:.2f}±{(p.ci_hi - p.mean) * 1e3:.2f};"
        f"n={v.n}"
    )


def _speedup_row(name: str, py_per_seed: float, n_seeds: int,
                 scan_wall: float) -> Row:
    py_est = py_per_seed * n_seeds
    ratio = py_est / scan_wall if scan_wall > 0 else float("inf")
    return Row(
        name, scan_wall * 1e6 / n_seeds,
        f"python_est={py_est:.0f}s;scan={scan_wall:.0f}s;"
        f"speedup={ratio:.1f}x;target=10x",
    )


def run() -> List[Row]:
    smoke = bool(os.environ.get("REPRO_FIG17_SMOKE"))
    n_seeds = 8 if smoke else N_SEEDS
    lambdas = (100.0, 220.0) if smoke else LAMBDAS
    horizon = 2.0 if smoke else HORIZON
    grid_chunk = 4 if smoke else GRID_CHUNK
    n_fleet = 6 if smoke else N_SEEDS
    fleet_horizon = 1.5 if smoke else FLEET_HORIZON
    fleet_chunk = 3 if smoke else FLEET_CHUNK
    py_sample = 1 if smoke else PY_SAMPLE

    table = ProfileTable.paper_rtx3080()
    cfg = SchedulerConfig(slo=SLO)
    rows: List[Row] = []

    # ---- part A: fig4 λ-grid seed bands -------------------------------
    scan_wall = 0.0
    py_wall = 0.0
    for policy in GRID_POLICIES:
        grid = (lambdas if policy == "edgeserving"
                else [lam for lam in lambdas if lam <= BASELINE_LAM_MAX])
        for lam in grid:
            proc = make_scenario("poisson", paper_rate_vector(lam))
            sched = make_scheduler(policy, table, cfg)
            t0 = time.perf_counter()
            band = simulate_scan_seedband(
                sched, table, proc, horizon, range(n_seeds),
                chunk=grid_chunk)
            dt = time.perf_counter() - t0
            scan_wall += dt
            rows.append(Row(f"fig17/grid/{policy}/lam{lam:g}",
                            dt * 1e6 / n_seeds, _band_derived(band)))
            for seed in range(py_sample):
                lane = proc.generate(horizon, seed=seed)
                t0 = time.perf_counter()
                ServingSimulator(
                    make_scheduler(policy, table, cfg), table,
                    num_models=len(paper_rate_vector(lam)),
                ).run(lane, horizon)
                py_wall += time.perf_counter() - t0
    # py_wall summed py_sample passes over every grid cell, so the
    # per-seed whole-grid Python cost is py_wall / py_sample
    rows.append(_speedup_row(
        "fig17/speedup/grid", py_wall / py_sample, n_seeds, scan_wall))

    # ---- part B: fig14 heterogeneous-fleet cell -----------------------
    proc = make_scenario("mmpp", paper_rate_vector(FLEET_LAM))
    # chunks pad to their longest lane; grouping MMPP seeds by arrival
    # count cuts the padding waste (per-seed results are chunk-invariant)
    seeds = sorted(
        range(n_fleet),
        key=lambda s: len(proc.generate_columns(fleet_horizon, seed=s)))
    fleet = make_fleet("heterogeneous", FLEET_SIZE, table)
    cols = {}
    scan_wall = 0.0
    py_wall = 0.0
    for disp in FLEET_DISPATCHERS:
        t0 = time.perf_counter()
        band = simulate_cluster_scan_seedband(
            fleet, proc, fleet_horizon, seeds, chunk=fleet_chunk,
            dispatcher=disp, power_d=FLEET_SIZE, config=cfg,
            max_queue=FLEET_MAX_QUEUE)
        dt = time.perf_counter() - t0
        scan_wall += dt
        cols[disp] = band.column("violation_ratio")
        rows.append(Row(f"fig17/fleet/{disp}", dt * 1e6 / n_fleet,
                        _band_derived(band)))
        # median-length lanes: representative per-seed Python cost
        for seed in seeds[len(seeds) // 2:len(seeds) // 2 + py_sample]:
            lane = proc.generate(fleet_horizon, seed=seed)
            t0 = time.perf_counter()
            ClusterSimulator(
                make_fleet("heterogeneous", FLEET_SIZE, table),
                config=cfg,
                dispatcher=make_dispatcher(disp, slo=SLO,
                                           power_d=FLEET_SIZE),
            ).run(lane, fleet_horizon)
            py_wall += time.perf_counter() - t0
    rows.append(_speedup_row(
        "fig17/speedup/fleet", py_wall / py_sample, n_fleet, scan_wall))

    # the question fig14's single seed cannot answer: is the
    # stability-aware advantage over JSQ real across the seed band?
    gap = compare_bands(cols["jsq"], cols["stability-aware"])
    rows.append(Row(
        "fig17/gap/jsq-minus-stability-aware", 0.0,
        f"gap={gap.gap * 100:.2f}pp;"
        f"ci=[{gap.ci_lo * 100:.2f},{gap.ci_hi * 100:.2f}]pp;"
        f"significant={'yes' if gap.significant else 'no'}",
    ))
    return rows
