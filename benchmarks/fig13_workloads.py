"""Beyond-paper Fig. 13: scheduling policies across workload scenarios.

The paper evaluates only stationary Poisson arrivals with one global SLO;
this sweep runs EdgeServing (greedy and lattice) against the All-Final and
Symphony baselines under every registered arrival process — stationary
Poisson, MMPP on-off bursts, a diurnal cycle, a flash crowd, and a replayed
MMPP trace — plus a heterogeneous-SLO leg where each queue carries its own
deadline. One row per (policy, scenario) cell reports the violation ratio,
P95 latency, and the per-model violation breakdown (``viol_by_model``),
which is where bursty-queue damage shows up even when the aggregate looks
healthy.

The grid fans across worker processes via ``SweepRunner``; set
``REPRO_FIG13_SMOKE=1`` (CI) for a 1-scenario, tiny-horizon smoke run.
"""

from __future__ import annotations

import os
from typing import List

from repro.core import ProfileTable, ServingMetrics, SweepRunner, SweepSpec
from benchmarks.common import HORIZON, Row, SEED, derived_str, sweep_rows

LAM = 160.0
POLICIES = ("edgeserving", "edgeserving-lattice", "all-final", "symphony")
SCENARIOS = ("poisson", "mmpp", "diurnal", "flash-crowd", "trace-replay")
HET_DEADLINES = (0.030, 0.050, 0.070)  # per-queue SLO vector for the het leg


def _derived(m: ServingMetrics) -> str:
    by_model = "|".join(
        f"m{pm.model}:{pm.violation_ratio*100:.1f}%" for pm in m.per_model
    )
    return f"{derived_str(m)};viol_by_model={by_model}"


def _specs() -> List[SweepSpec]:
    smoke = bool(os.environ.get("REPRO_FIG13_SMOKE"))
    policies = ("edgeserving", "all-final") if smoke else POLICIES
    scenarios = ("mmpp",) if smoke else SCENARIOS
    horizon = 2.0 if smoke else HORIZON
    warmup = 20 if smoke else 100
    specs = [
        SweepSpec(policy=p, scenario=sc, rate=LAM, seed=SEED, horizon=horizon,
                  warmup_tasks=warmup, label=f"fig13/{sc}/{p}")
        for sc in scenarios
        for p in policies
    ]
    if not smoke:
        # Heterogeneous-SLO leg: stationary arrivals, per-queue deadlines.
        specs += [
            SweepSpec(policy=p, scenario="poisson", rate=LAM, seed=SEED,
                      horizon=horizon, warmup_tasks=warmup,
                      deadlines=HET_DEADLINES, label=f"fig13/het-slo/{p}")
            for p in policies
        ]
    return specs


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080()
    results = sweep_rows(SweepRunner(table), _specs())
    return [
        Row(row.name, row.us_per_call, _derived(metrics))
        for row, metrics in results
    ]
