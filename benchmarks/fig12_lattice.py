"""Beyond-paper Fig. 12: joint (model, exit, batch) lattice vs Eq. 5 greedy.

Sweeps traffic intensity on a batch-saturating profile (accelerator
throughput flat past the knee — the BCEdge regime where batch size is a
real degree of freedom) and compares the paper-exact greedy scheduler
against the candidate-lattice scheduler at two SLOs. On the calibrated
sub-saturation RTX 3080 curve the two policies coincide (an extra batch
item costs ~L1/6, so the stability argmin always takes the full Eq. 5
batch); past the knee the lattice trades batch size against collateral
queue urgency and lowers the violation ratio at high load.

Each (slo, policy) sweep ends with a ``summary`` row carrying the mean
violation ratio across the sweep — the headline lattice-vs-greedy number.
The whole grid runs through the parallel ``SweepRunner``.
"""

from __future__ import annotations

from typing import List

from repro.core import ProfileTable, SweepRunner, SweepSpec
from benchmarks.common import HORIZON, LAMBDAS, Row, SEED, sweep_rows

SLOS = (0.030, 0.050)
POLICIES = ("edgeserving", "edgeserving-lattice")
KNEE = 4


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080().with_batch_saturation(KNEE)
    specs = [
        SweepSpec(policy=sched, rate=lam, slo=slo, seed=SEED, horizon=HORIZON,
                  label=f"fig12/{sched}/slo{int(slo*1e3)}ms/lam{lam:g}")
        for slo in SLOS
        for sched in POLICIES
        for lam in LAMBDAS
    ]
    results = sweep_rows(SweepRunner(table), specs)

    # Grid order is (slo, policy, lambda): chunk per (slo, policy) sweep and
    # append its mean-violation summary row.
    rows: List[Row] = []
    n_lam = len(LAMBDAS)
    for i in range(0, len(results), n_lam):
        chunk = results[i:i + n_lam]
        rows.extend(row for row, _ in chunk)
        spec = specs[i]
        mean_viol = sum(m.violation_ratio for _, m in chunk) / n_lam
        rows.append(Row(
            f"fig12/{spec.policy}/slo{int(spec.slo*1e3)}ms/summary", 0.0,
            f"mean_viol={mean_viol*100:.3f}%"))
    return rows
