"""Beyond-paper Fig. 12: joint (model, exit, batch) lattice vs Eq. 5 greedy.

Sweeps traffic intensity on a batch-saturating profile (accelerator
throughput flat past the knee — the BCEdge regime where batch size is a
real degree of freedom) and compares the paper-exact greedy scheduler
against the candidate-lattice scheduler at two SLOs. On the calibrated
sub-saturation RTX 3080 curve the two policies coincide (an extra batch
item costs ~L1/6, so the stability argmin always takes the full Eq. 5
batch); past the knee the lattice trades batch size against collateral
queue urgency and lowers the violation ratio at high load.

Each (slo, policy) sweep ends with a ``summary`` row carrying the mean
violation ratio across the sweep — the headline lattice-vs-greedy number.
"""

from __future__ import annotations

from typing import List

from repro.core import ProfileTable
from benchmarks.common import LAMBDAS, Row, serving_row

SLOS = (0.030, 0.050)
KNEE = 4


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080().with_batch_saturation(KNEE)
    rows: List[Row] = []
    for slo in SLOS:
        slo_ms = int(slo * 1e3)
        for sched in ("edgeserving", "edgeserving-lattice"):
            viols = []
            for lam in LAMBDAS:
                row, m = serving_row(
                    f"fig12/{sched}/slo{slo_ms}ms/lam{lam}", sched, table,
                    lam, slo=slo)
                rows.append(row)
                viols.append(m.violation_ratio)
            mean_viol = sum(viols) / len(viols)
            rows.append(Row(
                f"fig12/{sched}/slo{slo_ms}ms/summary", 0.0,
                f"mean_viol={mean_viol*100:.3f}%"))
    return rows
