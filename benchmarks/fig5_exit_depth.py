"""Paper Fig. 5: average early-exit depth vs traffic intensity (deep exits
at low load, progressive shallowing under load)."""

from __future__ import annotations

from typing import List

from repro.core import ProfileTable
from benchmarks.common import LAMBDAS, Row, serving_row


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080()
    rows = []
    depths = []
    for lam in LAMBDAS:
        row, m = serving_row(f"fig5/edgeserving/lam{lam}", "edgeserving",
                             table, lam)
        depths.append(m.mean_exit_depth)
        rows.append(row)
    monotone = all(a >= b - 0.05 for a, b in zip(depths, depths[1:]))
    rows.append(Row("fig5/trend", 0.0,
                    f"depths={['%.2f' % d for d in depths]};"
                    f"shallowing_with_load={monotone}"))
    return rows
