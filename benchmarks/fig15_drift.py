"""Beyond-paper Fig. 15: online profile adaptation under device drift.

The paper's scheduler trusts the offline 120-cell profile table for the
whole serving session; this study makes the *device* drift away from it
(``repro.core.adaptive.DriftModel``: thermal-throttle ramp, DVFS step,
contention bursts — true service times inflate while the scheduler's
belief stays put) and compares, per drift scenario:

  * **static**   — stock EdgeServing deciding with the cold-start table:
    Eq. 6 keeps picking exits whose *believed* latency fits the SLO while
    the true latency no longer does, and violations climb with the drift;
  * **adaptive** — the same scheduler fed by an ``OnlineProfiler``
    (``SweepSpec.adapt``): observed quantum service times refresh the
    table every ``refresh_every`` seconds, Eq. 5/6 and the stability score
    re-price themselves against the drifted device, and the violation
    ratio recovers toward the drift-free baseline;
  * **adaptive+safety** — adaptation plus the ``SafetyController``
    violation-headroom feedback on the table's safety multiplier.

Legs: three single-device drift scenarios (throttle / dvfs / contention)
at the paper's near-saturation λ₁₅₂ = 140, one heterogeneous-cluster
throttle leg (per-device profilers), and a **nodrift** control pair
asserting that a ``drift="none"`` cell is bitwise-identical to the stock
fig4 λ₁₅₂ = 140 cell (the drift/adapt plumbing leaves the drift-free path
untouched). Acceptance: each scenario's ``summary`` row must read
``adaptive_wins=yes`` (strictly lower violation ratio than static) on at
least the throttle and dvfs legs, and the ``nodrift`` row must read
``bitwise=yes``. ``REPRO_FIG15_SMOKE=1`` (CI) runs a single throttle
scenario on a tiny horizon.
"""

from __future__ import annotations

import os
from typing import Dict, List, Tuple

from repro.core import AdaptConfig, ProfileTable, SweepRunner, SweepSpec
from benchmarks.common import HORIZON, Row, SEED, derived_str, sweep_rows

LAM = 140.0            # fig4's near-saturation traffic point
DRIFT_HORIZON = 8.0    # long enough for onset -> ramp -> adapted steady state
SLO = 0.050

# Drift scenarios: (DRIFTS name, kwargs). Onsets sit past the warmup so the
# static and adaptive cells diverge inside the measured window.
SCENARIOS: Dict[str, Tuple[str, Tuple[Tuple[str, object], ...]]] = {
    "throttle": ("thermal-throttle",
                 (("onset", 1.5), ("ramp", 2.0), ("peak", 2.2))),
    "dvfs": ("dvfs-step", (("steps", ((2.0, 1.8),)),)),
    "contention": ("contention",
                   (("burst_rate", 0.3), ("burst_duration", 0.8),
                    ("magnitude", 2.2))),
}

ADAPT = AdaptConfig(refresh_every=0.25)
ADAPT_SAFETY = AdaptConfig(refresh_every=0.25, safety=True)


def _specs() -> List[SweepSpec]:
    smoke = bool(os.environ.get("REPRO_FIG15_SMOKE"))
    # Smoke compresses the throttle into the 2 s horizon (onset inside the
    # warmup window would hide the static/adaptive gap entirely otherwise).
    scenarios = (
        {"throttle": ("thermal-throttle",
                      (("onset", 0.3), ("ramp", 0.4), ("peak", 2.2)))}
        if smoke else SCENARIOS
    )
    horizon = 2.0 if smoke else DRIFT_HORIZON
    warmup = 20 if smoke else 100
    variants: List[Tuple[str, AdaptConfig]] = [
        ("static", None), ("adaptive", ADAPT)]
    if not smoke:
        variants.append(("adaptive-safety", ADAPT_SAFETY))
    specs = [
        SweepSpec(policy="edgeserving", rate=LAM, seed=SEED, slo=SLO,
                  horizon=horizon, warmup_tasks=warmup,
                  drift=name, drift_kwargs=kwargs, adapt=adapt,
                  label=f"fig15/{sc}/{variant}")
        for sc, (name, kwargs) in scenarios.items()
        for variant, adapt in variants
    ]
    if not smoke:
        # Cluster leg: a 2-fast + 2-slow fleet all throttling, per-device
        # profilers adapting each scheduler's own table.
        name, kwargs = SCENARIOS["throttle"]
        specs += [
            SweepSpec(policy="edgeserving", scenario="mmpp", rate=4 * LAM,
                      seed=SEED, slo=SLO, horizon=6.0,
                      fleet="heterogeneous", fleet_size=4,
                      dispatcher="stability-aware",
                      drift=name, drift_kwargs=kwargs, adapt=adapt,
                      label=f"fig15/cluster-throttle/{variant}")
            for variant, adapt in (("static", None), ("adaptive", ADAPT))
        ]
    return specs


def _nodrift_pair(horizon: float, warmup: int) -> List[SweepSpec]:
    """The stock fig4 λ₁₅₂ = 140 cell, with and without the drift plumbing
    engaged (``drift="none"``): metrics must match bitwise."""
    common = dict(policy="edgeserving", rate=LAM, seed=SEED, slo=SLO,
                  horizon=horizon, warmup_tasks=warmup)
    return [
        SweepSpec(**common, label="fig15/nodrift/fig4-cell"),
        SweepSpec(**common, drift="none", label="fig15/nodrift/drift-none"),
    ]


def run() -> List[Row]:
    smoke = bool(os.environ.get("REPRO_FIG15_SMOKE"))
    table = ProfileTable.paper_rtx3080()
    runner = SweepRunner(table)
    specs = _specs() + _nodrift_pair(
        horizon=2.0 if smoke else HORIZON, warmup=20 if smoke else 100)
    results = sweep_rows(runner, specs)
    rows = [row for row, _ in results]

    viol = {row.name: m.violation_ratio for row, m in results}
    # Acceptance summaries: adaptive strictly below static per scenario.
    legs = sorted({n.split("/")[1] for n in viol if n.startswith("fig15/")
                   and "/nodrift/" not in n})
    for leg in legs:
        cells = {n.rsplit("/", 1)[1]: v for n, v in viol.items()
                 if n.startswith(f"fig15/{leg}/")}
        if {"static", "adaptive"} <= set(cells):
            ok = cells["adaptive"] < cells["static"]
            extra = (f"adaptive_safety={cells['adaptive-safety']*100:.2f}%;"
                     if "adaptive-safety" in cells else "")
            rows.append(Row(
                f"fig15/summary/{leg}", 0.0,
                f"static={cells['static']*100:.2f}%;"
                f"adaptive={cells['adaptive']*100:.2f}%;{extra}"
                f"adaptive_wins={'yes' if ok else 'NO'}"))
    # Drift-off control: the drift="none" cell is bitwise the stock cell.
    pair = [m for row, m in results if row.name.startswith("fig15/nodrift/")]
    rows.append(Row(
        "fig15/summary/nodrift", 0.0,
        f"{derived_str(pair[0])};bitwise={'yes' if pair[0] == pair[1] else 'NO'}"))
    return rows
