"""Paper Fig. 7: impact of the available exit-point configuration
(layer1+final / layer2+final / layer3+final / all_exits). The scheduler's
view of the profile is restricted; execution uses the matching view."""

from __future__ import annotations

from typing import List

from repro.core import ProfileTable, SchedulerConfig, make_scheduler
from benchmarks.common import Row, serving_row

CONFIGS = {
    "layer1+final": (0, 3),
    "layer2+final": (1, 3),
    "layer3+final": (2, 3),
    "all_exits": (0, 1, 2, 3),
}


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080()
    rows = []
    for name, exits in CONFIGS.items():
        view = table.restrict_exits(exits)
        for lam in (100, 160, 200, 240):
            row, m = serving_row(
                f"fig7/{name}/lam{lam}", "edgeserving", view, lam)
            rows.append(row)
    return rows
