"""Shared benchmark scaffolding.

Every benchmark module exposes ``run() -> list[Row]``; ``benchmarks.run``
aggregates them into the ``name,us_per_call,derived`` CSV. ``us_per_call``
is the wall-clock microseconds spent producing that row (one serving
experiment / one kernel call); ``derived`` is the row's headline metric.

Serving sweeps go through ``repro.core.sweep.SweepRunner`` and fan across
worker processes by default (results are bitwise-identical to serial — see
``docs/scheduler.md``). ``REPRO_SWEEP_WORKERS=1`` forces serial;
``REPRO_SWEEP_WORKERS=N`` pins the worker count.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core import (
    ProfileTable,
    ServingMetrics,
    SweepRunner,
    SweepSpec,
)


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


# Default sweep (paper: lambda_152 from 20 to 240 req/s on the RTX 3080).
LAMBDAS = (20, 60, 100, 140, 180, 220, 240)
HORIZON = 10.0
SEED = 7


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def sweep_workers(n_specs: int) -> int:
    """Worker count for a benchmark sweep: ``REPRO_SWEEP_WORKERS`` if set,
    else one per CPU, capped at the grid size."""
    env = os.environ.get("REPRO_SWEEP_WORKERS")
    if env:
        return max(1, int(env))
    return max(1, min(os.cpu_count() or 1, n_specs))


def derived_str(m: ServingMetrics) -> str:
    """The standard headline-metric string shared by all serving rows."""
    return (
        f"p95_ms={m.p95_latency*1e3:.2f};viol={m.violation_ratio*100:.2f}%;"
        f"acc={m.mean_accuracy*100:.2f}%;depth={m.mean_exit_depth:.2f}"
    )


def serving_row(
    name: str,
    scheduler_name: str,
    table: ProfileTable,
    lam: float,
    slo: float = 0.050,
    rates=None,
    sched_table: Optional[ProfileTable] = None,
    model_map=None,
    horizon: float = HORIZON,
    seed: int = SEED,
    max_batch: int = 10,
    scenario: str = "poisson",
    warmup_tasks: int = 100,
) -> "tuple[Row, object]":
    """One serving experiment -> CSV row + metrics (a single sweep cell)."""
    runner = SweepRunner(table, sched_table=sched_table, model_map=model_map)
    spec = SweepSpec(
        policy=scheduler_name,
        scenario=scenario,
        rate=lam,
        seed=seed,
        slo=slo,
        max_batch=max_batch,
        horizon=horizon,
        warmup_tasks=warmup_tasks,
        rates=None if rates is None else tuple(rates),
        label=name,
    )
    res = runner.run_cell(spec)
    return Row(name, res.us_per_call, derived_str(res.metrics)), res.metrics


def sweep_rows(
    runner: SweepRunner,
    specs: Sequence[SweepSpec],
    workers: Optional[int] = None,
) -> List[Tuple[Row, ServingMetrics]]:
    """Run a sweep grid (parallel by default) -> (Row, metrics) per cell,
    in grid order. Row names come from each spec's ``label``/``title()``."""
    if workers is None:
        workers = sweep_workers(len(specs))
    results = runner.run(specs, workers=workers)
    return [
        (Row(r.spec.title(), r.us_per_call, derived_str(r.metrics)), r.metrics)
        for r in results
    ]
