"""Shared benchmark scaffolding.

Every benchmark module exposes ``run() -> list[Row]``; ``benchmarks.run``
aggregates them into the ``name,us_per_call,derived`` CSV. ``us_per_call``
is the wall-clock microseconds spent producing that row (one serving
experiment / one kernel call); ``derived`` is the row's headline metric.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, List, Optional

from repro.core import (
    ProfileTable,
    SchedulerConfig,
    make_scheduler,
    paper_rate_vector,
    run_experiment,
)


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


# Default sweep (paper: lambda_152 from 20 to 240 req/s on the RTX 3080).
LAMBDAS = (20, 60, 100, 140, 180, 220, 240)
HORIZON = 10.0
SEED = 7


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def serving_row(
    name: str,
    scheduler_name: str,
    table: ProfileTable,
    lam: float,
    slo: float = 0.050,
    rates=None,
    sched_table: Optional[ProfileTable] = None,
    model_map=None,
    horizon: float = HORIZON,
) -> "tuple[Row, object]":
    """One serving experiment -> CSV row + metrics."""
    cfg = SchedulerConfig(slo=slo, max_batch=10)
    sched = make_scheduler(scheduler_name, sched_table or table, cfg)
    res, us = timed(
        run_experiment, sched, table,
        rates if rates is not None else paper_rate_vector(lam),
        horizon=horizon, seed=SEED, model_map=model_map,
    )
    m = res.metrics
    derived = (
        f"p95_ms={m.p95_latency*1e3:.2f};viol={m.violation_ratio*100:.2f}%;"
        f"acc={m.mean_accuracy*100:.2f}%;depth={m.mean_exit_depth:.2f}"
    )
    return Row(name, us, derived), m
