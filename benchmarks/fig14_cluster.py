"""Beyond-paper Fig. 14: cluster scaling across dispatch policies.

The paper stops at one shared accelerator; this study shards the same
workload across a device fleet (``repro.core.cluster``) and compares the
dispatcher family on three legs:

  * **scaling** — homogeneous RTX 3080 fleets of G = 1, 2, 4, 8 under MMPP
    bursts with offered load proportional to G (λ₁₅₂ = 140·G): violations
    fall and exit depth recovers toward final as capacity grows; dispatcher
    choice barely matters when devices are interchangeable.
  * **het** — a heterogeneous fleet (2× RTX 3080 + 2× 3.2x-slower
    Jetson-class) under the same bursty load: queue-blind (round-robin) and
    speed-blind (JSQ) dispatch collapse, while the stability-aware
    power-of-d dispatcher — routing each request by its predicted
    per-device stability-score delta — holds violations near the
    capacity-weighted optimum.
  * **failure** — the same heterogeneous fleet losing its first fast device
    mid-run (``fail_at`` = horizon/2; queued requests fail over through the
    dispatcher): the acceptance read is stability-aware < round-robin and
    < JSQ on SLO violation ratio, here and on the het leg.

Each row reports the standard headline metrics plus a per-device breakdown
(``by_dev``: violation%, utilisation, dead flag) and dispatch counts. The
grid fans across worker processes via ``SweepRunner`` (parallel ≡ serial
bitwise); set ``REPRO_FIG14_SMOKE=1`` (CI) for a 2-dispatcher, tiny-horizon
smoke cell.
"""

from __future__ import annotations

import os
from typing import List

from repro.core import ProfileTable, ServingMetrics, SweepRunner, SweepSpec
from benchmarks.common import Row, SEED, derived_str, sweep_rows

LAM_PER_DEVICE = 140.0
DISPATCHERS = ("round-robin", "jsq", "least-loaded", "stability-aware")
FLEET_SIZES = (1, 2, 4, 8)
HORIZON = 6.0
HET_SIZE = 4           # 2 fast + 2 Jetson-class
HET_LAM = 160.0 * 4    # ~1.5x the het fleet's weighted capacity: hard leg
FAIL_LAM = 160.0 * 3   # moderate load so the failure, not the load, dominates


def _derived(m: ServingMetrics) -> str:
    by_dev = "|".join(
        f"{d.name}:{d.violation_ratio*100:.1f}%/u{d.utilization:.2f}"
        + ("/dead" if not d.alive else "")
        for d in m.per_device
    )
    return f"{derived_str(m)};by_dev={by_dev}"


def _specs() -> List[SweepSpec]:
    smoke = bool(os.environ.get("REPRO_FIG14_SMOKE"))
    if smoke:
        return [
            SweepSpec(policy="edgeserving", scenario="mmpp", rate=2 * 160.0,
                      seed=SEED, horizon=1.5, warmup_tasks=20,
                      fleet="heterogeneous", fleet_size=2, dispatcher=dp,
                      label=f"fig14/het/x2/{dp}")
            for dp in ("jsq", "stability-aware")
        ]
    specs = [
        # Leg 1: homogeneous scaling, offered load proportional to G.
        SweepSpec(policy="edgeserving", scenario="mmpp",
                  rate=LAM_PER_DEVICE * g, seed=SEED, horizon=HORIZON,
                  fleet="homogeneous", fleet_size=g, dispatcher=dp,
                  label=f"fig14/scaling/G{g}/{dp}")
        for g in FLEET_SIZES
        for dp in DISPATCHERS
    ]
    specs += [
        # Leg 2: heterogeneous fleet (fast/slow alternating) under bursts.
        SweepSpec(policy="edgeserving", scenario="mmpp", rate=HET_LAM,
                  seed=SEED, horizon=HORIZON,
                  fleet="heterogeneous", fleet_size=HET_SIZE, dispatcher=dp,
                  label=f"fig14/het/x{HET_SIZE}/{dp}")
        for dp in DISPATCHERS
    ]
    specs += [
        # Leg 3: same heterogeneous fleet, first fast device dies mid-run.
        SweepSpec(policy="edgeserving", scenario="poisson", rate=FAIL_LAM,
                  seed=SEED, horizon=HORIZON,
                  fleet="heterogeneous", fleet_size=HET_SIZE, dispatcher=dp,
                  fail_at=((0, HORIZON / 2),),
                  label=f"fig14/failure/x{HET_SIZE}/{dp}")
        for dp in DISPATCHERS
    ]
    return specs


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080()
    results = sweep_rows(SweepRunner(table), _specs())
    rows = [
        Row(row.name, row.us_per_call, _derived(metrics))
        for row, metrics in results
    ]
    # Acceptance summary: stability-aware vs the blind dispatchers per leg.
    viol = {row.name: metrics.violation_ratio for row, metrics in results}
    for leg in ("het", "failure"):
        cells = {name.rsplit("/", 1)[1]: v for name, v in viol.items()
                 if f"/{leg}/" in name}
        if {"stability-aware", "round-robin", "jsq"} <= set(cells):
            ok = (cells["stability-aware"] < cells["round-robin"]
                  and cells["stability-aware"] < cells["jsq"])
            rows.append(Row(
                f"fig14/summary/{leg}", 0.0,
                f"stability_aware={cells['stability-aware']*100:.2f}%;"
                f"round_robin={cells['round-robin']*100:.2f}%;"
                f"jsq={cells['jsq']*100:.2f}%;"
                f"stability_wins={'yes' if ok else 'NO'}"))
    return rows
