"""Paper Fig. 10: cross-platform generalisation — same algorithm, only the
profile table re-collected per platform (RTX 3080 / GTX 1650 / Jetson Orin
Nano; paper uses tau=100 ms on the Jetson), plus a TPU-v5e analytic profile
built from the dry-run roofline terms (the TPU-native adaptation)."""

from __future__ import annotations

import json
import os
from typing import List

import numpy as np

from repro.core import ProfileTable
from benchmarks.common import Row, serving_row


def _tpu_profile(table: ProfileTable) -> ProfileTable:
    """Analytic v5e profile: scale the calibrated table by the ratio of
    roofline-bound step times (single-chip serving of the ResNet trio is
    compute-bound; v5e bf16 peak vs RTX 3080 fp32 tensor ~ 30 TFLOP/s
    effective -> ~6.5x faster)."""
    return table.scaled(1.0 / 6.5, "tpu-v5e-analytic")


def run() -> List[Row]:
    rows = []
    platforms = {
        "rtx3080": (ProfileTable.paper_rtx3080(), 0.050, (60, 140, 240)),
        "gtx1650": (ProfileTable.paper_gtx1650(), 0.050, (20, 45, 75)),
        "jetson-orin-nano": (
            ProfileTable.paper_jetson_orin_nano(), 0.100, (10, 20, 34)),
        "tpu-v5e-analytic": (
            _tpu_profile(ProfileTable.paper_rtx3080()), 0.050,
            (200, 800, 1500)),
    }
    for plat, (table, slo, lams) in platforms.items():
        for lam in lams:
            row, m = serving_row(
                f"fig10/{plat}/lam{lam}", "edgeserving", table, lam, slo=slo)
            rows.append(row)
    return rows
