"""Benchmark harness: one module per paper table/figure (+ microbenches).

Prints ``name,us_per_call,derived`` CSV (one row per measurement).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig4 fig11 # subset by prefix
"""

from __future__ import annotations

import sys
import time

from benchmarks import (
    fig2_profile,
    fig4_baselines,
    fig5_exit_depth,
    fig6_pareto,
    fig7_exit_config,
    fig8_slo,
    fig9_model_combo,
    fig10_cross_platform,
    fig11_ablation,
    fig12_lattice,
    fig13_workloads,
    fig14_cluster,
    fig15_drift,
    fig16_timeline,
    fig17_seedband,
    micro_kernels,
    micro_scheduler,
    table1_accuracy,
)

MODULES = {
    "fig2": fig2_profile,
    "table1": table1_accuracy,
    "fig4": fig4_baselines,
    "fig5": fig5_exit_depth,
    "fig6": fig6_pareto,
    "fig7": fig7_exit_config,
    "fig8": fig8_slo,
    "fig9": fig9_model_combo,
    "fig10": fig10_cross_platform,
    "fig11": fig11_ablation,
    "fig12": fig12_lattice,
    "fig13": fig13_workloads,
    "fig14": fig14_cluster,
    "fig15": fig15_drift,
    "fig16": fig16_timeline,
    "fig17": fig17_seedband,
    "micro_scheduler": micro_scheduler,
    "micro_kernels": micro_kernels,
}


def main() -> None:
    wanted = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for key in wanted:
        mod = MODULES.get(key)
        if mod is None:
            print(f"# unknown benchmark {key!r}; known: {sorted(MODULES)}",
                  file=sys.stderr)
            continue
        for row in mod.run():
            print(row.csv(), flush=True)
    print(f"# total_wall_s={time.time() - t0:.1f}", file=sys.stderr)


if __name__ == "__main__":
    main()
