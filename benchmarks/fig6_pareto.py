"""Paper Fig. 6: accuracy vs P95 latency Pareto curve traced by the
scheduler as traffic intensity varies (graceful degradation)."""

from __future__ import annotations

from typing import List

from repro.core import ProfileTable
from benchmarks.common import Row, serving_row


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080()
    rows = []
    pts = []
    for lam in (20, 60, 100, 140, 180, 220, 240):
        row, m = serving_row(f"fig6/pareto/lam{lam}", "edgeserving", table,
                             lam)
        pts.append((m.p95_latency * 1e3, m.mean_accuracy * 100))
        rows.append(row)
    # paper: 76.75% @ 27.47ms (lam=20) -> 60.38% @ 44.46ms (lam>=180)
    lo, hi = pts[0], pts[-1]
    rows.append(Row(
        "fig6/summary", 0.0,
        f"low_traffic=({lo[0]:.1f}ms,{lo[1]:.1f}%);"
        f"high_traffic=({hi[0]:.1f}ms,{hi[1]:.1f}%);"
        f"graceful={hi[1] > 40 and hi[0] < 50}",
    ))
    return rows
