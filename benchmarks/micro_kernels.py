"""Kernel microbenchmarks on CPU: jnp reference paths (jitted; the honest
CPU numbers) for attention/exit-head/rmsnorm at serving-relevant shapes.
Pallas kernels are validated in interpret mode (tests/) and targeted at
TPU; interpret-mode wall time is not meaningful, so the CSV reports the
reference-path throughput these kernels must beat on device.

Each kernel is timed per iteration — ``jax.block_until_ready`` inside the
timed region on every call, not amortized over a batch — so the repeats
form a real latency sample. ``us_per_call`` is the p50 and the derived
column carries p50/p95, making dispatch-jitter outliers visible instead
of being averaged away."""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.exit_head.ref import exit_head_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from benchmarks.common import Row


def _time(fn, *args, n=10) -> Tuple[float, float]:
    """(p50_us, p95_us) over ``n`` individually-synchronized calls."""
    jax.block_until_ready(fn(*args))  # compile + warm caches
    samples = np.empty(n)
    for i in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples[i] = (time.perf_counter() - t0) * 1e6
    return float(np.percentile(samples, 50)), float(np.percentile(samples, 95))


def run() -> List[Row]:
    rows = []
    key = jax.random.key(0)

    # prefill attention (per-layer slice of a 4k-ctx batch)
    b, h, kh, s, d = 1, 8, 2, 1024, 64
    q = jax.random.normal(key, (b, h, s, d), jnp.float32)
    k = jax.random.normal(key, (b, kh, s, d), jnp.float32)
    v = jax.random.normal(key, (b, kh, s, d), jnp.float32)
    fa = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us, p95 = _time(fa, q, k, v)
    flops = 4 * b * h * s * s * d
    rows.append(Row(f"micro/attn-ref/b{b}h{h}s{s}d{d}", us,
                    f"gflops_cpu={flops/us/1e3:.2f};"
                    f"p50_us={us:.0f};p95_us={p95:.0f}"))

    # decode attention against a 32k cache slice
    s_kv = 8192
    q1 = jax.random.normal(key, (4, h, d))
    k1 = jax.random.normal(key, (4, kh, s_kv, d))
    v1 = jax.random.normal(key, (4, kh, s_kv, d))
    lens = jnp.full((4,), s_kv, jnp.int32)
    da = jax.jit(decode_attention_ref)
    us, p95 = _time(da, q1, k1, v1, lens)
    gb = 2 * 4 * kh * s_kv * d * 4 / 1e9
    rows.append(Row(f"micro/decode-ref/b4h{h}kv{s_kv}", us,
                    f"cache_gb_per_s={gb/(us/1e6):.2f};"
                    f"p50_us={us:.0f};p95_us={p95:.0f}"))

    # exit head at smollm scale
    t, dm, vv = 256, 576, 49152
    hh = jax.random.normal(key, (t, dm))
    g = jnp.ones((dm,))
    w = jax.random.normal(key, (dm, vv)) * 0.02
    eh = jax.jit(exit_head_ref)
    us, p95 = _time(eh, hh, g, w)
    rows.append(Row(f"micro/exit-head-ref/t{t}d{dm}v{vv}", us,
                    f"gflops_cpu={2*t*dm*vv/us/1e3:.2f};"
                    f"p50_us={us:.0f};p95_us={p95:.0f}"))

    # rmsnorm
    x = jax.random.normal(key, (4096, 4096))
    g2 = jnp.ones((4096,))
    rn = jax.jit(lambda x, g: rmsnorm_ref(x, g, 1e-6))
    us, p95 = _time(rn, x, g2)
    rows.append(Row("micro/rmsnorm-ref/4096x4096", us,
                    f"gb_per_s={2*x.nbytes/us/1e3:.2f};"
                    f"p50_us={us:.0f};p95_us={p95:.0f}"))
    return rows
