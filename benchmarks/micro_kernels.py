"""Kernel microbenchmarks on CPU: jnp reference paths (jitted; the honest
CPU numbers) for attention/exit-head/rmsnorm at serving-relevant shapes.
Pallas kernels are validated in interpret mode (tests/) and targeted at
TPU; interpret-mode wall time is not meaningful, so the CSV reports the
reference-path throughput these kernels must beat on device."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.exit_head.ref import exit_head_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from benchmarks.common import Row


def _time(fn, *args, n=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run() -> List[Row]:
    rows = []
    key = jax.random.key(0)

    # prefill attention (per-layer slice of a 4k-ctx batch)
    b, h, kh, s, d = 1, 8, 2, 1024, 64
    q = jax.random.normal(key, (b, h, s, d), jnp.float32)
    k = jax.random.normal(key, (b, kh, s, d), jnp.float32)
    v = jax.random.normal(key, (b, kh, s, d), jnp.float32)
    fa = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us = _time(fa, q, k, v)
    flops = 4 * b * h * s * s * d
    rows.append(Row(f"micro/attn-ref/b{b}h{h}s{s}d{d}", us,
                    f"gflops_cpu={flops/us/1e3:.2f}"))

    # decode attention against a 32k cache slice
    s_kv = 8192
    q1 = jax.random.normal(key, (4, h, d))
    k1 = jax.random.normal(key, (4, kh, s_kv, d))
    v1 = jax.random.normal(key, (4, kh, s_kv, d))
    lens = jnp.full((4,), s_kv, jnp.int32)
    da = jax.jit(decode_attention_ref)
    us = _time(da, q1, k1, v1, lens)
    gb = 2 * 4 * kh * s_kv * d * 4 / 1e9
    rows.append(Row(f"micro/decode-ref/b4h{h}kv{s_kv}", us,
                    f"cache_gb_per_s={gb/(us/1e6):.2f}"))

    # exit head at smollm scale
    t, dm, vv = 256, 576, 49152
    hh = jax.random.normal(key, (t, dm))
    g = jnp.ones((dm,))
    w = jax.random.normal(key, (dm, vv)) * 0.02
    eh = jax.jit(exit_head_ref)
    us = _time(eh, hh, g, w)
    rows.append(Row(f"micro/exit-head-ref/t{t}d{dm}v{vv}", us,
                    f"gflops_cpu={2*t*dm*vv/us/1e3:.2f}"))

    # rmsnorm
    x = jax.random.normal(key, (4096, 4096))
    g2 = jnp.ones((4096,))
    rn = jax.jit(lambda x, g: rmsnorm_ref(x, g, 1e-6))
    us = _time(rn, x, g2)
    rows.append(Row("micro/rmsnorm-ref/4096x4096", us,
                    f"gb_per_s={2*x.nbytes/us/1e3:.2f}"))
    return rows
