"""Paper Fig. 11: ablation of the core design components —
Early-Exit+LQF, Early-Exit+EDF, All-Final+Deadline-Aware, Ours+bs=1 vs the
full scheduler."""

from __future__ import annotations

from typing import List

from repro.core import ProfileTable
from benchmarks.common import LAMBDAS, Row, serving_row

VARIANTS = ("edgeserving", "earlyexit-lqf", "earlyexit-edf",
            "allfinal-deadline-aware", "ours-bs1")


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080()
    rows = []
    for sched in VARIANTS:
        for lam in LAMBDAS:
            row, _ = serving_row(f"fig11/{sched}/lam{lam}", sched, table, lam)
            rows.append(row)
    return rows
