"""Beyond-paper Fig. 16: flash-crowd anatomy — the first figure that
*explains* a violation spike instead of counting it.

Every earlier figure reports end-of-window aggregates; this one runs the
flash-crowd scenario with telemetry on (``SweepSpec(trace=True)``) and
reads the decision/request timeline back out through
``repro.core.telemetry.timeline_metrics``: binned queue depth, violation
ratio, utilization, and mean exit depth over the run, for EdgeServing vs
the All-Final, EDF, and Symphony baselines on the *identical* arrival
trace. The anatomy to look for (and what the derived columns quantify):
as the spike hits, EdgeServing's mean exit depth shifts *down* (the Eq. 6
feasibility rule buys latency with shallower exits), queue depth stays
bounded, and the exit depth recovers after the spike drains — while
all-final's queue grows until violations spike and Symphony sheds instead.

Per policy this emits a headline row (aggregate metrics + the pre/spike/
post exit-depth split + peak binned queue depth / violation rate) and a
``.../timeline`` row carrying the binned queue-depth and violation-ratio
series. For the EdgeServing cell the full trace is also exported as
Perfetto-loadable Chrome JSON + NDJSON (to ``REPRO_FIG16_OUT`` or a temp
dir) — open the ``.chrome.json`` in https://ui.perfetto.dev, or summarize
either file with ``python tools/tracestats.py``. The binned violation
timeline is checked against the run's aggregate ``violation_ratio``
(exact, by construction — see docs/observability.md).

``REPRO_FIG16_SMOKE=1`` (CI) shrinks to 2 policies on a short horizon.
"""

from __future__ import annotations

import os
import tempfile
from typing import List

import numpy as np

from repro.core import (
    ProfileTable,
    SweepRunner,
    SweepSpec,
    export_chrome_trace,
    export_ndjson,
    timeline_metrics,
)
from benchmarks.common import HORIZON, Row, SEED, derived_str, timed

LAM = 160.0
POLICIES = ("edgeserving", "all-final", "earlyexit-edf", "symphony")
NUM_BINS = 40
SPIKE_START_FRAC = 0.4   # FlashCrowdProcess defaults, made explicit so the
SPIKE_DURATION_FRAC = 0.1  # pre/spike/post windows below are exact
MAGNITUDE = 5.0


def _exit_depth_window(trace, lo: float, hi: float) -> float:
    """Mean exit depth (1-based) over completions finishing in [lo, hi)."""
    d = [s.exit_idx + 1 for s in trace.spans
         if s.status == "completed" and lo <= s.finish < hi]
    return float(np.mean(d)) if d else float("nan")


def _series(vals, fmt: str) -> str:
    return "|".join("-" if not np.isfinite(v) else fmt % v for v in vals)


def run() -> List[Row]:
    smoke = bool(os.environ.get("REPRO_FIG16_SMOKE"))
    policies = ("edgeserving", "all-final") if smoke else POLICIES
    horizon = 2.5 if smoke else HORIZON
    warmup = 20 if smoke else 100
    num_bins = 10 if smoke else NUM_BINS
    spike0 = SPIKE_START_FRAC * horizon
    spike1 = spike0 + SPIKE_DURATION_FRAC * horizon
    out_dir = os.environ.get("REPRO_FIG16_OUT") or tempfile.mkdtemp(
        prefix="fig16_")
    os.makedirs(out_dir, exist_ok=True)

    table = ProfileTable.paper_rtx3080()
    runner = SweepRunner(table)
    rows: List[Row] = []
    # Cells run serially in-process: traces are large, and shipping them
    # back through the process fan-out would dominate the cell time.
    for policy in policies:
        spec = SweepSpec(
            policy=policy, scenario="flash-crowd", rate=LAM, seed=SEED,
            horizon=horizon, warmup_tasks=warmup, trace=True,
            scenario_kwargs=(
                ("spike_start", spike0),
                ("spike_duration", spike1 - spike0),
                ("magnitude", MAGNITUDE),
            ),
            label=f"fig16/{policy}",
        )
        res = runner.run_cell(spec)
        trace, m = res.trace, res.metrics
        tm = timeline_metrics(trace, num_bins=num_bins, t_end=horizon)
        agg = tm.aggregate_violation_ratio()
        ok = np.isclose(agg, m.violation_ratio, rtol=0, atol=1e-12)
        depth_pre = _exit_depth_window(trace, 0.0, spike0)
        depth_spike = _exit_depth_window(trace, spike0, spike1)
        depth_post = _exit_depth_window(trace, spike1, horizon + 1e9)
        qd = np.nan_to_num(tm.queue_depth)
        viol = np.nan_to_num(tm.violation_ratio) * 100.0
        rows.append(Row(
            spec.label, res.us_per_call,
            f"{derived_str(m)};timeline_consistent={'yes' if ok else 'NO'};"
            f"depth_pre={depth_pre:.2f};depth_spike={depth_spike:.2f};"
            f"depth_post={depth_post:.2f};"
            f"peak_queue={float(qd.max()):.1f};"
            f"peak_bin_viol={float(viol.max()):.1f}%;"
            f"drops={m.dropped};residual={m.residual_queue}",
        ))
        rows.append(Row(
            f"{spec.label}/timeline", 0.0,
            f"bins={num_bins};bin_s={horizon / num_bins:.3f};"
            f"queue_depth={_series(qd, '%.1f')};"
            f"viol_pct={_series(viol, '%.1f')};"
            f"exit_depth={_series(tm.mean_exit_depth, '%.2f')}",
        ))
        if policy == "edgeserving":
            chrome = os.path.join(out_dir, "fig16_edgeserving.chrome.json")
            ndjson = os.path.join(out_dir, "fig16_edgeserving.ndjson")
            _, us1 = timed(export_chrome_trace, trace, chrome)
            _, us2 = timed(export_ndjson, trace, ndjson)
            rows.append(Row(
                "fig16/trace-export", us1 + us2,
                f"decisions={len(trace.decisions)};spans={len(trace.spans)};"
                f"events={len(trace.events)};chrome={chrome};ndjson={ndjson}",
            ))
    return rows
