"""Paper Fig. 8: SLO-threshold sensitivity (tau in 20..70 ms): P95 scales
with the SLO; violations stay controlled."""

from __future__ import annotations

from typing import List

from repro.core import ProfileTable
from benchmarks.common import Row, serving_row


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080()
    rows = []
    for slo_ms in (20, 30, 40, 50, 60, 70):
        for lam in (100, 200):
            row, m = serving_row(
                f"fig8/slo{slo_ms}ms/lam{lam}", "edgeserving", table, lam,
                slo=slo_ms * 1e-3)
            rows.append(row)
    return rows
