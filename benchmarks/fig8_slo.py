"""Paper Fig. 8: SLO-threshold sensitivity (tau in 20..70 ms): P95 scales
with the SLO; violations stay controlled (parallel sweep)."""

from __future__ import annotations

from typing import List

from repro.core import ProfileTable, SweepRunner, SweepSpec
from benchmarks.common import HORIZON, Row, SEED, sweep_rows


def run() -> List[Row]:
    table = ProfileTable.paper_rtx3080()
    specs = [
        SweepSpec(policy="edgeserving", rate=lam, slo=slo_ms * 1e-3,
                  seed=SEED, horizon=HORIZON,
                  label=f"fig8/slo{slo_ms}ms/lam{lam}")
        for slo_ms in (20, 30, 40, 50, 60, 70)
        for lam in (100, 200)
    ]
    return [row for row, _ in sweep_rows(SweepRunner(table), specs)]
