"""Integration + property tests for the serving simulator (paper Sec. VI)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    Completion,
    EdgeServingScheduler,
    ProfileTable,
    Request,
    SchedulerConfig,
    ServingSimulator,
    make_scheduler,
    paper_rate_vector,
    poisson_arrivals,
    run_experiment,
    summarize,
)


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080()


class TestTraffic:
    def test_deterministic(self):
        a = poisson_arrivals([100.0, 50.0], 5.0, seed=7)
        b = poisson_arrivals([100.0, 50.0], 5.0, seed=7)
        assert [(r.model, r.arrival) for r in a] == [(r.model, r.arrival) for r in b]

    def test_sorted_and_bounded(self):
        arr = poisson_arrivals([200.0, 100.0, 50.0], 10.0, seed=3)
        times = [r.arrival for r in arr]
        assert times == sorted(times)
        assert all(0 <= t < 10.0 for t in times)

    def test_rate_accuracy(self):
        arr = poisson_arrivals([300.0], 30.0, seed=1)
        # Poisson(9000): 4 sigma ~ 380
        assert abs(len(arr) - 9000) < 400

    def test_paper_rate_vector(self):
        assert paper_rate_vector(100) == [300.0, 200.0, 100.0]


class TestConservation:
    @given(
        seed=st.integers(0, 2**16),
        lam=st.sampled_from([40, 120, 200]),
        name=st.sampled_from(["edgeserving", "all-final", "earlyexit-edf"]),
    )
    @settings(max_examples=12, deadline=None)
    def test_property_all_arrivals_accounted(self, table, seed, lam, name):
        # completions + drops + residual == arrivals (no request lost/dup).
        sched = make_scheduler(name, table, SchedulerConfig(slo=0.05))
        arrivals = poisson_arrivals(paper_rate_vector(lam), 3.0, seed=seed)
        sim = ServingSimulator(sched, table, num_models=3, seed=seed)
        res = sim.run(arrivals, 3.0, warmup_tasks=0)
        total = (
            res.metrics.num_completed
            + res.metrics.dropped
            + res.metrics.residual_queue
        )
        assert total == len(arrivals)
        ids = [c.req_id for c in res.completions]
        assert len(ids) == len(set(ids))  # no duplicates

    def test_fifo_within_queue(self, table):
        # Within one model queue, dispatch order preserves arrival order.
        sched = make_scheduler("edgeserving", table, SchedulerConfig(slo=0.05))
        arrivals = poisson_arrivals([400.0, 0.0, 0.0], 2.0, seed=5)
        sim = ServingSimulator(sched, table, num_models=3)
        res = sim.run(arrivals, 2.0, warmup_tasks=0)
        d = [c.req_id for c in res.completions if c.model == 0]
        assert d == sorted(d)

    def test_time_division_no_overlap(self, table):
        # Quanta never overlap: the accelerator is exclusive (paper Sec. III).
        sched = make_scheduler("edgeserving", table, SchedulerConfig(slo=0.05))
        arrivals = poisson_arrivals(paper_rate_vector(150), 3.0, seed=2)
        sim = ServingSimulator(sched, table, num_models=3)
        res = sim.run(arrivals, 3.0, warmup_tasks=0, keep_traces=True)
        for a, b in zip(res.traces, res.traces[1:]):
            assert b.t_start >= a.t_end - 1e-12

    def test_latency_decomposition(self, table):
        # Eq. 1: T = w + t, with t == the profiled latency (no noise).
        sched = make_scheduler("edgeserving", table, SchedulerConfig(slo=0.05))
        arrivals = poisson_arrivals(paper_rate_vector(80), 2.0, seed=9)
        sim = ServingSimulator(sched, table, num_models=3)
        res = sim.run(arrivals, 2.0, warmup_tasks=0)
        for c in res.completions[:200]:
            assert c.total_latency == pytest.approx(c.queueing + c.service)
            assert c.service == pytest.approx(
                table(c.model, c.exit_idx, c.batch_size)
            )
            assert c.dispatch >= c.arrival - 1e-12


class TestEndToEndBehaviour:
    def test_edgeserving_beats_allfinal_under_load(self, table):
        cfg = SchedulerConfig(slo=0.05)
        ours = run_experiment(
            make_scheduler("edgeserving", table, cfg), table,
            paper_rate_vector(180), horizon=8.0, seed=4)
        allf = run_experiment(
            make_scheduler("all-final", table, cfg), table,
            paper_rate_vector(180), horizon=8.0, seed=4)
        assert ours.metrics.violation_ratio < 0.01
        assert allf.metrics.violation_ratio > 0.30
        assert ours.metrics.p95_latency < allf.metrics.p95_latency

    def test_exit_depth_shallows_under_load(self, table):
        # Paper Fig. 5: deeper exits at low traffic, shallower under load.
        cfg = SchedulerConfig(slo=0.05)
        lo = run_experiment(make_scheduler("edgeserving", table, cfg), table,
                            paper_rate_vector(20), horizon=8.0, seed=4)
        hi = run_experiment(make_scheduler("edgeserving", table, cfg), table,
                            paper_rate_vector(240), horizon=8.0, seed=4)
        assert lo.metrics.mean_exit_depth > hi.metrics.mean_exit_depth
        assert lo.metrics.mean_accuracy > hi.metrics.mean_accuracy

    def test_all_early_low_latency_low_accuracy(self, table):
        cfg = SchedulerConfig(slo=0.05)
        res = run_experiment(make_scheduler("all-early", table, cfg), table,
                             paper_rate_vector(100), horizon=5.0, seed=4)
        assert res.metrics.p95_latency < 0.01   # paper: ~2-3 ms
        assert res.metrics.mean_accuracy < 0.10  # paper: ~7.4%

    def test_service_noise_reproducible(self, table):
        cfg = SchedulerConfig(slo=0.05)
        r = [
            run_experiment(make_scheduler("edgeserving", table, cfg), table,
                           paper_rate_vector(100), horizon=3.0, seed=11,
                           service_noise_cov=0.03).metrics.p95_latency
            for _ in range(2)
        ]
        assert r[0] == r[1]

    def test_rerun_same_instance_bitwise(self, table):
        # Regression: ``run`` used to consume the noise rng across calls, so
        # a second ``run`` on the same simulator instance drew a different
        # noise stream and silently produced different metrics. ``run`` now
        # re-seeds at entry — reruns are bitwise repeats.
        cfg = SchedulerConfig(slo=0.05)
        sim = ServingSimulator(
            make_scheduler("edgeserving", table, cfg), table,
            num_models=3, service_noise_cov=0.03, seed=11)
        arrivals = poisson_arrivals(paper_rate_vector(100), 3.0, seed=11)
        first = sim.run(arrivals, 3.0)
        second = sim.run(arrivals, 3.0)
        assert first.metrics == second.metrics

    def test_symphony_sheds_under_overload(self, table):
        cfg = SchedulerConfig(slo=0.05)
        res = run_experiment(make_scheduler("symphony", table, cfg), table,
                             paper_rate_vector(240), horizon=5.0, seed=4)
        assert res.metrics.dropped > 0
        # shedding keeps completed-task P95 bounded near the SLO
        assert res.metrics.p95_latency < 0.08

    def test_model_map_deployment_mix(self, table):
        # 3x resnet50 homogeneous mix (paper Fig. 9) via model_map.
        cfg = SchedulerConfig(slo=0.05)
        res = run_experiment(
            make_scheduler("edgeserving", table, cfg), table,
            [100.0, 100.0, 100.0], horizon=4.0, seed=4,
            model_map=[0, 0, 0])
        assert res.metrics.violation_ratio < 0.01

    def test_warmup_exclusion(self, table):
        cfg = SchedulerConfig(slo=0.05)
        sched = make_scheduler("edgeserving", table, cfg)
        arrivals = poisson_arrivals(paper_rate_vector(60), 3.0, seed=8)
        sim = ServingSimulator(sched, table, num_models=3)
        all_tasks = sim.run(arrivals, 3.0, warmup_tasks=0).metrics.num_completed
        post = sim.run(arrivals, 3.0, warmup_tasks=100).metrics.num_completed
        assert post == all_tasks - 100


class TestStrictTimeProgress:
    """Regression for the idle-branch stall: the loop advanced time with a
    fixed ``+ 1e-12`` epsilon, which rounds to zero once the epsilon drops
    below half a float64 ulp of ``t`` (t >= 16384 s, e.g. wall-clock-offset
    trace replay) — against a deferring scheduler whose ``next_wake`` keeps
    returning (sub-ulp past) the same instant, ``t`` stopped advancing and
    the simulator looped forever. The fix is one-ulp strict progress via
    ``np.nextafter``."""

    T0 = 65536.0  # np.spacing(T0) ~ 1.5e-11 >> the old 1e-12 epsilon

    def _deferring_scheduler(self, table):
        release = self.T0 + 50 * np.spacing(self.T0)  # needs real progress

        class DeferringStub(EdgeServingScheduler):
            name = "deferring-stub"

            def decide(self, snapshot):
                if snapshot.nonempty() and snapshot.now < release:
                    return None  # defer: forces the idle branch each round
                return super().decide(snapshot)

            def next_wake(self, snapshot):
                if not snapshot.nonempty():
                    return None
                # sub-ulp slack at this magnitude: the old epsilon-advance
                # rounds max(t, wake) + 1e-12 straight back to t.
                return snapshot.now + 1e-13

        return DeferringStub(table, SchedulerConfig(slo=0.05))

    def test_deferring_wake_progresses_at_large_t(self, table):
        sched = self._deferring_scheduler(table)
        sim = ServingSimulator(sched, table, num_models=3)
        arrivals = [Request(req_id=0, model=0, arrival=self.T0)]
        res = sim.run(arrivals, horizon=self.T0 + 1.0, warmup_tasks=0)
        assert res.metrics.num_completed == 1
        assert res.completions[0].dispatch >= self.T0

    def test_offset_trace_replay_terminates(self, table):
        # Plain end-to-end run with a large wall-clock offset on every
        # arrival (recorded-trace replay): must drain normally.
        sched = make_scheduler("edgeserving", table, SchedulerConfig())
        offset = 20000.0
        arrivals = [
            Request(req_id=r.req_id, model=r.model,
                    arrival=r.arrival + offset, data_id=r.data_id)
            for r in poisson_arrivals(paper_rate_vector(40), 1.0, seed=3)
        ]
        sim = ServingSimulator(sched, table, num_models=3)
        res = sim.run(arrivals, horizon=offset + 1.0, warmup_tasks=0)
        assert res.metrics.num_completed == len(arrivals)
        assert res.metrics.residual_queue == 0


class TestSummarize:
    @staticmethod
    def _completions(n, latency=0.1, model=0):
        return [
            Completion(req_id=i, model=model, arrival=i * 1.0,
                       dispatch=i * 1.0, finish=i * 1.0 + latency,
                       exit_idx=0, batch_size=1)
            for i in range(n)
        ]

    def test_warmup_clamped_for_short_runs(self, table):
        # A 10-completion run with the default 100-task warmup must not
        # collapse to all-zero metrics: warmup clamps to half the run.
        m = summarize(self._completions(10), table, slo=0.05, warmup_tasks=100)
        assert m.num_completed == 5
        assert m.warmup_used == 5
        assert m.violation_ratio == 1.0      # latency 0.1 > slo 0.05
        assert m.p95_latency == pytest.approx(0.1)

    def test_warmup_untouched_for_long_runs(self, table):
        m = summarize(self._completions(150), table, slo=0.05, warmup_tasks=100)
        assert m.num_completed == 50
        assert m.warmup_used == 100

    def test_empty_completions_still_zero(self, table):
        m = summarize([], table, slo=0.05, warmup_tasks=100,
                      residual_queue=7, dropped=3)
        # (late + dropped) / (done + dropped) with done empty: every
        # accounted request was shed -> all violations.
        assert m.num_completed == 0 and m.violation_ratio == 1.0
        assert summarize([], table, slo=0.05).violation_ratio == 0.0
        # overload accounting survives the empty path in the right fields
        assert m.residual_queue == 7 and m.dropped == 3
        assert m.mean_batch == 0.0 and m.per_model == ()

    def test_per_model_breakdown(self, table):
        # Model 0 fast (never violates), model 2 slow (always violates):
        # the aggregate hides it, per_model exposes it.
        cs = self._completions(20, latency=0.01, model=0) + self._completions(
            20, latency=0.2, model=2
        )
        m = summarize(cs, table, slo=0.05, warmup_tasks=0)
        assert m.violation_ratio == pytest.approx(0.5)
        by = {pm.model: pm for pm in m.per_model}
        assert set(by) == {0, 2}
        assert by[0].violation_ratio == 0.0
        assert by[2].violation_ratio == 1.0
        assert by[2].num_completed == 20
        assert by[2].p95_latency == pytest.approx(0.2)
