"""Calibration tests for the trip-count-aware HLO analyzer that feeds the
roofline tables (EXPERIMENTS.md §Roofline)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import collective_bytes, hlo_metrics


def compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestFlopAccounting:
    def test_plain_matmul_exact(self):
        a = jnp.zeros((1024, 512))
        b = jnp.zeros((512, 256))
        m = hlo_metrics(compiled_text(lambda a, b: a @ b, a, b))
        assert m["flops"] == pytest.approx(2 * 1024 * 512 * 256)

    def test_scan_multiplies_by_trip_count(self):
        # XLA's cost_analysis counts the body once; ours multiplies by 8.
        def scanned(x, ws):
            def body(h, w):
                return h @ w, None
            return jax.lax.scan(body, x, ws)[0]

        x = jnp.zeros((512, 256))
        ws = jnp.zeros((8, 256, 256))
        txt = compiled_text(scanned, x, ws)
        m = hlo_metrics(txt)
        assert m["flops"] == pytest.approx(8 * 2 * 512 * 256 * 256)
        c = jax.jit(scanned).lower(x, ws).compile().cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        assert c["flops"] == pytest.approx(2 * 512 * 256 * 256)  # 1x only

    def test_batched_dot(self):
        a = jnp.zeros((4, 128, 64))
        b = jnp.zeros((4, 64, 32))
        m = hlo_metrics(compiled_text(
            lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b))
        assert m["flops"] == pytest.approx(2 * 4 * 128 * 64 * 32)

    def test_nested_scan_trips_compose(self):
        def inner(x, ws):
            def body(h, w):
                return h @ w, None
            return jax.lax.scan(body, x, ws)[0]

        def outer(x, ws2):
            def body(h, ws):
                return inner(h, ws), None
            return jax.lax.scan(body, x, ws2)[0]

        x = jnp.zeros((64, 64))
        ws2 = jnp.zeros((3, 5, 64, 64))
        m = hlo_metrics(compiled_text(outer, x, ws2))
        assert m["flops"] == pytest.approx(15 * 2 * 64**3)


class TestByteAccounting:
    def test_scan_weight_slicing_not_billed_full(self):
        # the stacked [8, 256, 256] weights must be billed per-slice inside
        # the loop, not 8x the full stack.
        def scanned(x, ws):
            def body(h, w):
                return h @ w, None
            return jax.lax.scan(body, x, ws)[0]

        x = jnp.zeros((512, 256))
        ws = jnp.zeros((8, 256, 256))
        m = hlo_metrics(compiled_text(scanned, x, ws))
        ideal = 8 * 256 * 256 * 4 + 9 * 512 * 256 * 4
        # Calibrated upper bound: far below the 8x full-stack billing that a
        # trip-count-unaware analyzer would report (observed ~3.5-6.5x ideal
        # across jax/XLA versions).
        assert m["bytes"] < 8 * ideal
        assert m["bytes"] > ideal       # and a true upper bound

    def test_memory_bound_op_dominates(self):
        # elementwise over a big array: bytes >> flops * 4
        x = jnp.zeros((4096, 4096))
        m = hlo_metrics(compiled_text(lambda x: x * 2.0 + 1.0, x))
        assert m["bytes"] >= 2 * x.nbytes  # read + write at least


class TestCollectiveParsing:
    def test_no_collectives_single_device(self):
        x = jnp.zeros((64, 64))
        cb = collective_bytes(compiled_text(lambda x: x @ x, x))
        assert cb["bytes"]["total"] == 0.0

    def test_psum_counted(self):
        # shard_map psum over 1 device still emits an all-reduce op.
        from jax.sharding import PartitionSpec as P
        if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5
            mesh = jax.make_mesh((1,), ("x",),
                                 axis_types=(jax.sharding.AxisType.Auto,))
        else:
            mesh = jax.make_mesh((1,), ("x",))
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:  # jax < 0.5
            from jax.experimental.shard_map import shard_map
        f = jax.jit(
            shard_map(
                lambda x: jax.lax.psum(x, "x"), mesh=mesh,
                in_specs=P("x"), out_specs=P()))
        txt = f.lower(jnp.zeros((8, 128))).compile().as_text()
        cb = collective_bytes(txt)
        # 8*128*4 bytes all-reduced (or optimised away on 1 device — accept
        # either zero or the exact size, but never garbage)
        total = cb["bytes"]["total"]
        assert total in (0.0, 8 * 128 * 4) or total >= 0
