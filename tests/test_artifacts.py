"""Validate the dry-run/roofline artifact pipeline.

The matrix itself is produced by ``repro.launch.dryrun`` (a separate
process: it must own the 512-device XLA flag). These tests check (i) the
analysis code on synthetic records and (ii), when the artifacts exist in
the repo, that the full matrix is present, error-free, and covers every
assigned cell on both meshes.
"""

import json
import os

import pytest

from repro.configs import ARCH_IDS, SHAPES, all_configs, applicable
from repro.launch.roofline import analyze_record, fmt_s

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "dryrun")


def synthetic_record():
    return {
        "arch": "qwen3-8b", "shape": "train_4k", "kind": "train",
        "mesh": [16, 16], "mesh_axes": ["data", "model"],
        "num_devices": 256, "rules": "train-fsdp",
        "hlo_metrics": {"flops": 1e14, "bytes": 1e12},
        "collectives": {"bytes": {"total": 5e10}},
        "model_flops": 5.3e16,
        "bytes_per_device_static": 4e8,
        "serve_variant": "baseline",
    }


class TestRooflineAnalysis:
    def test_terms_and_dominance(self):
        r = analyze_record(synthetic_record())
        assert r["compute_s"] == pytest.approx(1e14 / 197e12)
        assert r["memory_s"] == pytest.approx(1e12 / 819e9)
        assert r["collective_s"] == pytest.approx(5e10 / 50e9)
        assert r["dominant"] == "memory"
        assert r["t_star"] == r["memory_s"]

    def test_roofline_fraction_definition(self):
        r = analyze_record(synthetic_record())
        ideal = (5.3e16 / 256) / 197e12
        assert r["roofline_frac"] == pytest.approx(ideal / r["t_star"])
        assert 0 < r["roofline_frac"] < 1

    def test_skipped_and_error_records_pass_through(self):
        assert analyze_record({"skipped": "reason"}) is None
        assert analyze_record({"error": "trace"}) is None

    def test_fmt_s(self):
        assert fmt_s(2.5) == "2.50s"
        assert fmt_s(2.5e-3) == "2.50ms"
        assert fmt_s(2.5e-6) == "2.5us"


@pytest.mark.skipif(not os.path.isdir(ARTIFACTS),
                    reason="dry-run artifacts not generated")
class TestDryRunMatrix:
    @pytest.mark.parametrize("mesh", ["single", "multi"])
    def test_matrix_complete_and_green(self, mesh):
        d = os.path.join(ARTIFACTS, mesh)
        assert os.path.isdir(d), f"missing {mesh} artifacts"
        cfgs = all_configs()
        for arch in ARCH_IDS:
            for shape in SHAPES:
                path = os.path.join(d, f"{arch}__{shape}.json")
                assert os.path.exists(path), (arch, shape, mesh)
                with open(path) as f:
                    rec = json.load(f)
                assert "error" not in rec, (arch, shape, mesh)
                if applicable(cfgs[arch], shape):
                    assert rec["hlo_metrics"]["flops"] > 0, (arch, shape)
                    assert rec["num_devices"] == (512 if mesh == "multi"
                                                  else 256)
                else:
                    assert "skipped" in rec

    def test_multi_pod_uses_pod_axis(self):
        path = os.path.join(ARTIFACTS, "multi", "qwen3-8b__train_4k.json")
        with open(path) as f:
            rec = json.load(f)
        assert rec["mesh_axes"] == ["pod", "data", "model"]
        assert rec["mesh"] == [2, 16, 16]

    def test_dsv3_train_fits_v5e(self):
        path = os.path.join(ARTIFACTS, "single",
                            "deepseek-v3-671b__train_4k.json")
        with open(path) as f:
            rec = json.load(f)
        # 671B params + adafactor + FSDP: must fit in 16 GB v5e HBM
        assert rec["bytes_per_device_static"] < 16 * 2**30
