"""Unit + property tests for the EdgeServing scheduler and baselines
(paper Sec. V, Algorithm 1)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    EdgeServingScheduler,
    ProfileTable,
    QueueSnapshot,
    SchedulerConfig,
    VectorizedEdgeServingScheduler,
    make_scheduler,
)


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080()


def snap(waits_per_model, now=0.0):
    return QueueSnapshot(now, [np.asarray(w, dtype=np.float64) for w in waits_per_model])


class TestBatchAndExitSelection:
    def test_batch_rule_eq5(self, table):
        s = EdgeServingScheduler(table, SchedulerConfig(max_batch=10))
        assert s.batch_size(3) == 3
        assert s.batch_size(10) == 10
        assert s.batch_size(37) == 10

    def test_exit_deepest_feasible(self, table):
        # Plenty of slack -> final exit; tight slack -> shallower.
        cfg = SchedulerConfig(slo=0.050)
        s = EdgeServingScheduler(table, cfg)
        e, lat = s.select_exit(m=2, w_max=0.0, batch=1)
        assert e == table.num_exits - 1  # final feasible at w=0

        # w_max so large that only layer1 fits: L(152, final|3|2, B) too big.
        w = 0.050 - table(2, 1, 1) + 1e-6  # layer2 infeasible by epsilon
        e, lat = s.select_exit(m=2, w_max=w, batch=1)
        assert e == 0

    def test_exit_fallback_when_infeasible(self, table):
        s = EdgeServingScheduler(table, SchedulerConfig(slo=0.050))
        e, lat = s.select_exit(m=2, w_max=10.0, batch=10)  # already violated
        assert e == 0  # shallowest minimises collateral damage

    def test_restricted_exits(self, table):
        cfg = SchedulerConfig(slo=0.050, allowed_exits=(0, 3))
        s = EdgeServingScheduler(table, cfg)
        # slack admits layer3 but not final -> with {layer1, final} must pick layer1
        w = 0.050 - table(2, 2, 1)  # layer3 exactly feasible, final not
        assert w > 0
        e, _ = s.select_exit(m=2, w_max=w, batch=1)
        assert e == 0

    @given(
        w_max=st.floats(min_value=0.0, max_value=0.2),
        batch=st.integers(1, 10),
        m=st.integers(0, 2),
        slo=st.sampled_from([0.02, 0.03, 0.05, 0.07]),
    )
    @settings(max_examples=100, deadline=None)
    def test_exit_property_constraint(self, table, w_max, batch, m, slo):
        # Whenever a feasible exit exists, the chosen exit satisfies Eq. 6 and
        # is the deepest feasible one.
        s = EdgeServingScheduler(table, SchedulerConfig(slo=slo))
        e, lat = s.select_exit(m, w_max, batch)
        feasible = [
            ei for ei in range(table.num_exits) if w_max + table(m, ei, batch) <= slo
        ]
        if feasible:
            assert e == max(feasible)
            assert w_max + lat <= slo + 1e-12
        else:
            assert e == 0


class TestEdgeServingDecision:
    def test_two_queue_handcheck(self, table):
        # Queue 0 (R50) has 1 fresh task; queue 2 (R152) has a near-deadline
        # task. Serving R152 first avoids pushing it over; stability score
        # must prefer it.
        cfg = SchedulerConfig(slo=0.050)
        s = EdgeServingScheduler(table, cfg)
        d = s.decide(snap([[0.001], [], [0.045]]))
        assert d.model == 2

    def test_empty_queues_return_none(self, table):
        s = EdgeServingScheduler(table, SchedulerConfig())
        assert s.decide(snap([[], [], []])) is None

    def test_decision_batch_never_exceeds_queue(self, table):
        s = EdgeServingScheduler(table, SchedulerConfig(max_batch=10))
        d = s.decide(snap([[0.01, 0.005], [], []]))
        assert d.model == 0 and d.batch_size == 2

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=30, deadline=None)
    def test_vectorized_matches_reference(self, table, seed):
        # The vectorised scheduler is numerically identical to the loop
        # implementation (same decision, same score).
        rng = np.random.default_rng(seed)
        waits = [
            np.sort(rng.uniform(0, 0.08, size=rng.integers(0, 12)))[::-1]
            for _ in range(3)
        ]
        s = snap(waits)
        cfg = SchedulerConfig(slo=0.050)
        d_ref = EdgeServingScheduler(table, cfg).decide(s)
        d_vec = VectorizedEdgeServingScheduler(table, cfg).decide(s)
        if d_ref is None:
            assert d_vec is None
        else:
            assert (d_ref.model, d_ref.exit_idx, d_ref.batch_size) == (
                d_vec.model, d_vec.exit_idx, d_vec.batch_size
            )
            assert d_vec.stability_score == pytest.approx(
                d_ref.stability_score, rel=1e-9
            )


class TestBaselinePolicies:
    def test_all_final_lqf(self, table):
        s = make_scheduler("all-final", table, SchedulerConfig())
        d = s.decide(snap([[0.01], [0.02, 0.01, 0.005], [0.04]]))
        assert d.model == 1  # longest queue
        assert d.exit_idx == table.num_exits - 1

    def test_all_early_exit_zero(self, table):
        s = make_scheduler("all-early", table, SchedulerConfig())
        d = s.decide(snap([[0.01, 0.003], [0.02], []]))
        assert d.exit_idx == 0

    def test_edf_selects_least_slack(self, table):
        s = make_scheduler("earlyexit-edf", table, SchedulerConfig(slo=0.05))
        d = s.decide(snap([[0.010], [0.049], [0.020]]))
        assert d.model == 1

    def test_allfinal_da_never_early_exits(self, table):
        s = make_scheduler("allfinal-deadline-aware", table, SchedulerConfig())
        d = s.decide(snap([[0.049], [0.01], []]))
        assert d.exit_idx == table.num_exits - 1

    def test_bs1_fixes_batch(self, table):
        s = make_scheduler("ours-bs1", table, SchedulerConfig(max_batch=10))
        d = s.decide(snap([[0.02, 0.01, 0.005], [], []]))
        assert d.batch_size == 1

    def test_symphony_defers_fresh_queue(self, table):
        s = make_scheduler("symphony", table, SchedulerConfig(slo=0.05))
        # single fresh task: plenty of slack -> defer (None) with a wake time
        snap0 = snap([[0.001], [], []])
        assert s.decide(snap0) is None
        wake = s.next_wake(snap0)
        assert wake is not None and wake > 0

    def test_symphony_dispatches_due_queue(self, table):
        s = make_scheduler("symphony", table, SchedulerConfig(slo=0.05))
        d = s.decide(snap([[0.045], [], []]))
        assert d is not None and d.model == 0
        assert d.exit_idx == table.num_exits - 1  # symphony never early-exits

    def test_symphony_dispatches_full_batch(self, table):
        s = make_scheduler("symphony", table, SchedulerConfig(slo=0.05, max_batch=4))
        d = s.decide(snap([[0.002, 0.002, 0.001, 0.001], [], []]))
        assert d is not None and d.batch_size == 4

    def test_symphony_prunes_expired(self, table):
        s = make_scheduler("symphony", table, SchedulerConfig(slo=0.05))
        drops = s.prune(snap([[0.08, 0.06, 0.01], [0.02], []]))
        assert drops == [(0, 2)]

    def test_unknown_scheduler_raises(self, table):
        with pytest.raises(ValueError):
            make_scheduler("nope", table, SchedulerConfig())

    @given(seed=st.integers(0, 2**16), name=st.sampled_from(
        ["edgeserving", "all-final", "all-early", "earlyexit-lqf",
         "earlyexit-edf", "allfinal-deadline-aware", "ours-bs1"]))
    @settings(max_examples=60, deadline=None)
    def test_property_decisions_well_formed(self, table, seed, name):
        rng = np.random.default_rng(seed)
        waits = [
            np.sort(rng.uniform(0, 0.1, size=rng.integers(0, 15)))[::-1]
            for _ in range(3)
        ]
        s = snap(waits)
        sched = make_scheduler(name, table, SchedulerConfig(slo=0.05, max_batch=10))
        d = sched.decide(s)
        if all(len(w) == 0 for w in waits):
            assert d is None
        elif d is not None:
            assert 0 <= d.model < 3 and len(waits[d.model]) > 0
            assert 1 <= d.batch_size <= min(len(waits[d.model]), 10)
            assert 0 <= d.exit_idx < table.num_exits
            assert d.predicted_latency == pytest.approx(
                table(d.model, d.exit_idx, d.batch_size)
            )
