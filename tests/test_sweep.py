"""SweepRunner: grid construction, parallel-vs-serial bitwise identity."""

import pytest

from repro.core import ProfileTable, SweepRunner, SweepSpec


@pytest.fixture(scope="module")
def runner():
    return SweepRunner(ProfileTable.paper_rtx3080())


def small_grid(runner):
    # Small but non-trivial: 2 policies x 2 scenarios (one bursty) x 2 rates,
    # short horizon so the whole grid stays cheap.
    return runner.grid(
        policies=("edgeserving", "all-final"),
        scenarios=("poisson", "mmpp"),
        rates=(100.0, 180.0),
        seeds=(7,),
        horizon=1.5,
        warmup_tasks=20,
    )


class TestGrid:
    def test_product_order_and_pairing(self, runner):
        specs = small_grid(runner)
        assert len(specs) == 8
        # policy-major nesting: paired (scenario, rate, seed) cells differ
        # only in policy -> identical arrival traces per comparison.
        assert specs[0].policy == "edgeserving" and specs[4].policy == "all-final"
        assert (specs[0].scenario, specs[0].rate) == (specs[4].scenario, specs[4].rate)

    def test_rate_vector_expansion(self):
        assert SweepSpec(policy="x", rate=100.0).rate_vector() == [300.0, 200.0, 100.0]
        assert SweepSpec(policy="x", rates=(5.0, 6.0)).rate_vector() == [5.0, 6.0]

    def test_empty_grid(self, runner):
        assert runner.run([], workers=4) == []


class TestDeterminism:
    def test_serial_rerun_identical(self, runner):
        specs = small_grid(runner)[:2]
        a = runner.run(specs, workers=1)
        b = runner.run(specs, workers=1)
        assert [r.metrics for r in a] == [r.metrics for r in b]

    def test_parallel_bitwise_identical_to_serial(self, runner):
        """The acceptance guarantee: workers>1 yields bitwise-identical
        metrics to workers=1, in grid order (only wall timings differ)."""
        specs = small_grid(runner)
        serial = runner.run(specs, workers=1)
        parallel = runner.run(specs, workers=2)
        assert [r.spec for r in serial] == specs
        assert [r.spec for r in parallel] == specs
        # ServingMetrics is a frozen dataclass of floats/ints/tuples:
        # == here is exact (bitwise) equality, including per_model rows.
        assert [r.metrics for r in serial] == [r.metrics for r in parallel]

    def test_het_deadline_cells_parallelise(self, runner):
        specs = [
            SweepSpec(policy=p, scenario="poisson", rate=120.0, seed=3,
                      horizon=1.5, warmup_tasks=20,
                      deadlines=(0.03, 0.05, 0.07))
            for p in ("edgeserving", "symphony")
        ]
        serial = runner.run(specs, workers=1)
        parallel = runner.run(specs, workers=2)
        assert [r.metrics for r in serial] == [r.metrics for r in parallel]
        assert all(len(r.metrics.per_model) > 0 for r in serial)
