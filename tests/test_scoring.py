"""Scoring-backend tests: registry, cross-backend decision equivalence
(greedy + lattice, scalar and per-task tau), the het-tau kernel paths, and
the no-recompile guarantee for traced tau/clip."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import (
    EdgeServingScheduler,
    LatticeEdgeServingScheduler,
    ProfileTable,
    QueueSnapshot,
    SCORING_BACKENDS,
    SchedulerConfig,
    VectorizedEdgeServingScheduler,
    make_scoring_backend,
)
from repro.kernels.stability_score.ops import stability_scores
from repro.kernels.stability_score.ref import lattice_stability_scores_ref

# "pallas" (compiled) is CPU-hostile; interpret mode runs the identical
# kernel semantics everywhere, so CI equivalence runs cover it via
# pallas-interpret and TPU hosts exercise the compiled path.
CPU_BACKENDS = ("numpy", "jnp", "pallas-interpret")


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080()


def random_snapshot(rng, m_count=3, max_len=10, het_tau=False):
    waits = [
        np.sort(rng.uniform(0, 0.08, size=rng.integers(0, max_len)))[::-1]
        for _ in range(m_count)
    ]
    deadlines = None
    if het_tau:
        deadlines = [
            np.where(rng.uniform(size=len(w)) < 0.5,
                     rng.uniform(0.02, 0.09, size=len(w)), np.nan)
            for w in waits
        ]
    return QueueSnapshot(0.0, waits, deadlines)


class TestBackendRegistry:
    def test_all_names_construct(self):
        for name in SCORING_BACKENDS:
            assert make_scoring_backend(name).name == name

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown scoring backend"):
            make_scoring_backend("cuda")

    def test_scheduler_config_builds_backend(self, table):
        s = VectorizedEdgeServingScheduler(
            table, SchedulerConfig(backend="jnp"))
        assert s.scoring.name == "jnp"

    def test_factory_is_cached(self):
        assert make_scoring_backend("numpy") is make_scoring_backend("numpy")


class TestDecisionEquivalence:
    """All backends must produce identical Decisions on the equivalence
    suite: greedy and lattice layouts, scalar and per-task tau."""

    @given(seed=st.integers(0, 2**16),
           lattice=st.sampled_from([False, True]),
           het=st.sampled_from([False, True]))
    @settings(max_examples=25, deadline=None)
    def test_property_backends_agree(self, table, seed, lattice, het):
        rng = np.random.default_rng(seed)
        snapshot = random_snapshot(rng, het_tau=het)
        cls = (LatticeEdgeServingScheduler if lattice
               else VectorizedEdgeServingScheduler)
        picks = {}
        for be in CPU_BACKENDS:
            d = cls(table, SchedulerConfig(
                slo=0.05, lattice=lattice, backend=be)).decide(snapshot)
            picks[be] = (None if d is None
                         else (d.model, d.exit_idx, d.batch_size))
        assert len(set(picks.values())) == 1, picks

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_edgeserving_ignores_lattice_flag_on_every_backend(
            self, table, seed):
        """Regression: EdgeServingScheduler is the paper-exact greedy —
        even constructed directly with lattice=True, switching backend for
        speed must never change its decisions (the accelerated route used
        to enumerate the lattice while the numpy loop ignored it)."""
        sat = table.with_batch_saturation(4)
        rng = np.random.default_rng(seed)
        snapshot = random_snapshot(rng)
        picks = set()
        for be in CPU_BACKENDS:
            for lattice in (False, True):
                d = EdgeServingScheduler(sat, SchedulerConfig(
                    slo=0.03, lattice=lattice, backend=be)).decide(snapshot)
                picks.add(None if d is None
                          else (d.model, d.exit_idx, d.batch_size))
        assert len(picks) == 1, picks

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=15, deadline=None)
    def test_property_loop_reference_matches_backends(self, table, seed):
        # The paper-exact loop (numpy) vs the accelerated greedy paths.
        rng = np.random.default_rng(seed)
        snapshot = random_snapshot(rng, het_tau=bool(seed % 2))
        d_ref = EdgeServingScheduler(
            table, SchedulerConfig(slo=0.05)).decide(snapshot)
        for be in ("jnp", "pallas-interpret"):
            d = EdgeServingScheduler(
                table, SchedulerConfig(slo=0.05, backend=be)).decide(snapshot)
            if d_ref is None:
                assert d is None
            else:
                assert (d_ref.model, d_ref.exit_idx, d_ref.batch_size) == (
                    d.model, d.exit_idx, d.batch_size)

    def test_numpy_backend_bitwise_matches_legacy_vectorized(self, table):
        # The default backend must reproduce the historical vectorised
        # scoring bit-for-bit: per-candidate scores are the same float ops
        # in the same order.
        rng = np.random.default_rng(3)
        snapshot = random_snapshot(rng)
        sched = VectorizedEdgeServingScheduler(table, SchedulerConfig())
        cq, cb, _, cl, _ = sched.enumerate_candidates(snapshot)
        scores = sched.score_candidates(snapshot, cl, cb, cq)
        tau, clip = sched.config.slo, sched.config.clip
        w, mask = snapshot.padded()
        shifted = w[None, :, :] + cl[:, None, None]
        urg = np.minimum(
            np.exp(np.minimum(shifted / tau - 1.0, np.log(clip))), clip
        ) * mask[None, :, :]
        total = urg.sum(axis=(1, 2))
        pos = np.arange(w.shape[1])[None, :]
        served = (pos < cb[:, None]).astype(np.float32)
        own = urg[np.arange(len(cq)), cq, :]
        np.testing.assert_array_equal(
            scores, total - (own * served).sum(axis=1))


class TestHetTauScoring:
    def test_het_tau_flips_argmin(self, table):
        """The case the scalar-tau fast path silently got wrong: a task
        near the *global* SLO but with a relaxed own deadline vs a fresher
        task about to blow its tight own deadline."""
        waits = [np.array([0.045]), np.array([0.030])]
        deadlines = [np.array([0.5]), np.array([0.032])]
        scalar_snap = QueueSnapshot(0.0, waits)
        het_snap = QueueSnapshot(0.0, waits, deadlines)
        for be in CPU_BACKENDS:
            sched = VectorizedEdgeServingScheduler(
                table, SchedulerConfig(slo=0.05, backend=be))
            d_scalar = sched.decide(scalar_snap)
            d_het = sched.decide(het_snap)
            # scalar view: queue 0 looks most urgent; per-task deadlines
            # reveal queue 1 is the one about to violate.
            assert d_scalar.model == 0, be
            assert d_het.model == 1, be

    def test_kernel_het_tau_matches_ref_with_padding(self):
        # N not a multiple of block_m (pad path) + per-task tau matrix.
        rng = np.random.default_rng(11)
        m, q, n, bm = 5, 33, 13, 8
        w = jnp.asarray(np.sort(rng.uniform(0, 0.1, (m, q)))[:, ::-1].copy(),
                        jnp.float32)
        mask = jnp.asarray((rng.uniform(size=(m, q)) > 0.3), jnp.float32)
        tau = jnp.asarray(rng.uniform(0.02, 0.09, (m, q)), jnp.float32)
        lat = jnp.asarray(rng.uniform(1e-3, 2e-2, n), jnp.float32)
        bat = jnp.asarray(rng.integers(1, q + 1, n), jnp.int32)
        cq = jnp.asarray(rng.integers(0, m, n), jnp.int32)
        out = stability_scores(w, mask, lat, bat, cq, tau=tau, block_m=bm,
                               interpret=True)
        ref = lattice_stability_scores_ref(w, mask, lat, bat, cq, tau, 10.0)
        assert out.shape == (n,)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5)

    def test_kernel_scalar_tau_bitwise_matches_filled_matrix(self):
        # The scalar fast path is literally the filled-matrix path.
        rng = np.random.default_rng(12)
        m, q, n = 4, 16, 9
        w = jnp.asarray(np.sort(rng.uniform(0, 0.1, (m, q)))[:, ::-1].copy(),
                        jnp.float32)
        mask = jnp.ones((m, q), jnp.float32)
        lat = jnp.asarray(rng.uniform(1e-3, 2e-2, n), jnp.float32)
        bat = jnp.asarray(rng.integers(1, 5, n), jnp.int32)
        cq = jnp.asarray(rng.integers(0, m, n), jnp.int32)
        out_scalar = stability_scores(w, mask, lat, bat, cq, tau=0.05,
                                      interpret=True)
        out_matrix = stability_scores(
            w, mask, lat, bat, cq, tau=jnp.full((m, q), 0.05, jnp.float32),
            interpret=True)
        np.testing.assert_array_equal(np.asarray(out_scalar),
                                      np.asarray(out_matrix))

    def test_kernel_het_tau_flips_argmin(self):
        # Same silent-wrong-answer scenario, pinned at the kernel level.
        w = jnp.asarray([[0.045], [0.030]], jnp.float32)
        mask = jnp.ones((2, 1), jnp.float32)
        tau = jnp.asarray([[0.5], [0.032]], jnp.float32)
        lat = jnp.asarray([0.005, 0.005], jnp.float32)
        bat = jnp.asarray([1, 1], jnp.int32)
        s_scalar = np.asarray(stability_scores(
            w, mask, lat, bat, tau=0.05, interpret=True))
        s_het = np.asarray(stability_scores(
            w, mask, lat, bat, tau=tau, interpret=True))
        assert int(np.argmin(s_scalar)) == 0
        assert int(np.argmin(s_het)) == 1


class TestNoRecompileAcrossTaus:
    def test_single_compile_across_slo_and_clip_sweep(self):
        """tau/clip are traced operands: a fig8-style SLO sweep must reuse
        one executable instead of recompiling per deadline."""
        rng = np.random.default_rng(13)
        m, q = 3, 8
        w = jnp.asarray(np.sort(rng.uniform(0, 0.1, (m, q)))[:, ::-1].copy(),
                        jnp.float32)
        mask = jnp.ones((m, q), jnp.float32)
        lat = jnp.asarray(rng.uniform(1e-3, 2e-2, m), jnp.float32)
        bat = jnp.asarray(rng.integers(1, 5, m), jnp.int32)
        # prime the cache for this shape/arg-structure signature
        stability_scores(w, mask, lat, bat, tau=0.019, clip=7.0,
                         interpret=True)
        before = stability_scores._cache_size()
        for tau in (0.02, 0.03, 0.05, 0.07, 0.1):
            for clip in (5.0, 10.0, 20.0):
                out = stability_scores(w, mask, lat, bat, tau=tau, clip=clip,
                                       interpret=True)
                assert out.shape == (m,)
        assert stability_scores._cache_size() == before

    def test_backend_schedulers_share_jit_cache(self, table):
        from repro.core.scoring import _jnp_score

        rng = np.random.default_rng(14)
        waits = [rng.uniform(0, 0.08, size=5)[::-1] for _ in range(3)]
        snapshot = QueueSnapshot(0.0, [np.sort(w)[::-1] for w in waits])
        cfgs = [SchedulerConfig(slo=s, backend="jnp")
                for s in (0.02, 0.05, 0.08)]
        VectorizedEdgeServingScheduler(table, cfgs[0]).decide(snapshot)
        before = _jnp_score._cache_size()
        for cfg in cfgs:
            VectorizedEdgeServingScheduler(table, cfg).decide(snapshot)
        assert _jnp_score._cache_size() == before


class TestSharedEnumeration:
    def test_greedy_enumeration_is_single_rung(self, table):
        sched = VectorizedEdgeServingScheduler(table, SchedulerConfig())
        snapshot = QueueSnapshot(
            0.0, [np.array([0.03, 0.02, 0.01]), np.array([]),
                  np.array([0.04])])
        cq, cb, ce, cl, cw = sched.enumerate_candidates(snapshot)
        assert list(cq) == [0, 2]
        assert list(cb) == [3, 1]
        for m, b, e, lat in zip(cq, cb, ce, cl):
            assert lat == table(int(m), int(e), int(b))

    def test_lattice_flag_upgrades_enumeration(self, table):
        cfg = SchedulerConfig(lattice=True)
        sched = VectorizedEdgeServingScheduler(table, cfg)
        snapshot = QueueSnapshot(0.0, [np.array([0.03, 0.02, 0.01, 0.005]),
                                       np.array([]), np.array([])])
        cq, cb, _, _, _ = sched.enumerate_candidates(snapshot)
        assert list(cq) == [0, 0, 0]
        assert list(cb) == [4, 2, 1]

    def test_backend_equivalent_through_config_replace(self, table):
        # dataclasses.replace keeps frozen-config ergonomics working.
        cfg = SchedulerConfig(slo=0.05)
        cfg2 = dataclasses.replace(cfg, backend="pallas-interpret")
        s = VectorizedEdgeServingScheduler(table, cfg2)
        assert s.scoring.name == "pallas-interpret"
