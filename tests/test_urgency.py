"""Unit + property tests for the urgency activation and stability score
(paper Eq. 3-4)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import (
    DEFAULT_CLIP,
    QueueSnapshot,
    candidate_stability_scores,
    stability_score,
    stability_score_np,
    urgency,
    urgency_np,
)


class TestUrgency:
    def test_value_at_deadline_is_one(self):
        # Eq. 3: f(tau) = exp(0) = 1 for any tau.
        for tau in (0.02, 0.05, 0.1, 1.0):
            assert urgency_np(np.array([tau]), tau)[0] == pytest.approx(1.0)

    def test_clip_threshold(self):
        # Paper: w > tau(1 + ln 10) ~ 3.3 tau saturates at C = 10.
        tau = 0.05
        w = np.array([tau * (1 + np.log(10.0)) + 1e-9, 100.0])
        out = urgency_np(w, tau)
        assert np.all(out == DEFAULT_CLIP)

    def test_zero_wait(self):
        assert urgency_np(np.array([0.0]), 0.05)[0] == pytest.approx(np.exp(-1.0))

    @given(
        w=st.floats(min_value=0.0, max_value=10.0),
        tau=st.floats(min_value=1e-3, max_value=1.0),
        clip=st.floats(min_value=1.0, max_value=100.0),
    )
    def test_bounds_property(self, w, tau, clip):
        v = float(urgency_np(np.array([w]), tau, clip)[0])
        assert 0.0 < v <= clip

    @given(
        w1=st.floats(min_value=0.0, max_value=5.0),
        dw=st.floats(min_value=0.0, max_value=5.0),
        tau=st.floats(min_value=1e-3, max_value=1.0),
    )
    def test_monotone_property(self, w1, dw, tau):
        a = float(urgency_np(np.array([w1]), tau)[0])
        b = float(urgency_np(np.array([w1 + dw]), tau)[0])
        assert b >= a  # urgency never decreases with waiting time

    def test_jnp_matches_np(self):
        rng = np.random.default_rng(0)
        w = rng.uniform(0, 0.3, size=64)
        np.testing.assert_allclose(
            np.asarray(urgency(jnp.asarray(w), 0.05)),
            urgency_np(w, 0.05),
            rtol=1e-6,
        )


class TestStabilityScore:
    def test_additive_over_queues(self):
        tau = 0.05
        waits = [np.array([0.01, 0.02]), np.array([0.03]), np.array([])]
        expect = sum(float(urgency_np(w, tau).sum()) for w in waits if len(w))
        assert stability_score_np(waits, tau) == pytest.approx(expect)

    def test_padded_jnp_matches_list_np(self):
        rng = np.random.default_rng(1)
        waits = [rng.uniform(0, 0.2, size=n) for n in (5, 0, 3, 17)]
        snap = QueueSnapshot(0.0, waits)
        w, mask = snap.padded()
        got = float(stability_score(jnp.asarray(w), jnp.asarray(mask), 0.05))
        want = stability_score_np(waits, 0.05)
        assert got == pytest.approx(want, rel=1e-6)

    @given(
        seed=st.integers(0, 2**16),
        m_count=st.integers(1, 5),
        tau=st.floats(min_value=5e-3, max_value=0.5),
    )
    @settings(max_examples=40, deadline=None)
    def test_monotone_in_any_wait(self, seed, m_count, tau):
        # S is strictly non-decreasing if any task waits longer.
        rng = np.random.default_rng(seed)
        waits = [np.sort(rng.uniform(0, 2 * tau, size=rng.integers(1, 8)))[::-1]
                 for _ in range(m_count)]
        s0 = stability_score_np(waits, tau)
        waits2 = [w.copy() for w in waits]
        waits2[0] = waits2[0] + 0.01 * tau
        assert stability_score_np(waits2, tau) >= s0


class TestCandidateScores:
    def test_matches_manual_prediction(self):
        # Hand-check Sec. V-C: candidate m serves its B oldest tasks; all
        # other tasks (own tail + other queues) wait L_m longer.
        tau, clip = 0.05, 10.0
        waits = [np.array([0.030, 0.020, 0.010]), np.array([0.040])]
        snap = QueueSnapshot(0.0, waits)
        w, mask = snap.padded()
        lats = np.array([0.008, 0.004])
        batches = np.array([2, 1])
        got = np.asarray(
            candidate_stability_scores(
                jnp.asarray(w, jnp.float32),
                jnp.asarray(mask, jnp.float32),
                jnp.asarray(lats, jnp.float32),
                jnp.asarray(batches),
                tau,
                clip,
            )
        )

        def f(x):
            return min(np.exp(x / tau - 1.0), clip)

        # candidate 0: serves its 2 oldest; tail task 0.010 and queue-1 task
        # 0.040 each wait 0.008 longer.
        want0 = f(0.010 + 0.008) + f(0.040 + 0.008)
        # candidate 1: serves its single task; queue-0 tasks wait 0.004 longer.
        want1 = f(0.030 + 0.004) + f(0.020 + 0.004) + f(0.010 + 0.004)
        np.testing.assert_allclose(got, [want0, want1], rtol=1e-5)

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=25, deadline=None)
    def test_property_served_tasks_excluded(self, seed):
        # Serving more tasks from a queue can only lower that candidate's
        # score (served tasks are removed from the prediction).
        rng = np.random.default_rng(seed)
        m_count = rng.integers(2, 5)
        waits = [np.sort(rng.uniform(0, 0.1, size=rng.integers(1, 9)))[::-1]
                 for _ in range(m_count)]
        snap = QueueSnapshot(0.0, waits)
        w, mask = snap.padded()
        lats = rng.uniform(1e-3, 2e-2, size=m_count)
        b_small = np.array([1] * m_count)
        b_big = np.array([min(len(q), 3) for q in waits])
        args = lambda b: (
            jnp.asarray(w, jnp.float32), jnp.asarray(mask, jnp.float32),
            jnp.asarray(lats, jnp.float32), jnp.asarray(b), 0.05, 10.0,
        )
        s_small = np.asarray(candidate_stability_scores(*args(b_small)))
        s_big = np.asarray(candidate_stability_scores(*args(b_big)))
        assert np.all(s_big <= s_small + 1e-5)
