"""Statistical correctness of the seed-band layer (``core/seedband.py``).

``summarize_band`` must agree with a plain-numpy reference (percentile
band and normal-approximation mean CI), the mean-CI width must shrink
like 1/sqrt(n) on a fixed serving workload, and the per-seed metric
columns must be bitwise-stable across reruns and across vmap-vs-loop
execution (chunk size changes how many lanes share one XLA launch, never
any lane's result).
"""

from __future__ import annotations

import math

import numpy as np
import pytest

from repro.core import (
    PoissonProcess,
    ProfileTable,
    SchedulerConfig,
    columns_from_requests,
    make_fleet,
    make_scenario,
    make_scheduler,
    paper_rate_vector,
)
from repro.core.clusterfast import simulate_cluster_scan
from repro.core.simfast import simulate_scan_batch
from repro.core.seedband import (
    BandSummary,
    compare_bands,
    simulate_cluster_scan_seedband,
    simulate_scan_seedband,
    summarize_band,
    _z_for_level,
)


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080().with_batch_saturation(4)


def _sched(table):
    return make_scheduler("edgeserving", table, SchedulerConfig(slo=0.05))


class TestSummarizeBand:
    def test_normal_quantiles(self):
        # two-sided standard-normal quantiles, to well under MC noise
        assert _z_for_level(0.90) == pytest.approx(1.6448536269, abs=1e-9)
        assert _z_for_level(0.95) == pytest.approx(1.9599639845, abs=1e-9)
        assert _z_for_level(0.99) == pytest.approx(2.5758293035, abs=1e-9)

    @pytest.mark.parametrize("dist", ["normal", "exponential", "bimodal"])
    def test_matches_numpy_reference(self, dist):
        rng = np.random.default_rng(7)
        if dist == "normal":
            col = rng.normal(3.0, 0.5, size=501)
        elif dist == "exponential":
            col = rng.exponential(2.0, size=501)
        else:
            col = np.concatenate(
                [rng.normal(0.0, 0.1, 250), rng.normal(5.0, 0.1, 251)])
        s = summarize_band(col, level=0.95)
        assert s.n == 501
        assert s.mean == float(col.mean())
        assert s.sd == float(col.std(ddof=1))
        # the documented tail points: 100*(1-level)/2 on either side
        tail = 100.0 * (1.0 - 0.95) / 2.0
        lo, hi = np.percentile(col, [tail, 100.0 - tail])
        assert s.band_lo == float(lo)
        assert s.band_hi == float(hi)
        assert s.band_lo == pytest.approx(np.percentile(col, 2.5), rel=1e-9)
        assert s.band_hi == pytest.approx(np.percentile(col, 97.5), rel=1e-9)
        half = _z_for_level(0.95) * s.sd / math.sqrt(501)
        assert s.ci_lo == pytest.approx(s.mean - half, rel=1e-12)
        assert s.ci_hi == pytest.approx(s.mean + half, rel=1e-12)

    def test_level_changes_band_tails(self):
        col = np.linspace(0.0, 1.0, 1001)
        s80 = summarize_band(col, level=0.80)
        assert s80.band_lo == pytest.approx(0.10, abs=1e-9)
        assert s80.band_hi == pytest.approx(0.90, abs=1e-9)

    def test_single_seed_degenerates(self):
        s = summarize_band([0.25])
        assert s.mean == 0.25
        assert s.sd == 0.0
        assert s.ci_lo == s.ci_hi == 0.25

    def test_rejects_bad_input(self):
        with pytest.raises(ValueError):
            summarize_band([])
        with pytest.raises(ValueError):
            summarize_band(np.zeros((3, 3)))
        with pytest.raises(ValueError):
            summarize_band([1.0, 2.0], level=1.5)

    def test_str_is_readable(self):
        s = summarize_band([1.0, 2.0, 3.0])
        assert "n=3" in str(s)
        assert isinstance(s, BandSummary)


class TestCompareBands:
    def test_detects_a_real_gap(self):
        rng = np.random.default_rng(3)
        a = rng.normal(0.18, 0.01, 400)
        b = rng.normal(0.03, 0.01, 400)
        gap = compare_bands(a, b)
        assert gap.significant
        assert gap.ci_lo > 0.1
        assert gap.gap == pytest.approx(0.15, abs=0.01)

    def test_same_distribution_is_not_significant(self):
        rng = np.random.default_rng(5)
        a = rng.normal(0.10, 0.02, 400)
        b = rng.normal(0.10, 0.02, 400)
        assert not compare_bands(a, b).significant

    def test_needs_two_seeds_per_side(self):
        with pytest.raises(ValueError):
            compare_bands([1.0], [1.0, 2.0])


class TestCIShrinksWithSeeds:
    def test_mean_ci_width_shrinks_like_inverse_sqrt_n(self, table):
        """Fixed workload, n in {10, 100, 1000}: each 10x in seeds must
        shrink the mean CI by ~1/sqrt(10) (loose band: MC noise)."""
        proc = make_scenario("poisson", paper_rate_vector(170.0))
        band = simulate_scan_seedband(
            _sched(table), table, proc, 0.6, range(1000), chunk=250)
        col = band.column("violation_ratio")
        assert col.std() > 0  # the cell must actually vary seed to seed
        widths = [summarize_band(col[:n]).ci_width for n in (10, 100, 1000)]
        assert widths[0] > widths[1] > widths[2] > 0
        for wide, narrow in zip(widths, widths[1:]):
            assert 0.15 < narrow / wide < 0.55  # ideal 1/sqrt(10) ~ 0.316


class TestColumnStability:
    def test_rerun_is_bitwise_identical(self, table):
        proc = make_scenario("poisson", paper_rate_vector(120.0))
        a = simulate_scan_seedband(
            _sched(table), table, proc, 0.8, range(12), chunk=12)
        b = simulate_scan_seedband(
            _sched(table), table, proc, 0.8, range(12), chunk=12)
        assert a.metrics == b.metrics  # frozen dataclasses: bitwise
        assert np.array_equal(a.column("p95_latency"),
                              b.column("p95_latency"))

    def test_vmap_vs_loop_chunking_is_bitwise_identical(self, table):
        """chunk=12 (one vmapped launch) vs chunk=1 (plain loop) vs an
        uneven split: per-seed columns may not move by a single bit."""
        proc = make_scenario("poisson", paper_rate_vector(120.0))
        args = (_sched(table), table, proc, 0.8, range(12))
        vmapped = simulate_scan_seedband(*args, chunk=12)
        loop = simulate_scan_seedband(*args, chunk=1)
        uneven = simulate_scan_seedband(*args, chunk=5)
        assert vmapped.metrics == loop.metrics == uneven.metrics

    def test_cluster_chunking_is_bitwise_identical(self, table):
        proc = make_scenario("poisson", paper_rate_vector(100.0))
        fleet = make_fleet("homogeneous", 2, table)
        kw = dict(dispatcher="jsq")
        a = simulate_cluster_scan_seedband(fleet, proc, 0.8, range(6),
                                           chunk=6, **kw)
        b = simulate_cluster_scan_seedband(fleet, proc, 0.8, range(6),
                                           chunk=2, **kw)
        assert a.metrics == b.metrics

    def test_cluster_band_matches_single_runs(self, table):
        proc = make_scenario("poisson", paper_rate_vector(100.0))
        fleet = make_fleet("homogeneous", 2, table)
        band = simulate_cluster_scan_seedband(
            fleet, proc, 0.8, range(4), dispatcher="least-loaded")
        for seed, got in zip(band.seeds, band.metrics):
            ref = simulate_cluster_scan(
                fleet, proc.generate(0.8, seed=seed), 0.8,
                dispatcher="least-loaded", keep_completions=False)
            assert got == ref.metrics

    def test_chunk_must_be_positive(self, table):
        proc = make_scenario("poisson", paper_rate_vector(100.0))
        with pytest.raises(ValueError):
            simulate_scan_seedband(
                _sched(table), table, proc, 0.5, range(2), chunk=0)


class TestTraceColumns:
    """The columnar trace fast path seedband rides is bitwise-identical
    to generating Request lanes (same draws, same sort order)."""

    @pytest.mark.parametrize(
        "scenario", ["poisson", "mmpp", "diurnal", "flash-crowd"])
    def test_columns_match_request_lanes(self, scenario):
        proc = make_scenario(scenario, paper_rate_vector(120.0))
        for seed in (0, 7):
            ref = columns_from_requests(proc.generate(1.5, seed=seed))
            col = proc.generate_columns(1.5, seed=seed)
            assert np.array_equal(ref.arrival, col.arrival)
            assert np.array_equal(ref.model, col.model)
            assert np.array_equal(ref.data_id, col.data_id)
            assert ref.deadline is None and col.deadline is None

    def test_deadline_vector_stamped(self):
        rates = paper_rate_vector(100.0)
        proc = PoissonProcess(
            rates, deadlines=[0.03 + 0.01 * m for m in range(len(rates))])
        ref = columns_from_requests(proc.generate(1.0, seed=3))
        col = proc.generate_columns(1.0, seed=3)
        assert np.array_equal(ref.deadline, col.deadline)

    def test_trace_replay_falls_back_through_generate(self):
        proc = make_scenario("trace-replay", paper_rate_vector(80.0))
        ref = columns_from_requests(proc.generate(1.0, seed=2))
        col = proc.generate_columns(1.0, seed=2)
        assert np.array_equal(ref.arrival, col.arrival)
        assert np.array_equal(ref.model, col.model)

    def test_indexing_materialises_requests(self):
        proc = make_scenario("poisson", paper_rate_vector(60.0))
        reqs = proc.generate(1.0, seed=1)
        cols = proc.generate_columns(1.0, seed=1)
        assert len(cols) == len(reqs)
        for i in (0, len(reqs) // 2, len(reqs) - 1):
            assert cols[i] == reqs[i]

    def test_scan_batch_accepts_columns(self, table):
        proc = make_scenario("poisson", paper_rate_vector(120.0))
        req_lanes = [proc.generate(0.8, seed=s) for s in range(3)]
        col_lanes = [proc.generate_columns(0.8, seed=s) for s in range(3)]
        a = simulate_scan_batch(_sched(table), table, req_lanes, 0.8,
                                keep_completions=True)
        b = simulate_scan_batch(_sched(table), table, col_lanes, 0.8,
                                keep_completions=True)
        for ra, rb in zip(a, b):
            assert ra.metrics == rb.metrics
            assert ra.completions == rb.completions

    def test_cluster_scan_accepts_columns(self, table):
        proc = make_scenario("poisson", paper_rate_vector(100.0))
        fleet = make_fleet("heterogeneous", 2, table)
        a = simulate_cluster_scan(
            fleet, proc.generate(0.8, seed=4), 0.8, dispatcher="jsq",
            keep_completions=True)
        b = simulate_cluster_scan(
            fleet, proc.generate_columns(0.8, seed=4), 0.8,
            dispatcher="jsq", keep_completions=True)
        assert a.metrics == b.metrics
        assert a.completions == b.completions
