"""Tests for the offline profile table (paper Sec. IV)."""

import numpy as np
import pytest

from repro.core import ProfileTable, paper_rate_vector


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080()


class TestPaperCalibration:
    def test_shape_is_paper_120_cells(self, table):
        # 3 models x 4 exits x 10 batch sizes (paper Sec. IV-B).
        assert table.latency.shape == (3, 4, 10)

    def test_batch_growth_2_to_3x(self, table):
        # Paper Fig. 2: batch 1 -> 10 raises latency ~2-3x, not 10x.
        ratio = table.latency[:, :, -1] / table.latency[:, :, 0]
        assert np.all(ratio >= 2.0) and np.all(ratio <= 3.0)

    def test_final_vs_layer1_6_to_8x_for_r152(self, table):
        r = table.latency[2, 3, :] / table.latency[2, 0, :]
        assert np.all(r >= 6.0) and np.all(r <= 8.0)

    def test_model_ordering(self, table):
        # R50 < R101 < R152 at every exit/batch; gap widest at final.
        assert np.all(table.latency[0] < table.latency[1])
        assert np.all(table.latency[1] < table.latency[2])
        gaps = table.latency[2] - table.latency[0]
        assert np.all(gaps[-1] >= gaps[0])

    def test_allfinal_saturation_near_paper_value(self, table):
        # Utilisation of the All-Final policy hits 1.0 near lambda_152 ~ 140
        # req/s (paper Fig. 4 knee: "degrades sharply beyond ~140 req/s").
        def util(lam):
            return sum(
                rate / 10.0 * table(m, 3, 10)
                for m, rate in enumerate(paper_rate_vector(lam))
            )
        assert util(140) < 1.0 < util(165)

    def test_accuracy_matches_table1(self, table):
        np.testing.assert_allclose(table.accuracy[0], [0.076, 0.121, 0.308, 0.744])
        np.testing.assert_allclose(table.accuracy[2, 3], 0.780)

    def test_monotone_in_batch(self, table):
        assert np.all(np.diff(table.latency, axis=2) >= 0)


class TestTableOps:
    def test_lookup_semantics(self, table):
        assert table(1, 2, 5) == table.latency[1, 2, 4]
        # batch beyond the profiled grid clamps to the largest entry
        assert table(1, 2, 99) == table.latency[1, 2, 9]

    def test_restrict_exits(self, table):
        sub = table.restrict_exits([0, 3])
        assert sub.exit_names == ("layer1", "final")
        assert sub.latency.shape == (3, 2, 10)
        np.testing.assert_array_equal(sub.latency[:, 1], table.latency[:, 3])

    def test_select_models(self, table):
        mix = table.select_models([0, 0, 0])
        assert mix.model_names == ("resnet50",) * 3
        np.testing.assert_array_equal(mix.latency[2], table.latency[0])

    def test_scaled_platform(self, table):
        slow = table.scaled(3.2, "gtx1650")
        np.testing.assert_allclose(slow.latency, table.latency * 3.2)
        assert slow.accuracy is table.accuracy  # accuracy platform-invariant

    def test_save_load_roundtrip(self, table, tmp_path):
        p = str(tmp_path / "profile.json")
        table.save(p)
        back = ProfileTable.load(p)
        np.testing.assert_allclose(back.latency, table.latency)
        np.testing.assert_allclose(back.accuracy, table.accuracy)
        assert back.model_names == table.model_names

    def test_measure_builder(self):
        import time
        calls = []

        def run_fn(m, e, b):
            calls.append((m, e, b))
            # millisecond-scale sleeps: sub-ms ones drown in OS scheduler
            # jitter and make the exit-ordering assertion below flaky.
            time.sleep(0.001 * (1 + m + e) * (1 + 0.1 * b))

        t = ProfileTable.measure(
            ["m0", "m1"], ["e0", "e1"], [1, 2], run_fn, repeats=3, warmup=1
        )
        assert t.latency.shape == (2, 2, 2)
        assert np.all(t.latency > 0)
        # deeper exits cost more in this synthetic workload
        assert np.all(t.latency[:, 1, :] >= t.latency[:, 0, :] * 0.5)

    def test_rejects_nonmonotone_batch_latency(self):
        lat = np.ones((1, 1, 3))
        lat[0, 0] = [2.0, 1.0, 3.0]
        with pytest.raises(AssertionError):
            ProfileTable(("m",), ("e",), (1, 2, 3), lat, np.ones((1, 1)))

    def test_from_roofline_builder(self):
        t = ProfileTable.from_roofline(
            ["m"], ["e0", "e1"], [1, 2],
            terms_fn=lambda m, e, b: (1e-3 * (e + 1) * b, 0.5e-3, 0.1e-3),
            safety=1.0, dispatch_overhead_s=0.0,
        )
        # compute-bound everywhere here: L = compute term
        np.testing.assert_allclose(t.latency[0, :, 0], [1e-3, 2e-3])
        np.testing.assert_allclose(t.latency[0, :, 1], [2e-3, 4e-3])
