"""Cluster serving subsystem: dispatchers, fleets, placement, determinism.

The two load-bearing guarantees (ISSUE acceptance criteria):
  * a G=1 cluster reproduces the single-device simulator bitwise on the
    same trace;
  * cluster sweep cells are parallel ≡ serial bitwise through SweepRunner.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    ClusterSimulator,
    DeviceSpec,
    ProfileTable,
    SchedulerConfig,
    ServingSimulator,
    SweepRunner,
    drain_estimate,
    make_dispatcher,
    make_fleet,
    make_scheduler,
    paper_rate_vector,
)
from repro.core.cluster import (
    DISPATCHERS,
    DeviceLoadView,
    JoinShortestQueueDispatcher,
    LeastLoadedDispatcher,
    RoundRobinDispatcher,
    StabilityAwareDispatcher,
)
from repro.core.workloads import make_scenario


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080()


def trace(lam, horizon=3.0, seed=7, scenario="poisson"):
    return make_scenario(scenario, paper_rate_vector(lam)).generate(
        horizon, seed=seed)


# ---------------------------------------------------------------------------
# Dispatcher policies against a synthetic view
# ---------------------------------------------------------------------------


class _FakeView(DeviceLoadView):
    """Scripted fleet state so dispatcher selection logic tests in isolation."""

    def __init__(self, backlogs, queued=None, service=None):
        self.backlogs = list(backlogs)
        self.queued = list(queued or [0] * len(self.backlogs))
        self.service = list(service or [0.0] * len(self.backlogs))

    def healthy(self, d):
        return True

    def effective_backlog(self, d):
        return self.backlogs[d]

    def total_queued(self, d):
        return self.queued[d]

    def predicted_completion(self, d, model):
        return self.backlogs[d] + self.service[d]


class TestDispatchers:
    def test_registry_and_factory(self):
        assert set(DISPATCHERS) == {
            "round-robin", "jsq", "least-loaded", "stability-aware"}
        for name in DISPATCHERS:
            assert make_dispatcher(name).name == name
        with pytest.raises(ValueError):
            make_dispatcher("nope")

    def test_round_robin_cycles_eligible(self):
        rr = RoundRobinDispatcher()
        view = _FakeView([0, 0, 0])
        picks = [rr.pick(0, [0, 2], view) for _ in range(4)]
        assert picks == [0, 2, 0, 2]
        rr.reset()
        assert rr.pick(0, [0, 2], view) == 0

    def test_jsq_min_queue_tie_lowest_id(self):
        jsq = JoinShortestQueueDispatcher()
        view = _FakeView([9, 0, 0], queued=[3, 5, 3])
        assert jsq.pick(0, [0, 1, 2], view) == 0  # tie 0 vs 2 -> lowest id
        view.queued = [3, 1, 3]
        assert jsq.pick(0, [0, 1, 2], view) == 1

    def test_least_loaded_uses_effective_backlog(self):
        ll = LeastLoadedDispatcher()
        view = _FakeView([0.5, 0.1, 0.3])
        assert ll.pick(0, [0, 1, 2], view) == 1

    def test_stability_aware_prices_device_speed(self):
        # Same backlog, but device 1 is 3x slower at serving the request
        # itself: JSQ/least-loaded can't see it, stability-aware can.
        sa = StabilityAwareDispatcher(slo=0.050, power_d=2)
        sa.reset(0)
        view = _FakeView([0.01, 0.01], service=[0.005, 0.015])
        assert sa.pick(0, [0, 1], view) == 0

    def test_stability_aware_ranks_hopeless_devices_by_completion(self):
        # Both saturate the urgency clip, but the argmin-on-T_hat shortcut
        # still prefers the sooner completion (delta ties are T_hat ties).
        sa = StabilityAwareDispatcher(slo=0.050, power_d=2)
        sa.reset(0)
        view = _FakeView([10.0, 5.0], service=[0.01, 0.01])
        assert sa.pick(0, [0, 1], view) == 1
        assert sa.delta(10.01) == sa.delta(5.01) == 10.0  # both clipped

    def test_stability_aware_accepts_request_deadline(self):
        # Het-SLO workloads pass the request's own tau so the priced delta
        # is in the right currency (the pick itself is tau-invariant for a
        # shared tau, since the urgency is monotone in predicted completion).
        sa = StabilityAwareDispatcher(slo=0.050, power_d=2)
        sa.reset(0)
        view = _FakeView([0.01, 0.01], service=[0.005, 0.015])
        assert sa.pick(0, [0, 1], view, deadline=0.005) == 0
        assert sa.pick(0, [0, 1], view, deadline=0.500) == 0

    def test_stability_aware_sampling_deterministic_per_seed(self):
        view = _FakeView([0.1, 0.2, 0.3, 0.4], service=[0.01] * 4)
        a = StabilityAwareDispatcher(power_d=2)
        b = StabilityAwareDispatcher(power_d=2)
        a.reset(42)
        b.reset(42)
        picks_a = [a.pick(0, [0, 1, 2, 3], view) for _ in range(32)]
        picks_b = [b.pick(0, [0, 1, 2, 3], view) for _ in range(32)]
        assert picks_a == picks_b


# ---------------------------------------------------------------------------
# Drain estimate (closed form)
# ---------------------------------------------------------------------------


class TestDrainEstimate:
    def test_matches_explicit_serve_loop(self, table):
        sched = make_scheduler("edgeserving", table, SchedulerConfig())
        qlens = [25, 0, 7]
        est = drain_estimate(sched, qlens)
        # the pre-refactor O(queue-length) while-loop, verbatim
        e = table.num_exits - 1
        total = 0.0
        for m, n in enumerate(qlens):
            while n > 0:
                b = sched.batch_size(n)
                total += table(m, e, b)
                n -= b
        assert est == total  # closed form is exact, not approximate

    def test_respects_policy_batch_cap(self, table):
        bs1 = make_scheduler("ours-bs1", table, SchedulerConfig())
        full = make_scheduler("edgeserving", table, SchedulerConfig())
        assert drain_estimate(bs1, [10, 0, 0]) == pytest.approx(
            10 * table(0, 3, 1))
        assert drain_estimate(bs1, [10, 0, 0]) > drain_estimate(full, [10, 0, 0])

    def test_non_min_form_ladder_falls_back_to_exact_loop(self, table):
        # A scheduler whose batch rule is NOT B* = min(|Q|, B_max) — e.g. a
        # geometric power-of-two ladder — must get the exact serve-out, not
        # the quotient+remainder closed form.
        from repro.core import EdgeServingScheduler

        class PowerOfTwoBatch(EdgeServingScheduler):
            def batch_size(self, qlen):
                b = 1
                while b * 2 <= min(qlen, self.config.max_batch):
                    b *= 2
                return b

        sched = PowerOfTwoBatch(table, SchedulerConfig(max_batch=16))
        e = table.num_exits - 1
        for qlens in ([25, 0, 0], [9, 3, 1], [31, 17, 2]):
            expect = 0.0
            for m, n in enumerate(qlens):
                while n > 0:
                    b = sched.batch_size(n)
                    expect += table(m, e, b)
                    n -= b
            assert drain_estimate(sched, qlens) == pytest.approx(expect)


# ---------------------------------------------------------------------------
# Fleets and placement
# ---------------------------------------------------------------------------


class TestFleets:
    def test_homogeneous_fleet(self, table):
        fleet = make_fleet("homogeneous", 3, table)
        assert len(fleet) == 3
        assert all(s.table is table for s in fleet)

    def test_heterogeneous_fleet_alternates_speed(self, table):
        fleet = make_fleet("heterogeneous", 4, table)
        assert np.allclose(fleet[1].table.latency, table.latency * 3.2)
        assert np.allclose(fleet[3].table.latency, table.latency * 3.2)
        assert fleet[0].table is table and fleet[2].table is table

    def test_fail_at_schedule(self, table):
        fleet = make_fleet("homogeneous", 2, table, fail_at=((1, 2.5),))
        assert fleet[0].fail_at is None and fleet[1].fail_at == 2.5

    def test_unknown_fleet_raises(self, table):
        with pytest.raises(ValueError):
            make_fleet("nope", 2, table)

    def test_placement_map(self, table):
        devices = [
            DeviceSpec(table, models=(0, 1)),
            DeviceSpec(table, models=(2,)),
        ]
        sim = ClusterSimulator(devices, num_models=3)
        assert sim.placement == [[0], [0], [1]]

    def test_unplaced_model_rejected(self, table):
        with pytest.raises(AssertionError):
            ClusterSimulator([DeviceSpec(table, models=(0,))], num_models=2)

    def test_placement_respected_end_to_end(self, table):
        devices = [
            DeviceSpec(table, models=(0,)),
            DeviceSpec(table, models=(1, 2)),
        ]
        arrivals = trace(100.0)
        sim = ClusterSimulator(devices, num_models=3, seed=7)
        res = sim.run(list(arrivals), 3.0, warmup_tasks=20)
        # with one host per model, dispatch counts are fully determined
        n_model0 = sum(1 for r in arrivals if r.model == 0)
        assert res.dispatch_counts == (n_model0, len(arrivals) - n_model0)
        assert res.metrics.residual_queue == 0


# ---------------------------------------------------------------------------
# G=1 cluster ≡ single-device simulator (bitwise)
# ---------------------------------------------------------------------------


class TestSingleDeviceEquivalence:
    @pytest.mark.parametrize("policy", ["edgeserving", "edgeserving-lattice",
                                        "all-final", "symphony"])
    def test_g1_bitwise_identical(self, table, policy):
        cfg = SchedulerConfig(slo=0.050)
        arrivals = trace(160.0, scenario="mmpp")
        single = ServingSimulator(
            make_scheduler(policy, table, cfg), table, num_models=3, seed=7)
        ref = single.run(list(arrivals), 3.0, warmup_tasks=50)
        sim = ClusterSimulator(
            make_fleet("homogeneous", 1, table), policy=policy, config=cfg,
            num_models=3, seed=7)
        got = sim.run(list(arrivals), 3.0, warmup_tasks=50)
        assert got.completions == ref.completions
        assert got.span == ref.span
        # metrics equal apart from the cluster-only per_device rollup
        assert dataclasses.replace(got.metrics, per_device=()) == ref.metrics
        assert len(got.metrics.per_device) == 1

    def test_g1_bitwise_identical_with_service_noise(self, table):
        # device 0's noise stream must equal the single-device stream
        cfg = SchedulerConfig(slo=0.050)
        arrivals = trace(160.0)
        single = ServingSimulator(
            make_scheduler("edgeserving", table, cfg), table, num_models=3,
            seed=11, service_noise_cov=0.03)
        ref = single.run(list(arrivals), 3.0, warmup_tasks=50)
        sim = ClusterSimulator(
            make_fleet("homogeneous", 1, table), config=cfg, num_models=3,
            seed=11, service_noise_cov=0.03)
        got = sim.run(list(arrivals), 3.0, warmup_tasks=50)
        assert got.completions == ref.completions
        assert dataclasses.replace(got.metrics, per_device=()) == ref.metrics

    def test_g1_rerun_stable(self, table):
        sim = ClusterSimulator(make_fleet("homogeneous", 1, table),
                               num_models=3, seed=7)
        arrivals = trace(120.0)
        a = sim.run(list(arrivals), 3.0)
        b = sim.run(list(arrivals), 3.0)
        assert a.metrics == b.metrics


# ---------------------------------------------------------------------------
# Multi-device behaviour
# ---------------------------------------------------------------------------


class TestCluster:
    def test_scaling_restores_depth_and_compliance(self, table):
        # EdgeServing absorbs overload by exiting shallow, so the scaling
        # win shows up as *both* fewer violations and deeper exits (higher
        # accuracy), not violations alone.
        arrivals = trace(160.0 * 3, horizon=3.0)
        ms = []
        for g in (1, 2, 4):
            sim = ClusterSimulator(
                make_fleet("homogeneous", g, table),
                dispatcher=make_dispatcher("least-loaded"),
                num_models=3, seed=7)
            ms.append(sim.run(list(arrivals), 3.0).metrics)
        assert ms[2].violation_ratio <= ms[1].violation_ratio <= ms[0].violation_ratio
        assert ms[0].mean_exit_depth < ms[1].mean_exit_depth < ms[2].mean_exit_depth
        assert ms[2].mean_exit_depth > 3.5  # near-final exits once scaled out

    def test_heterogeneous_fleet_stability_beats_blind_dispatch(self, table):
        arrivals = trace(160.0 * 4, horizon=3.0, scenario="mmpp")
        viol = {}
        for dp in ("round-robin", "jsq", "stability-aware"):
            sim = ClusterSimulator(
                make_fleet("heterogeneous", 4, table),
                dispatcher=make_dispatcher(dp, slo=0.050),
                num_models=3, seed=7)
            viol[dp] = sim.run(list(arrivals), 3.0).metrics.violation_ratio
        assert viol["stability-aware"] < viol["round-robin"]
        assert viol["stability-aware"] < viol["jsq"]

    def test_device_failure_reroutes_and_completes(self, table):
        arrivals = trace(160.0 * 2, horizon=4.0)
        sim = ClusterSimulator(
            make_fleet("homogeneous", 2, table, fail_at=((0, 2.0),)),
            dispatcher=make_dispatcher("least-loaded"),
            num_models=3, seed=7)
        res = sim.run(list(arrivals), 4.0)
        dead, alive = res.metrics.per_device
        assert not dead.alive and alive.alive
        # failover: nothing stranded, everything eventually completes
        assert res.metrics.residual_queue == 0
        assert len(res.completions) == len(arrivals)
        # the dead device stopped half-way: the survivor did more work
        assert dead.utilization < alive.utilization

    def test_late_failure_does_not_inflate_span(self, table):
        # a fail_at long after the workload drains is an idle death: it
        # must not stretch span (and so deflate throughput/utilization).
        arrivals = trace(100.0, horizon=2.0)
        base = ClusterSimulator(make_fleet("homogeneous", 2, table),
                                num_models=3, seed=7)
        ref = base.run(list(arrivals), 2.0)
        late = ClusterSimulator(
            make_fleet("homogeneous", 2, table, fail_at=((0, 500.0),)),
            num_models=3, seed=7)
        got = late.run(list(arrivals), 2.0)
        assert got.span == ref.span
        assert got.metrics.throughput == ref.metrics.throughput

    def test_all_hosts_dead_requests_strand(self, table):
        devices = [
            DeviceSpec(table, models=(0,), fail_at=0.5),
            DeviceSpec(table, models=(1, 2)),
        ]
        sim = ClusterSimulator(devices, num_models=3, seed=7)
        res = sim.run(trace(100.0, horizon=3.0), 3.0)
        assert res.metrics.residual_queue > 0  # model-0 arrivals after 0.5 s

    def test_het_slo_deadlines_flow_through_dispatch(self, table):
        from repro.core import SweepRunner, SweepSpec
        runner = SweepRunner(table)
        res = runner.run_cell(SweepSpec(
            policy="edgeserving", fleet="heterogeneous", fleet_size=2,
            dispatcher="stability-aware", rate=200.0, seed=7, horizon=1.5,
            warmup_tasks=20, deadlines=(0.030, 0.050, 0.070)))
        assert res.metrics.num_completed > 0
        assert len(res.metrics.per_model) == 3  # judged by their own taus

    def test_per_device_drops_counted_as_violations(self, table):
        # Symphony sheds under overload; a device's shed requests must show
        # up in its own violation ratio (same rule as the aggregate).
        arrivals = trace(500.0, horizon=3.0)
        sim = ClusterSimulator(
            make_fleet("heterogeneous", 2, table), policy="symphony",
            dispatcher=make_dispatcher("round-robin"), num_models=3, seed=7)
        res = sim.run(list(arrivals), 3.0)
        assert res.metrics.dropped > 0
        assert res.metrics.dropped == sum(
            d.dropped for d in res.metrics.per_device)
        for d in res.metrics.per_device:
            if d.dropped:
                assert d.violation_ratio > 0.0

    def test_drops_without_completions_are_full_violations(self, table):
        from repro.core import summarize
        m = summarize([], table, 0.05, warmup_tasks=0, dropped=17)
        assert m.violation_ratio == 1.0 and m.dropped == 17
        assert summarize([], table, 0.05, warmup_tasks=0).violation_ratio == 0.0

    def test_per_device_rollup_consistent(self, table):
        sim = ClusterSimulator(
            make_fleet("heterogeneous", 2, table),
            dispatcher=make_dispatcher("stability-aware"),
            num_models=3, seed=7)
        res = sim.run(trace(200.0), 3.0, warmup_tasks=40)
        pd = res.metrics.per_device
        assert len(pd) == 2
        assert sum(d.num_completed for d in pd) == res.metrics.num_completed
        assert sum(d.dispatched for d in pd) == len(res.completions)
        assert all(0.0 <= d.utilization <= 1.0 for d in pd)
        # aggregate utilization is the fleet mean, in [0, 1]
        assert 0.0 < res.metrics.utilization <= 1.0


# ---------------------------------------------------------------------------
# Sweep integration: cluster cells, parallel ≡ serial
# ---------------------------------------------------------------------------


class TestClusterSweep:
    def test_cluster_grid_shape(self, table):
        runner = SweepRunner(table)
        specs = runner.cluster_grid(
            dispatchers=("round-robin", "jsq"),
            fleets=(("homogeneous", 2), ("heterogeneous", 4)),
            rates=(200.0,),
            horizon=1.5,
        )
        assert len(specs) == 4
        assert specs[0].dispatcher == "round-robin"
        assert specs[1].fleet_size == 4
        assert "homogeneousx2" in specs[0].title()

    def test_parallel_bitwise_identical_to_serial(self, table):
        runner = SweepRunner(table)
        specs = runner.cluster_grid(
            dispatchers=("least-loaded", "stability-aware"),
            fleets=(("heterogeneous", 2),),
            scenarios=("mmpp",),
            rates=(250.0,),
            horizon=1.5,
            warmup_tasks=20,
        ) + runner.cluster_grid(
            dispatchers=("jsq",),
            fleets=(("homogeneous", 2),),
            rates=(200.0,),
            horizon=1.5,
            warmup_tasks=20,
            fail_at=((0, 0.8),),
        )
        serial = runner.run(specs, workers=1)
        parallel = runner.run(specs, workers=2)
        assert [r.spec for r in parallel] == specs
        # frozen dataclasses of floats/ints/tuples: == is bitwise equality,
        # including the per_device rollups.
        assert [r.metrics for r in serial] == [r.metrics for r in parallel]
        assert all(len(r.metrics.per_device) == 2 for r in serial)

    def test_cluster_cell_rejects_runner_sched_table(self, table):
        # sched_table / model_map apply to single-device cells only; a
        # cluster cell must fail loudly instead of silently ignoring them.
        from repro.core import SweepSpec
        runner = SweepRunner(table, sched_table=table.restrict_exits([3]))
        spec = SweepSpec(policy="edgeserving", fleet="homogeneous",
                         fleet_size=2, horizon=1.0)
        with pytest.raises(NotImplementedError):
            runner.run_cell(spec)

    def test_single_device_cell_rejects_cluster_only_fields(self, table):
        # the symmetric guard: cluster knobs without fleet= must fail
        # loudly, not silently run a fleetless experiment.
        from repro.core import SweepSpec
        runner = SweepRunner(table)
        for kw in ({"fail_at": ((0, 3.0),)}, {"dispatcher": "jsq"},
                   {"fleet_size": 2}):
            with pytest.raises(ValueError):
                runner.run_cell(SweepSpec(policy="edgeserving", horizon=1.0,
                                          **kw))

    def test_g1_cluster_cell_matches_single_device_cell(self, table):
        runner = SweepRunner(table)
        base = dict(scenario="mmpp", rate=160.0, seed=7, horizon=1.5,
                    warmup_tasks=20)
        from repro.core import SweepSpec
        single = runner.run_cell(SweepSpec(policy="edgeserving", **base))
        cluster = runner.run_cell(SweepSpec(
            policy="edgeserving", fleet="homogeneous", fleet_size=1, **base))
        assert dataclasses.replace(
            cluster.metrics, per_device=()) == single.metrics
