"""Tests for the workload subsystem: arrival-process statistics, trace
round-trips, heterogeneous per-request deadlines end to end."""

import numpy as np
import pytest

from repro.core import (
    DiurnalProcess,
    FlashCrowdProcess,
    MMPPProcess,
    PoissonProcess,
    ProfileTable,
    SchedulerConfig,
    ServingSimulator,
    TraceReplayProcess,
    burstiness_index,
    interarrival_cov,
    make_scenario,
    make_scheduler,
    paper_rate_vector,
    poisson_arrivals,
    record_trace,
    run_experiment,
)
from repro.core.workloads import SCENARIOS

RATES = [120.0, 80.0, 40.0]


def all_processes():
    return [
        PoissonProcess(RATES),
        MMPPProcess(RATES),
        DiurnalProcess(RATES),
        FlashCrowdProcess(RATES),
        TraceReplayProcess(source=MMPPProcess(RATES)),
    ]


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080()


class TestInterface:
    @pytest.mark.parametrize("proc", all_processes(), ids=lambda p: p.name)
    def test_sorted_bounded_monotone_ids(self, proc):
        reqs = proc.generate(10.0, seed=3)
        times = [r.arrival for r in reqs]
        assert times == sorted(times)
        assert all(0 <= t < 10.0 for t in times)
        assert [r.req_id for r in reqs] == list(range(len(reqs)))
        assert all(0 <= r.model < 3 for r in reqs)

    @pytest.mark.parametrize("proc", all_processes(), ids=lambda p: p.name)
    def test_seed_deterministic(self, proc):
        a = proc.generate(5.0, seed=11)
        b = proc.generate(5.0, seed=11)
        c = proc.generate(5.0, seed=12)
        key = lambda rs: [(r.model, r.arrival, r.data_id) for r in rs]
        assert key(a) == key(b)
        assert key(a) != key(c)

    def test_poisson_import_compatible(self):
        # traffic.poisson_arrivals is the same algorithm: identical traces.
        a = poisson_arrivals(RATES, 5.0, seed=7)
        b = PoissonProcess(RATES).generate(5.0, seed=7)
        assert [(r.model, r.arrival, r.data_id) for r in a] == [
            (r.model, r.arrival, r.data_id) for r in b
        ]

    def test_registry_covers_all_scenarios(self):
        for name in SCENARIOS:
            proc = make_scenario(name, RATES)
            assert proc.generate(2.0, seed=1)
        with pytest.raises(ValueError):
            make_scenario("nope", RATES)


class TestStatistics:
    """Empirical rate / burstiness checks (long horizons, fixed seeds)."""

    HORIZON = 100.0

    def _count_tolerance(self, proc, seed=5, tol=0.15):
        reqs = proc.generate(self.HORIZON, seed=seed)
        for m, lam in enumerate(RATES):
            expect = proc.mean_rate(m) * self.HORIZON
            got = sum(1 for r in reqs if r.model == m)
            assert abs(got - expect) <= tol * expect, (proc.name, m, got, expect)
        return reqs

    def test_poisson_rate(self):
        self._count_tolerance(PoissonProcess(RATES), tol=0.05)

    def test_mmpp_rate_preserving(self):
        # The OFF multiplier is derived so the long-run mean equals RATES.
        self._count_tolerance(MMPPProcess(RATES), tol=0.15)

    def test_diurnal_rate_preserving(self):
        # Whole periods average the sinusoid out.
        self._count_tolerance(DiurnalProcess(RATES, period=10.0), tol=0.10)

    def test_flash_crowd_adds_load(self):
        # magnitude 5 over 10% of the horizon => mean multiplier 1.4.
        proc = FlashCrowdProcess(RATES, magnitude=5.0)
        reqs = proc.generate(self.HORIZON, seed=5)
        expect = sum(RATES) * self.HORIZON * 1.4
        assert abs(len(reqs) - expect) <= 0.10 * expect

    def test_burstiness_ordering_mmpp_above_poisson(self):
        # The defining property: MMPP interarrivals are overdispersed.
        po = PoissonProcess(RATES).generate(self.HORIZON, seed=5)
        mm = MMPPProcess(RATES).generate(self.HORIZON, seed=5)
        cov_po = interarrival_cov(po)
        cov_mm = interarrival_cov(mm)
        assert 0.9 < cov_po < 1.1          # Poisson: CoV ~ 1
        assert cov_mm > cov_po * 1.3       # clear separation
        assert burstiness_index(mm) > 1.5

    def test_flash_crowd_spike_window(self):
        proc = FlashCrowdProcess(
            RATES, spike_start=4.0, spike_duration=1.0, magnitude=8.0,
            spike_models=(0,),
        )
        reqs = proc.generate(10.0, seed=9)
        in_w = sum(1 for r in reqs if r.model == 0 and 4.0 <= r.arrival < 5.0)
        out_w = sum(1 for r in reqs if r.model == 0 and r.arrival < 1.0)
        assert in_w > 4 * max(out_w, 1)    # ~8x rate inside the window
        # non-spiked models are untouched by the window
        m2_in = sum(1 for r in reqs if r.model == 2 and 4.0 <= r.arrival < 5.0)
        assert m2_in < 3 * RATES[2] * 1.0


class TestTraceReplay:
    def test_round_trip_exact(self):
        src = MMPPProcess(RATES, deadlines=[0.03, 0.05, 0.07])
        reqs = src.generate(5.0, seed=1)
        replay = TraceReplayProcess(trace=record_trace(reqs)).generate(
            5.0, seed=999  # seed must not matter for explicit traces
        )
        key = lambda rs: [(r.model, r.arrival, r.data_id, r.deadline) for r in rs]
        assert key(replay) == key(reqs)
        assert [r.req_id for r in replay] == list(range(len(replay)))

    def test_source_replay_matches_source(self):
        src = MMPPProcess(RATES)
        direct = src.generate(5.0, seed=4)
        replayed = TraceReplayProcess(source=MMPPProcess(RATES)).generate(
            5.0, seed=4
        )
        assert [(r.model, r.arrival) for r in direct] == [
            (r.model, r.arrival) for r in replayed
        ]

    def test_horizon_truncation_and_time_scale(self):
        src = PoissonProcess(RATES)
        trace = record_trace(src.generate(10.0, seed=2))
        half = TraceReplayProcess(trace=trace).generate(5.0)
        assert all(r.arrival < 5.0 for r in half)
        compressed = TraceReplayProcess(trace=trace, time_scale=0.5).generate(5.0)
        assert len(compressed) == len(trace)  # 10 s of traffic in 5 s


class TestHeterogeneousDeadlines:
    def test_deadline_stamping(self):
        dl = (0.02, 0.05, 0.08)
        reqs = make_scenario("mmpp", RATES, deadlines=dl).generate(3.0, seed=1)
        assert reqs and all(r.deadline == dl[r.model] for r in reqs)

    def test_end_to_end_simulator(self, table):
        """Per-queue SLO vectors flow arrivals -> scheduler -> completions
        -> violation accounting."""
        dl = (0.030, 0.050, 0.070)
        proc = make_scenario("poisson", paper_rate_vector(120), deadlines=dl)
        sched = make_scheduler("edgeserving", table, SchedulerConfig(slo=0.05))
        res = run_experiment(
            sched, table, paper_rate_vector(120), horizon=4.0, seed=4,
            process=proc,
        )
        assert res.completions
        assert all(c.deadline == dl[c.model] for c in res.completions)
        # violation accounting uses each request's own deadline
        expect = np.mean([
            c.total_latency > c.deadline
            for c in res.completions[res.metrics.warmup_used:]
        ])
        assert res.metrics.violation_ratio == pytest.approx(float(expect))

    def test_tight_deadline_shallows_exit_and_counts_violation(self, table):
        """Eq. 6 feasibility uses the request's own deadline: a tight one
        forces a shallower exit, and an impossibly tight one (below even the
        shallowest exit's latency) is judged by its own deadline."""
        from repro.core import Request

        sched = make_scheduler("edgeserving", table, SchedulerConfig(slo=0.05))
        final_lat = table(2, table.num_exits - 1, 1)
        shallow_lat = table(2, 0, 1)
        assert final_lat < 0.05  # sanity: final exit meets the global SLO

        # Deadline between exits: scheduler drops to a feasible shallower
        # exit and meets the request's own deadline (no violation).
        sim = ServingSimulator(sched, table, num_models=3)
        tight = [Request(req_id=0, model=2, arrival=0.0, deadline=final_lat / 2)]
        res = sim.run(tight, horizon=0.1, warmup_tasks=0)
        c = res.completions[0]
        assert c.exit_idx < table.num_exits - 1
        assert res.metrics.violation_ratio == 0.0

        # Deadline below the shallowest exit: unsatisfiable; counted as a
        # violation against the request's own deadline even though the
        # global 50 ms SLO would have called it fine.
        sim2 = ServingSimulator(sched, table, num_models=3)
        hopeless = [
            Request(req_id=0, model=2, arrival=0.0, deadline=shallow_lat / 2)
        ]
        res2 = sim2.run(hopeless, horizon=0.1, warmup_tasks=0)
        assert res2.completions[0].total_latency < 0.05
        assert res2.metrics.violation_ratio == 1.0

    def test_scheduler_prioritises_tight_deadline_queue(self, table):
        """Two equally-old heads; serving order follows the per-request
        deadlines, whichever queue holds the tight one."""
        from repro.core import QueueSnapshot

        w = [np.array([0.02]), np.array([]), np.array([0.02])]
        sched = make_scheduler("edgeserving", table, SchedulerConfig(slo=0.05))

        d = [np.array([0.025]), np.array([]), np.array([0.075])]
        assert sched.decide(QueueSnapshot(0.0, w, d)).model == 0
        d_swapped = [np.array([0.075]), np.array([]), np.array([0.025])]
        assert sched.decide(QueueSnapshot(0.0, w, d_swapped)).model == 2
