"""End-to-end integration: live serving engine with real jitted models on
CPU, measured profiles, and the EdgeServing scheduler; plus a short real
training run (loss must decrease)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    EdgeServingScheduler,
    Request,
    SchedulerConfig,
    make_scheduler,
)
from repro.models import build_model, split_params
from repro.optim import AdamW
from repro.runtime.server import ServedModel, ServingEngine, measure_profile
from repro.runtime.trainer import make_train_step


def _tiny_lm(arch: str, key: int, num_layers=2, d=32, vocab=64):
    from repro.models.transformer import LMConfig
    cfg = LMConfig(
        arch_id=f"{arch}-{key}", family="dense", num_layers=num_layers,
        d_model=d, num_heads=4, num_kv_heads=2, d_ff=2 * d,
        vocab_size=vocab, exits=tuple(range(1, num_layers + 1)),
    )
    model = build_model(cfg)
    values, _ = split_params(model.init(jax.random.key(key)))
    return cfg, model, values


def _served(cfg, model, values, name, seq=8):
    def forward(v, x, e):
        return model.forward_exit(v, {"tokens": x}, e)

    def data(b):
        return jnp.zeros((b, seq), jnp.int32)

    return ServedModel(name=name, values=values, forward_fn=forward,
                       data_fn=data, num_exits=cfg.num_exits)


@pytest.fixture(scope="module")
def deployment():
    # three models of increasing cost, all with 2 exit points (the paper's
    # R50 < R101 < R152 pattern)
    models = []
    for i, d in enumerate((16, 32, 64)):
        cfg, model, values = _tiny_lm(f"m{i}", i, num_layers=2, d=d)
        models.append(_served(cfg, model, values, f"model{i}"))
    return models


class TestLiveServing:
    def test_measured_profile_is_sane(self, deployment):
        table = measure_profile(deployment, batch_sizes=[1, 2, 4],
                                repeats=3, warmup=1)
        assert table.latency.shape == (3, 2, 3)
        assert np.all(table.latency > 0)
        # deeper exits of the deepest model cost >= its shallowest exit
        assert np.all(table.latency[2, -1, :] >= table.latency[2, 0, :] * 0.5)

    def test_engine_serves_all_requests(self, deployment):
        table = measure_profile(deployment, batch_sizes=[1, 2, 4],
                                repeats=2, warmup=1)
        cfg = SchedulerConfig(slo=10.0, max_batch=4)  # generous SLO on CPU
        sched = EdgeServingScheduler(table, cfg)
        engine = ServingEngine(deployment, sched)
        engine.warmup([1, 2, 4])
        arrivals = [
            Request(req_id=i, model=i % 3, arrival=i * 0.002)
            for i in range(30)
        ]
        completions, span = engine.run(arrivals, duration=0.06, drain=True)
        assert len(completions) == 30
        m = engine.metrics(table, slo=10.0, span=span)
        assert m.violation_ratio == 0.0
        ids = sorted(c.req_id for c in completions)
        assert ids == list(range(30))

    def test_engine_respects_time_division(self, deployment):
        table = measure_profile(deployment, batch_sizes=[1, 2],
                                repeats=2, warmup=1)
        sched = make_scheduler("all-final", table,
                               SchedulerConfig(slo=10.0, max_batch=2))
        engine = ServingEngine(deployment, sched)
        engine.warmup([1, 2])
        arrivals = [Request(req_id=i, model=0, arrival=0.0) for i in range(6)]
        completions, _ = engine.run(arrivals, duration=0.01, drain=True)
        # quanta are serial: completion intervals must not overlap
        spans = sorted((c.dispatch, c.finish) for c in completions)
        for (a1, b1), (a2, b2) in zip(spans, spans[1:]):
            if a1 != a2:  # different quanta
                assert a2 >= b1 - 1e-9


class TestEngineDrainCap:
    """Regression: ``drain=True`` busy-waited forever when a policy left
    queues non-empty while ``decide`` kept returning ``None`` past
    ``duration`` (the simulator has ``drain_cap``; the live engine had no
    equivalent). The engine now mirrors the simulator's cap and surfaces
    stranded requests via ``residual_queue``."""

    def _never_scheduler(self):
        from repro.core import ProfileTable, Scheduler

        class NeverScheduler(Scheduler):
            name = "never-stub"

            def decide(self, snapshot):
                return None  # e.g. a pruning baseline that stops dispatching

        return NeverScheduler(ProfileTable.paper_rtx3080(),
                              SchedulerConfig(slo=0.05))

    def test_drain_cap_bounds_the_busy_wait(self, deployment):
        ticks = iter(np.arange(0.0, 60.0, 0.05))
        engine = ServingEngine(deployment, self._never_scheduler(),
                               clock=lambda: float(next(ticks)))
        arrivals = [Request(req_id=i, model=0, arrival=0.0) for i in range(4)]
        completions, span = engine.run(
            arrivals, duration=0.1, drain=True, idle_sleep=0.0, drain_cap=0.5)
        assert completions == []
        assert span <= 1.0  # returned at the cap, not the clock's horizon
        m = engine.metrics(engine.scheduler.table, slo=0.05, span=span)
        assert m.residual_queue == 4

    def test_unsubmitted_tail_counts_as_residual(self, deployment):
        # An arrival beyond the cap is never ingested but must not vanish:
        # completions + dropped + residual == arrivals (simulator parity).
        ticks = iter(np.arange(0.0, 60.0, 0.05))
        engine = ServingEngine(deployment, self._never_scheduler(),
                               clock=lambda: float(next(ticks)))
        arrivals = [Request(req_id=0, model=0, arrival=0.0),
                    Request(req_id=1, model=0, arrival=30.0)]
        completions, span = engine.run(
            arrivals, duration=0.1, drain=True, idle_sleep=0.0, drain_cap=0.5)
        assert completions == []
        m = engine.metrics(engine.scheduler.table, slo=0.05, span=span)
        assert m.residual_queue == 2  # 1 queued + 1 never-ingested

    def test_default_cap_preserves_normal_drain(self, deployment):
        # sanity: a working scheduler under the default cap still drains
        table = measure_profile(deployment, batch_sizes=[1, 2],
                                repeats=2, warmup=1)
        sched = EdgeServingScheduler(table,
                                     SchedulerConfig(slo=10.0, max_batch=2))
        engine = ServingEngine(deployment, sched)
        engine.warmup([1, 2])
        arrivals = [Request(req_id=i, model=0, arrival=0.0) for i in range(4)]
        completions, _ = engine.run(arrivals, duration=0.01, drain=True)
        assert len(completions) == 4


class TestTrainingIntegration:
    def test_loss_decreases_tiny_lm(self):
        cfg = get_config("smollm-135m", smoke=True)
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        opt = AdamW(lr=5e-3, weight_decay=0.0)
        opt_state = opt.init(values)
        step = jax.jit(make_train_step(model, opt))
        key = jax.random.key(1)
        # fixed tiny corpus: the model must memorise it
        toks = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        losses = []
        for i in range(30):
            values, opt_state, metrics = step(values, opt_state, batch, i)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] * 0.7, losses[::10]
        assert np.isfinite(losses).all()

    def test_grad_accum_matches_full_batch(self):
        cfg = get_config("smollm-135m", smoke=True)
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        opt = AdamW(lr=1e-3, weight_decay=0.0)
        toks = jax.random.randint(jax.random.key(2), (8, 16), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}

        s1 = jax.jit(make_train_step(model, opt))
        s2 = jax.jit(make_train_step(model, opt, grad_accum=4))
        v1, _, m1 = s1(values, opt.init(values), batch, 0)
        v2, _, m2 = s2(values, opt.init(values), batch, 0)
        # same global batch semantics -> same loss and nearly same update
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                                  rel=1e-5)
        diff = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(v1), jax.tree.leaves(v2))
        )
        # Adam's rsqrt amplifies fp32 summation-order noise; 1e-3 of the
        # lr-scale update is well below one optimizer step of drift.
        assert diff < 1e-3

    def test_train_step_with_resnet(self):
        from repro.configs import resnet_configs
        from repro.models import EarlyExitResNet
        cfg = resnet_configs(smoke=True)["resnet50"]
        model = EarlyExitResNet(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        opt = AdamW(lr=1e-3, weight_decay=0.0)
        opt_state = opt.init(values)
        imgs = jax.random.normal(jax.random.key(1), (8, 32, 32, 3))
        lbls = jax.random.randint(jax.random.key(2), (8,), 0, 100)
        batch = {"images": imgs, "labels": lbls}
        step = jax.jit(make_train_step(model, opt))
        losses = []
        for i in range(10):
            values, opt_state, metrics = step(values, opt_state, batch, i)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0]
