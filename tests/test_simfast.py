"""Cross-implementation equivalence: compiled scan engine vs Python loop.

``repro.core.simfast`` re-implements the serving simulator as a jitted
``lax.scan`` — the easiest place in the repo to introduce silent semantic
drift. This suite pins the scan path to the reference event loop
decision-by-decision: same (model, exit, batch) dispatch sequence, same
``ServingMetrics`` (bitwise on the fixed grids we ship, tight-tolerance
under hypothesis), and the same conservation law, all through the shared
``tests/engine_conformance.py`` harness so both engines face identical
inputs and identical assertions (and the cluster-scan suite reuses the
same scaffolding instead of keeping a third copy).

The 10^6-request scaling check is ``slow``-marked: it runs in the CI
smoke step (``REPRO_SIMFAST_SMOKE=1``, which also implies the slow
tests), not tier-1. Smoke mode trims the hypothesis example counts so
the step fits a CPU-only runner's budget.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ProfileTable,
    Request,
    ScanEngineUnsupported,
    SchedulerConfig,
    ServingSimulator,
    SweepRunner,
    SweepSpec,
    make_scheduler,
    paper_rate_vector,
    poisson_arrivals,
    simulate_scan,
    simulate_scan_batch,
    summarize,
    summarize_arrays,
)
from engine_conformance import (
    assert_conservation as _conservation,
    assert_metrics_close as _assert_metrics_close,
    decisions as _decisions,
    run_both as _run_both,
)

SUPPORTED_POLICIES = (
    "edgeserving", "edgeserving-lattice", "allfinal-deadline-aware",
    "ours-bs1",
)
UNSUPPORTED_POLICIES = (
    "all-final", "all-early", "symphony", "earlyexit-lqf", "earlyexit-edf",
)
_SMOKE = bool(os.environ.get("REPRO_SIMFAST_SMOKE"))


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080().with_batch_saturation(4)


class TestDecisionEquivalence:
    @given(
        seed=st.integers(0, 9999),
        lam=st.sampled_from([40.0, 110.0, 190.0]),
        policy=st.sampled_from(SUPPORTED_POLICIES),
        slo=st.floats(0.030, 0.080),
    )
    @settings(max_examples=4 if _SMOKE else 10, deadline=None)
    def test_property_same_decisions_and_metrics(self, table, seed, lam,
                                                 policy, slo):
        arrivals = poisson_arrivals(paper_rate_vector(lam), 2.5, seed=seed)
        py, sc = _run_both(policy, table, arrivals, 2.5, slo=slo)
        assert _decisions(py) == _decisions(sc)
        _assert_metrics_close(py.metrics, sc.metrics)

    def test_fig4_grid_bitwise(self, table):
        """The fig4-shaped regime the benchmark quotes: bitwise equality,
        whole grid in one vmapped launch, greedy and lattice."""
        lanes = [poisson_arrivals(paper_rate_vector(lam), 4.0, seed=s)
                 for lam in (60.0, 140.0, 220.0) for s in (7, 8)]
        for policy in ("edgeserving", "edgeserving-lattice"):
            sched = make_scheduler(policy, table, SchedulerConfig(slo=0.05))
            py = [ServingSimulator(
                make_scheduler(policy, table, SchedulerConfig(slo=0.05)),
                table, num_models=3).run(a, 4.0) for a in lanes]
            sc = simulate_scan_batch(sched, table, lanes, 4.0, num_models=3)
            for p, s in zip(py, sc):
                assert p.metrics == s.metrics  # frozen dataclass: bitwise

    def test_factored_and_direct_scoring_agree(self, table):
        arrivals = poisson_arrivals(paper_rate_vector(140.0), 3.0, seed=7)
        py, sc_f = _run_both("edgeserving", table, arrivals, 3.0,
                             factored=True)
        _, sc_d = _run_both("edgeserving", table, arrivals, 3.0,
                            factored=False)
        assert _decisions(py) == _decisions(sc_f) == _decisions(sc_d)
        assert py.metrics == sc_f.metrics
        assert py.metrics == sc_d.metrics

    def test_traces_carry_matching_clock(self, table):
        arrivals = poisson_arrivals(paper_rate_vector(100.0), 2.0, seed=3)
        py, sc = _run_both("edgeserving", table, arrivals, 2.0)
        assert [(t.t_start, t.t_end) for t in py.traces] == \
               [(t.t_start, t.t_end) for t in sc.traces]

    def test_model_map_deployment_mix(self, table):
        arrivals = poisson_arrivals([100.0, 100.0, 100.0], 3.0, seed=4)
        py, sc = _run_both("edgeserving", table, arrivals, 3.0,
                           model_map=[0, 0, 0])
        assert _decisions(py) == _decisions(sc)
        assert py.metrics == sc.metrics

    def test_per_model_constant_deadlines(self, table):
        taus = (0.060, 0.045, 0.035)
        arrivals = [
            dataclasses.replace(r, deadline=taus[r.model])
            for r in poisson_arrivals(paper_rate_vector(120.0), 3.0, seed=9)
        ]
        py, sc = _run_both("edgeserving", table, arrivals, 3.0)
        assert _decisions(py) == _decisions(sc)
        assert py.metrics == sc.metrics

    def test_queue_overflow_retries_wider_window(self, table):
        # max_queue=2 is far below the true depth at lambda=140; the engine
        # must detect the overflow and retry with a doubled window, not
        # silently drop queued work.
        arrivals = poisson_arrivals(paper_rate_vector(140.0), 2.0, seed=5)
        py, sc = _run_both("edgeserving", table, arrivals, 2.0, max_queue=2)
        assert _decisions(py) == _decisions(sc)
        assert py.metrics == sc.metrics

    def test_empty_arrivals(self, table):
        py, sc = _run_both("edgeserving", table, [], 1.0)
        assert py.metrics == sc.metrics
        assert sc.metrics.num_completed == 0


class TestConservationProperty:
    @given(
        seed=st.integers(0, 2**16),
        lam=st.sampled_from([30.0, 150.0]),
        policy=st.sampled_from(("edgeserving", "ours-bs1")),
    )
    @settings(max_examples=3 if _SMOKE else 6, deadline=None)
    def test_property_all_arrivals_accounted_both_engines(
            self, table, seed, lam, policy):
        arrivals = poisson_arrivals(paper_rate_vector(lam), 2.0, seed=seed)
        # _run_both asserts the conservation law on each engine separately.
        py, sc = _run_both(policy, table, arrivals, 2.0)
        assert len(py.completions) == len(sc.completions)


class TestLoudRejection:
    @pytest.mark.parametrize("policy", UNSUPPORTED_POLICIES)
    def test_unsupported_policies_raise(self, table, policy):
        sched = make_scheduler(policy, table, SchedulerConfig(slo=0.05))
        arrivals = poisson_arrivals(paper_rate_vector(50.0), 1.0, seed=1)
        with pytest.raises(ScanEngineUnsupported):
            simulate_scan(sched, table, arrivals, 1.0, num_models=3)

    def test_non_numpy_backend_raises(self, table):
        sched = make_scheduler(
            "edgeserving", table, SchedulerConfig(slo=0.05, backend="jnp"))
        arrivals = poisson_arrivals(paper_rate_vector(50.0), 1.0, seed=1)
        with pytest.raises(ScanEngineUnsupported):
            simulate_scan(sched, table, arrivals, 1.0, num_models=3)

    def test_varying_deadlines_raise(self, table):
        rng = np.random.default_rng(0)
        arrivals = [
            dataclasses.replace(r, deadline=float(rng.uniform(0.02, 0.09)))
            for r in poisson_arrivals(paper_rate_vector(50.0), 1.0, seed=1)
        ]
        sched = make_scheduler("edgeserving", table, SchedulerConfig(slo=0.05))
        with pytest.raises(ScanEngineUnsupported):
            simulate_scan(sched, table, arrivals, 1.0, num_models=3)

    def test_unsorted_arrivals_raise(self, table):
        arrivals = list(
            reversed(poisson_arrivals(paper_rate_vector(50.0), 1.0, seed=1)))
        sched = make_scheduler("edgeserving", table, SchedulerConfig(slo=0.05))
        with pytest.raises(ValueError):
            simulate_scan(sched, table, arrivals, 1.0, num_models=3)

    @pytest.mark.parametrize("kw", [
        dict(drift="thermal-throttle"),
        dict(scenario="trace-replay"),
        dict(backend="jnp"),
        # fleets themselves route to clusterfast since PR 10; what stays
        # rejected is what its state layout cannot express (telemetry
        # reconstruction, power-of-d RNG subsampling).
        dict(fleet="homogeneous", fleet_size=2, trace=True),
        dict(fleet="homogeneous", fleet_size=3,
             dispatcher="stability-aware"),
    ])
    def test_sweep_cell_rejects(self, table, kw):
        spec = SweepSpec(policy="edgeserving", rate=40.0, horizon=1.0,
                         engine="scan", **kw)
        with pytest.raises(ScanEngineUnsupported):
            SweepRunner(table).run_cell(spec)

    def test_sweep_noise_rejected(self, table):
        spec = SweepSpec(policy="edgeserving", rate=40.0, horizon=1.0,
                         engine="scan")
        with pytest.raises(ScanEngineUnsupported):
            SweepRunner(table, service_noise_cov=0.03).run_cell(spec)

    def test_unknown_engine_rejected(self, table):
        spec = SweepSpec(policy="edgeserving", rate=40.0, horizon=1.0,
                         engine="fortran")
        with pytest.raises(ValueError):
            SweepRunner(table).run_cell(spec)


class TestSweepEngine:
    def test_scan_cell_matches_python_cell(self, table):
        runner = SweepRunner(table)
        kw = dict(policy="edgeserving", rate=120.0, seed=7, horizon=3.0)
        py = runner.run_cell(SweepSpec(**kw))
        sc = runner.run_cell(SweepSpec(engine="scan", **kw))
        assert py.metrics == sc.metrics

    def test_scan_cell_with_restricted_sched_table(self, table):
        # The scheduler decides with a restricted view; execution uses the
        # ground-truth table — the split must survive compilation.
        view = table.restrict_exits([table.num_exits - 1])
        runner = SweepRunner(table, sched_table=view)
        kw = dict(policy="edgeserving", rate=80.0, seed=3, horizon=2.0)
        py = runner.run_cell(SweepSpec(**kw))
        sc = runner.run_cell(SweepSpec(engine="scan", **kw))
        assert py.metrics == sc.metrics

    def test_title_tags_engine(self):
        assert "[scan]" in SweepSpec(policy="edgeserving",
                                     engine="scan").title()
        assert "[" not in SweepSpec(policy="edgeserving").title()


class TestSharedAccounting:
    def test_summarize_delegates_to_summarize_arrays(self, table):
        # Both engines settle their books through summarize_arrays; pin the
        # object-path wrapper to the array path directly.
        from repro.core import Completion
        rng = np.random.default_rng(11)
        n = 400
        comps = []
        t = 0.0
        for i in range(n):
            dispatch = t + float(rng.uniform(0, 0.005))
            finish = dispatch + float(rng.uniform(0.001, 0.05))
            arrival = dispatch - float(rng.uniform(0, 0.04))
            comps.append(Completion(
                req_id=i, model=int(rng.integers(0, 3)), arrival=arrival,
                dispatch=dispatch, finish=finish,
                exit_idx=int(rng.integers(0, table.num_exits)),
                batch_size=int(rng.integers(1, 5))))
            t = finish
        obj = summarize(comps, table, slo=0.05, busy_time=1.0, span=4.0)
        arr = summarize_arrays(
            models=np.array([c.model for c in comps]),
            exits=np.array([c.exit_idx for c in comps]),
            batches=np.array([c.batch_size for c in comps]),
            latencies=np.array([c.total_latency for c in comps]),
            queueings=np.array([c.queueing for c in comps]),
            taus=np.full(n, 0.05),
            table=table, busy_time=1.0, span=4.0)
        assert obj == arr


@pytest.mark.slow
class TestScaling:
    def test_million_request_run(self, table):
        """10^6-request trace in one scan (ROADMAP "millions of users").

        The long horizon pushes arrival/tau past the factored-exponential
        range, so this also exercises the direct-scoring fallback at scale.
        """
        lam = 240.0
        horizon = 1e6 / sum(paper_rate_vector(lam))
        arrivals = poisson_arrivals(paper_rate_vector(lam), horizon, seed=7)
        assert len(arrivals) > 900_000
        sched = make_scheduler("edgeserving", table, SchedulerConfig(slo=0.05))
        res = simulate_scan(sched, table, arrivals, horizon, num_models=3,
                            keep_completions=True)
        _conservation(res, len(arrivals))
        assert res.metrics.num_completed > 900_000
        # stationary near-capacity load: the backlog at the end is a queue,
        # not a meltdown
        assert res.metrics.residual_queue < 5_000
