"""Tests for the determinism & numerics static-analysis suite.

Three layers, each proven in both directions:

  * every DET rule fires on a positive fixture, honours an inline
    ``# detlint: disable=...``, and stays quiet on the clean twin;
  * the jaxpr auditor flags a deliberately float32-polluted "scheduling"
    function declared float64, a denylisted debug callback, and a
    static-argified recompile trap — and each, injected through
    ``run_suite``, turns into a nonzero exit code with file:line output;
  * the Pallas auditor flags a misaligned BlockSpec, an out-of-bounds
    index map, a missing memory-space annotation, and a blown VMEM budget
    on synthetic kernels, again end-to-end through ``run_suite``;
  * tier-1: the repo itself is clean against the committed (empty)
    baseline, and ``python tools/lint.py`` run as a subprocess agrees —
    while the same subprocess on a copy of the tree seeded with an
    ``np.random.rand`` call and an f32 cast in ``core/urgency.py`` exits
    nonzero naming both files.

Plus the two satellite numerics tests: the stability-score kernel's
declared f64->f32 downcast stays inside its manifest ``rtol`` under
extreme tau/latency magnitudes, and checkpoint manifests are
bytes-identical across runs now that wall time is injected.
"""

import functools
import json
import os
import shutil
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.baseline import Baseline
from repro.analysis.detlint import (
    DetlintConfig,
    Finding,
    default_config,
    lint_source,
)
from repro.analysis.jaxpr_audit import audit_artifact, no_recompile_findings
from repro.analysis.manifest import (
    PRECISION_ARTIFACTS,
    ArtifactSpec,
    KernelSpec,
    RecompileGuard,
)
from repro.analysis.pallas_audit import audit_kernel, capture_pallas_calls
from repro.analysis.runner import run_suite

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LINT_CLI = os.path.join(REPO_ROOT, "tools", "lint.py")


def rules_of(findings):
    return sorted({f.rule for f in findings})


def lint(src, path="src/sample.py", config=None):
    if config is None:
        config = DetlintConfig()
    return lint_source(textwrap.dedent(src), path, config)


# ---------------------------------------------------------------------------
# Layer 1: detlint rules
# ---------------------------------------------------------------------------


class TestDET001UnseededRNG:
    def test_numpy_global_rng_flagged(self):
        got, _ = lint("""
            import numpy as np
            VAL = np.random.rand(3)
        """)
        assert rules_of(got) == ["DET001"]
        assert got[0].line == 3

    def test_numpy_alias_resolved(self):
        got, _ = lint("""
            import numpy as xp
            xp.random.shuffle([1, 2])
        """)
        assert rules_of(got) == ["DET001"]

    def test_stdlib_random_flagged(self):
        got, _ = lint("""
            import random
            x = random.randint(0, 10)
        """)
        assert rules_of(got) == ["DET001"]

    def test_seeded_generator_clean(self):
        got, _ = lint("""
            import numpy as np
            import random
            rng = np.random.default_rng(42)
            x = rng.normal(size=3)
            r = random.Random(7)
            y = r.randint(0, 10)
        """)
        assert got == []

    def test_inline_suppression(self):
        got, sup = lint("""
            import numpy as np
            VAL = np.random.rand(3)  # detlint: disable=DET001
        """)
        assert got == []
        assert rules_of(sup) == ["DET001"]


class TestDET002WallClock:
    CFG = DetlintConfig(engine_modules=("src/repro/core/sim.py",))
    CFG_ALLOW = DetlintConfig(
        engine_modules=("src/repro/core/sim.py",),
        timing_allowlist=(("src/repro/core/sim.py", "bench"),))

    def test_wall_clock_in_engine_flagged(self):
        got, _ = lint("""
            import time
            def step():
                return time.perf_counter()
        """, path="src/repro/core/sim.py", config=self.CFG)
        assert rules_of(got) == ["DET002"]

    def test_datetime_now_flagged(self):
        got, _ = lint("""
            import datetime
            def stamp():
                return datetime.datetime.now()
        """, path="src/repro/core/sim.py", config=self.CFG)
        assert rules_of(got) == ["DET002"]

    def test_outside_engine_clean(self):
        got, _ = lint("""
            import time
            def step():
                return time.time()
        """, path="src/repro/runtime/serve.py", config=self.CFG)
        assert got == []

    def test_allowlisted_scope_clean(self):
        got, _ = lint("""
            import time
            def bench():
                return time.perf_counter()
        """, path="src/repro/core/sim.py", config=self.CFG_ALLOW)
        assert got == []


class TestDET003SetIteration:
    def test_set_sum_flagged(self):
        got, _ = lint("""
            def total(items):
                seen = set(items)
                acc = 0.0
                for x in seen:
                    acc += x
                return acc
        """)
        assert rules_of(got) == ["DET003"]

    def test_set_emission_flagged(self):
        got, _ = lint("""
            def emit(trace):
                for x in {1, 2, 3}:
                    trace.append(x)
        """)
        assert rules_of(got) == ["DET003"]

    def test_sorted_set_clean(self):
        got, _ = lint("""
            def total(items):
                seen = set(items)
                acc = 0.0
                for x in sorted(seen):
                    acc += x
                return acc
        """)
        assert got == []

    def test_dict_iteration_clean(self):
        # dicts are insertion-ordered since 3.7: deliberately not flagged
        got, _ = lint("""
            def total(d):
                acc = 0.0
                for k in d:
                    acc += d[k]
                return acc
        """)
        assert got == []


class TestDET004MutableDefault:
    def test_list_default_flagged(self):
        got, _ = lint("""
            def f(acc=[]):
                acc.append(1)
                return acc
        """)
        assert rules_of(got) == ["DET004"]

    def test_factory_default_flagged(self):
        got, _ = lint("""
            def f(*, cache=dict()):
                return cache
        """)
        assert rules_of(got) == ["DET004"]

    def test_none_default_clean(self):
        got, _ = lint("""
            def f(acc=None):
                acc = [] if acc is None else acc
                return acc
        """)
        assert got == []


class TestDET005Float32InF64Path:
    CFG = DetlintConfig(float64_paths=("src/repro/core/",))
    CFG_ALLOW = DetlintConfig(
        float64_paths=("src/repro/core/",),
        float32_allowances=(("src/repro/core/x.py", "Fast.score"),))

    def test_f32_attribute_flagged(self):
        got, _ = lint("""
            import jax.numpy as jnp
            def score(w):
                return w.astype(jnp.float32).sum()
        """, path="src/repro/core/x.py", config=self.CFG)
        assert rules_of(got) == ["DET005"]

    def test_dtype_string_flagged(self):
        got, _ = lint("""
            import numpy as np
            def score(w):
                return np.zeros(3, dtype="float32") + w.astype("f32")
        """, path="src/repro/core/x.py", config=self.CFG)
        assert [f.rule for f in got] == ["DET005", "DET005"]

    def test_outside_f64_path_clean(self):
        got, _ = lint("""
            import jax.numpy as jnp
            def score(w):
                return w.astype(jnp.float32).sum()
        """, path="src/repro/kernels/x.py", config=self.CFG)
        assert got == []

    def test_allowance_scope_clean(self):
        got, _ = lint("""
            import jax.numpy as jnp
            class Fast:
                def score(self, w):
                    return w.astype(jnp.float32).sum()
        """, path="src/repro/core/x.py", config=self.CFG_ALLOW)
        assert got == []

    def test_float64_clean(self):
        got, _ = lint("""
            import numpy as np
            def score(w):
                return w.astype(np.float64).sum()
        """, path="src/repro/core/x.py", config=self.CFG)
        assert got == []


class TestDET006ExceptAndIs:
    def test_bare_except_flagged(self):
        got, _ = lint("""
            def f():
                try:
                    return 1
                except:
                    return 0
        """)
        assert rules_of(got) == ["DET006"]

    def test_is_literal_flagged(self):
        got, _ = lint("""
            def f(x):
                return x is 5
        """)
        assert rules_of(got) == ["DET006"]

    def test_is_none_clean(self):
        got, _ = lint("""
            def f(x):
                if x is None or x is True:
                    return 0
                try:
                    return 1
                except ValueError:
                    return 0
        """)
        assert got == []


class TestDetlintMechanics:
    def test_syntax_error_is_det000(self):
        got, _ = lint("def f(:\n    pass\n")
        assert rules_of(got) == ["DET000"]

    def test_fingerprint_is_line_number_free(self):
        src_a = "import numpy as np\nVAL = np.random.rand(3)\n"
        src_b = "import numpy as np\n\n\nVAL = np.random.rand(3)\n"
        (fa,), _ = lint_source(src_a, "p.py", DetlintConfig())
        (fb,), _ = lint_source(src_b, "p.py", DetlintConfig())
        assert fa.line != fb.line
        assert fa.fingerprint == fb.fingerprint


class TestBaseline:
    F = Finding("DET001", "a.py", 3, "msg", snippet="np.random.rand(3)")

    def entry(self, f, justification="known"):
        return {"rule": f.rule, "path": f.path, "snippet": f.snippet,
                "justification": justification}

    def test_split_new_accepted_stale(self):
        other = Finding("DET004", "b.py", 9, "msg", snippet="def f(a=[]):")
        base = Baseline([self.entry(self.F), self.entry(other)])
        new, accepted, stale = base.split([self.F])
        assert new == []
        assert accepted == [self.F]
        assert [e["path"] for e in stale] == ["b.py"]

    def test_multiset_matching(self):
        # two identical lines need two entries: one entry covers only one
        base = Baseline([self.entry(self.F)])
        new, accepted, _ = base.split([self.F, self.F])
        assert len(accepted) == 1 and len(new) == 1

    def test_rebuilt_preserves_justification(self, tmp_path):
        base = Baseline([self.entry(self.F, "reviewed 2026-08")])
        rebuilt = base.rebuilt_from([self.F])
        assert rebuilt.entries[0]["justification"] == "reviewed 2026-08"
        p = tmp_path / "baseline.json"
        rebuilt.save(str(p))
        assert Baseline.load(str(p)).entries == rebuilt.entries


# ---------------------------------------------------------------------------
# Layer 2: jaxpr auditor
# ---------------------------------------------------------------------------


def _polluted_score(w, tau):
    # a "scheduling" function with a hidden f32 round-trip: the classic
    # silent-downcast bug the auditor exists to catch
    shifted = w.astype(jnp.float32) / tau.astype(jnp.float32)
    return jnp.exp(shifted.astype(jnp.float64) - 1.0).sum()


def _clean_score(w, tau):
    return jnp.exp(w / tau - 1.0).sum()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _score_spec(fn, name):
    return ArtifactSpec(
        name=name, dtype_contract="float64",
        build=lambda: (fn, (_sds((4, 4), np.float64),
                            _sds((), np.float64)), {}))


class TestJaxprAuditor:
    def test_polluted_artifact_flagged(self):
        findings = audit_artifact(_score_spec(_polluted_score, "polluted"))
        assert "JXP001" in rules_of(findings)
        assert all("polluted" in f.message for f in findings)

    def test_clean_artifact_passes(self):
        assert audit_artifact(_score_spec(_clean_score, "clean")) == []

    def test_debug_callback_flagged(self):
        def chatty(w, tau):
            jax.debug.print("w={w}", w=w.sum())
            return (w / tau).sum()

        findings = audit_artifact(_score_spec(chatty, "chatty"))
        assert "JXP002" in rules_of(findings)

    def test_trace_failure_is_jxp000(self):
        def broken():
            raise RuntimeError("boom")

        spec = ArtifactSpec(name="broken", dtype_contract="float64",
                            build=lambda: (broken, (), {}))
        assert rules_of(audit_artifact(spec)) == ["JXP000"]

    def test_polluted_artifact_fails_suite(self, tmp_path):
        report = run_suite(
            REPO_ROOT, layers=("jaxpr",),
            artifacts=[_score_spec(_polluted_score, "polluted")],
            recompile_guards=[],
            baseline_path=str(tmp_path / "baseline.json"))
        assert report.exit_code == 1
        out = report.format()
        assert "JXP001" in out
        # file:line of the polluted function, repo-relative
        assert "tests/test_analysis.py:" in out


@functools.partial(jax.jit, static_argnums=1)
def _static_tau_score(w, tau):
    return (w / tau).sum()


@jax.jit
def _traced_tau_score(w, tau):
    return (w / tau).sum()


def _sweep_calls(fn_is_static):
    w = jnp.ones((4, 4), jnp.float32)
    taus = (0.02, 0.05, 0.08, 0.12)
    if fn_is_static:
        return [((w, t), {}) for t in taus]
    return [((w, jnp.float32(t)), {}) for t in taus]


class TestRecompileGuards:
    def test_static_argified_sweep_flagged(self):
        guard = RecompileGuard(
            name="static-tau",
            build=lambda: (_static_tau_score, _sweep_calls(True)))
        findings = no_recompile_findings(guard)
        assert rules_of(findings) == ["JXP003"]
        assert "compile cache grew" in findings[0].message

    def test_traced_sweep_clean(self):
        guard = RecompileGuard(
            name="traced-tau",
            build=lambda: (_traced_tau_score, _sweep_calls(False)))
        assert no_recompile_findings(guard) == []

    def test_uninstrumented_target_flagged(self):
        guard = RecompileGuard(
            name="opaque",
            build=lambda: (lambda x: x, [((1,), {}), ((2,), {})]))
        findings = no_recompile_findings(guard)
        assert rules_of(findings) == ["JXP003"]
        assert "no _cache_size" in findings[0].message


# ---------------------------------------------------------------------------
# Layer 3: Pallas kernel auditor
# ---------------------------------------------------------------------------

from jax.experimental import pallas as pl  # noqa: E402
from jax.experimental.pallas import tpu as pltpu  # noqa: E402


def _copy_body(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _toy_kernel(n, bn, *, index_map=None, memory_space=pltpu.VMEM,
                grid=None):
    index_map = index_map or (lambda i: (i,))
    grid = grid or (max(n // bn, 1),)
    kw = {} if memory_space is None else {"memory_space": memory_space}

    def call(x):
        return pl.pallas_call(
            _copy_body,
            grid=grid,
            in_specs=[pl.BlockSpec((bn,), index_map, **kw)],
            out_specs=pl.BlockSpec((bn,), index_map, **kw),
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        )(x)

    def build():
        return call, (jnp.zeros(n, jnp.float32),), {}

    return build


class TestPallasAuditor:
    def test_aligned_kernel_clean(self):
        spec = KernelSpec(name="ok", build=_toy_kernel(8, 4))
        assert audit_kernel(spec) == []

    def test_misaligned_block_flagged(self):
        spec = KernelSpec(name="misaligned", build=_toy_kernel(8, 3))
        assert "PAL001" in rules_of(audit_kernel(spec))

    def test_oob_index_map_flagged(self):
        spec = KernelSpec(
            name="oob",
            build=_toy_kernel(8, 4, index_map=lambda i: (i + 1,)))
        assert "PAL002" in rules_of(audit_kernel(spec))

    def test_missing_memory_space_flagged(self):
        spec = KernelSpec(name="nospace",
                          build=_toy_kernel(8, 4, memory_space=None))
        assert rules_of(audit_kernel(spec)) == ["PAL003"]

    def test_vmem_budget_flagged(self):
        spec = KernelSpec(name="fat", build=_toy_kernel(8, 4),
                          vmem_budget_bytes=16)
        assert rules_of(audit_kernel(spec)) == ["PAL004"]

    def test_dead_wrapper_flagged(self):
        spec = KernelSpec(
            name="dead", build=lambda: (lambda x: x + 1, (jnp.zeros(4),), {}))
        assert rules_of(audit_kernel(spec)) == ["PAL000"]

    def test_capture_records_real_layout(self):
        # the recorder sees the exact grid/specs the wrapper constructs
        fn, args, kwargs = _toy_kernel(8, 4)()
        (call,), = (capture_pallas_calls(fn, *args, **kwargs),)
        assert call.grid == (2,)
        assert call.operands == [((8,), "float32")]

    def test_misaligned_kernel_fails_suite(self, tmp_path):
        report = run_suite(
            REPO_ROOT, layers=("pallas",),
            kernel_specs=[KernelSpec(name="misaligned",
                                     build=_toy_kernel(8, 3))],
            baseline_path=str(tmp_path / "baseline.json"))
        assert report.exit_code == 1
        out = report.format()
        assert "PAL001" in out
        assert "tests/test_analysis.py:" in out


# ---------------------------------------------------------------------------
# End-to-end: the repo itself is clean
# ---------------------------------------------------------------------------


class TestRepoIsClean:
    def test_ast_and_pallas_layers_clean(self):
        # tier-1: the committed tree has no non-baselined findings in the
        # cheap layers (the full three-layer run is the CI lint step)
        report = run_suite(REPO_ROOT, layers=("ast", "pallas"))
        assert report.new == [], report.format()
        assert report.stale_baseline == []
        assert report.files_scanned > 50

    def test_precision_artifacts_clean(self):
        # jaxpr dtype contracts only (recompile guards execute compiled
        # sweeps and stay in the CI lint step / slow lane)
        report = run_suite(REPO_ROOT, layers=("jaxpr",), recompile_guards=[])
        assert report.new == [], report.format()

    @pytest.mark.slow
    def test_full_suite_clean(self):
        report = run_suite(REPO_ROOT)
        assert report.exit_code == 0, report.format()


class TestLintCLI:
    def run_cli(self, *argv):
        env = dict(os.environ, JAX_PLATFORMS="cpu")
        return subprocess.run(
            [sys.executable, LINT_CLI, *argv],
            capture_output=True, text=True, env=env)

    def test_repo_exits_zero(self):
        proc = self.run_cli("--ast-only")
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_seeded_violations_exit_nonzero(self, tmp_path):
        # copy the linted tree, seed one DET001 and one DET005 violation
        root = tmp_path / "repo"
        for sub in ("src", "benchmarks"):
            shutil.copytree(os.path.join(REPO_ROOT, sub), root / sub)
        bad_rng = root / "src" / "repro" / "core" / "flaky.py"
        bad_rng.write_text("import numpy as np\nJITTER = np.random.rand(4)\n")
        urgency = root / "src" / "repro" / "core" / "urgency.py"
        urgency.write_text(
            urgency.read_text()
            + "\n\ndef _downcast(w):\n"
              "    import jax.numpy as jnp\n"
              "    return w.astype(jnp.float32)\n")
        proc = self.run_cli("--ast-only", "--root", str(root))
        assert proc.returncode == 1, proc.stdout + proc.stderr
        assert "src/repro/core/flaky.py:2: DET001" in proc.stdout
        assert "src/repro/core/urgency.py" in proc.stdout
        assert "DET005" in proc.stdout

    def test_update_baseline_then_clean(self, tmp_path):
        root = tmp_path / "repo"
        (root / "src").mkdir(parents=True)
        (root / "src" / "app.py").write_text(
            "import numpy as np\nVAL = np.random.rand(3)\n")
        baseline = str(root / "lint_baseline.json")
        args = ("--ast-only", "--root", str(root), "--baseline", baseline)
        assert self.run_cli(*args).returncode == 1
        assert self.run_cli(*args, "--update-baseline").returncode == 0
        proc = self.run_cli(*args)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        entries = json.load(open(baseline))["findings"]
        assert [e["rule"] for e in entries] == ["DET001"]


# ---------------------------------------------------------------------------
# Satellite: the declared stability-score downcast stays inside its bound
# ---------------------------------------------------------------------------


class TestStabilityDowncastTolerance:
    """kernels/stability_score/ops.py downcasts cand_latency f64->f32; the
    precision manifest declares the path float32 with an rtol bound. This
    pins that bound against the float64 reference at extreme magnitudes."""

    RTOL = next(a.rtol for a in PRECISION_ARTIFACTS
                if a.name == "stability_score.kernel")

    @pytest.mark.parametrize("tau,lat_scale", [
        (1e-3, 1e-6),   # microsecond latencies against a ms deadline
        (1e-3, 5e-3),   # deep saturation: everything rides the clip
        (0.05, 0.02),   # the paper's operating point
        (1e3, 1e2),     # huge magnitudes: f32 mantissa stress
    ])
    def test_kernel_matches_f64_reference(self, tau, lat_scale):
        from repro.core.scoring import NumpyScoringBackend
        from repro.kernels.stability_score.ops import stability_scores

        rng = np.random.default_rng(17)
        m, q, n = 4, 16, 24
        w = np.sort(rng.uniform(0, 2 * tau, (m, q)))[:, ::-1].copy()
        mask = (rng.uniform(size=(m, q)) < 0.8).astype(np.float64)
        lat = rng.uniform(0.1 * lat_scale, lat_scale, n)
        bat = rng.integers(1, q, n)
        cq = rng.integers(0, m, n)

        ref = NumpyScoringBackend().score(w, mask, lat, bat, cq, tau)
        got = np.asarray(stability_scores(
            jnp.asarray(w, jnp.float32), jnp.asarray(mask, jnp.float32),
            jnp.asarray(lat, jnp.float32), jnp.asarray(bat, jnp.int32),
            jnp.asarray(cq, jnp.int32), tau=jnp.float32(tau),
            clip=jnp.float32(10.0), interpret=True))

        denom = np.maximum(np.abs(ref), 1e-30)
        rel = np.max(np.abs(got.astype(np.float64) - ref) / denom)
        assert rel <= self.RTOL, (tau, lat_scale, rel)


# ---------------------------------------------------------------------------
# Satellite: bytes-identical checkpoint manifests
# ---------------------------------------------------------------------------


class TestCheckpointDeterminism:
    def _tree(self):
        rng = np.random.default_rng(5)
        return {"w": rng.normal(size=(4, 3)), "step_count": np.int64(7)}

    def _save(self, root, **kwargs):
        from repro.runtime.checkpoint import Checkpointer

        ckpt = Checkpointer(str(root), async_save=False)
        ckpt.save(3, self._tree(), extra={"lr": 0.1}, **kwargs)
        return os.path.join(str(root), "step_000000003")

    def test_manifest_bytes_identical_across_runs(self, tmp_path):
        d1 = self._save(tmp_path / "a")
        d2 = self._save(tmp_path / "b")
        for name in sorted(os.listdir(d1)):
            with open(os.path.join(d1, name), "rb") as f1, \
                    open(os.path.join(d2, name), "rb") as f2:
                assert f1.read() == f2.read(), name

    def test_timestamp_omitted_by_default(self, tmp_path):
        d = self._save(tmp_path / "a")
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        assert "time" not in manifest

    def test_injected_timestamp_recorded(self, tmp_path):
        d = self._save(tmp_path / "a", timestamp=1722.5)
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        assert manifest["time"] == 1722.5

    def test_round_trip_restores_tree(self, tmp_path):
        from repro.runtime.checkpoint import Checkpointer

        self._save(tmp_path / "a")
        ckpt = Checkpointer(str(tmp_path / "a"), async_save=False)
        step, tree, extra = ckpt.restore(template=self._tree())
        assert step == 3 and extra == {"lr": 0.1}
        np.testing.assert_array_equal(tree["w"], self._tree()["w"])
