"""Model-substrate tests: per-family forward/train correctness, decode ==
full-forward equivalence, early-exit semantics, and abstract init."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    EarlyExitResNet,
    LMConfig,
    ResNetConfig,
    build_model,
    split_params,
)
from repro.models.encdec import EncDecLM


def tiny_cfg(family="dense", **kw):
    base = dict(
        arch_id=f"tiny-{family}", family=family, num_layers=4, d_model=32,
        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=61, exits=(2, 4),
    )
    if family == "moe":
        base.update(num_experts=4, top_k=2, num_shared_experts=1,
                    d_ff_expert=16, dense_prefix=1, moe_group_size=8,
                    moe_capacity_factor=100.0)
    if family == "jamba":
        base.update(num_layers=8, exits=(4, 8), attn_period=4, attn_offset=3,
                    moe_period=2, num_experts=4, top_k=2, d_ff_expert=16,
                    moe_group_size=8, moe_capacity_factor=100.0,
                    mamba_d_state=8, mamba_d_conv=3)
    if family == "rwkv":
        base.update(num_kv_heads=4)
    if family == "encdec":
        base.update(num_kv_heads=4, num_encoder_layers=2, frontend="audio",
                    frontend_seq=5)
    base.update(kw)
    return LMConfig(**base)


def make_batch(cfg, key=0, batch=2, seq=6):
    ks = jax.random.split(jax.random.key(key), 3)
    toks = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        b["src_embeds"] = jax.random.normal(
            ks[1], (batch, cfg.frontend_seq, cfg.d_model))
    return b


FAMILIES = ["dense", "moe", "rwkv", "jamba", "encdec"]


@pytest.mark.parametrize("family", FAMILIES)
class TestFamilies:
    def test_train_loss_finite_and_grads(self, family):
        cfg = tiny_cfg(family)
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        batch = make_batch(cfg)
        loss, metrics = model.train_loss(values, batch)
        assert jnp.isfinite(loss)
        assert "nll_final" in metrics
        g = jax.grad(lambda v: model.train_loss(v, batch)[0])(values)
        norms = [float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g)]
        assert all(np.isfinite(n) for n in norms)
        assert sum(norms) > 0

    def test_forward_exit_shapes(self, family):
        cfg = tiny_cfg(family)
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        batch = make_batch(cfg)
        for e in range(cfg.num_exits):
            logits = model.forward_exit(values, batch, e)
            assert logits.shape == (2, 6, cfg.vocab_size)
            assert bool(jnp.all(jnp.isfinite(logits)))

    def test_decode_matches_full_forward(self, family):
        cfg = tiny_cfg(family)
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(1)))
        batch = make_batch(cfg, key=2)
        toks = batch["tokens"]
        e = cfg.num_exits - 1
        full = model.forward_exit(values, batch, e)
        if family == "encdec":
            cache = model.prepare_decode_cache(
                values, batch["src_embeds"], 2, 10, e)
        else:
            cache = model.init_cache(2, 10, e)
        outs = []
        for i in range(toks.shape[1]):
            lg, cache = model.decode_step(values, toks[:, i:i + 1], cache, e)
            outs.append(lg[:, 0])
        step = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                                   rtol=5e-3, atol=5e-3)

    def test_early_exit_cheaper_than_final(self, family):
        # Early exits must execute strictly fewer layers: check by FLOP count
        # of the jitted computation.
        cfg = tiny_cfg(family)
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        batch = make_batch(cfg)

        def flops(e):
            c = jax.jit(
                lambda v, b: model.forward_exit(v, b, e)
            ).lower(values, batch).compile()
            ca = c.cost_analysis()
            if isinstance(ca, (list, tuple)):  # jax < 0.5 returns [dict]
                ca = ca[0] if ca else {}
            return ca.get("flops", 0.0)

        assert flops(0) < flops(cfg.num_exits - 1)

    def test_abstract_init_no_alloc(self, family):
        cfg = tiny_cfg(family)
        model = build_model(cfg)
        shapes, axes = model.abstract(jax.random.key(0))
        leaves = jax.tree.leaves(shapes)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        axes_leaves = jax.tree.leaves(
            axes, is_leaf=lambda x: isinstance(x, tuple))
        # every param has an axes tuple matching its rank
        flat_shapes = jax.tree.leaves(shapes)
        for s, a in zip(flat_shapes, axes_leaves):
            assert len(a) == len(s.shape), (s.shape, a)

    def test_prefill_logits_match_forward_last_position(self, family):
        cfg = tiny_cfg(family)
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(3)))
        batch = make_batch(cfg, key=4)
        e = 0
        full = model.forward_exit(values, batch, e)
        pre, _ = model.prefill(values, batch, e)
        np.testing.assert_allclose(
            np.asarray(full[:, -1:, :]), np.asarray(pre), rtol=5e-3, atol=5e-3)


class TestMoESpecifics:
    def test_capacity_drops_bounded(self):
        # With capacity factor 1.0 and adversarially identical tokens, drops
        # happen but output stays finite and bounded.
        cfg = tiny_cfg("moe", moe_capacity_factor=1.0)
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        toks = jnp.zeros((2, 6), jnp.int32)  # all tokens identical
        logits = model.forward_exit(values, {"tokens": toks}, 1)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_router_types(self):
        for router in ("softmax", "sigmoid"):
            cfg = tiny_cfg("moe", moe_router=router)
            model = build_model(cfg)
            values, _ = split_params(model.init(jax.random.key(0)))
            loss, _ = model.train_loss(values, make_batch(cfg))
            assert jnp.isfinite(loss)

    def test_moe_aux_loss_positive(self):
        cfg = tiny_cfg("moe")
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        _, metrics = model.train_loss(values, make_batch(cfg))
        assert float(metrics["moe_aux"]) > 0


class TestRWKVSpecifics:
    def test_state_is_o1_in_sequence(self):
        cfg = tiny_cfg("rwkv")
        model = build_model(cfg)
        c_small = model.init_cache(2, 10, 1)
        c_large = model.init_cache(2, 100000, 1)
        sz = lambda c: sum(np.prod(x.shape) for x in jax.tree.leaves(c))
        assert sz(c_small) == sz(c_large)  # no KV growth: attention-free

    def test_decay_in_unit_interval(self):
        from repro.models.rwkv6 import RWKV6Config, init_time_mix
        from repro.models.common import split_params as sp
        cfg = RWKV6Config(d_model=16, num_heads=2, d_ff=32)
        params, _ = sp(init_time_mix(jax.random.key(0), cfg))
        x = jax.random.normal(jax.random.key(1), (1, 4, 16))
        logit = params["decay_base"] + jnp.tanh(
            x @ params["decay_a"]) @ params["decay_b"]
        w = jnp.exp(-jnp.exp(logit))
        assert bool(jnp.all((w > 0) & (w < 1)))


class TestJambaSpecifics:
    def test_exit_alignment_enforced(self):
        with pytest.raises(AssertionError):
            build_model(tiny_cfg("jamba", exits=(3, 8)))

    def test_kv_cache_only_for_attn_sublayers(self):
        cfg = tiny_cfg("jamba")
        model = build_model(cfg)
        cache = model.init_cache(2, 10, 1)
        seg = cache["segments"][0]
        kinds = model._sub_kinds()
        for j, (mixer, _) in enumerate(kinds):
            if mixer == "attn":
                assert "k" in seg[f"sub{j}"]
            else:
                assert "h" in seg[f"sub{j}"]  # mamba state


class TestEncDecSpecifics:
    def test_exits_are_decoder_only(self):
        # encoder always runs fully: exit 0 and exit 1 share encoder cost;
        # difference in FLOPs comes from decoder segments only.
        cfg = tiny_cfg("encdec")
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        batch = make_batch(cfg)
        enc = model.encode(values, batch["src_embeds"])
        assert enc.shape == (2, cfg.frontend_seq, cfg.d_model)


class TestResNet:
    def test_paper_variants_structure(self):
        from repro.models.resnet import STAGE_BLOCKS
        assert STAGE_BLOCKS["resnet50"] == (3, 4, 6, 3)
        assert STAGE_BLOCKS["resnet101"] == (3, 4, 23, 3)
        assert STAGE_BLOCKS["resnet152"] == (3, 8, 36, 3)

    def test_reduced_train_and_exits(self):
        cfg = ResNetConfig(variant="resnet50", num_classes=10,
                           width_multiplier=0.125, blocks_override=(1, 1, 1, 1))
        model = EarlyExitResNet(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        imgs = jax.random.normal(jax.random.key(1), (4, 32, 32, 3))
        lbls = jax.random.randint(jax.random.key(2), (4,), 0, 10)
        loss, metrics = model.train_loss(values, {"images": imgs,
                                                  "labels": lbls})
        assert jnp.isfinite(loss)
        for e in range(4):
            lg = model.forward_exit(values, imgs, e)
            assert lg.shape == (4, 10)

    def test_exit_flops_ordering(self):
        cfg = ResNetConfig(variant="resnet50", num_classes=10,
                           width_multiplier=0.25, blocks_override=(1, 1, 1, 1))
        model = EarlyExitResNet(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        imgs = jnp.zeros((2, 32, 32, 3))

        def flops(e):
            ca = jax.jit(
                lambda v, x: model.forward_exit(v, x, e)
            ).lower(values, imgs).compile().cost_analysis()
            if isinstance(ca, (list, tuple)):  # jax < 0.5 returns [dict]
                ca = ca[0] if ca else {}
            return ca.get("flops", 0.0)

        f = [flops(e) for e in range(4)]
        assert f[0] < f[1] < f[2] < f[3]
