"""Tests for joint (model, exit, batch) candidate-lattice scheduling: the
LatticeEdgeServingScheduler, the lattice layout of the stability-score
kernel, and the batch-saturation profile view that motivates them."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    EdgeServingScheduler,
    LatticeEdgeServingScheduler,
    ProfileTable,
    QueueSnapshot,
    SchedulerConfig,
    VectorizedEdgeServingScheduler,
    make_scheduler,
    run_experiment,
)
from repro.kernels.stability_score.ops import stability_scores
from repro.kernels.stability_score.ref import lattice_stability_scores_ref


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080()


def snap(waits_per_model, now=0.0):
    return QueueSnapshot(
        now, [np.asarray(w, dtype=np.float64) for w in waits_per_model])


def random_snapshot(rng, m_count=3, max_wait=0.08, max_len=12):
    return snap([
        np.sort(rng.uniform(0, max_wait, size=rng.integers(0, max_len)))[::-1]
        for _ in range(m_count)
    ])


class TestBatchLadder:
    def test_greedy_single_rung(self, table):
        s = EdgeServingScheduler(table, SchedulerConfig(max_batch=10))
        assert s.batch_candidates(3) == (3,)
        assert s.batch_candidates(37) == (10,)
        assert s.batch_candidates(0) == ()

    def test_geometric_ladder(self, table):
        s = LatticeEdgeServingScheduler(
            table, SchedulerConfig(max_batch=10, lattice=True))
        assert s.batch_candidates(10) == (10, 8, 4, 2, 1)
        assert s.batch_candidates(37) == (10, 8, 4, 2, 1)
        assert s.batch_candidates(3) == (3, 2, 1)
        assert s.batch_candidates(1) == (1,)

    def test_explicit_ladder_clipped_to_cap(self, table):
        cfg = SchedulerConfig(max_batch=10, lattice=True, batch_ladder=(4, 10))
        s = LatticeEdgeServingScheduler(table, cfg)
        assert s.batch_candidates(10) == (10, 4)
        assert s.batch_candidates(6) == (6, 4)   # cap always included
        assert s.batch_candidates(2) == (2,)

    def test_make_scheduler_lattice_switch(self, table):
        cfg = SchedulerConfig(lattice=True)
        assert isinstance(
            make_scheduler("edgeserving", table, cfg),
            LatticeEdgeServingScheduler)
        # baselines are never upgraded by the switch
        from repro.core import AllFinalScheduler
        assert isinstance(
            make_scheduler("all-final", table, cfg), AllFinalScheduler)
        # the named policy forces the switch on even with a default config
        s = make_scheduler("edgeserving-lattice", table, SchedulerConfig())
        assert s.config.lattice


class TestGreedyEquivalence:
    """With the lattice restricted to the single Eq. 5 rung, the lattice
    scheduler must return bitwise-identical Decisions to the vectorised
    greedy on any snapshot."""

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=40, deadline=None)
    def test_restricted_lattice_bitwise_identical(self, table, seed):
        rng = np.random.default_rng(seed)
        waits = [
            np.sort(rng.uniform(0, 0.08, size=rng.integers(0, 12)))[::-1]
            for _ in range(3)
        ]
        cfg = SchedulerConfig(slo=0.050)
        restricted = SchedulerConfig(
            slo=0.050, lattice=True, batch_ladder=(cfg.max_batch,))
        d_vec = VectorizedEdgeServingScheduler(table, cfg).decide(snap(waits))
        d_lat = LatticeEdgeServingScheduler(table, restricted).decide(
            snap(waits))
        if d_vec is None:
            assert d_lat is None
        else:
            assert (d_vec.model, d_vec.exit_idx, d_vec.batch_size) == (
                d_lat.model, d_lat.exit_idx, d_lat.batch_size)
            # bitwise: same float ops in the same order on both paths
            assert d_vec.stability_score == d_lat.stability_score
            assert d_vec.predicted_latency == d_lat.predicted_latency

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=30, deadline=None)
    def test_full_lattice_never_scores_worse(self, table, seed):
        # The greedy candidate is a lattice point, so the lattice argmin's
        # predicted score is <= the greedy decision's.
        rng = np.random.default_rng(seed)
        s1, s2 = (random_snapshot(np.random.default_rng(seed)) for _ in "ab")
        cfg = SchedulerConfig(slo=0.050)
        d_vec = VectorizedEdgeServingScheduler(table, cfg).decide(s1)
        d_lat = LatticeEdgeServingScheduler(
            table, dataclasses.replace(cfg, lattice=True)).decide(s2)
        if d_vec is not None:
            assert d_lat.stability_score <= d_vec.stability_score + 1e-12


class TestLatticeDecisions:
    def test_candidates_cover_ladder_with_eq6_exits(self, table):
        cfg = SchedulerConfig(slo=0.050, lattice=True)
        s = LatticeEdgeServingScheduler(table, cfg)
        snapshot = snap([[0.03, 0.02, 0.01, 0.005], [], [0.045]])
        cq, cb, ce, cl, cw = s.enumerate_candidates(snapshot)
        # queue 0: ladder (4, 2, 1); queue 2: ladder (1,)
        assert list(cq) == [0, 0, 0, 2]
        assert list(cb) == [4, 2, 1, 1]
        for q, b, e, lat, wm in zip(cq, cb, ce, cl, cw):
            exp_e, exp_lat = s.select_exit(int(q), float(wm), int(b))
            assert (e, lat) == (exp_e, exp_lat)
            assert lat == table(int(q), int(e), int(b))

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=30, deadline=None)
    def test_decisions_well_formed(self, table, seed):
        rng = np.random.default_rng(seed)
        waits = [
            np.sort(rng.uniform(0, 0.1, size=rng.integers(0, 15)))[::-1]
            for _ in range(3)
        ]
        s = LatticeEdgeServingScheduler(
            table, SchedulerConfig(slo=0.05, lattice=True))
        d = s.decide(snap(waits))
        if all(len(w) == 0 for w in waits):
            assert d is None
        else:
            assert len(waits[d.model]) > 0
            assert d.batch_size in s.batch_candidates(len(waits[d.model]))
            assert d.predicted_latency == pytest.approx(
                table(d.model, d.exit_idx, d.batch_size))

    def test_empty_queues_return_none(self, table):
        s = LatticeEdgeServingScheduler(
            table, SchedulerConfig(lattice=True))
        assert s.decide(snap([[], [], []])) is None


class TestLatticeKernel:
    """stability_scores with a flattened [N] candidate lattice and a
    candidate->queue index map (the tentpole kernel extension)."""

    @pytest.mark.parametrize("m,q,n,bm", [(3, 16, 13, 8), (5, 33, 21, 4),
                                          (8, 64, 8, 8), (4, 24, 40, 16)])
    def test_allclose_sweep(self, m, q, n, bm):
        rng = np.random.default_rng(m * 1000 + n)
        w = jnp.asarray(np.sort(rng.uniform(0, 0.1, (m, q)))[:, ::-1].copy(),
                        jnp.float32)
        mask = jnp.asarray((rng.uniform(size=(m, q)) > 0.3), jnp.float32)
        lat = jnp.asarray(rng.uniform(1e-3, 2e-2, n), jnp.float32)
        bat = jnp.asarray(rng.integers(1, q + 1, n), jnp.int32)
        cq = jnp.asarray(rng.integers(0, m, n), jnp.int32)
        out = stability_scores(w, mask, lat, bat, cq, tau=0.05, block_m=bm,
                               interpret=True)
        ref = lattice_stability_scores_ref(w, mask, lat, bat, cq, 0.05, 10.0)
        assert out.shape == (n,)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5)

    def test_greedy_layout_is_arange_lattice(self):
        # cand_queue=None must equal the explicit arange map (back-compat).
        rng = np.random.default_rng(0)
        m, q = 4, 16
        w = jnp.asarray(np.sort(rng.uniform(0, 0.1, (m, q)))[:, ::-1].copy(),
                        jnp.float32)
        mask = jnp.ones((m, q), jnp.float32)
        lat = jnp.asarray(rng.uniform(1e-3, 2e-2, m), jnp.float32)
        bat = jnp.asarray(rng.integers(1, 5, m), jnp.int32)
        implicit = stability_scores(w, mask, lat, bat, tau=0.05,
                                    interpret=True)
        explicit = stability_scores(w, mask, lat, bat,
                                    jnp.arange(m, dtype=jnp.int32),
                                    tau=0.05, interpret=True)
        np.testing.assert_allclose(np.asarray(implicit), np.asarray(explicit),
                                   rtol=1e-6)

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_property_matches_ref(self, seed):
        rng = np.random.default_rng(seed)
        m = int(rng.integers(2, 6))
        q = int(rng.integers(4, 24))
        n = int(rng.integers(1, 4 * m))
        w = jnp.asarray(np.sort(rng.uniform(0, 0.2, (m, q)))[:, ::-1].copy(),
                        jnp.float32)
        mask = jnp.asarray((rng.uniform(size=(m, q)) > 0.2), jnp.float32)
        lat = jnp.asarray(rng.uniform(1e-3, 3e-2, n), jnp.float32)
        bat = jnp.asarray(rng.integers(1, q + 1, n), jnp.int32)
        cq = jnp.asarray(rng.integers(0, m, n), jnp.int32)
        out = stability_scores(w, mask, lat, bat, cq, tau=0.05,
                               interpret=True)
        ref = lattice_stability_scores_ref(w, mask, lat, bat, cq, 0.05, 10.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4)


class TestBatchSaturation:
    def test_below_knee_unchanged(self, table):
        sat = table.with_batch_saturation(4)
        np.testing.assert_allclose(sat.latency[:, :, :4],
                                   table.latency[:, :, :4])
        assert np.all(np.diff(sat.latency, axis=2) >= -1e-12)

    def test_noncontiguous_batch_grid_indexed_by_value(self):
        # knee/base columns must be found by batch-size value, not position
        t = ProfileTable(
            model_names=("m",), exit_names=("e",), batch_sizes=(1, 2, 4, 8),
            latency=np.array([[[1.0, 1.5, 2.5, 4.0]]]),
            accuracy=np.array([[0.9]]),
        )
        sat = t.with_batch_saturation(4, slope=1.0)
        # base = L(B=4) = 2.5, per-item = L(1); batch 8 pays 4 extra items
        assert sat.latency[0, 0, 3] == pytest.approx(2.5 + 4 * 1.0)
        np.testing.assert_allclose(sat.latency[0, 0, :3], t.latency[0, 0, :3])

    def test_past_knee_costs_per_item(self, table):
        sat = table.with_batch_saturation(4, slope=0.85)
        # marginal cost of item knee+1 is ~slope * batch-1 latency: much
        # steeper than the sub-saturation curve's L1/6 per item
        marginal = sat.latency[:, :, 4] - sat.latency[:, :, 3]
        np.testing.assert_allclose(marginal, 0.85 * table.latency[:, :, 0])

    def test_lattice_beats_or_matches_greedy_on_saturated_profile(self, table):
        # Acceptance (fig12 in miniature): mean SLO-violation over a load
        # sweep x seeds; single (load, seed) points can go either way under
        # one-step-greedy myopia, the sweep mean must not.
        sat = table.with_batch_saturation(4)
        tot = {"edgeserving": 0.0, "edgeserving-lattice": 0.0}
        for name in tot:
            sched = make_scheduler(name, sat, SchedulerConfig(slo=0.050))
            for seed in (0, 7):
                for lam in (100, 180, 220):
                    res = run_experiment(sched, sat,
                                         [3 * lam, 2 * lam, lam],
                                         horizon=5.0, seed=seed)
                    tot[name] += res.metrics.violation_ratio
        assert tot["edgeserving-lattice"] <= tot["edgeserving"] + 1e-9


class TestRuntimeThreading:
    def test_policy_aware_backlog_matches_default_for_paper_policy(self, table):
        from repro.runtime.router import ReplicaRouter
        s = EdgeServingScheduler(table, SchedulerConfig(max_batch=10))
        qlens = [23, 0, 7]
        assert ReplicaRouter.backlog_from_scheduler(s, qlens) == pytest.approx(
            ReplicaRouter.backlog_from_queues(table, qlens, max_batch=10))

    def test_policy_aware_backlog_respects_small_max_batch(self, table):
        from repro.core import NoBatchingScheduler
        from repro.runtime.router import ReplicaRouter
        s = NoBatchingScheduler(table, SchedulerConfig(max_batch=10))
        qlens = [5, 0, 0]
        # bs=1 ablation drains one request per quantum
        assert ReplicaRouter.backlog_from_scheduler(s, qlens) == pytest.approx(
            5 * table(0, table.num_exits - 1, 1))

    def test_warmup_reachable_batch_set(self, table):
        # greedy and lattice policies can both dispatch any B in 1..B_max
        # (short queues), which is what the engine's default warmup covers.
        for cfg in (SchedulerConfig(max_batch=10),
                    SchedulerConfig(max_batch=10, lattice=True)):
            s = make_scheduler("edgeserving", table, cfg)
            reach = set()
            for qlen in range(1, s.config.max_batch + 1):
                reach.update(s.batch_candidates(qlen))
            assert reach == set(range(1, 11))


class TestPaddedSnapshotReuse:
    def test_default_padded_view_is_cached(self):
        s = snap([[0.02, 0.01], [0.03]])
        w1, m1 = s.padded()
        w2, m2 = s.padded()
        assert w1 is w2 and m1 is m2
        # explicit shapes/dtypes bypass the cache
        w3, _ = s.padded(max_q=8)
        assert w3.shape == (2, 8)
        w4, _ = s.padded(dtype=np.float32)
        assert w4.dtype == np.float32
