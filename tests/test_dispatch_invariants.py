"""Dispatcher invariants, independent of serving engine.

The bitwise conformance suites pin the compiled engines to the Python
loops; this suite pins what the *policies themselves* must do regardless
of which engine runs them: JSQ never routes past a strictly
shorter-loaded candidate, round-robin is arrival-order periodic,
per-device request accounting sums to fleet totals on both engines, and
failover re-dispatches its orphans in (arrival, req_id) order.

The policy-level properties run against a synthetic
:class:`~repro.core.DeviceLoadView` (hypothesis-generated load vectors),
so they hold for any engine that feeds dispatchers honest views — the
engines' own views are covered by the conformance suites.
"""

from __future__ import annotations

import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ClusterSimulator,
    DeviceLoadView,
    Request,
    SchedulerConfig,
    make_dispatcher,
    make_fleet,
    paper_rate_vector,
    poisson_arrivals,
    ProfileTable,
)
from repro.core.clusterfast import simulate_cluster_scan
from engine_conformance import run_both_cluster


class _FakeView(DeviceLoadView):
    """A fleet reduced to the numbers dispatchers may observe."""

    def __init__(self, queued, backlog=None, alive=None):
        self._queued = list(queued)
        self._backlog = list(backlog or [float(q) for q in queued])
        self._alive = list(alive or [True] * len(self._queued))

    def healthy(self, d):
        return self._alive[d]

    def total_queued(self, d):
        return self._queued[d]

    def effective_backlog(self, d):
        return self._backlog[d]

    def predicted_completion(self, d, model):
        return self._backlog[d] + 0.010


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080().with_batch_saturation(4)


class TestPolicyProperties:
    @given(seed=st.integers(0, 10**6), g=st.integers(2, 8))
    @settings(max_examples=50, deadline=None)
    def test_jsq_never_skips_a_strictly_shorter_queue(self, seed, g):
        rng = random.Random(seed)
        queued = [rng.randint(0, 50) for _ in range(g)]
        eligible = sorted(rng.sample(range(g), rng.randint(1, g)))
        pick = make_dispatcher("jsq").pick(0, eligible, _FakeView(queued))
        assert pick in eligible
        assert all(queued[pick] <= queued[d] for d in eligible)

    @given(seed=st.integers(0, 10**6), g=st.integers(2, 8))
    @settings(max_examples=50, deadline=None)
    def test_least_loaded_never_skips_a_lighter_backlog(self, seed, g):
        rng = random.Random(seed)
        backlog = [rng.uniform(0.0, 10.0) for _ in range(g)]
        eligible = sorted(rng.sample(range(g), rng.randint(1, g)))
        view = _FakeView([0] * g, backlog=backlog)
        pick = make_dispatcher("least-loaded").pick(0, eligible, view)
        assert pick in eligible
        assert all(backlog[pick] <= backlog[d] for d in eligible)

    @given(
        g=st.integers(1, 6),
        n=st.integers(1, 40),
    )
    @settings(max_examples=30, deadline=None)
    def test_round_robin_is_arrival_order_periodic(self, g, n):
        disp = make_dispatcher("round-robin")
        disp.reset()
        view = _FakeView([0] * g)
        eligible = list(range(g))
        picks = [disp.pick(0, eligible, view) for _ in range(n)]
        assert picks == [i % g for i in range(n)]

    def test_stability_aware_full_scan_tracks_predicted_completion(self):
        view = _FakeView([0, 0, 0], backlog=[3.0, 0.5, 2.0])
        disp = make_dispatcher("stability-aware", power_d=3)
        disp.reset(seed=0)
        assert disp.pick(0, [0, 1, 2], view) == 1


class TestEngineAccounting:
    @given(
        seed=st.integers(0, 9999),
        dispatcher=st.sampled_from(
            ("round-robin", "jsq", "least-loaded")),
    )
    @settings(max_examples=6, deadline=None)
    def test_per_device_counts_sum_to_fleet_totals(self, table, seed,
                                                   dispatcher):
        arrivals = poisson_arrivals(paper_rate_vector(100.0), 1.5, seed=seed)
        py, sc = run_both_cluster(
            make_fleet("homogeneous", 3, table), arrivals, 1.5,
            dispatcher=dispatcher)
        for res in (py, sc):
            per = res.metrics.per_device
            # full placement, no failures: every arrival routed exactly once
            assert sum(d.dispatched for d in per) == len(arrivals)
            assert (sum(d.num_completed for d in per)
                    == res.metrics.num_completed)
            assert sum(d.dropped for d in per) == res.metrics.dropped
        assert py.dispatch_counts == sc.dispatch_counts

    def test_failover_redispatch_counts_against_survivor(self, table):
        arrivals = poisson_arrivals(paper_rate_vector(120.0), 2.0, seed=4)
        py, sc = run_both_cluster(
            make_fleet("homogeneous", 2, table, fail_at=((0, 1.0),)),
            arrivals, 2.0, dispatcher="least-loaded")
        for res in (py, sc):
            per = res.metrics.per_device
            # orphans re-dispatched to the survivor count twice (once per
            # routing), so totals exceed raw arrivals by the failover volume
            assert sum(d.dispatched for d in per) >= len(arrivals)
            assert (len(res.completions) + res.metrics.residual_queue
                    + res.metrics.dropped) == len(arrivals)
        assert py.dispatch_counts == sc.dispatch_counts


class TestFailoverOrder:
    def test_orphans_redispatch_in_arrival_then_req_id_order(self, table):
        """White-box: preload the doomed device's queues with shuffled
        arrival times and ids, kill it, and read the re-dispatch order off
        a round-robin dispatcher (pick k lands on survivor k mod G)."""
        sim = ClusterSimulator(
            make_fleet("homogeneous", 4, table),
            config=SchedulerConfig(slo=0.05),
            dispatcher=make_dispatcher("round-robin"),
        )
        sim.run([], 0.0)  # initialise per-run device state
        doomed = sim._devs[0]
        reqs = [
            Request(req_id=i, model=i % 3, arrival=a, data_id=0)
            for i, a in [(5, 0.3), (2, 0.1), (9, 0.1), (1, 0.7), (7, 0.2)]
        ]
        for r in reqs:
            doomed.queues[r.model].push(r)
        sim.dispatcher.reset()
        stranded = sim._fail(0, 1.0)
        assert stranded == 0
        expected = sorted(reqs, key=lambda r: (r.arrival, r.req_id))
        # round-robin over the 3 survivors: k-th re-dispatch -> survivor
        # [1, 2, 3][k % 3]; read each survivor's queues back in FIFO order
        landed = {1: [], 2: [], 3: []}
        for d in (1, 2, 3):
            for q in sim._devs[d].queues:
                landed[d].extend(q.pop_batch(len(q)))
        for k, r in enumerate(expected):
            assert r in landed[(1, 2, 3)[k % 3]], (
                f"re-dispatch {k} ({r.req_id}) landed out of "
                f"(arrival, req_id) order")


class TestCompiledFailoverOrder:
    def test_scan_engine_preserves_redispatch_order(self, table):
        """The compiled engine's host-side failover must replay the same
        (arrival, req_id) orphan order; with round-robin routing any
        reordering changes dispatch counts and metrics."""
        arrivals = poisson_arrivals(paper_rate_vector(140.0), 2.0, seed=8)
        py, sc = run_both_cluster(
            make_fleet("homogeneous", 3, table, fail_at=((1, 0.9),)),
            arrivals, 2.0, dispatcher="round-robin")
        assert py.dispatch_counts == sc.dispatch_counts
        assert py.metrics == sc.metrics
