"""Test-suite bootstrap: ``slow`` marker gating and a deterministic
fallback for ``hypothesis``.

``@pytest.mark.slow`` marks scaling checks (e.g. the 10^6-request scan run
in ``tests/test_simfast.py``) that belong in the dedicated CI smoke step,
not the tier-1 suite. They are skipped unless ``REPRO_RUN_SLOW`` is set.

Seven test modules use hypothesis property checks. On a fresh checkout
without dev dependencies (``pip install -r requirements-dev.txt``) the
import used to fail at collection and take the whole tier-1 suite down.
Instead of skipping those modules wholesale, this conftest registers a
minimal, deterministic stand-in that supports exactly the API surface the
suite uses (``given``, ``settings(max_examples=..., deadline=...)`` and the
``integers`` / ``floats`` / ``sampled_from`` strategies): each property
test then runs ``max_examples`` seeded-random examples, with the strategy
bounds exercised on the first draws.

With the real hypothesis installed (CI does), this file is a no-op and the
full shrinking/coverage machinery is used.
"""

from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types
import zlib

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: scaling checks run by the CI smoke step (REPRO_RUN_SLOW=1), "
        "skipped in tier-1",
    )


def pytest_collection_modifyitems(config, items):
    if os.environ.get("REPRO_RUN_SLOW") or os.environ.get(
            "REPRO_SIMFAST_SMOKE"):
        return
    skip = pytest.mark.skip(reason="slow: set REPRO_RUN_SLOW=1 to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


try:  # real hypothesis wins whenever it is available
    import hypothesis  # noqa: F401
except ModuleNotFoundError:

    class _Strategy:
        """A draw function plus the boundary examples to try first."""

        def __init__(self, draw, corners=()):
            self._draw = draw
            self.corners = tuple(corners)

        def example(self, rng: random.Random, index: int):
            if index < len(self.corners):
                return self.corners[index]
            return self._draw(rng)

    def _integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: rng.randint(min_value, max_value),
            corners=(min_value, max_value),
        )

    def _floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> _Strategy:
        return _Strategy(
            lambda rng: rng.uniform(min_value, max_value),
            corners=(min_value, max_value),
        )

    def _sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def _settings(max_examples: int = 25, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def _given(**strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            kept = [p for n, p in sig.parameters.items() if n not in strategies]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_fallback_max_examples",
                            getattr(fn, "_fallback_max_examples", 25))
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    drawn = {k: s.example(rng, i) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # pytest must see only the fixture params, not the drawn ones
            wrapper.__signature__ = sig.replace(parameters=kept)
            return wrapper

        return deco

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_fallback__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
