"""§Perf optimization equivalence tests: every beyond-paper optimization
must be numerically faithful to its baseline (same math, better layout)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import build_model, split_params
from repro.models.rwkv6 import _wkv_chunked, _wkv_scan
from repro.models.transformer import LMConfig


def mla_cfg(**kw):
    base = dict(
        arch_id="t", family="moe", num_layers=4, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=53, exits=(2, 4), num_experts=8,
        top_k=2, num_shared_experts=1, d_ff_expert=16, dense_prefix=1,
        mla=True, q_lora_rank=24, kv_lora_rank=16, qk_nope_head_dim=8,
        qk_rope_head_dim=4, v_head_dim=8, moe_group_size=8,
        moe_capacity_factor=100.0,
    )
    base.update(kw)
    return LMConfig(**base)


class TestAbsorbedMLA:
    def test_decode_equivalence(self):
        cfg = mla_cfg()
        m1 = build_model(cfg)
        values, _ = split_params(m1.init(jax.random.key(0)))
        m2 = build_model(dataclasses.replace(cfg, mla_absorbed_decode=True))
        toks = jax.random.randint(jax.random.key(1), (2, 6), 0, 53)
        c1, c2 = m1.init_cache(2, 8, 1), m2.init_cache(2, 8, 1)
        for i in range(6):
            lg1, c1 = m1.decode_step(values, toks[:, i:i + 1], c1, 1)
            lg2, c2 = m2.decode_step(values, toks[:, i:i + 1], c2, 1)
        np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                                   rtol=2e-4, atol=2e-4)

    def test_absorbed_matches_full_forward(self):
        cfg = mla_cfg(mla_absorbed_decode=True)
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(2)))
        toks = jax.random.randint(jax.random.key(3), (1, 5), 0, 53)
        full = model.forward_exit(values, {"tokens": toks}, 1)
        c = model.init_cache(1, 8, 1)
        outs = []
        for i in range(5):
            lg, c = model.decode_step(values, toks[:, i:i + 1], c, 1)
            outs.append(lg[:, 0])
        np.testing.assert_allclose(
            np.asarray(full), np.asarray(jnp.stack(outs, 1)),
            rtol=5e-3, atol=5e-3)


class TestChunkedWKV:
    @pytest.mark.parametrize("chunk", [8, 16, 32])
    def test_matches_scan(self, chunk):
        ks = jax.random.split(jax.random.key(4), 5)
        B, S, H, N = 2, 64, 4, 8
        r = jax.random.normal(ks[0], (B, S, H, N))
        k = jax.random.normal(ks[1], (B, S, H, N))
        v = jax.random.normal(ks[2], (B, S, H, N))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, N))) * 0.8 + 0.1
        u = jax.random.normal(ks[4], (H, N)) * 0.1
        o1, s1 = _wkv_scan(r, k, v, w, u, None)
        o2, s2 = _wkv_chunked(r, k, v, w, u, None, chunk)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=2e-4, atol=2e-4)

    def test_with_carry_state(self):
        ks = jax.random.split(jax.random.key(5), 6)
        B, S, H, N = 1, 32, 2, 4
        args = [jax.random.normal(ks[i], (B, S, H, N)) for i in range(3)]
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, N))) * 0.7 + 0.2
        u = jax.random.normal(ks[4], (H, N)) * 0.1
        s0 = jax.random.normal(ks[5], (B, H, N, N)) * 0.3
        o1, s1 = _wkv_scan(*args, w, u, s0)
        o2, s2 = _wkv_chunked(*args, w, u, s0, 8)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-4, atol=2e-4)

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_property_strong_decay_stable(self, seed):
        # even with strong decay (w -> 0), the chunked form stays finite
        # and matches the scan (log-space clamp at -60).
        rng = np.random.default_rng(seed)
        B, S, H, N = 1, 32, 2, 4
        r = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
        w = jnp.asarray(rng.uniform(0.05, 0.99, size=(B, S, H, N)),
                        jnp.float32)
        u = jnp.zeros((H, N), jnp.float32)
        o1, _ = _wkv_scan(r, k, v, w, u, None)
        o2, _ = _wkv_chunked(r, k, v, w, u, None, 16)
        assert bool(jnp.all(jnp.isfinite(o2)))
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=5e-3, atol=5e-3)

    def test_rwkv_model_end_to_end_with_chunking(self):
        cfg = LMConfig(arch_id="rc", family="rwkv", num_layers=2, d_model=32,
                       num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=61,
                       exits=(2,), rwkv_chunk=8)
        cfg0 = dataclasses.replace(cfg, rwkv_chunk=0)
        m1, m0 = build_model(cfg), build_model(cfg0)
        values, _ = split_params(m0.init(jax.random.key(6)))
        toks = jax.random.randint(jax.random.key(7), (2, 16), 0, 61)
        l1 = m1.forward_exit(values, {"tokens": toks}, 0)
        l0 = m0.forward_exit(values, {"tokens": toks}, 0)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l0),
                                   rtol=2e-3, atol=2e-3)


class TestVocabPadding:
    def test_padded_head_masks_tail(self):
        cfg = LMConfig(arch_id="p", family="dense", num_layers=2, d_model=16,
                       num_heads=2, num_kv_heads=1, d_ff=32, vocab_size=41,
                       exits=(2,), vocab_pad_multiple=16)
        assert cfg.vocab_padded == 48
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(8)))
        assert values["embed"].shape == (48, 16)
        toks = jax.random.randint(jax.random.key(9), (2, 6), 0, 41)
        logits = model.forward_exit(values, {"tokens": toks}, 0)
        assert logits.shape[-1] == 48
        assert bool(jnp.all(logits[..., 41:] < -1e29))
        loss, _ = model.train_loss(values, {"tokens": toks, "labels": toks})
        assert bool(jnp.isfinite(loss))

    def test_padding_loss_equals_unpadded_semantics(self):
        # CE over masked padded logits == CE over unpadded logits for the
        # same parameters (pad rows zero-initialised are never gold labels
        # and -inf masked from the partition function).
        cfg0 = LMConfig(arch_id="p0", family="dense", num_layers=1,
                        d_model=16, num_heads=2, num_kv_heads=1, d_ff=32,
                        vocab_size=41, exits=(1,))
        cfgp = dataclasses.replace(cfg0, vocab_pad_multiple=16)
        m0, mp = build_model(cfg0), build_model(cfgp)
        v0, _ = split_params(m0.init(jax.random.key(10)))
        vp = jax.tree.map(lambda x: x, v0)
        vp["embed"] = jnp.pad(v0["embed"], ((0, 7), (0, 0)))
        vp["lm_head"] = jnp.pad(v0["lm_head"], ((0, 0), (0, 7)))
        toks = jax.random.randint(jax.random.key(11), (2, 5), 0, 41)
        batch = {"tokens": toks, "labels": toks}
        l0, _ = m0.train_loss(v0, batch)
        lp, _ = mp.train_loss(vp, batch)
        np.testing.assert_allclose(float(l0), float(lp), rtol=1e-5)
