"""Tests for the multi-replica traffic router (the serving `pod` axis)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ProfileTable, SchedulerConfig, make_scheduler
from repro.core.cluster import (
    JoinShortestQueueDispatcher,
    LeastLoadedDispatcher,
    RoundRobinDispatcher,
    StabilityAwareDispatcher,
)
from repro.runtime.fault_tolerance import StragglerPolicy
from repro.runtime.router import ReplicaRouter


class TestRouting:
    def test_least_loaded_wins(self):
        r = ReplicaRouter(3)
        r.update_backlog(0, 0.5)
        r.update_backlog(1, 0.1)
        r.update_backlog(2, 0.3)
        assert r.route() == 1

    def test_straggler_scales_load(self):
        # equal backlog, but replica 0 runs 4x slow -> route elsewhere
        r = ReplicaRouter(2, straggler=StragglerPolicy(2, alpha=1.0))
        r.update_backlog(0, 0.2)
        r.update_backlog(1, 0.2)
        r.observe_quantum(0, observed_s=0.4, expected_s=0.1)
        assert r.route() == 1

    def test_detached_replica_gets_nothing(self):
        r = ReplicaRouter(2, straggler=StragglerPolicy(2, alpha=1.0))
        r.update_backlog(0, 0.0)   # idle but 10x slow -> detached
        r.update_backlog(1, 5.0)
        r.observe_quantum(0, observed_s=1.0, expected_s=0.1)
        assert r.route() == 1

    def test_all_failed_degrades_gracefully(self):
        r = ReplicaRouter(2, straggler=StragglerPolicy(2, alpha=1.0))
        for i in range(2):
            r.observe_quantum(i, observed_s=1.0, expected_s=0.1)
        assert r.route() in (0, 1)  # still routes somewhere

    def test_sticky_key_prefers_home(self):
        r = ReplicaRouter(4)
        for i in range(4):
            r.update_backlog(i, 0.1)
        homes = {r.route(key=f"session-{k}") for k in range(64)}
        assert len(homes) > 1  # rendezvous spreads sessions
        # deterministic stickiness
        assert r.route(key="session-1") == r.route(key="session-1")

    def test_sticky_key_spills_under_overload(self):
        r = ReplicaRouter(2, spill_factor=2.0)
        home = ReplicaRouter(2).route(key="s")  # same hash, same home
        r.update_backlog(home, 10.0)
        r.update_backlog(1 - home, 0.1)
        assert r.route(key="s") == 1 - home

    def test_route_batch_spreads_burst(self):
        r = ReplicaRouter(4)
        for i in range(4):
            r.update_backlog(i, 0.0)
        picks = r.route_batch(400)
        counts = np.bincount(picks, minlength=4)
        assert counts.min() > 50  # no replica starved, no dogpile

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=25, deadline=None)
    def test_property_routes_only_healthy(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 6))
        r = ReplicaRouter(n, straggler=StragglerPolicy(n, alpha=1.0))
        for i in range(n):
            r.update_backlog(i, float(rng.uniform(0, 1)))
        bad = int(rng.integers(0, n))
        r.observe_quantum(bad, observed_s=1.0, expected_s=0.05)
        if len(r.straggler.healthy()) > 0:
            for _ in range(10):
                assert r.route() != bad


class TestSharedDispatchers:
    """The router routes through the repro.core.cluster dispatcher family;
    these tests drive each policy through the router's DeviceLoadView."""

    def test_default_dispatcher_is_least_loaded(self):
        r = ReplicaRouter(2)
        assert isinstance(r.dispatcher, LeastLoadedDispatcher)

    def test_round_robin_cycles_healthy(self):
        r = ReplicaRouter(3, dispatcher=RoundRobinDispatcher())
        assert [r.route() for _ in range(4)] == [0, 1, 2, 0]

    def test_round_robin_skips_unhealthy(self):
        r = ReplicaRouter(3, straggler=StragglerPolicy(3, alpha=1.0),
                          dispatcher=RoundRobinDispatcher())
        r.observe_quantum(1, observed_s=1.0, expected_s=0.1)  # detach 1
        assert set(r.route() for _ in range(6)) == {0, 2}

    def test_jsq_uses_reported_queue_lengths(self):
        r = ReplicaRouter(3, dispatcher=JoinShortestQueueDispatcher())
        r.update_backlog(0, 0.0, qlens=[5, 5])   # short drain, long queue
        r.update_backlog(1, 9.0, qlens=[1, 0])
        r.update_backlog(2, 9.0, qlens=[2, 2])
        assert r.route() == 1

    def test_jsq_route_batch_spreads_burst(self):
        # the greedy in-flight estimate (pending) must be visible to JSQ,
        # or a burst between replica reports dogpiles one replica.
        r = ReplicaRouter(2, dispatcher=JoinShortestQueueDispatcher())
        r.update_backlog(0, 0.0, qlens=[1])
        r.update_backlog(1, 0.0, qlens=[2])
        picks = np.bincount(r.route_batch(10), minlength=2)
        assert picks.min() >= 4
        # a fresh report supersedes the in-flight estimate
        r.update_backlog(0, 0.0, qlens=[0])
        assert r.total_queued(0) == 0

    def test_keyed_requests_do_not_consume_dispatcher_state(self):
        r = ReplicaRouter(2, dispatcher=RoundRobinDispatcher())
        # keyed lookups stick to their rendezvous home without advancing
        # the round-robin counter...
        unkeyed = [r.route(), r.route(key="s"), r.route(key="s"), r.route()]
        # ...so unkeyed traffic still alternates 0, 1, 0, 1, ...
        assert (unkeyed[0], unkeyed[3]) == (0, 1)

    def test_backlog_only_report_invalidates_stale_qlens(self):
        # a fresh backlog-only report must not leave JSQ reading an old
        # queue-length snapshot next to a new backlog.
        r = ReplicaRouter(2, dispatcher=JoinShortestQueueDispatcher())
        r.update_backlog(0, 0.5, qlens=[10])
        r.update_backlog(1, 0.5, qlens=[1])
        r.update_backlog(0, 0.0)  # drained; historical backlog-only style
        assert r.route() == 0     # falls back to backlog ordering for 0

    def test_jsq_without_reports_falls_back_to_backlog(self):
        # no caller ever reported qlens: JSQ must degrade to backlog
        # ordering, not dogpile replica 0 on an all-zeros tie.
        r = ReplicaRouter(3, dispatcher=JoinShortestQueueDispatcher())
        r.update_backlog(0, 0.5)
        r.update_backlog(1, 0.002)
        r.update_backlog(2, 0.3)
        assert r.route() == 1

    def test_stability_aware_prefers_fast_replica(self):
        table = ProfileTable.paper_rtx3080()
        sa = StabilityAwareDispatcher(slo=0.050, power_d=2)
        sa.reset(0)
        r = ReplicaRouter(2, straggler=StragglerPolicy(2, alpha=1.0),
                          table=table, dispatcher=sa)
        # equal raw backlog, replica 1 runs 2.5x slow (not yet detached)
        r.update_backlog(0, 0.02)
        r.update_backlog(1, 0.02)
        r.observe_quantum(1, observed_s=0.25, expected_s=0.1)
        assert r.route(model=2) == 0

    def test_sticky_key_still_spills_with_custom_dispatcher(self):
        r = ReplicaRouter(2, spill_factor=2.0,
                          dispatcher=JoinShortestQueueDispatcher())
        home = ReplicaRouter(2).route(key="s")
        r.update_backlog(home, 10.0, qlens=[100])
        r.update_backlog(1 - home, 0.1, qlens=[1])
        assert r.route(key="s") == 1 - home


class TestBacklogEstimate:
    def test_full_batches_plus_remainder(self):
        table = ProfileTable.paper_rtx3080()
        qlens = [25, 0, 7]
        est = ReplicaRouter.backlog_from_queues(table, qlens, max_batch=10)
        expect = (2 * table(0, 3, 10) + table(0, 3, 5)) + table(2, 3, 7)
        assert est == pytest.approx(expect)

    def test_empty_queues_zero(self):
        table = ProfileTable.paper_rtx3080()
        assert ReplicaRouter.backlog_from_queues(table, [0, 0, 0]) == 0.0

    @given(q0=st.integers(0, 64), q1=st.integers(0, 64), q2=st.integers(0, 64),
           max_batch=st.integers(1, 10))
    @settings(max_examples=25, deadline=None)
    def test_scheduler_drain_closed_form_pins_old_loop(self, q0, q1, q2,
                                                       max_batch):
        """Regression: the closed-form drain (full-batch quotient +
        remainder rung) must reproduce the pre-refactor O(queue-length)
        serve-loop exactly for any queue state and batch cap."""
        table = ProfileTable.paper_rtx3080()
        sched = make_scheduler("edgeserving", table,
                               SchedulerConfig(max_batch=max_batch))
        qlens = [q0, q1, q2]
        new = ReplicaRouter.backlog_from_scheduler(sched, qlens)
        e = table.num_exits - 1
        old = 0.0
        for m, n in enumerate(qlens):
            while n > 0:
                b = sched.batch_size(n)
                old += table(m, e, b)
                n -= b
        # identical up to float summation order: the closed form computes
        # full * L where the loop adds L full times (last-ulp difference).
        assert new == pytest.approx(old, rel=1e-12, abs=0.0)


class TestRouteBatchServiceShare:
    def test_share_derived_from_profile_table(self):
        table = ProfileTable.paper_rtx3080()
        r = ReplicaRouter(2, table=table, max_batch=10)
        e, cap = table.num_exits - 1, 10
        expect = np.mean([table(m, e, cap) / cap
                          for m in range(table.num_models)])
        assert r._service_share == pytest.approx(expect)

    def test_slow_fleet_spreads_less_eagerly_than_placeholder(self):
        # A 7x-slower (Jetson-class) fleet has a 7x-larger per-request
        # share, so a burst inflates backlogs proportionally faster.
        fast = ReplicaRouter(2, table=ProfileTable.paper_rtx3080())
        slow = ReplicaRouter(2, table=ProfileTable.paper_jetson_orin_nano())
        assert slow._service_share == pytest.approx(7 * fast._service_share)
        slow.route_batch(10)
        fast.route_batch(10)
        assert sum(r.backlog_s for r in slow.replicas) == pytest.approx(
            7 * sum(r.backlog_s for r in fast.replicas))

    def test_no_table_keeps_nominal_share(self):
        r = ReplicaRouter(2)
        r.route_batch(4)
        assert sum(x.backlog_s for x in r.replicas) == pytest.approx(4e-3)
