"""Tests for the multi-replica traffic router (the serving `pod` axis)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ProfileTable
from repro.runtime.fault_tolerance import StragglerPolicy
from repro.runtime.router import ReplicaRouter


class TestRouting:
    def test_least_loaded_wins(self):
        r = ReplicaRouter(3)
        r.update_backlog(0, 0.5)
        r.update_backlog(1, 0.1)
        r.update_backlog(2, 0.3)
        assert r.route() == 1

    def test_straggler_scales_load(self):
        # equal backlog, but replica 0 runs 4x slow -> route elsewhere
        r = ReplicaRouter(2, straggler=StragglerPolicy(2, alpha=1.0))
        r.update_backlog(0, 0.2)
        r.update_backlog(1, 0.2)
        r.observe_quantum(0, observed_s=0.4, expected_s=0.1)
        assert r.route() == 1

    def test_detached_replica_gets_nothing(self):
        r = ReplicaRouter(2, straggler=StragglerPolicy(2, alpha=1.0))
        r.update_backlog(0, 0.0)   # idle but 10x slow -> detached
        r.update_backlog(1, 5.0)
        r.observe_quantum(0, observed_s=1.0, expected_s=0.1)
        assert r.route() == 1

    def test_all_failed_degrades_gracefully(self):
        r = ReplicaRouter(2, straggler=StragglerPolicy(2, alpha=1.0))
        for i in range(2):
            r.observe_quantum(i, observed_s=1.0, expected_s=0.1)
        assert r.route() in (0, 1)  # still routes somewhere

    def test_sticky_key_prefers_home(self):
        r = ReplicaRouter(4)
        for i in range(4):
            r.update_backlog(i, 0.1)
        homes = {r.route(key=f"session-{k}") for k in range(64)}
        assert len(homes) > 1  # rendezvous spreads sessions
        # deterministic stickiness
        assert r.route(key="session-1") == r.route(key="session-1")

    def test_sticky_key_spills_under_overload(self):
        r = ReplicaRouter(2, spill_factor=2.0)
        home = ReplicaRouter(2).route(key="s")  # same hash, same home
        r.update_backlog(home, 10.0)
        r.update_backlog(1 - home, 0.1)
        assert r.route(key="s") == 1 - home

    def test_route_batch_spreads_burst(self):
        r = ReplicaRouter(4)
        for i in range(4):
            r.update_backlog(i, 0.0)
        picks = r.route_batch(400)
        counts = np.bincount(picks, minlength=4)
        assert counts.min() > 50  # no replica starved, no dogpile

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=25, deadline=None)
    def test_property_routes_only_healthy(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 6))
        r = ReplicaRouter(n, straggler=StragglerPolicy(n, alpha=1.0))
        for i in range(n):
            r.update_backlog(i, float(rng.uniform(0, 1)))
        bad = int(rng.integers(0, n))
        r.observe_quantum(bad, observed_s=1.0, expected_s=0.05)
        if len(r.straggler.healthy()) > 0:
            for _ in range(10):
                assert r.route() != bad


class TestBacklogEstimate:
    def test_full_batches_plus_remainder(self):
        table = ProfileTable.paper_rtx3080()
        qlens = [25, 0, 7]
        est = ReplicaRouter.backlog_from_queues(table, qlens, max_batch=10)
        expect = (2 * table(0, 3, 10) + table(0, 3, 5)) + table(2, 3, 7)
        assert est == pytest.approx(expect)

    def test_empty_queues_zero(self):
        table = ProfileTable.paper_rtx3080()
        assert ReplicaRouter.backlog_from_queues(table, [0, 0, 0]) == 0.0
