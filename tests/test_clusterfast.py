"""Cross-implementation equivalence: compiled cluster scan vs ClusterSimulator.

``repro.core.clusterfast`` runs G per-device Algorithm-1 schedulers
behind a compiled dispatcher step in one jitted ``lax.scan``. This suite
pins it to the Python ``ClusterSimulator`` through the shared
``tests/engine_conformance.py`` harness: same dispatch decisions, same
completion log, same ``ServingMetrics`` — bitwise — across dispatchers,
fleet sizes, heterogeneous profiles, and the failure/failover leg; plus
the G=1 collapse onto single-device ``simulate_scan`` (closing the
triangle with PR 3's G=1-equals-simulator guarantee) and loud rejects
for everything the fixed-shape state layout cannot express.

The big fleet-scale equivalence cell is ``slow``-marked and runs in the
CI ``REPRO_RUN_SLOW=1`` job, not tier-1.
"""

from __future__ import annotations

import dataclasses
import os

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ClusterSimulator,
    DeviceSpec,
    ProfileTable,
    ScanEngineUnsupported,
    SchedulerConfig,
    SweepRunner,
    SweepSpec,
    Tracer,
    make_dispatcher,
    make_drift,
    make_fleet,
    make_scheduler,
    paper_rate_vector,
    poisson_arrivals,
    simulate_scan,
)
from repro.core.clusterfast import (
    SUPPORTED_DISPATCHERS,
    simulate_cluster_scan,
    simulate_cluster_scan_batch,
)
from engine_conformance import (
    assert_cluster_equal,
    assert_conservation,
    run_both_cluster,
)

_SMOKE = bool(os.environ.get("REPRO_SIMFAST_SMOKE"))


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080().with_batch_saturation(4)


def _arrivals(lam, horizon, seed):
    return poisson_arrivals(paper_rate_vector(lam), horizon, seed=seed)


class TestClusterDecisionEquivalence:
    @given(
        seed=st.integers(0, 9999),
        lam=st.sampled_from([40.0, 120.0]),
        gsize=st.sampled_from([1, 2, 3]),
        dispatcher=st.sampled_from(SUPPORTED_DISPATCHERS),
    )
    @settings(max_examples=4 if _SMOKE else 8, deadline=None)
    def test_property_bitwise_over_seed_lam_g_dispatcher(
            self, table, seed, lam, gsize, dispatcher):
        arrivals = _arrivals(lam, 1.5, seed)
        py, sc = run_both_cluster(
            make_fleet("homogeneous", gsize, table), arrivals, 1.5,
            dispatcher=dispatcher, power_d=gsize)
        assert_cluster_equal(py, sc)

    @pytest.mark.parametrize("dispatcher", SUPPORTED_DISPATCHERS)
    def test_fig14_shaped_cell_bitwise(self, table, dispatcher):
        """The fig14 regime the benchmarks quote: G=3, every dispatcher."""
        arrivals = _arrivals(150.0, 2.0, 7)
        py, sc = run_both_cluster(
            make_fleet("homogeneous", 3, table), arrivals, 2.0,
            dispatcher=dispatcher, power_d=3)
        assert_cluster_equal(py, sc)

    def test_heterogeneous_fleet_bitwise(self, table):
        arrivals = _arrivals(120.0, 2.0, 11)
        py, sc = run_both_cluster(
            make_fleet("heterogeneous", 3, table), arrivals, 2.0,
            dispatcher="least-loaded")
        assert_cluster_equal(py, sc)

    def test_partial_placement_bitwise(self, table):
        # model 2 lives on device 1 only; dispatch must respect placement
        fleet = [
            DeviceSpec(table=table, name="a", models=(0, 1)),
            DeviceSpec(table=table, name="b", models=(0, 1, 2)),
        ]
        arrivals = _arrivals(100.0, 2.0, 5)
        py, sc = run_both_cluster(fleet, arrivals, 2.0, dispatcher="jsq")
        assert_cluster_equal(py, sc)

    def test_g1_collapses_to_simulate_scan_bitwise(self, table):
        """G=1 fleet == single-device compiled scan == Python simulator,
        closing the triangle with PR 3's G=1 guarantee."""
        arrivals = _arrivals(120.0, 2.5, 9)
        ref = simulate_scan(
            make_scheduler("edgeserving", table, SchedulerConfig(slo=0.05)),
            table, arrivals, 2.5, keep_completions=True)
        got = simulate_cluster_scan(
            make_fleet("homogeneous", 1, table), arrivals, 2.5)
        assert len(ref.completions) == len(got.completions)
        for a, b in zip(ref.completions, got.completions):
            assert a == b
        # cluster metrics add per_device rows and span-based utilization;
        # everything else must be bitwise-identical
        assert ref.metrics == dataclasses.replace(
            got.metrics, per_device=(), utilization=ref.metrics.utilization)

    def test_queue_overflow_retries_wider_window(self, table):
        arrivals = _arrivals(150.0, 1.5, 5)
        py, sc = run_both_cluster(
            make_fleet("homogeneous", 2, table), arrivals, 1.5,
            dispatcher="jsq", max_queue=2)
        assert_cluster_equal(py, sc)

    def test_empty_arrivals(self, table):
        py, sc = run_both_cluster(
            make_fleet("homogeneous", 2, table), [], 1.0)
        assert_cluster_equal(py, sc)
        assert sc.metrics.num_completed == 0


class TestFailover:
    def test_single_failure_bitwise(self, table):
        arrivals = _arrivals(120.0, 2.0, 3)
        py, sc = run_both_cluster(
            make_fleet("homogeneous", 2, table, fail_at=((0, 1.0),)),
            arrivals, 2.0, dispatcher="least-loaded")
        assert_cluster_equal(py, sc)
        assert py.metrics.per_device[0].alive is False
        assert sc.metrics.per_device[0].alive is False

    @pytest.mark.parametrize("dispatcher", SUPPORTED_DISPATCHERS)
    def test_two_failures_every_dispatcher_bitwise(self, table, dispatcher):
        arrivals = _arrivals(100.0, 2.0, 13)
        py, sc = run_both_cluster(
            make_fleet("homogeneous", 3, table,
                       fail_at=((0, 0.7), (2, 1.4))),
            arrivals, 2.0, dispatcher=dispatcher, power_d=3)
        assert_cluster_equal(py, sc)

    def test_failure_in_heterogeneous_fleet(self, table):
        arrivals = _arrivals(100.0, 2.0, 17)
        py, sc = run_both_cluster(
            make_fleet("heterogeneous", 3, table, fail_at=((1, 0.9),)),
            arrivals, 2.0, dispatcher="jsq")
        assert_cluster_equal(py, sc)


class TestArrayRollup:
    def test_arrays_rollup_matches_object_rollup(self, table):
        """keep_completions=False settles the books through
        summarize_arrays; metrics must stay bitwise-identical."""
        arrivals = _arrivals(120.0, 2.0, 21)
        fleet = make_fleet("heterogeneous", 3, table, fail_at=((1, 1.0),))
        a = simulate_cluster_scan(fleet, arrivals, 2.0, dispatcher="jsq",
                                  keep_completions=True)
        b = simulate_cluster_scan(fleet, arrivals, 2.0, dispatcher="jsq",
                                  keep_completions=False)
        assert a.metrics == b.metrics
        assert b.completions == []

    def test_batch_matches_singles(self, table):
        lanes = [_arrivals(80.0, 1.5, s) for s in (1, 2, 3)]
        fleet = make_fleet("homogeneous", 2, table)
        batch = simulate_cluster_scan_batch(fleet, lanes, 1.5,
                                            dispatcher="least-loaded")
        for lane, got in zip(lanes, batch):
            ref = simulate_cluster_scan(fleet, lane, 1.5,
                                        dispatcher="least-loaded")
            assert ref.metrics == got.metrics
            assert ref.completions == got.completions
            assert_conservation(got, len(lane))


class TestLoudRejection:
    def test_stability_aware_power_of_d_subsample_rejected(self, table):
        arrivals = _arrivals(50.0, 1.0, 1)
        with pytest.raises(ScanEngineUnsupported, match="power-of-d"):
            simulate_cluster_scan(
                make_fleet("homogeneous", 3, table), arrivals, 1.0,
                dispatcher="stability-aware", power_d=2)

    def test_tracer_rejected(self, table):
        with pytest.raises(ScanEngineUnsupported, match="telemetry"):
            simulate_cluster_scan(
                make_fleet("homogeneous", 2, table), [], 1.0,
                tracer=Tracer())

    def test_service_noise_rejected(self, table):
        with pytest.raises(ScanEngineUnsupported, match="noise"):
            simulate_cluster_scan(
                make_fleet("homogeneous", 2, table), [], 1.0,
                service_noise_cov=0.05)

    def test_per_device_drift_rejected(self, table):
        fleet = make_fleet("homogeneous", 2, table,
                           drift=((0, make_drift("thermal-throttle")),))
        with pytest.raises(ScanEngineUnsupported, match="drift"):
            simulate_cluster_scan(fleet, [], 1.0)

    def test_unequal_exit_counts_rejected(self, table):
        fleet = [
            DeviceSpec(table=table, name="full"),
            DeviceSpec(table=table.restrict_exits([table.num_exits - 1]),
                       name="final-only"),
        ]
        with pytest.raises(ScanEngineUnsupported, match="exits"):
            simulate_cluster_scan(fleet, [], 1.0)

    def test_non_algorithm1_policy_rejected(self, table):
        with pytest.raises(ScanEngineUnsupported):
            simulate_cluster_scan(
                make_fleet("homogeneous", 2, table), [], 1.0,
                policy="symphony")

    def test_non_numpy_backend_rejected(self, table):
        with pytest.raises(ScanEngineUnsupported):
            simulate_cluster_scan(
                make_fleet("homogeneous", 2, table), [], 1.0,
                config=SchedulerConfig(slo=0.05, backend="jnp"))

    def test_unknown_dispatcher_is_value_error(self, table):
        with pytest.raises(ValueError, match="unknown dispatcher"):
            simulate_cluster_scan(
                make_fleet("homogeneous", 2, table), [], 1.0,
                dispatcher="fortune-teller")


class TestSweepIntegration:
    def test_fleet_scan_cell_matches_python_cell(self, table):
        runner = SweepRunner(table)
        kw = dict(policy="edgeserving", rate=100.0, seed=7, horizon=1.5,
                  fleet="homogeneous", fleet_size=2, dispatcher="jsq")
        py = runner.run_cell(SweepSpec(**kw))
        sc = runner.run_cell(SweepSpec(engine="scan", **kw))
        assert py.metrics == sc.metrics

    def test_fleet_scan_cell_with_failover(self, table):
        runner = SweepRunner(table)
        kw = dict(policy="edgeserving", rate=100.0, seed=5, horizon=1.5,
                  fleet="homogeneous", fleet_size=3,
                  dispatcher="round-robin", fail_at=((1, 0.8),))
        py = runner.run_cell(SweepSpec(**kw))
        sc = runner.run_cell(SweepSpec(engine="scan", **kw))
        assert py.metrics == sc.metrics

    def test_power_d_reaches_both_engines(self, table):
        runner = SweepRunner(table)
        kw = dict(policy="edgeserving", rate=80.0, seed=3, horizon=1.5,
                  fleet="homogeneous", fleet_size=3,
                  dispatcher="stability-aware", power_d=3)
        py = runner.run_cell(SweepSpec(**kw))
        sc = runner.run_cell(SweepSpec(engine="scan", **kw))
        assert py.metrics == sc.metrics


@pytest.mark.slow
class TestClusterScaling:
    def test_large_fleet_cell_bitwise(self, table):
        """Fleet-scale equivalence: G=4 under sustained overload with a
        mid-run failure — the regime fig17's Part B actually sweeps."""
        arrivals = _arrivals(240.0, 20.0, 7)
        assert len(arrivals) > 8_000
        py, sc = run_both_cluster(
            make_fleet("homogeneous", 4, table, fail_at=((2, 12.0),)),
            arrivals, 20.0, dispatcher="least-loaded")
        assert_cluster_equal(py, sc)
