"""Online profile adaptation (``repro.core.adaptive``): estimator
correctness vs numpy, seed-determinism of every DriftModel, drift-off ≡
stock bitwise, and the adaptive-beats-static regression under a throttle
ramp."""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    AdaptConfig,
    ClusterSimulator,
    ContentionDrift,
    DVFSStepDrift,
    OnlineProfiler,
    ProfileTable,
    SafetyController,
    SchedulerConfig,
    ServingSimulator,
    SweepRunner,
    SweepSpec,
    ThermalThrottleDrift,
    make_drift,
    make_fleet,
    make_scheduler,
)
from repro.core.traffic import poisson_arrivals


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080()


def trace(lam=140.0, horizon=3.0, seed=7):
    return poisson_arrivals([3 * lam / 1.4, 2 * lam / 1.4, lam], horizon,
                            seed=seed)


# ---------------------------------------------------------------------------
# Streaming estimators vs numpy
# ---------------------------------------------------------------------------


class TestEstimators:
    def test_ewma_matches_numpy_closed_form(self, table):
        alpha = 0.3
        prof = OnlineProfiler(table, AdaptConfig(alpha=alpha, window=16))
        rng = np.random.default_rng(0)
        xs = rng.uniform(1e-3, 5e-3, size=40)
        for i, x in enumerate(xs):
            prof.observe(0, 1, 4, float(x), now=float(i))
        # closed form: mu_n = (1-a)^(n-1) x_0 + a * sum_i (1-a)^(n-1-i) x_i
        n = len(xs)
        weights = alpha * (1 - alpha) ** (n - 1 - np.arange(n))
        weights[0] = (1 - alpha) ** (n - 1)
        expected = float(np.sum(weights * xs))
        count, ewma, _ = prof.cell_stats(0, 1, 4)
        assert count == n
        assert ewma == pytest.approx(expected, rel=1e-12)

    def test_streaming_p95_matches_numpy_window(self, table):
        window = 16
        prof = OnlineProfiler(table, AdaptConfig(window=window))
        rng = np.random.default_rng(1)
        xs = rng.uniform(1e-3, 9e-3, size=50)
        for i, x in enumerate(xs):
            prof.observe(2, 0, 1, float(x), now=float(i))
        _, _, p95 = prof.cell_stats(2, 0, 1)
        assert p95 == pytest.approx(np.percentile(xs[-window:], 95.0))

    def test_unobserved_cell_reports_zero(self, table):
        prof = OnlineProfiler(table, AdaptConfig())
        assert prof.cell_stats(1, 1, 1) == (0, 0.0, 0.0)
        assert prof.num_observations == 0
        assert prof.drift_ratio == 1.0

    def test_batch_maps_to_grid_cell(self, table):
        # batch 3 on the 1..10 grid lands in the batch-size-3 column
        prof = OnlineProfiler(table, AdaptConfig())
        prof.observe(0, 0, 3, 2e-3, now=0.0)
        assert prof.cell_stats(0, 0, 3)[0] == 1
        assert prof._count[0, 0, 2] == 1


# ---------------------------------------------------------------------------
# Drift models: seed determinism
# ---------------------------------------------------------------------------


class TestDriftModels:
    def test_thermal_throttle_ramp(self):
        d = ThermalThrottleDrift(onset=1.0, ramp=2.0, peak=3.0)
        assert d.multiplier(0.0) == 1.0
        assert d.multiplier(1.0) == 1.0
        assert d.multiplier(2.0) == pytest.approx(2.0)
        assert d.multiplier(3.0) == pytest.approx(3.0)
        assert d.multiplier(100.0) == 3.0

    def test_dvfs_steps_piecewise_constant(self):
        d = DVFSStepDrift(steps=((2.0, 1.5), (4.0, 1.2)))
        assert d.multiplier(1.9) == 1.0
        assert d.multiplier(2.0) == 1.5
        assert d.multiplier(3.9) == 1.5
        assert d.multiplier(5.0) == 1.2

    def test_contention_seed_deterministic(self):
        ts = np.linspace(0.0, 30.0, 301)
        a = ContentionDrift(seed=3)
        b = ContentionDrift(seed=3)
        c = ContentionDrift(seed=4)
        ma = [a.multiplier(t) for t in ts]
        mb = [b.multiplier(t) for t in ts]
        mc = [c.multiplier(t) for t in ts]
        assert ma == mb
        assert ma != mc  # different seed, different burst windows
        assert set(ma) <= {1.0, a.magnitude} and a.magnitude in ma

    def test_contention_query_order_independent(self):
        ts = np.linspace(0.0, 20.0, 101)
        fwd = ContentionDrift(seed=9)
        scrambled = ContentionDrift(seed=9)
        order = np.random.default_rng(0).permutation(len(ts))
        got = {}
        for i in order:
            got[i] = scrambled.multiplier(ts[i])
        assert [got[i] for i in range(len(ts))] == [
            fwd.multiplier(t) for t in ts]

    def test_reset_reproduces_stream(self):
        d = ContentionDrift(seed=0)
        first = [d.multiplier(t) for t in np.linspace(0, 10, 50)]
        d.reset(0)
        assert [d.multiplier(t) for t in np.linspace(0, 10, 50)] == first

    def test_make_drift_factory(self):
        assert make_drift(None) is None
        assert make_drift("none") is None
        assert isinstance(make_drift("thermal-throttle"), ThermalThrottleDrift)
        with pytest.raises(ValueError, match="unknown drift"):
            make_drift("microwave")
        with pytest.raises(AssertionError):
            make_drift(None, peak=2.0)  # kwargs without a model


# ---------------------------------------------------------------------------
# Safety controller
# ---------------------------------------------------------------------------


class TestSafetyController:
    def test_rises_under_violations_and_caps(self):
        c = SafetyController(target=0.01, max_mult=1.4)
        for _ in range(400):
            c.observe(latency=0.08, deadline=0.05)  # all late
        assert c.multiplier == pytest.approx(1.4)
        assert c.violation_ewma > 0.9

    def test_decays_when_headroom_is_ample(self):
        c = SafetyController(target=0.01)
        for _ in range(200):
            c.observe(latency=0.08, deadline=0.05)
        inflated = c.multiplier
        assert inflated > 1.0
        for _ in range(2000):
            c.observe(latency=0.01, deadline=0.05)  # all on time
        assert c.multiplier < inflated
        assert c.multiplier >= c.min_mult

    def test_deterministic_fold(self):
        a, b = SafetyController(), SafetyController()
        stream = [(0.06, 0.05), (0.01, 0.05), (0.09, 0.05)] * 50
        for lat, dl in stream:
            a.observe(lat, dl)
            b.observe(lat, dl)
        assert a.multiplier == b.multiplier
        assert a.violation_ewma == b.violation_ewma

    def test_dropped_requests_count_as_violations(self, table):
        # summarize() counts every shed request as a violation; the
        # controller's stream must agree, or it decays the multiplier
        # exactly while an overload burst is being shed.
        prof = OnlineProfiler(table, AdaptConfig(safety=True))
        for _ in range(50):
            prof.observe_latency(0.01, 0.05)  # on-time completions
        assert prof.safety.multiplier == prof.safety.min_mult
        prof.observe_dropped(100)
        assert prof.safety.violation_ewma > 0.9
        assert prof.safety.multiplier > prof.safety.min_mult


# ---------------------------------------------------------------------------
# Materialisation and refresh cadence
# ---------------------------------------------------------------------------


class TestMaterialize:
    def test_propagates_global_drift_ratio_to_unobserved_cells(self, table):
        prof = OnlineProfiler(table, AdaptConfig(alpha=1.0, min_samples=1,
                                                 mode="mean"))
        # one cell observed at exactly 2x its cold-start value
        base = float(table.latency[0, 3, 9])
        prof.observe(0, 3, 10, 2.0 * base, now=0.0)
        out = prof.materialize()
        assert prof.drift_ratio == pytest.approx(2.0)
        # unobserved cells scaled by the global ratio
        np.testing.assert_allclose(out.latency[1], 2.0 * table.latency[1])
        assert out.meta["builder"] == "online"

    def test_observed_cells_use_estimate_and_stay_monotone(self, table):
        prof = OnlineProfiler(table, AdaptConfig(min_samples=1,
                                                 propagate=False))
        # implausibly small observation at B=10 would break monotonicity;
        # materialize must re-enforce it like ProfileTable.measure
        for _ in range(3):
            prof.observe(1, 2, 10, 1e-6, now=0.0)
        out = prof.materialize()
        assert np.all(np.diff(out.latency, axis=2) >= -1e-12)

    def test_p95_vs_mean_mode(self, table):
        samples = list(np.random.default_rng(2).uniform(1e-3, 9e-3, 20))
        for mode in ("p95", "mean"):
            prof = OnlineProfiler(
                table, AdaptConfig(mode=mode, min_samples=1, alpha=0.5,
                                   propagate=False))
            for x in samples:
                prof.observe(0, 0, 1, float(x), now=0.0)
            _, ewma, p95 = prof.cell_stats(0, 0, 1)
            expected = p95 if mode == "p95" else ewma
            assert float(prof.materialize().latency[0, 0, 0]) == pytest.approx(
                expected), mode

    def test_safety_multiplier_applied_last(self, table):
        prof = OnlineProfiler(table, AdaptConfig(safety=True, propagate=False))
        for _ in range(100):
            prof.observe_latency(0.09, 0.05)  # drive the controller up
        mult = prof.safety.multiplier
        assert mult > 1.0
        np.testing.assert_allclose(prof.materialize().latency,
                                   table.latency * mult)

    def test_refresh_cadence(self, table):
        prof = OnlineProfiler(table, AdaptConfig(refresh_every=1.0))
        assert prof.maybe_refresh(5.0) is None  # nothing observed yet
        prof.observe(0, 0, 1, 2e-3, now=0.1)
        assert prof.maybe_refresh(0.5) is None  # cadence not reached
        assert prof.maybe_refresh(1.5) is not None
        assert prof.maybe_refresh(2.9) is None  # not dirty again yet
        prof.observe(0, 0, 1, 2e-3, now=3.0)
        assert prof.maybe_refresh(3.1) is not None


# ---------------------------------------------------------------------------
# Simulator integration: drift-off bitwise, adaptive-beats-static regression
# ---------------------------------------------------------------------------


class TestSimulatorIntegration:
    def test_identity_drift_bitwise_stock(self, table):
        arrivals = trace()
        cfg = SchedulerConfig()

        def run(drift):
            sched = make_scheduler("edgeserving", table, cfg)
            sim = ServingSimulator(sched, table, num_models=3, seed=7,
                                   drift=drift)
            return sim.run(list(arrivals), 3.0, warmup_tasks=50)

        stock = run(None)
        ident = run(ThermalThrottleDrift(peak=1.0))  # multiplier ≡ 1.0
        assert ident.completions == stock.completions
        assert ident.metrics == stock.metrics

    def test_drift_none_spec_bitwise_stock_cell(self, table):
        runner = SweepRunner(table)
        common = dict(policy="edgeserving", rate=140.0, horizon=1.5,
                      warmup_tasks=20)
        stock = runner.run_cell(SweepSpec(**common))
        none = runner.run_cell(SweepSpec(**common, drift="none"))
        assert none.metrics == stock.metrics

    def test_adaptive_strictly_beats_static_under_throttle(self, table):
        arrivals = trace(horizon=4.0)
        cfg = SchedulerConfig()

        def run(adapt):
            sched = make_scheduler("edgeserving", table, cfg)
            sim = ServingSimulator(
                sched, table, num_models=3, seed=7,
                drift=ThermalThrottleDrift(onset=0.5, ramp=1.0, peak=2.2),
                adapt=adapt)
            return sim.run(list(arrivals), 4.0, warmup_tasks=50)

        static = run(None)
        adaptive = run(AdaptConfig(refresh_every=0.25))
        assert static.metrics.violation_ratio > 0.02  # drift really hurts
        assert (adaptive.metrics.violation_ratio
                < static.metrics.violation_ratio)
        assert adaptive.adapted_table is not None
        # the learned global ratio tracks the true 2.2x throttle
        assert adaptive.adapted_table.meta["drift_ratio"] == pytest.approx(
            2.2, rel=0.1)

    def test_shared_drift_instance_not_cross_contaminated(self, table):
        # Drift is re-seeded at run() start, so an instance shared across
        # simulators (or a run interleaved with another construction)
        # still produces the stream its own seed dictates.
        arrivals = trace(horizon=2.0)
        dm = ContentionDrift(magnitude=2.0)

        def run(drift, seed):
            sched = make_scheduler("edgeserving", table, SchedulerConfig())
            sim = ServingSimulator(sched, table, num_models=3, seed=seed,
                                   drift=drift)
            return sim.run(list(arrivals), 2.0, warmup_tasks=20)

        solo = run(ContentionDrift(magnitude=2.0), seed=7)
        # constructing a second simulator around the same instance must not
        # disturb the first simulator's run
        ServingSimulator(make_scheduler("edgeserving", table,
                                        SchedulerConfig()),
                         table, num_models=3, seed=99, drift=dm)
        shared = run(dm, seed=7)
        assert shared.metrics == solo.metrics

    def test_adapt_run_is_hermetic_and_rerunnable(self, table):
        arrivals = trace(horizon=2.0)
        sched = make_scheduler("edgeserving", table, SchedulerConfig())
        sim = ServingSimulator(
            sched, table, num_models=3, seed=7,
            drift=ThermalThrottleDrift(onset=0.3, ramp=0.5, peak=2.0),
            adapt=AdaptConfig())
        a = sim.run(list(arrivals), 2.0, warmup_tasks=20)
        assert sched.table is table  # belief restored after the run
        b = sim.run(list(arrivals), 2.0, warmup_tasks=20)
        assert a.metrics == b.metrics


# ---------------------------------------------------------------------------
# Live engine feedback loop
# ---------------------------------------------------------------------------


class TestServingEngineAdaptation:
    @pytest.fixture()
    def engine_parts(self, table):
        from repro.core import Request
        from repro.runtime.server import ServedModel, ServingEngine

        view = table.select_models([0]).restrict_exits([0, 3])
        mod = ServedModel("m0", values=None,
                          forward_fn=lambda v, x, e: np.sum(x),
                          data_fn=lambda b: np.ones((b, 2)), num_exits=2)
        return Request, ServedModel, ServingEngine, view, mod

    def test_profiler_feeds_and_refreshes(self, engine_parts, table):
        Request, _, ServingEngine, view, mod = engine_parts

        class StepClock:
            """Deterministic clock: each read advances 1 ms."""

            def __init__(self):
                self.t = 0.0

            def __call__(self):
                self.t += 1e-3
                return self.t

        sched = make_scheduler("edgeserving", view,
                               SchedulerConfig(slo=0.05, max_batch=4))
        prof = OnlineProfiler(view, AdaptConfig(refresh_every=0.005,
                                                min_samples=1, safety=True))
        eng = ServingEngine([mod], sched, clock=StepClock(), profiler=prof)
        arrivals = [Request(req_id=i, model=0, arrival=0.0) for i in range(40)]
        comps, span = eng.run(arrivals, duration=0.05)
        assert len(comps) == 40
        assert prof.num_observations > 0
        assert prof.safety.num_observed >= len(comps)
        # the refresh swapped the scheduler onto the profiler's view
        assert sched.table is not view
        assert sched.table.meta["builder"] == "online"
        m = eng.metrics(view, 0.05, span)
        assert len(comps) + eng.dropped + m.residual_queue == 40

    def test_zero_service_sample_is_skipped_not_fatal(self, table):
        # a coarse live clock can measure a 0.0-length quantum; the shared
        # ingest path must skip the sample, not crash the serving loop
        prof = OnlineProfiler(table, AdaptConfig())
        out = prof.ingest_quantum(0, 0, 1, 0.0, now=1.0, batch=[],
                                  default_slo=0.05)
        assert out is None
        assert prof.num_observations == 0


# ---------------------------------------------------------------------------
# Cluster integration and sweep determinism
# ---------------------------------------------------------------------------


class TestClusterIntegration:
    def test_g1_drift_adapt_bitwise_single_device(self, table):
        arrivals = trace()
        cfg = SchedulerConfig()
        adapt = AdaptConfig(refresh_every=0.25)
        single = ServingSimulator(
            make_scheduler("edgeserving", table, cfg), table, num_models=3,
            seed=7, drift=ThermalThrottleDrift(onset=0.5, ramp=1.0, peak=2.0),
            adapt=adapt)
        ref = single.run(list(arrivals), 3.0, warmup_tasks=50)
        fleet = make_fleet(
            "homogeneous", 1, table,
            drift=[(0, ThermalThrottleDrift(onset=0.5, ramp=1.0, peak=2.0))])
        sim = ClusterSimulator(fleet, config=cfg, num_models=3, seed=7,
                               adapt=adapt)
        got = sim.run(list(arrivals), 3.0, warmup_tasks=50)
        assert got.completions == ref.completions
        assert dataclasses.replace(got.metrics, per_device=()) == ref.metrics

    def test_cluster_drift_adapt_rerun_stable(self, table):
        fleet = make_fleet(
            "heterogeneous", 2, table,
            drift=[(d, ContentionDrift(magnitude=2.0)) for d in range(2)])
        sim = ClusterSimulator(fleet, num_models=3, seed=7,
                               adapt=AdaptConfig())
        arrivals = trace(lam=200.0, horizon=2.0)
        a = sim.run(list(arrivals), 2.0, warmup_tasks=20)
        b = sim.run(list(arrivals), 2.0, warmup_tasks=20)
        assert a.completions == b.completions
        assert a.metrics == b.metrics

    def test_drift_adapt_cells_parallel_bitwise_serial(self, table):
        runner = SweepRunner(table)
        specs = [
            SweepSpec(policy="edgeserving", rate=140.0, horizon=1.5,
                      warmup_tasks=20, drift="thermal-throttle",
                      drift_kwargs=(("onset", 0.3), ("peak", 2.0)),
                      adapt=adapt)
            for adapt in (None, AdaptConfig())
        ] + [
            SweepSpec(policy="edgeserving", scenario="mmpp", rate=280.0,
                      horizon=1.5, warmup_tasks=20, fleet="heterogeneous",
                      fleet_size=2, dispatcher="stability-aware",
                      drift="contention", adapt=AdaptConfig()),
        ]
        serial = runner.run(specs, workers=1)
        parallel = runner.run(specs, workers=2)
        assert [r.metrics for r in serial] == [r.metrics for r in parallel]

    def test_drift_kwargs_without_drift_rejected(self, table):
        runner = SweepRunner(table)
        with pytest.raises(AssertionError):
            runner.run_cell(SweepSpec(policy="edgeserving", rate=100.0,
                                      horizon=1.0,
                                      drift_kwargs=(("peak", 2.0),)))
