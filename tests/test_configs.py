"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, assert output shapes + no NaNs. FULL configs are
structure-checked only (exercised via the dry-run, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    ARCH_IDS,
    SHAPES,
    all_configs,
    applicable,
    get_config,
    input_specs,
    skip_reason,
)
from repro.models import build_model, split_params


def smoke_batch(cfg, batch=2, seq=8):
    ks = jax.random.split(jax.random.key(7), 3)
    toks = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        b["src_embeds"] = jax.random.normal(
            ks[1], (batch, cfg.frontend_seq, cfg.d_model))
    if cfg.frontend == "vision":
        b = {"embeds": jax.random.normal(ks[2], (batch, seq, cfg.d_model)),
             "labels": toks}
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestSmokePerArch:
    def test_train_step(self, arch):
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        batch = smoke_batch(cfg)
        loss, metrics = jax.jit(model.train_loss)(values, batch)
        assert np.isfinite(float(loss)), arch
        assert all(np.isfinite(float(v)) for v in metrics.values())

    def test_forward_all_exits(self, arch):
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        batch = smoke_batch(cfg)
        for e in range(cfg.num_exits):
            logits = model.forward_exit(values, batch, e)
            assert logits.shape == (2, 8, cfg.vocab_size), (arch, e)
            assert bool(jnp.all(jnp.isfinite(logits))), (arch, e)

    def test_decode_step(self, arch):
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        values, _ = split_params(model.init(jax.random.key(0)))
        e = cfg.num_exits - 1
        if cfg.family == "encdec":
            src = jax.random.normal(jax.random.key(1),
                                    (2, cfg.frontend_seq, cfg.d_model))
            cache = model.prepare_decode_cache(values, src, 2, 12, e)
        else:
            cache = model.init_cache(2, 12, e)
        tok = jnp.zeros((2, 1), jnp.int32)
        logits, cache2 = jax.jit(
            lambda v, t, c: model.decode_step(v, t, c, e)
        )(values, tok, cache)
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))


class TestFullConfigStructure:
    """FULL configs: exact dims from the assignment (no allocation)."""

    EXPECT = {
        "seamless-m4t-large-v2": dict(num_layers=24, d_model=1024,
                                      num_heads=16, num_kv_heads=16,
                                      d_ff=8192, vocab_size=256206),
        "qwen3-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12288, vocab_size=151936),
        "smollm-135m": dict(num_layers=30, d_model=576, num_heads=9,
                            num_kv_heads=3, d_ff=1536, vocab_size=49152),
        "starcoder2-7b": dict(num_layers=32, d_model=4608, num_heads=36,
                              num_kv_heads=4, d_ff=18432, vocab_size=49152),
        "phi4-mini-3.8b": dict(num_layers=32, d_model=3072, num_heads=24,
                               num_kv_heads=8, d_ff=8192, vocab_size=200064),
        "deepseek-moe-16b": dict(num_layers=28, d_model=2048, num_heads=16,
                                 num_kv_heads=16, vocab_size=102400,
                                 num_experts=64, top_k=6,
                                 num_shared_experts=2, d_ff_expert=1408),
        "deepseek-v3-671b": dict(num_layers=61, d_model=7168, num_heads=128,
                                 vocab_size=129280, num_experts=256, top_k=8,
                                 num_shared_experts=1, d_ff_expert=2048,
                                 mla=True),
        "llava-next-mistral-7b": dict(num_layers=32, d_model=4096,
                                      num_heads=32, num_kv_heads=8,
                                      d_ff=14336, vocab_size=32000),
        "rwkv6-1.6b": dict(num_layers=24, d_model=2048, d_ff=7168,
                           vocab_size=65536, family="rwkv"),
        "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=8, d_ff=14336, num_experts=16,
                               top_k=2, attn_period=8),
    }

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_dims_match_assignment(self, arch):
        cfg = get_config(arch, smoke=False)
        for field, want in self.EXPECT[arch].items():
            assert getattr(cfg, field) == want, (arch, field)

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_four_exits_and_final_is_full_depth(self, arch):
        cfg = get_config(arch, smoke=False)
        assert 2 <= cfg.num_exits <= 4
        assert cfg.exits[-1] == cfg.num_layers

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_abstract_param_count(self, arch):
        # eval_shape init (no allocation even for 671B) + sanity on scale.
        cfg = get_config(arch, smoke=False)
        model = build_model(cfg)
        shapes, axes = model.abstract(jax.random.key(0))
        n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        expected_range = {
            "smollm-135m": (0.1e9, 0.3e9),
            "qwen3-8b": (7e9, 10e9),
            "starcoder2-7b": (6.5e9, 9e9),
            "phi4-mini-3.8b": (3.4e9, 5.5e9),
            "llava-next-mistral-7b": (6.5e9, 8.5e9),
            "deepseek-moe-16b": (14e9, 20e9),
            "deepseek-v3-671b": (600e9, 720e9),
            "rwkv6-1.6b": (1.3e9, 2.2e9),
            "jamba-v0.1-52b": (45e9, 60e9),
            "seamless-m4t-large-v2": (1.2e9, 2.8e9),
        }[arch]
        assert expected_range[0] <= n_params <= expected_range[1], (
            arch, f"{n_params/1e9:.2f}B"
        )


class TestShapes:
    def test_shape_table(self):
        assert SHAPES["train_4k"].seq_len == 4096
        assert SHAPES["train_4k"].global_batch == 256
        assert SHAPES["prefill_32k"].seq_len == 32768
        assert SHAPES["decode_32k"].global_batch == 128
        assert SHAPES["long_500k"].seq_len == 524288

    def test_long_500k_applicability(self):
        cfgs = all_configs()
        runs = [a for a, c in cfgs.items() if applicable(c, "long_500k")]
        assert sorted(runs) == ["jamba-v0.1-52b", "rwkv6-1.6b"]
        assert skip_reason(cfgs["qwen3-8b"], "long_500k") is not None

    def test_total_cells(self):
        # 10 archs x 4 shapes - 8 long_500k skips = 32 dry-run cells.
        cells = [
            (a, s)
            for a, c in all_configs().items()
            for s in SHAPES
            if applicable(c, s)
        ]
        assert len(cells) == 32

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_input_specs_no_alloc(self, arch):
        cfg = get_config(arch, smoke=False)
        for shape in SHAPES:
            if not applicable(cfg, shape):
                continue
            kind, kw = input_specs(cfg, shape)
            leaves = jax.tree.leaves(kw)
            assert all(
                isinstance(l, jax.ShapeDtypeStruct) or np.isscalar(l)
                for l in leaves
            ), (arch, shape)
            if kind == "train":
                tokens = kw["batch"].get("tokens", kw["batch"].get("embeds"))
                assert tokens.shape[0] == SHAPES[shape].global_batch
            if kind == "decode":
                assert kw["token"].shape == (SHAPES[shape].global_batch, 1)
