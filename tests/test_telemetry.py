"""Telemetry subsystem: record-only tracing, timelines, and exporters.

The load-bearing guarantees, in order of importance:

  * **Observation is free of side effects** — a simulation with a
    :class:`Tracer` attached produces *bitwise-identical* metrics and
    decisions to the same simulation without one, on both engines. The
    tracer only appends to Python lists; it never touches the RNG, float
    accumulation order, or scheduler state. (Heisenberg clause.)
  * **Engines agree on the timeline, not just the aggregates** — the
    compiled scan engine reconstructs its decision/span timeline
    host-side from packed codes, and it must match the Python event
    loop record-for-record.
  * **Timelines conserve requests** — every arrival appears in exactly
    one span (completed / dropped / residual), including Symphony sheds
    and overload residuals.
  * **Rollups are consistent with the aggregates** — summing
    ``timeline_metrics`` bins reproduces ``ServingMetrics
    .violation_ratio`` exactly (same integer sums, same division).
  * **Exports round-trip** — NDJSON is lossless; Chrome trace JSON is
    strict (Perfetto rejects bare ``NaN``) with matched async ``b``/``e``
    request pairs; ``tools/tracestats.py`` summarizes both formats.
"""

from __future__ import annotations

import json
import math
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ClusterSimulator,
    ProfileTable,
    Request,
    SchedulerConfig,
    ServingSimulator,
    SweepRunner,
    SweepSpec,
    Tracer,
    export_chrome_trace,
    export_ndjson,
    load_ndjson,
    make_dispatcher,
    make_fleet,
    make_scenario,
    make_scheduler,
    paper_rate_vector,
    poisson_arrivals,
    simulate_scan,
    timeline_metrics,
)

REPO = pathlib.Path(__file__).resolve().parent.parent
TRACESTATS = REPO / "tools" / "tracestats.py"

SCAN_POLICIES = ("edgeserving", "edgeserving-lattice",
                 "allfinal-deadline-aware")


@pytest.fixture(scope="module")
def table():
    return ProfileTable.paper_rtx3080()


def _arrivals(lam=110.0, horizon=2.0, seed=7):
    return poisson_arrivals(paper_rate_vector(lam), horizon, seed=seed)


def _run(policy, table, arrivals, horizon, tracer=None, seed=7, slo=0.05,
         warmup=20):
    sched = make_scheduler(policy, table, SchedulerConfig(slo=slo))
    sim = ServingSimulator(sched, table, num_models=3, seed=seed,
                           tracer=tracer)
    return sim.run(list(arrivals), horizon, warmup_tasks=warmup)


def _assert_span_conservation(trace, n_arrivals):
    counts = trace.span_counts()
    assert sum(counts.values()) == n_arrivals
    ids = [s.req_id for s in trace.spans]
    assert len(ids) == len(set(ids))  # each request exactly once


def _assert_decisions_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert (ra.t, ra.t_end, ra.device, ra.model, ra.exit_idx,
                ra.batch_size) == (rb.t, rb.t_end, rb.device, rb.model,
                                   rb.exit_idx, rb.batch_size)
        assert ra.queue_depths == rb.queue_depths
        assert ra.oldest_ages == rb.oldest_ages
        # scores travel through float32 on the scan path
        np.testing.assert_allclose(ra.score, rb.score, rtol=1e-6)
        if math.isfinite(ra.margin) or math.isfinite(rb.margin):
            np.testing.assert_allclose(ra.margin, rb.margin, rtol=1e-5)


class TestHeisenberg:
    """Tracing on == tracing off, bitwise, on every engine."""

    @given(seed=st.integers(0, 999),
           lam=st.sampled_from([60.0, 130.0, 200.0]),
           policy=st.sampled_from(("edgeserving", "symphony",
                                   "earlyexit-edf", "all-final")))
    @settings(max_examples=6, deadline=None)
    def test_python_engine_bitwise(self, table, seed, lam, policy):
        arrivals = _arrivals(lam, 1.5, seed)
        off = _run(policy, table, arrivals, 1.5, seed=seed)
        on = _run(policy, table, arrivals, 1.5, tracer=Tracer(), seed=seed)
        assert off.metrics == on.metrics
        assert off.trace is None
        _assert_span_conservation(on.trace, len(arrivals))

    @given(seed=st.integers(0, 999),
           policy=st.sampled_from(SCAN_POLICIES))
    @settings(max_examples=4, deadline=None)
    def test_scan_engine_bitwise(self, table, seed, policy):
        arrivals = _arrivals(110.0, 1.5, seed)
        sched = make_scheduler(policy, table, SchedulerConfig(slo=0.05))
        off = simulate_scan(sched, table, list(arrivals), 1.5, num_models=3,
                            warmup_tasks=20)
        on = simulate_scan(sched, table, list(arrivals), 1.5, num_models=3,
                           warmup_tasks=20, tracer=Tracer())
        assert off.metrics == on.metrics
        assert off.trace is None
        _assert_span_conservation(on.trace, len(arrivals))

    def test_rerun_resets_the_tracer(self, table):
        tracer = Tracer()
        arrivals = _arrivals()
        a = _run("edgeserving", table, arrivals, 2.0, tracer=tracer)
        b = _run("edgeserving", table, arrivals, 2.0, tracer=tracer)
        assert a.metrics == b.metrics
        assert len(a.trace.decisions) == len(b.trace.decisions)
        assert len(a.trace.spans) == len(b.trace.spans)


class TestEngineTimelineEquivalence:
    """Python event loop ≡ compiled scan, record-for-record."""

    @given(seed=st.integers(0, 999),
           lam=st.sampled_from([60.0, 130.0, 200.0]),
           policy=st.sampled_from(SCAN_POLICIES))
    @settings(max_examples=6, deadline=None)
    def test_property_same_timeline(self, table, seed, lam, policy):
        arrivals = _arrivals(lam, 1.5, seed)
        py = _run(policy, table, arrivals, 1.5, tracer=Tracer(), seed=seed)
        sched = make_scheduler(policy, table, SchedulerConfig(slo=0.05))
        sc = simulate_scan(sched, table, list(arrivals), 1.5, num_models=3,
                           warmup_tasks=20, tracer=Tracer())
        _assert_decisions_equal(py.trace.decisions, sc.trace.decisions)
        # completed spans finish in the same order on both engines
        pyc = [s for s in py.trace.spans if s.status == "completed"]
        scc = [s for s in sc.trace.spans if s.status == "completed"]
        assert pyc == scc
        pyr = sorted(s.req_id for s in py.trace.spans
                     if s.status == "residual")
        scr = sorted(s.req_id for s in sc.trace.spans
                     if s.status == "residual")
        assert pyr == scr
        assert py.trace.meta["engine"] == "python"
        assert sc.trace.meta["engine"] == "scan"

    def test_scan_margin_matches_rescored_python(self, table):
        """The scan step computes the margin inside the compiled kernel;
        the Python engine re-scores host-side. Overload makes margins
        finite and discriminating."""
        arrivals = _arrivals(200.0, 2.0)
        py = _run("edgeserving", table, arrivals, 2.0, tracer=Tracer())
        sched = make_scheduler("edgeserving", table,
                               SchedulerConfig(slo=0.05))
        sc = simulate_scan(sched, table, list(arrivals), 2.0, num_models=3,
                           warmup_tasks=20, tracer=Tracer())
        margins_py = [r.margin for r in py.trace.decisions]
        margins_sc = [r.margin for r in sc.trace.decisions]
        assert any(math.isfinite(m) for m in margins_py)
        for a, b in zip(margins_py, margins_sc):
            if math.isfinite(a) or math.isfinite(b):
                np.testing.assert_allclose(a, b, rtol=1e-5)


class TestSpanConservation:
    def test_symphony_sheds_are_dropped_spans(self, table):
        arrivals = _arrivals(220.0, 2.0)
        res = _run("symphony", table, arrivals, 2.0, tracer=Tracer())
        counts = res.trace.span_counts()
        assert counts.get("dropped", 0) == res.metrics.dropped > 0
        _assert_span_conservation(res.trace, len(arrivals))
        assert any(e.kind == "shed" for e in res.trace.events)

    def test_overload_residuals_are_residual_spans(self, table):
        # all-final at high load leaves work queued at the drain cap
        arrivals = _arrivals(240.0, 2.0)
        sched = make_scheduler("all-final", table, SchedulerConfig(slo=0.05))
        sim = ServingSimulator(sched, table, num_models=3, seed=7,
                               tracer=Tracer(), drain_cap=0.1)
        res = sim.run(list(arrivals), 2.0, warmup_tasks=20)
        counts = res.trace.span_counts()
        assert counts.get("residual", 0) == res.metrics.residual_queue > 0
        _assert_span_conservation(res.trace, len(arrivals))
        # residuals in single-device engines carry the device=-1 sentinel
        assert all(s.device == -1 for s in res.trace.spans
                   if s.status == "residual")

    def test_slack_sign_matches_violation_count(self, table):
        arrivals = _arrivals(200.0, 2.0)
        res = _run("edgeserving", table, arrivals, 2.0, tracer=Tracer())
        comp = sorted((s for s in res.trace.spans
                       if s.status == "completed"),
                      key=lambda s: s.finish)
        comp = comp[res.metrics.warmup_used:]
        late = sum(1 for s in comp if s.slack < 0)
        # Eq. 2 accounting: (late + dropped) / (done + dropped)
        expect = ((late + res.metrics.dropped)
                  / (len(comp) + res.metrics.dropped))
        assert expect == pytest.approx(res.metrics.violation_ratio, abs=1e-12)


class TestClusterTelemetry:
    def test_g1_cluster_matches_single_device_timeline(self, table):
        arrivals = _arrivals()
        single = _run("edgeserving", table, arrivals, 2.0, tracer=Tracer())
        sim = ClusterSimulator(
            make_fleet("homogeneous", 1, table), policy="edgeserving",
            config=SchedulerConfig(slo=0.05),
            dispatcher=make_dispatcher("least-loaded", slo=0.05),
            num_models=3, seed=7, tracer=Tracer())
        clus = sim.run(list(arrivals), 2.0, warmup_tasks=20)
        _assert_decisions_equal(single.trace.decisions,
                                clus.trace.decisions)
        assert clus.trace.meta["engine"] == "cluster"
        _assert_span_conservation(clus.trace, len(arrivals))

    def test_failure_emits_events_and_conserves_spans(self, table):
        arrivals = _arrivals(150.0, 2.0)
        sim = ClusterSimulator(
            make_fleet("homogeneous", 2, table, fail_at=((1, 0.8),)),
            policy="edgeserving", config=SchedulerConfig(slo=0.05),
            dispatcher=make_dispatcher("least-loaded", slo=0.05),
            num_models=3, seed=7, tracer=Tracer())
        res = sim.run(list(arrivals), 2.0, warmup_tasks=20)
        kinds = {e.kind for e in res.trace.events}
        assert "device-failure" in kinds
        assert "failover" in kinds
        fail = next(e for e in res.trace.events
                    if e.kind == "device-failure")
        assert fail.device == 1
        assert fail.t == pytest.approx(0.8)
        _assert_span_conservation(res.trace, len(arrivals))
        assert res.trace.meta["num_devices"] == 2
        assert {r.device for r in res.trace.decisions} <= {0, 1}


class TestTimelineMetrics:
    @given(seed=st.integers(0, 999), num_bins=st.integers(1, 60),
           policy=st.sampled_from(("edgeserving", "symphony", "all-final")))
    @settings(max_examples=8, deadline=None)
    def test_bins_sum_back_to_aggregate_exactly(self, table, seed, num_bins,
                                                policy):
        arrivals = _arrivals(180.0, 1.5, seed)
        res = _run(policy, table, arrivals, 1.5, tracer=Tracer(), seed=seed)
        tm = timeline_metrics(res.trace, num_bins=num_bins)
        # exact: identical integer sums, identical float division
        assert tm.aggregate_violation_ratio() == res.metrics.violation_ratio
        assert int(tm.dropped.sum()) == res.metrics.dropped

    def test_flash_crowd_spike_is_localized(self, table):
        proc = make_scenario("flash-crowd", paper_rate_vector(160.0),
                             spike_start=2.0, spike_duration=0.5,
                             magnitude=5.0)
        arrivals = proc.generate(5.0, seed=7)
        res = _run("edgeserving", table, arrivals, 5.0, tracer=Tracer(),
                   warmup=100)
        tm = timeline_metrics(res.trace, num_bins=20, t_end=5.0)
        qd = np.nan_to_num(tm.queue_depth)
        spike_bins = range(8, 12)  # spike window [2.0, 2.5) plus drain
        assert qd[list(spike_bins)].max() > 3 * qd[:8].max()
        # Eq. 6 anatomy: exit depth shifts down inside the spike
        depth = np.nan_to_num(tm.mean_exit_depth, nan=np.inf)
        assert depth[8:11].min() < np.nanmean(tm.mean_exit_depth[:8])
        assert tm.num_bins == 20
        assert tm.edges[0] == 0.0 and tm.edges[-1] == 5.0

    def test_utilization_bounded_by_device_count(self, table):
        arrivals = _arrivals(200.0, 2.0)
        res = _run("edgeserving", table, arrivals, 2.0, tracer=Tracer())
        tm = timeline_metrics(res.trace, num_bins=10)
        assert np.all(tm.utilization >= 0.0)
        assert np.all(tm.utilization <= 1.0 + 1e-9)


class TestExporters:
    @pytest.fixture(scope="class")
    def traced(self, table):
        arrivals = _arrivals(220.0, 2.0)
        # symphony: gives the trace drops + shed events + NaN margins,
        # the fields most likely to break strict JSON
        return _run("symphony", table, arrivals, 2.0, tracer=Tracer())

    def test_ndjson_round_trips_losslessly(self, traced, tmp_path):
        path = str(tmp_path / "t.ndjson")
        export_ndjson(traced.trace, path)
        back = load_ndjson(path)
        # NaN != NaN blocks plain dataclass equality (symphony traces carry
        # NaN margins); losslessness == a second export is byte-identical.
        path2 = str(tmp_path / "t2.ndjson")
        export_ndjson(back, path2)
        assert open(path).read() == open(path2).read()
        assert len(back.decisions) == len(traced.trace.decisions)
        assert len(back.spans) == len(traced.trace.spans)
        completed = [s for s in back.spans if s.status == "completed"]
        assert completed == [s for s in traced.trace.spans
                             if s.status == "completed"]
        assert back.meta == traced.trace.meta
        nan_margins = [r.margin for r in back.decisions
                       if not math.isfinite(r.margin)]
        assert nan_margins and all(math.isnan(m) for m in nan_margins)

    def test_chrome_trace_is_strict_perfetto_json(self, traced, tmp_path):
        path = str(tmp_path / "t.chrome.json")
        export_chrome_trace(traced.trace, path)

        def reject(s):
            raise AssertionError(f"non-strict JSON constant {s!r}")

        doc = json.load(open(path), parse_constant=reject)
        evs = doc["traceEvents"]
        assert {"displayTimeUnit", "otherData"} <= set(doc)
        for e in evs:
            assert e["ph"] in ("M", "X", "i", "b", "e")
            assert isinstance(e["ts"], (int, float))
            if e["ph"] == "X":
                assert e["dur"] >= 0.0
        # async request spans pair up exactly
        opens = [e["id"] for e in evs
                 if e["ph"] == "b" and e.get("cat") == "request"]
        closes = [e["id"] for e in evs
                  if e["ph"] == "e" and e.get("cat") == "request"]
        assert sorted(opens) == sorted(closes)
        assert len(opens) == len(set(opens))
        n_quanta = sum(1 for e in evs if e["ph"] == "X")
        assert n_quanta == len(traced.trace.decisions)

    @pytest.mark.parametrize("fmt", ["ndjson", "chrome"])
    def test_tracestats_summarizes_both_formats(self, traced, tmp_path, fmt):
        if fmt == "ndjson":
            path = str(tmp_path / "t.ndjson")
            export_ndjson(traced.trace, path)
        else:
            path = str(tmp_path / "t.chrome.json")
            export_chrome_trace(traced.trace, path)
        out = subprocess.run(
            [sys.executable, str(TRACESTATS), path, "--top", "3",
             "--bins", "5"],
            capture_output=True, text=True, timeout=120,
            env={**os.environ, "PYTHONPATH": str(REPO / "src")})
        assert out.returncode == 0, out.stderr
        assert "per-model decisions" in out.stdout
        assert "worst 3 requests" in out.stdout
        assert f"dropped={traced.metrics.dropped}" in out.stdout

    def test_tracestats_rejects_empty_trace(self, tmp_path):
        path = tmp_path / "empty.ndjson"
        path.write_text('{"type": "meta", "engine": "python"}\n')
        out = subprocess.run(
            [sys.executable, str(TRACESTATS), str(path)],
            capture_output=True, text=True, timeout=60)
        assert out.returncode != 0

    def test_tracestats_rejects_unmatched_pairs(self, tmp_path):
        doc = {"traceEvents": [
            {"ph": "b", "pid": 2, "tid": 0, "cat": "request", "id": "0x1",
             "name": "m0", "ts": 0.0,
             "args": {"req": 1, "model": 0, "status": "completed",
                      "deadline_ms": 50.0, "slack_ms": 1.0, "exit": 0,
                      "batch": 1}},
        ]}
        path = tmp_path / "broken.chrome.json"
        path.write_text(json.dumps(doc))
        out = subprocess.run(
            [sys.executable, str(TRACESTATS), str(path)],
            capture_output=True, text=True, timeout=60)
        assert out.returncode != 0
        assert "unclosed" in out.stderr


class TestSweepSurface:
    def test_trace_flag_attaches_and_defaults_off(self, table):
        runner = SweepRunner(table)
        base = dict(policy="edgeserving", rate=110.0, seed=7, horizon=1.5,
                    warmup_tasks=20)
        off = runner.run_cell(SweepSpec(**base))
        on = runner.run_cell(SweepSpec(**base, trace=True))
        assert off.trace is None
        assert on.trace is not None
        assert off.metrics == on.metrics
        assert len(on.trace.decisions) > 0

    def test_trace_flag_on_scan_engine(self, table):
        runner = SweepRunner(table)
        base = dict(policy="edgeserving", rate=110.0, seed=7, horizon=1.5,
                    warmup_tasks=20, engine="scan")
        off = runner.run_cell(SweepSpec(**base))
        on = runner.run_cell(SweepSpec(**base, trace=True))
        assert off.trace is None
        assert on.trace.meta["engine"] == "scan"
        assert off.metrics == on.metrics


class TestEngineCounters:
    """Live engine: structured counters + trace through the same tracer."""

    def _engine(self, table, tracer=None):
        from repro.runtime.server import ServedModel, ServingEngine

        class StepClock:
            def __init__(self):
                self.t = 0.0

            def __call__(self):
                self.t += 1e-3
                return self.t

        view = table.select_models([0]).restrict_exits([0, 3])
        mod = ServedModel("m0", values=None,
                          forward_fn=lambda v, x, e: np.sum(x),
                          data_fn=lambda b: np.ones((b, 2)), num_exits=2)
        sched = make_scheduler("edgeserving", view,
                               SchedulerConfig(slo=0.05, max_batch=4))
        return ServingEngine([mod], sched, clock=StepClock(),
                             tracer=tracer), view

    def test_counters_reconcile_with_completions(self, table):
        tracer = Tracer()
        eng, view = self._engine(table, tracer)
        arrivals = [Request(req_id=i, model=0, arrival=0.0)
                    for i in range(24)]
        comps, span = eng.run(arrivals, duration=0.05)
        c = eng.counters
        assert c["requests_served"] == len(comps) == 24
        assert 0 < c["batches_served"] <= 24
        assert c["dropped"] == 0
        assert c["drain_residual"] == 0
        trace = eng.trace(run="unit")
        assert trace.meta["engine"] == "live"
        assert trace.meta["run"] == "unit"
        assert len(trace.decisions) == c["batches_served"]
        done = [e for e in trace.events if e.kind == "engine-counters"]
        assert done and done[-1].payload_dict()["requests_served"] == 24

    def test_counters_without_tracer_still_populate(self, table):
        eng, _ = self._engine(table, tracer=None)
        arrivals = [Request(req_id=i, model=0, arrival=0.0)
                    for i in range(8)]
        comps, _ = eng.run(arrivals, duration=0.05)
        assert eng.counters["requests_served"] == len(comps)
        assert eng.trace() is None
