"""Runtime-substrate tests: optimizers, checkpointing (atomic/async/restore),
fault tolerance, gradient compression, sharding rules."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.distributed.collectives import (
    dequantize_int8,
    quantize_int8,
    quantize_tree,
)
from repro.distributed.sharding import (
    sanitize_spec,
    serve_rules,
    serve_rules_ep_wide,
    spec_for_param,
    train_rules,
)
from repro.optim import (
    Adafactor,
    AdamW,
    SGD,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
)
from repro.runtime.checkpoint import Checkpointer
from repro.runtime.fault_tolerance import (
    ElasticMesh,
    PreemptionGuard,
    StragglerPolicy,
)
from jax.sharding import PartitionSpec as P


class TestOptimizers:
    def _quad(self, opt, steps=200):
        # minimise ||x - 3||^2 over a small pytree
        params = {"a": jnp.zeros((4,)), "b": {"c": jnp.zeros((2, 3))}}
        target = jax.tree.map(lambda p: jnp.full(p.shape, 3.0), params)
        state = opt.init(params)
        for i in range(steps):
            grads = jax.tree.map(lambda p, t: 2 * (p - t), params, target)
            params, state = opt.step(params, grads, state, i)
        err = max(
            float(jnp.max(jnp.abs(p - t)))
            for p, t in zip(jax.tree.leaves(params), jax.tree.leaves(target))
        )
        return err

    def test_sgd_converges(self):
        assert self._quad(SGD(lr=0.05, momentum=0.5)) < 1e-2

    def test_adamw_converges(self):
        assert self._quad(AdamW(lr=0.1, weight_decay=0.0), 300) < 1e-2

    def test_adafactor_converges(self):
        assert self._quad(Adafactor(lr=0.3), 400) < 5e-2

    def test_adafactor_state_is_factored(self):
        p = {"w": jnp.zeros((256, 512)), "b": jnp.zeros((7,))}
        opt = Adafactor(min_dim_size_to_factor=128)
        st_ = opt.init(p)
        assert set(st_["v"]["w"]) == {"vr", "vc"}
        assert st_["v"]["w"]["vr"].shape == (256,)
        assert st_["v"]["w"]["vc"].shape == (512,)
        assert st_["v"]["b"]["v"].shape == (7,)  # small/1D: unfactored

    def test_adafactor_state_bytes_much_smaller(self):
        p = {"w": jnp.zeros((1024, 1024))}
        adam_bytes = sum(x.nbytes for x in jax.tree.leaves(AdamW().init(p)))
        fact_bytes = sum(x.nbytes for x in jax.tree.leaves(
            Adafactor().init(p)))
        assert fact_bytes < adam_bytes / 100

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_cosine_schedule_shape(self):
        lr = cosine_schedule(1e-3, warmup=10, total=100)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1e-3)
        assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)

    def test_mixed_dtype_params_preserved(self):
        p = {"w": jnp.zeros((8, 8), jnp.bfloat16)}
        opt = AdamW(lr=1e-2)
        s = opt.init(p)
        g = {"w": jnp.ones((8, 8), jnp.bfloat16)}
        p2, _ = opt.step(p, g, s, 0)
        assert p2["w"].dtype == jnp.bfloat16


class TestGradCompression:
    def test_quantize_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.key(0), (128,)) * 5
        q, scale = quantize_int8(x)
        err = jnp.max(jnp.abs(dequantize_int8(q, scale) - x))
        assert float(err) <= float(scale) / 2 + 1e-6

    def test_error_feedback_accumulates(self):
        grads = {"w": jnp.full((16,), 0.001)}
        deq, scales, resid = quantize_tree(grads, None)
        # residual + dequantised == original
        np.testing.assert_allclose(
            np.asarray(deq["w"], np.float64) + np.asarray(resid["w"]),
            np.asarray(grads["w"], np.float64), rtol=1e-6)

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=20, deadline=None)
    def test_property_feedback_unbiased_over_steps(self, seed):
        # With constant gradients, error feedback makes the *cumulative*
        # applied update converge to the true cumulative gradient.
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(size=(32,)) * 1e-3, jnp.float32)
        applied = jnp.zeros_like(g)
        resid = None
        steps = 50
        for _ in range(steps):
            deq, _, resid = quantize_tree({"g": g}, resid)
            applied = applied + deq["g"]
        np.testing.assert_allclose(
            np.asarray(applied) / steps, np.asarray(g), atol=2e-5)


class TestCheckpointer:
    def _tree(self, k=0):
        return {"w": jnp.arange(12.0).reshape(3, 4) + k,
                "opt": {"m": jnp.ones((5,)) * k}}

    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=False)
        ck.save(7, self._tree(1), extra={"loss": 2.5})
        step, tree, extra = ck.restore(template=self._tree())
        assert step == 7 and extra["loss"] == 2.5
        np.testing.assert_array_equal(np.asarray(tree["w"]),
                                      np.asarray(self._tree(1)["w"]))

    def test_async_save_and_wait(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=True)
        ck.save(1, self._tree(1))
        ck.save(2, self._tree(2))
        ck.wait()
        assert ck.committed_steps() == [1, 2]

    def test_atomic_commit_markers(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=False)
        ck.save(3, self._tree())
        # simulate a torn write: directory without marker is invisible
        os.makedirs(tmp_path / "step_000000009")
        assert ck.latest_step() == 3
        with pytest.raises(FileNotFoundError):
            ck.restore(step=9, template=self._tree())

    def test_keep_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
        for s in range(5):
            ck.save(s, self._tree(s))
        assert ck.committed_steps() == [3, 4]

    def test_restore_latest_by_default(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=False)
        for s in (1, 5, 3):
            ck.save(s, self._tree(s))
        step, tree, _ = ck.restore(template=self._tree())
        assert step == 5
        np.testing.assert_array_equal(np.asarray(tree["opt"]["m"]),
                                      np.full(5, 5.0))


class TestFaultTolerance:
    def test_preemption_guard_flag(self):
        g = PreemptionGuard()
        assert not g.should_stop()
        g.request_stop()
        assert g.should_stop()

    def test_preemption_guard_deadline(self):
        g = PreemptionGuard(deadline_s=0.01)
        time.sleep(0.02)
        assert g.should_stop()

    def test_elastic_mesh_proposals(self):
        em = ElasticMesh(model_axis=16)
        # full pod
        assert em.propose(256) == (16, 16, 1)
        # lost 32 chips -> shrink data axis to 8, double accumulation
        data, model, accum = em.propose(224)
        assert (data, model) == (8, 16) and accum == 2
        with pytest.raises(AssertionError):
            em.propose(8)  # below TP degree

    def test_straggler_policy_detach_and_scale(self):
        sp = StragglerPolicy(num_replicas=3, alpha=1.0)
        sp.observe(1, observed_s=0.5, expected_s=0.1)  # 5x slow
        assert sp.healthy() == [0, 2]
        from repro.core import ProfileTable
        table = ProfileTable.paper_rtx3080()
        scaled = sp.scale_profile(1, table)
        np.testing.assert_allclose(scaled.latency, table.latency * 5.0)

    def test_straggler_recovery(self):
        sp = StragglerPolicy(num_replicas=2, alpha=0.5)
        sp.observe(0, 1.0, 0.1)   # transient 10x blip
        for _ in range(10):
            sp.observe(0, 0.1, 0.1)
        assert sp.multipliers[0] < 1.2
        assert 0 in sp.healthy()


class TestShardingRules:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_sanitize_drops_nondivisible(self):
        mesh = jax.make_mesh((1,), ("model",))
        # 7 not divisible by any >1 axis; with axis size 1 everything divides
        spec = sanitize_spec((7,), P("model"), mesh)
        assert spec == P("model")

    def test_sanitize_no_duplicate_axes(self):
        mesh = self._mesh()
        spec = sanitize_spec((4, 4), P("model", "model"), mesh)
        # second use of "model" dropped
        assert spec == P("model", None)

    def test_train_rules_fsdp_embed(self):
        r = train_rules()
        assert r.axis_for("embed") == ("data",)
        assert r.axis_for("heads") == "model"
        assert r.axis_for("layers") is None

    def test_serve_rules_replicate_embed(self):
        r = serve_rules()
        assert r.axis_for("embed") is None
        assert r.seq_axes == "model"

    def test_ep_wide_shards_experts_everywhere(self):
        r = serve_rules_ep_wide()
        assert r.axis_for("expert") == ("data", "model")

    def test_spec_for_param(self):
        mesh = self._mesh()
        spec = spec_for_param((64, 128), ("embed", "heads"), train_rules(),
                              mesh)
        # jax versions differ on whether a single-axis entry is normalised
        # from ("data",) to "data"; compare semantically.
        norm = tuple(a if isinstance(a, tuple) else (a,) for a in spec)
        assert norm == (("data",), ("model",))
