"""Reusable python-vs-scan engine conformance harness.

The compiled engines (``repro.core.simfast``, ``repro.core.clusterfast``)
promise bitwise equality with their Python reference loops on the
supported family. Every suite that checks that promise used to grow its
own copy of the same scaffolding — run both engines on identical inputs,
compare decision traces and ``ServingMetrics`` field by field, assert the
request conservation law, check loud rejects. This module is the single
shared copy; ``tests/test_simfast.py`` and ``tests/test_clusterfast.py``
both build on it rather than keeping third copies in sync.

Not a test file (no ``test_`` prefix): pytest's prepend import mode puts
``tests/`` on ``sys.path``, so suites just ``import engine_conformance``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
import pytest

from repro.core import (
    ClusterSimulator,
    ScanEngineUnsupported,
    SchedulerConfig,
    ServingSimulator,
    make_dispatcher,
    make_scheduler,
    simulate_scan,
)
from repro.core.clusterfast import simulate_cluster_scan


def decisions(res):
    """The (model, exit, batch) dispatch sequence of a traced run."""
    return [(t.decision.model, t.decision.exit_idx, t.decision.batch_size)
            for t in res.traces]


def assert_metrics_close(a, b, rtol=1e-6):
    """Field-by-field ServingMetrics comparison at float tolerance.

    For exact runs prefer ``assert a == b`` (frozen dataclass: bitwise);
    this is for hypothesis sweeps where a tolerance keeps shrinking sane.
    """
    da, db = dataclasses.asdict(a), dataclasses.asdict(b)
    assert da.keys() == db.keys()
    for key in da:
        va, vb = da[key], db[key]
        if key in ("per_model", "per_device"):
            assert len(va) == len(vb), key
            for ma, mb in zip(va, vb):
                for f in ma:
                    if isinstance(ma[f], str):
                        assert ma[f] == mb[f], f"{key}.{f}"
                        continue
                    np.testing.assert_allclose(
                        ma[f], mb[f], rtol=rtol, err_msg=f"{key}.{f}")
        else:
            np.testing.assert_allclose(va, vb, rtol=rtol, err_msg=key)


def assert_conservation(res, n_arrivals):
    """completions + residual + dropped == arrivals, on any engine."""
    assert (len(res.completions) + res.metrics.residual_queue
            + res.metrics.dropped) == n_arrivals
    ids = [c.req_id for c in res.completions]
    assert len(ids) == len(set(ids))  # no request served twice


def assert_loud_reject(fn, exc=ScanEngineUnsupported, match: str = ""):
    """The scan engines must refuse what they cannot reproduce, loudly."""
    with pytest.raises(exc, match=match or None):
        fn()


# -- single-device family ------------------------------------------------------


def run_both(policy, table, arrivals, horizon, slo=0.05, model_map=None,
             num_models=3, **scan_kw):
    """Identical inputs through ServingSimulator and simulate_scan;
    conservation asserted on each; (python, scan) results returned."""
    def sched():
        return make_scheduler(policy, table, SchedulerConfig(slo=slo))

    py = ServingSimulator(sched(), table, num_models=num_models,
                          model_map=model_map).run(
        arrivals, horizon, keep_traces=True)
    sc = simulate_scan(sched(), table, arrivals, horizon,
                       num_models=num_models, model_map=model_map,
                       keep_traces=True, keep_completions=True, **scan_kw)
    assert_conservation(py, len(arrivals))
    assert_conservation(sc, len(arrivals))
    return py, sc


# -- cluster family ------------------------------------------------------------


def run_both_cluster(
    devices,
    arrivals,
    horizon,
    policy: str = "edgeserving",
    dispatcher: str = "least-loaded",
    power_d: int = 2,
    slo: float = 0.05,
    num_models: Optional[int] = None,
    warmup_tasks: int = 100,
    **scan_kw,
):
    """Identical inputs through ClusterSimulator and simulate_cluster_scan;
    conservation asserted on each; (python, scan) ClusterResults returned.

    ``scan_kw`` reaches only the compiled engine (``max_queue``,
    ``keep_completions``, ``factored``, ...)."""
    py = ClusterSimulator(
        list(devices),
        policy=policy,
        config=SchedulerConfig(slo=slo),
        dispatcher=make_dispatcher(dispatcher, slo=slo, power_d=power_d),
        num_models=num_models,
    ).run(list(arrivals), horizon, warmup_tasks=warmup_tasks)
    sc = simulate_cluster_scan(
        list(devices), list(arrivals), horizon,
        policy=policy,
        config=SchedulerConfig(slo=slo),
        dispatcher=dispatcher,
        power_d=power_d,
        num_models=num_models,
        warmup_tasks=warmup_tasks,
        **scan_kw,
    )
    n = len(arrivals)
    assert_conservation(py, n)
    if scan_kw.get("keep_completions", True):
        assert_conservation(sc, n)
    return py, sc


def assert_cluster_equal(py, sc, completions: bool = True):
    """Bitwise ClusterResult equality: completion log, span, metrics."""
    if completions:
        assert len(py.completions) == len(sc.completions)
        for a, b in zip(py.completions, sc.completions):
            assert a == b
    assert py.span == sc.span
    assert py.metrics == sc.metrics  # frozen dataclass: bitwise
