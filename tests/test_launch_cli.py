"""CLI driver smoke tests (launch/serve.py, launch/train.py plumbing)."""

import os
import subprocess
import sys

import pytest


def run_cli(args, timeout=420):
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    # A scrubbed env must not change jax backend selection: without e.g.
    # JAX_PLATFORMS=cpu the subprocess may probe for a TPU and stall in
    # metadata-retry loops on TPU-less CI hosts.
    for var in ("JAX_PLATFORMS", "JAX_PLATFORM_NAME", "XLA_FLAGS"):
        if var in os.environ:
            env[var] = os.environ[var]
    return subprocess.run(
        [sys.executable, "-m"] + args,
        capture_output=True, text=True, timeout=timeout,
        env=env,
        cwd=".",
    )


class TestServeCLI:
    def test_single_scheduler(self):
        r = run_cli(["repro.launch.serve", "--scheduler", "edgeserving",
                     "--lam", "100", "--horizon", "3"])
        assert r.returncode == 0, r.stderr[-800:]
        assert "edgeserving" in r.stdout
        assert "P95=" in r.stdout

    def test_platform_jetson(self):
        r = run_cli(["repro.launch.serve", "--scheduler", "edgeserving",
                     "--platform", "jetson", "--slo-ms", "100",
                     "--lam", "20", "--horizon", "3"])
        assert r.returncode == 0, r.stderr[-800:]


class TestTrainCLI:
    def test_smoke_train_with_resume(self, tmp_path):
        ckpt = str(tmp_path / "ck")
        r = run_cli(["repro.launch.train", "--arch", "smollm-135m",
                     "--smoke", "--steps", "6", "--batch", "2",
                     "--seq", "16", "--checkpoint-dir", ckpt,
                     "--checkpoint-every", "3"])
        assert r.returncode == 0, r.stderr[-800:]
        assert "loss=" in r.stdout
        r2 = run_cli(["repro.launch.train", "--arch", "smollm-135m",
                      "--smoke", "--steps", "8", "--batch", "2",
                      "--seq", "16", "--checkpoint-dir", ckpt, "--resume"])
        assert r2.returncode == 0, r2.stderr[-800:]
        assert "resumed from step" in r2.stdout


class TestDryRunCLI:
    def test_list_cells(self):
        r = run_cli(["repro.launch.dryrun", "--list"])
        assert r.returncode == 0, r.stderr[-800:]
        # 40 rows: 32 runnable + 8 skips with reasons
        lines = [l for l in r.stdout.splitlines() if l.startswith("(")]
        assert len(lines) == 40
        assert sum("long_500k" in l and "full-attention" in l
                   for l in lines) == 8
