"""Per-kernel validation: shape/dtype sweeps, interpret=True vs the pure-jnp
ref.py oracle (assert_allclose), plus hypothesis property checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.exit_head.ops import exit_head
from repro.kernels.exit_head.ref import confidence_from, exit_head_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.stability_score.ops import stability_scores
from repro.kernels.stability_score.ref import stability_scores_ref

TOL = {jnp.float32: dict(rtol=2e-3, atol=2e-3),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,kh,s,d", [
        (2, 4, 2, 128, 64),
        (1, 8, 2, 256, 32),
        (1, 2, 2, 64, 128),
        (2, 2, 1, 192, 64),     # uneven-ish: s multiple of blocks only
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal", [True, False])
    def test_allclose_sweep(self, b, h, kh, s, d, dtype, causal):
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (b, h, s, d), dtype)
        k = jax.random.normal(ks[1], (b, kh, s, d), dtype)
        v = jax.random.normal(ks[2], (b, kh, s, d), dtype)
        out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
        ref = flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOL[dtype])

    def test_block_shape_invariance(self):
        ks = jax.random.split(jax.random.key(1), 3)
        q = jax.random.normal(ks[0], (1, 4, 256, 64))
        k = jax.random.normal(ks[1], (1, 2, 256, 64))
        v = jax.random.normal(ks[2], (1, 2, 256, 64))
        outs = [
            np.asarray(flash_attention(q, k, v, block_q=bq, block_k=bk,
                                       interpret=True))
            for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)

    def test_causal_first_row_attends_self_only(self):
        # row 0 of a causal attention equals v[0] exactly (softmax of one).
        ks = jax.random.split(jax.random.key(2), 3)
        q = jax.random.normal(ks[0], (1, 2, 64, 32))
        k = jax.random.normal(ks[1], (1, 2, 64, 32))
        v = jax.random.normal(ks[2], (1, 2, 64, 32))
        out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out[0, :, 0]),
                                   np.asarray(v[0, :, 0]), rtol=1e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("b,h,kh,s,d,bs", [
        (2, 4, 2, 256, 64, 64),
        (1, 8, 4, 512, 128, 128),
        (3, 2, 1, 128, 32, 128),
        (1, 16, 2, 1024, 64, 256),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose_sweep(self, b, h, kh, s, d, bs, dtype):
        ks = jax.random.split(jax.random.key(3), 4)
        q = jax.random.normal(ks[0], (b, h, d), dtype)
        k = jax.random.normal(ks[1], (b, kh, s, d), dtype)
        v = jax.random.normal(ks[2], (b, kh, s, d), dtype)
        lens = jax.random.randint(ks[3], (b,), 1, s + 1)
        out = decode_attention(q, k, v, lens, block_s=bs, interpret=True)
        ref = decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOL[dtype])

    def test_length_one_returns_first_value(self):
        ks = jax.random.split(jax.random.key(4), 3)
        q = jax.random.normal(ks[0], (1, 2, 32))
        k = jax.random.normal(ks[1], (1, 2, 64, 32))
        v = jax.random.normal(ks[2], (1, 2, 64, 32))
        out = decode_attention(q, k, v, jnp.array([1]), block_s=32,
                               interpret=True)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(v[0, :, 0]),
                                   rtol=1e-5)

    def test_cache_tail_is_ignored(self):
        # garbage beyond `lengths` must not affect the result.
        ks = jax.random.split(jax.random.key(5), 3)
        q = jax.random.normal(ks[0], (1, 2, 32))
        k = jax.random.normal(ks[1], (1, 2, 128, 32))
        v = jax.random.normal(ks[2], (1, 2, 128, 32))
        lens = jnp.array([40])
        out1 = decode_attention(q, k, v, lens, block_s=64, interpret=True)
        k2 = k.at[:, :, 40:].set(1e4)
        v2 = v.at[:, :, 40:].set(-1e4)
        out2 = decode_attention(q, k2, v2, lens, block_s=64, interpret=True)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-5)


class TestExitHead:
    @pytest.mark.parametrize("t,d,v,bt,bv", [
        (8, 64, 512, 8, 128),
        (16, 128, 1024, 8, 256),
        (4, 32, 256, 4, 256),
        (32, 256, 2048, 16, 512),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose_sweep(self, t, d, v, bt, bv, dtype):
        ks = jax.random.split(jax.random.key(6), 3)
        h = jax.random.normal(ks[0], (t, d), dtype)
        g = (jax.random.normal(ks[1], (d,)) * 0.1 + 1.0).astype(dtype)
        w = (jax.random.normal(ks[2], (d, v)) / np.sqrt(d)).astype(dtype)
        idx, mx, lse = exit_head(h, g, w, block_t=bt, block_v=bv,
                                 interpret=True)
        ridx, rmx, rlse = exit_head_ref(h, g, w)
        tol = TOL[dtype]
        np.testing.assert_allclose(np.asarray(mx), np.asarray(rmx), **tol)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(rlse), **tol)
        if dtype == jnp.float32:
            np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))

    def test_confidence_is_probability(self):
        ks = jax.random.split(jax.random.key(7), 3)
        h = jax.random.normal(ks[0], (8, 64))
        g = jnp.ones((64,))
        w = jax.random.normal(ks[2], (64, 512)) * 0.2
        _, mx, lse = exit_head(h, g, w, block_t=8, block_v=128,
                               interpret=True)
        conf = np.asarray(confidence_from(mx, lse))
        assert np.all(conf > 0) and np.all(conf <= 1 + 1e-6)


class TestStabilityScoreKernel:
    @pytest.mark.parametrize("m,q,bm", [(3, 16, 8), (8, 64, 4), (5, 33, 2),
                                        (16, 128, 8)])
    def test_allclose_sweep(self, m, q, bm):
        rng = np.random.default_rng(m * 100 + q)
        w = jnp.asarray(np.sort(rng.uniform(0, 0.1, (m, q)))[:, ::-1].copy(),
                        jnp.float32)
        mask = jnp.asarray((rng.uniform(size=(m, q)) > 0.3), jnp.float32)
        lat = jnp.asarray(rng.uniform(1e-3, 2e-2, m), jnp.float32)
        bat = jnp.asarray(rng.integers(1, 5, m), jnp.int32)
        out = stability_scores(w, mask, lat, bat, tau=0.05, block_m=bm,
                               interpret=True)
        ref = stability_scores_ref(w, mask, lat, bat, 0.05, 10.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5)

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=15, deadline=None)
    def test_property_matches_scheduler_reference(self, seed):
        rng = np.random.default_rng(seed)
        m = int(rng.integers(2, 6))
        q = int(rng.integers(4, 24))
        w = jnp.asarray(np.sort(rng.uniform(0, 0.2, (m, q)))[:, ::-1].copy(),
                        jnp.float32)
        mask = jnp.asarray((rng.uniform(size=(m, q)) > 0.2), jnp.float32)
        lat = jnp.asarray(rng.uniform(1e-3, 3e-2, m), jnp.float32)
        bat = jnp.asarray(rng.integers(1, q + 1, m), jnp.int32)
        out = stability_scores(w, mask, lat, bat, tau=0.05, interpret=True)
        ref = stability_scores_ref(w, mask, lat, bat, 0.05, 10.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4)


class TestRMSNorm:
    @pytest.mark.parametrize("t,d,bt", [(8, 64, 8), (32, 512, 8),
                                        (64, 1024, 32), (16, 96, 16)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose_sweep(self, t, d, bt, dtype):
        x = jax.random.normal(jax.random.key(8), (t, d), dtype)
        g = (jax.random.normal(jax.random.key(9), (d,)) * 0.2 + 1.0).astype(
            dtype)
        out = rmsnorm(x, g, block_t=bt, interpret=True)
        ref = rmsnorm_ref(x, g, 1e-6)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOL[dtype])

    def test_unit_rows_unchanged(self):
        d = 128
        x = jnp.ones((8, d))
        out = rmsnorm(x, jnp.ones((d,)), block_t=8, interpret=True)
        np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5)
