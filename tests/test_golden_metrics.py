"""Golden-metrics regression pins.

``tests/data/golden_metrics.json`` freezes the headline numbers the docs
and benchmark write-ups quote: the four fig12 mean-violation summaries
(greedy vs lattice at 30 ms / 50 ms SLO on the batch-saturating table),
the full ``ServingMetrics`` row of the fig4 lambda=140 cell, and the
fig14 cluster summary rows (stability-aware / round-robin / JSQ
violation percentages on the heterogeneous leg, plus the G=1 scaling
cell). This test recomputes them with the reference Python engine, so
any change to the scheduler, simulator, dispatcher, traffic generator,
or metrics accounting that moves a quoted number fails loudly here
instead of silently rotting the docs.

The scan engines are pinned to the Python engines decision-by-decision
in ``tests/test_simfast.py`` / ``tests/test_clusterfast.py``; together
the suites anchor every engine to these numbers.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.core import ProfileTable, SweepRunner, SweepSpec

GOLDEN = pathlib.Path(__file__).parent / "data" / "golden_metrics.json"
LAMBDAS = (20.0, 60.0, 100.0, 140.0, 180.0, 220.0, 240.0)


@pytest.fixture(scope="module")
def golden():
    with GOLDEN.open() as f:
        return json.load(f)


@pytest.mark.parametrize("policy,slo,quoted", [
    ("edgeserving", 0.030, "3.458%"),
    ("edgeserving-lattice", 0.030, "3.328%"),
    ("edgeserving", 0.050, "2.472%"),
    ("edgeserving-lattice", 0.050, "2.196%"),
])
def test_fig12_summary_pins(golden, policy, slo, quoted):
    entry = golden["fig12"][f"{policy}/slo{int(slo * 1e3)}ms"]
    # the fixture itself must carry the number the docs quote
    assert entry["quoted"] == quoted

    runner = SweepRunner(ProfileTable.paper_rtx3080().with_batch_saturation(4))
    viols = [
        runner.run_cell(
            SweepSpec(policy=policy, rate=lam, slo=slo, seed=7, horizon=10.0)
        ).metrics.violation_ratio
        for lam in LAMBDAS
    ]
    np.testing.assert_allclose(viols, entry["per_lambda"], rtol=1e-9)
    mean = sum(viols) / len(viols)
    np.testing.assert_allclose(mean, entry["mean_violation_ratio"], rtol=1e-9)
    assert f"{mean * 100:.3f}%" == quoted


@pytest.mark.parametrize("cell,quoted", [
    ("het/stability-aware", "3.02%"),
    ("het/round-robin", "18.65%"),
    ("het/jsq", "13.30%"),
    ("scaling/G1/least-loaded", "0.45%"),
])
def test_fig14_summary_pins(golden, cell, quoted):
    """The fig14 rows the ROADMAP quotes (stability-aware ~3.0% vs
    round-robin ~18.7% on the heterogeneous leg), recomputed through the
    Python cluster engine — the cluster tier's first golden guard."""
    entry = golden["fig14"][cell]
    assert entry["quoted"] == quoted

    leg, dispatcher = cell.split("/")[0], cell.rsplit("/", 1)[1]
    fleet, size, rate = (
        ("heterogeneous", 4, 640.0) if leg == "het"
        else ("homogeneous", 1, 140.0))
    runner = SweepRunner(ProfileTable.paper_rtx3080())
    res = runner.run_cell(SweepSpec(
        policy="edgeserving", scenario="mmpp", rate=rate, seed=7,
        horizon=6.0, fleet=fleet, fleet_size=size, dispatcher=dispatcher))
    got = res.metrics.violation_ratio
    np.testing.assert_allclose(got, entry["violation_ratio"], rtol=1e-9)
    assert f"{got * 100:.2f}%" == quoted


def test_fig4_lam140_cell(golden):
    runner = SweepRunner(ProfileTable.paper_rtx3080())
    res = runner.run_cell(
        SweepSpec(policy="edgeserving", rate=140.0, seed=7, horizon=10.0))
    got = dataclasses.asdict(res.metrics)
    want = golden["fig4_lam140"]
    assert got.keys() == want.keys()
    for key in want:
        if key in ("per_model", "per_device"):
            assert len(got[key]) == len(want[key]), key
            for gm, wm in zip(got[key], want[key]):
                for f in wm:
                    np.testing.assert_allclose(
                        gm[f], wm[f], rtol=1e-9, err_msg=f"{key}.{f}")
        else:
            np.testing.assert_allclose(
                got[key], want[key], rtol=1e-9, err_msg=key)
