#!/usr/bin/env python3
"""Fail on broken intra-repo links in markdown docs.

Checks every ``[text](target)`` in the given markdown files (default:
README.md, docs/, benchmarks/README.md) whose target is a relative path —
external http(s)/mailto links are ignored — and verifies the target exists
relative to the file. Anchors (``path#section``) are checked for path
existence only.

    python tools/check_links.py            # default doc set
    python tools/check_links.py FILE...    # explicit files
"""

from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
REPO = pathlib.Path(__file__).resolve().parent.parent


def default_docs() -> "list[pathlib.Path]":
    docs = [REPO / "README.md", REPO / "benchmarks" / "README.md"]
    docs += sorted((REPO / "docs").glob("**/*.md"))
    return [d for d in docs if d.exists()]


def check(path: pathlib.Path) -> "list[str]":
    errors = []
    text = path.read_text()
    try:
        display = path.relative_to(REPO)
    except ValueError:  # explicit file outside the repo root
        display = path
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            errors.append(f"{display}: broken link -> {target}")
    return errors


def main(argv: "list[str]") -> int:
    files = [pathlib.Path(a).resolve() for a in argv] or default_docs()
    errors = []
    for f in files:
        errors.extend(check(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
