#!/usr/bin/env python3
"""Summarize a serving telemetry trace from the command line.

Reads either export format produced by ``repro.core.telemetry`` —
NDJSON (``export_ndjson``) or Chrome trace-event JSON
(``export_chrome_trace``), auto-detected — and prints three tables:

  * per-model decision histogram (dispatches, mean exit depth, mean batch);
  * the top-K worst requests by slack deficit (most-negative slack first,
    with drops ranked ahead of late completions);
  * a time-bin table (completions / violations / drops / mean exit depth
    per bin), the textual cousin of ``timeline_metrics``.

Deliberately standalone — stdlib ``json`` + numpy only, no ``repro``
imports — so a trace file can be inspected on a machine without the
package (or a JAX install). Exits non-zero on an empty trace or a Chrome
file with unmatched request ``b``/``e`` pairs.

    python tools/tracestats.py trace.ndjson
    python tools/tracestats.py trace.chrome.json --top 20 --bins 25
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Tuple

import numpy as np


def _dec(v):
    if v in ("NaN", "Infinity", "-Infinity"):
        return float(v.replace("Infinity", "inf"))
    return v


def _load_ndjson(path: str) -> Tuple[list, list, list, dict]:
    decisions, spans, events, meta = [], [], [], {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            kind = d.pop("type", None)
            if kind == "meta":
                meta = {k: _dec(v) for k, v in d.items()}
            elif kind == "decision":
                decisions.append({k: _dec(v) for k, v in d.items()})
            elif kind == "span":
                spans.append({k: _dec(v) for k, v in d.items()})
            elif kind == "event":
                events.append(d)
    return decisions, spans, events, meta


def _load_chrome(path: str) -> Tuple[list, list, list, dict]:
    with open(path) as f:
        doc = json.load(f)
    decisions, spans, events = [], [], []
    opens: Dict[Tuple[int, str], dict] = {}
    unmatched_ends = 0
    us = 1e-6
    for e in doc.get("traceEvents", []):
        ph, cat = e.get("ph"), e.get("cat")
        args = e.get("args", {}) or {}
        if ph == "i" and cat == "decision":
            decisions.append({
                "t": e["ts"] * us, "device": e.get("tid", 0),
                "model": args["model"], "exit": args["exit"],
                "batch": args["batch"],
                "depths": args.get("queue_depths", []),
            })
        elif ph == "b" and cat == "request":
            opens[(e.get("pid"), e["id"])] = {
                "req": args["req"], "model": args["model"],
                "device": e.get("tid", 0), "arrival": e["ts"] * us,
                "status": args["status"],
                "deadline": (args["deadline_ms"] or float("nan")) * 1e-3,
                "slack": (args["slack_ms"] if args["slack_ms"] is not None
                          else float("nan")) * 1e-3,
                "exit": args.get("exit", -1), "batch": args.get("batch", 0),
            }
        elif ph == "e" and cat == "request":
            span = opens.pop((e.get("pid"), e["id"]), None)
            if span is None:
                unmatched_ends += 1
                continue
            span["finish"] = e["ts"] * us
            spans.append(span)
        elif ph == "i" and cat == "residual":
            spans.append({
                "req": args.get("req"), "model": args.get("model"),
                "device": -1, "arrival": e["ts"] * us,
                "finish": float("nan"), "slack": float("nan"),
                "deadline": float("nan"), "exit": -1, "batch": 0,
                "status": "residual",
            })
        elif ph == "i" and cat == "event":
            events.append({"t": e["ts"] * us, "kind": e.get("name"),
                           "device": e.get("tid", 0), "payload": args})
    if opens or unmatched_ends:
        raise SystemExit(
            f"error: {len(opens)} unclosed 'b' and {unmatched_ends} "
            f"unmatched 'e' request events — truncated trace?")
    meta = doc.get("otherData", {})
    return decisions, spans, events, meta


def load(path: str) -> Tuple[list, list, list, dict]:
    """Auto-detect the format: Chrome JSON is one object starting with
    ``{`` whose first line never parses as a full NDJSON record."""
    with open(path) as f:
        head = f.read(4096).lstrip()
    if not head:
        raise SystemExit(f"error: {path} is empty")
    try:
        first = json.loads(head.splitlines()[0])
        if isinstance(first, dict) and "type" in first:
            return _load_ndjson(path)
    except json.JSONDecodeError:
        pass
    if head.startswith("{"):
        return _load_chrome(path)
    raise SystemExit(f"error: {path} is neither NDJSON nor Chrome trace JSON")


def _fmt(v, spec: str = ".2f") -> str:
    if v is None or (isinstance(v, float) and not math.isfinite(v)):
        return "-"
    return format(v, spec)


def decision_table(decisions: list) -> List[str]:
    models = sorted({d["model"] for d in decisions})
    lines = ["model  dispatches  requests  mean_exit  mean_batch"]
    for m in models:
        ds = [d for d in decisions if d["model"] == m]
        exits = np.array([d["exit"] for d in ds], dtype=float)
        batches = np.array([d["batch"] for d in ds], dtype=float)
        lines.append(
            f"m{m:<5} {len(ds):>10}  {int(batches.sum()):>8}  "
            f"{_fmt(float(exits.mean() + 1))}{'':>6}"
            f"{_fmt(float(batches.mean()))}")
    return lines


def worst_requests(spans: list, top: int) -> List[str]:
    ranked = [s for s in spans if s["status"] in ("completed", "dropped")]

    def deficit(s):
        # drops have no finish-slack; rank them by full-deadline deficit
        if s["status"] == "dropped" or not math.isfinite(s["slack"]):
            return -s["deadline"] if math.isfinite(s["deadline"]) else 0.0
        return s["slack"]

    ranked.sort(key=deficit)
    lines = ["req       model  status     slack_ms  deadline_ms  exit  batch"]
    for s in ranked[:top]:
        lines.append(
            f"{s['req']:<9} m{s['model']:<5} {s['status']:<9} "
            f"{_fmt(s['slack'] * 1e3 if math.isfinite(s['slack']) else s['slack']):>9}  "
            f"{_fmt(s['deadline'] * 1e3):>11}  {s['exit']:>4}  {s['batch']:>5}")
    return lines


def bin_table(spans: list, decisions: list, bins: int) -> List[str]:
    comp = [s for s in spans if s["status"] == "completed"]
    drops = [s for s in spans if s["status"] == "dropped"]
    times = ([s["finish"] for s in comp + drops]
             + [d["t"] for d in decisions])
    times = [t for t in times if isinstance(t, float) and math.isfinite(t)]
    if not times:
        return ["(no timed records)"]
    T = max(times) or 1e-12
    edges = np.linspace(0.0, T, bins + 1)

    def _bin(ts):
        return np.clip(np.searchsorted(edges, ts, side="right") - 1,
                       0, bins - 1)

    completed = np.zeros(bins, dtype=int)
    late = np.zeros(bins, dtype=int)
    exit_sum = np.zeros(bins)
    if comp:
        b = _bin(np.array([s["finish"] for s in comp]))
        completed = np.bincount(b, minlength=bins)
        slk = np.array([s["slack"] for s in comp])
        late = np.bincount(b[slk < 0], minlength=bins)
        exit_sum = np.bincount(
            b, weights=np.array([s["exit"] for s in comp]) + 1.0,
            minlength=bins)
    dropped = np.zeros(bins, dtype=int)
    if drops:
        dropped = np.bincount(_bin(np.array([s["finish"] for s in drops])),
                              minlength=bins)
    depth = np.full(bins, np.nan)
    if decisions:
        b = _bin(np.array([d["t"] for d in decisions]))
        totals = np.array([float(sum(d.get("depths", []) or [0]))
                           for d in decisions])
        cnt = np.bincount(b, minlength=bins)
        np.divide(np.bincount(b, weights=totals, minlength=bins),
                  cnt, out=depth, where=cnt > 0)
    lines = ["bin  t0_s   t1_s   done  late  drop  viol%  queue  exit"]
    for i in range(bins):
        denom = completed[i] + dropped[i]
        viol = 100.0 * (late[i] + dropped[i]) / denom if denom else None
        mexit = exit_sum[i] / completed[i] if completed[i] else None
        lines.append(
            f"{i:>3}  {edges[i]:>5.2f}  {edges[i + 1]:>5.2f}  "
            f"{completed[i]:>4}  {late[i]:>4}  {dropped[i]:>4}  "
            f"{_fmt(viol, '.1f'):>5}  {_fmt(depth[i], '.1f'):>5}  "
            f"{_fmt(mexit):>4}")
    return lines


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="NDJSON or Chrome trace-event JSON file")
    ap.add_argument("--top", type=int, default=10,
                    help="worst requests to show (default 10)")
    ap.add_argument("--bins", type=int, default=20,
                    help="time bins in the bin table (default 20)")
    args = ap.parse_args(argv)

    decisions, spans, events, meta = load(args.trace)
    if not decisions and not spans:
        print("error: trace has no decision or span records", file=sys.stderr)
        return 1

    engine = meta.get("engine", "?")
    counts: Dict[str, int] = {}
    for s in spans:
        counts[s["status"]] = counts.get(s["status"], 0) + 1
    print(f"trace: {args.trace}")
    print(f"engine={engine} decisions={len(decisions)} spans={len(spans)} "
          f"events={len(events)}")
    print("spans by status: " + ", ".join(
        f"{k}={v}" for k, v in sorted(counts.items())) if counts else "")
    if events:
        kinds: Dict[str, int] = {}
        for e in events:
            kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
        print("events by kind: " + ", ".join(
            f"{k}={v}" for k, v in sorted(kinds.items())))

    if decisions:
        print("\n== per-model decisions ==")
        print("\n".join(decision_table(decisions)))
    if spans:
        print(f"\n== worst {args.top} requests by slack deficit ==")
        print("\n".join(worst_requests(spans, args.top)))
    print(f"\n== {args.bins}-bin timeline ==")
    print("\n".join(bin_table(spans, decisions, args.bins)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
