#!/usr/bin/env python
"""Determinism & numerics static-analysis CLI.

Runs the three-layer suite from ``repro.analysis`` over the repo:

    python tools/lint.py                    # all layers, exit 1 on findings
    python tools/lint.py --ast-only         # fast AST pass only
    python tools/lint.py --update-baseline  # accept current findings
    python tools/lint.py --paths src/repro/core/urgency.py
    python tools/lint.py -v                 # also show baselined/suppressed

Exit code 0 means: no findings outside the committed baseline
(``tools/lint_baseline.json``) and no stale baseline entries are treated
as errors (stale entries are reported but informational). See
docs/static-analysis.md for the rule catalogue and workflow.
"""

from __future__ import annotations

import argparse
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        description="determinism & numerics static analysis")
    parser.add_argument("--root", default=_REPO_ROOT,
                        help="repo root to lint (default: this repo)")
    parser.add_argument("--ast-only", action="store_true",
                        help="run only the AST layer (no jax import)")
    parser.add_argument("--layers", default=None,
                        help="comma-separated subset of ast,jaxpr,pallas")
    parser.add_argument("--paths", nargs="*", default=None,
                        help="repo-relative .py files for the AST layer "
                             "(default: all of src/ and benchmarks/)")
    parser.add_argument("--baseline", default=None,
                        help="baseline file (default: "
                             "<root>/tools/lint_baseline.json)")
    parser.add_argument("--update-baseline", action="store_true",
                        help="rewrite the baseline from this run's findings")
    parser.add_argument("-v", "--verbose", action="store_true",
                        help="also print baselined and suppressed findings")
    args = parser.parse_args(argv)

    root = os.path.abspath(args.root)
    # make `python tools/lint.py` work without PYTHONPATH=src
    src = os.path.join(_REPO_ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)

    if args.layers:
        layers = tuple(x.strip() for x in args.layers.split(",") if x.strip())
    elif args.ast_only:
        layers = ("ast",)
    else:
        layers = ("ast", "jaxpr", "pallas")
    unknown = set(layers) - {"ast", "jaxpr", "pallas"}
    if unknown:
        parser.error(f"unknown layers: {sorted(unknown)}")

    if layers != ("ast",):
        # the jaxpr/pallas layers trace tiny artifacts; CPU is all they need
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from repro.analysis.runner import run_suite

    report = run_suite(
        root,
        layers,
        paths=args.paths,
        baseline_path=args.baseline,
        update_baseline=args.update_baseline,
    )
    print(report.format(verbose=args.verbose))
    if args.update_baseline:
        print(f"baseline rewritten with {len(report.accepted)} entr"
              f"{'y' if len(report.accepted) == 1 else 'ies'}")
        return 0
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
