"""Pallas kernel auditor — layout checks on the *real* ``pallas_call``.

Layer 3 of the static-analysis suite. Instead of a hand-maintained shadow
registry of block shapes (which would drift), the auditor monkeypatches
``jax.experimental.pallas.pallas_call`` with a recorder and invokes each
kernel wrapper at its manifest-declared deployment envelope: whatever
grid / BlockSpecs / scratch the kernel actually constructs is what gets
audited, with nothing compiled or executed. Checks per captured call:

  PAL001  BlockSpec/grid divisibility — every blocked operand dimension
          must be a multiple of its block dimension (the repo's kernels
          pad on the host; a misaligned block silently reads garbage or
          asserts at Mosaic-lowering time on real TPUs only).
  PAL002  index-map bounds — evaluating each spec's ``index_map`` over the
          whole grid must keep every block inside its operand.
  PAL003  explicit memory-space annotations — every BlockSpec must say
          where its block lives (``pltpu.VMEM``/``SMEM``/...); an
          unannotated spec compiles today and moves silently when the
          Pallas default changes.
  PAL004  VMEM footprint — the per-grid-step working set (VMEM blocks +
          scratch) must fit the manifest budget (~16 MB/core).
"""

from __future__ import annotations

import dataclasses
import inspect
import itertools
import math
from typing import Any, List, Optional, Sequence, Tuple

from repro.analysis.detlint import Finding
from repro.analysis import manifest as _manifest

__all__ = ["CapturedPallasCall", "capture_pallas_calls", "audit_captured",
           "audit_kernel", "audit_kernel_manifest"]

_MAX_GRID_POINTS = 65536   # bound on exhaustive index-map evaluation


@dataclasses.dataclass
class CapturedPallasCall:
    """One recorded ``pallas_call`` layout plus its operand shapes."""

    grid: Tuple[int, ...]
    in_specs: List[Any]
    out_specs: List[Any]
    out_shapes: List[Any]            # ShapeDtypeStruct leaves
    scratch_shapes: Tuple[Any, ...]
    operands: List[Tuple[Tuple[int, ...], str]]   # (shape, dtype) per input


def _as_list(x) -> list:
    if x is None:
        return []
    if isinstance(x, (list, tuple)):
        return list(x)
    return [x]


def capture_pallas_calls(fn, *args, **kwargs) -> List[CapturedPallasCall]:
    """Invoke ``fn`` with ``pallas_call`` replaced by a recorder.

    The recorder returns zeros of ``out_shape`` so wrapper post-processing
    (slicing off padding, reshapes) still runs; nothing is lowered.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl_mod

    captured: List[CapturedPallasCall] = []
    real = pl_mod.pallas_call

    def recorder(kernel, *, grid=None, in_specs=None, out_specs=None,
                 out_shape=None, scratch_shapes=(), **kw):
        def run(*operands):
            grid_t = (grid,) if isinstance(grid, int) else tuple(grid or ())
            captured.append(CapturedPallasCall(
                grid=grid_t,
                in_specs=_as_list(in_specs),
                out_specs=_as_list(out_specs),
                out_shapes=jax.tree.leaves(out_shape),
                scratch_shapes=tuple(scratch_shapes or ()),
                operands=[(tuple(o.shape), str(o.dtype)) for o in operands],
            ))
            return jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), out_shape)

        return run

    pl_mod.pallas_call = recorder
    try:
        fn(*args, **kwargs)
    finally:
        pl_mod.pallas_call = real
    return captured


# ---------------------------------------------------------------------------
# checks
# ---------------------------------------------------------------------------


def _is_smem(memory_space) -> bool:
    return memory_space is not None and "smem" in str(memory_space).lower()


def _block_bytes(block_shape, shape, dtype) -> int:
    import numpy as np

    dims = [s if b is None else b
            for b, s in zip(block_shape, shape)] if block_shape else shape
    return int(math.prod(dims)) * np.dtype(dtype).itemsize


def _check_spec(name, kind, i, spec, shape, dtype, grid, path, line,
                findings: List[Finding]):
    """Divisibility + index-map bounds + memory-space presence for one
    (BlockSpec, operand) pair."""
    where = f"{name} {kind}[{i}]"
    if getattr(spec, "memory_space", None) is None:
        findings.append(Finding(
            "PAL003", path, line,
            f"{where}: BlockSpec has no explicit memory_space; declare "
            f"pltpu.VMEM/SMEM so placement survives Pallas default changes",
            snippet=f"{where}::memory-space"))
    block = getattr(spec, "block_shape", None)
    if block is None:
        return                      # whole-operand spec: nothing to tile
    block = tuple(block)
    if len(block) != len(shape):
        findings.append(Finding(
            "PAL001", path, line,
            f"{where}: block rank {len(block)} != operand rank "
            f"{len(shape)} (shape {shape})",
            snippet=f"{where}::rank"))
        return
    full = tuple(s if b is None else b for b, s in zip(block, shape))
    for d, (b, s) in enumerate(zip(full, shape)):
        if b <= 0 or s % b != 0:
            findings.append(Finding(
                "PAL001", path, line,
                f"{where}: operand dim {d} ({s}) is not divisible by "
                f"block dim ({b}); pad on the host before the call",
                snippet=f"{where}::div[{d}]"))
    index_map = getattr(spec, "index_map", None)
    if index_map is None or not grid:
        return
    n_points = math.prod(grid)
    if n_points > _MAX_GRID_POINTS:
        return                      # declared envelope too large to sweep
    for point in itertools.product(*(range(g) for g in grid)):
        try:
            idx = index_map(*point)
        except TypeError:
            findings.append(Finding(
                "PAL002", path, line,
                f"{where}: index_map arity does not match grid rank "
                f"{len(grid)}",
                snippet=f"{where}::arity"))
            return
        idx = (idx,) if not isinstance(idx, (tuple, list)) else tuple(idx)
        if len(idx) != len(full):
            findings.append(Finding(
                "PAL002", path, line,
                f"{where}: index_map returns {len(idx)} indices for a "
                f"rank-{len(full)} block",
                snippet=f"{where}::idx-rank"))
            return
        for d, (ix, b, s) in enumerate(zip(idx, full, shape)):
            ix = int(ix)
            if ix < 0 or (ix + 1) * b > s:
                findings.append(Finding(
                    "PAL002", path, line,
                    f"{where}: grid point {point} maps dim {d} to block "
                    f"{ix} => elements [{ix * b}, {(ix + 1) * b}) outside "
                    f"operand dim {s}",
                    snippet=f"{where}::oob[{d}]"))
                return


def audit_captured(call: CapturedPallasCall, *, name: str,
                   vmem_budget_bytes: int = _manifest.VMEM_BUDGET_BYTES,
                   path: str = "<kernel>", line: int = 1) -> List[Finding]:
    """Run all layout checks on one captured call."""
    findings: List[Finding] = []
    if len(call.in_specs) != len(call.operands):
        findings.append(Finding(
            "PAL001", path, line,
            f"{name}: {len(call.in_specs)} in_specs for "
            f"{len(call.operands)} operands",
            snippet=f"{name}::spec-count"))
        return findings

    vmem = 0
    for i, (spec, (shape, dtype)) in enumerate(
            zip(call.in_specs, call.operands)):
        _check_spec(name, "in", i, spec, shape, dtype, call.grid, path,
                    line, findings)
        if not _is_smem(getattr(spec, "memory_space", None)):
            vmem += _block_bytes(getattr(spec, "block_shape", None), shape,
                                 dtype)
    for i, (spec, out) in enumerate(zip(call.out_specs, call.out_shapes)):
        shape, dtype = tuple(out.shape), str(out.dtype)
        _check_spec(name, "out", i, spec, shape, dtype, call.grid, path,
                    line, findings)
        if not _is_smem(getattr(spec, "memory_space", None)):
            vmem += _block_bytes(getattr(spec, "block_shape", None), shape,
                                 dtype)
    for scratch in call.scratch_shapes:
        shape = tuple(getattr(scratch, "shape", ()))
        dtype = getattr(scratch, "dtype", "float32")
        if not _is_smem(getattr(scratch, "memory_space", None)):
            vmem += _block_bytes(None, shape, dtype)

    if vmem > vmem_budget_bytes:
        findings.append(Finding(
            "PAL004", path, line,
            f"{name}: per-step VMEM working set ~{vmem / 2**20:.2f} MiB "
            f"exceeds the {vmem_budget_bytes / 2**20:.0f} MiB budget; "
            f"shrink blocks or split the kernel",
            snippet=f"{name}::vmem"))
    return findings


def _kernel_location(fn) -> Tuple[str, int]:
    target = fn
    while hasattr(target, "func"):
        target = target.func
    try:
        path = inspect.getsourcefile(target) or "<kernel>"
        _, line = inspect.getsourcelines(target)
        return path, line
    except (TypeError, OSError):
        return "<kernel>", 1


def audit_kernel(spec) -> List[Finding]:
    """Capture + audit one manifest :class:`KernelSpec`."""
    fn, args, kwargs = spec.build()
    path, line = _kernel_location(fn)
    try:
        calls = capture_pallas_calls(fn, *args, **kwargs)
    except Exception as e:
        return [Finding(
            "PAL000", path, line,
            f"kernel {spec.name!r} failed under capture: {e}",
            snippet=f"{spec.name}::capture-error")]
    if not calls:
        return [Finding(
            "PAL000", path, line,
            f"kernel {spec.name!r} made no pallas_call at the audited "
            f"envelope (dead wrapper or capture miss)",
            snippet=f"{spec.name}::no-call")]
    findings: List[Finding] = []
    for call in calls:
        findings.extend(audit_captured(
            call, name=spec.name,
            vmem_budget_bytes=spec.vmem_budget_bytes, path=path, line=line))
    return findings


def audit_kernel_manifest(specs: Optional[Sequence] = None) -> List[Finding]:
    if specs is None:
        specs = _manifest.KERNEL_SPECS
    findings: List[Finding] = []
    for spec in specs:
        findings.extend(audit_kernel(spec))
    return findings
