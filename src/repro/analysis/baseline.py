"""Committed-baseline store for accepted analyzer findings.

The baseline is a reviewed, committed JSON file (``tools/lint_baseline.json``)
listing findings the repo explicitly accepts, each with a justification.
``python tools/lint.py`` exits nonzero on any finding *not* in the baseline;
``--update-baseline`` rewrites the file from the current run (preserving
justifications of entries that survive) so every newly accepted finding is
an explicit diff in review.

Entries match on ``(rule, path, snippet)`` — the stripped source line, not
the line number — so unrelated edits that shift code do not invalidate the
baseline, while any edit to the offending line itself resurfaces the
finding for re-review. Matching is multiset-aware: two identical lines need
two entries.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
from typing import List, Sequence, Tuple

from repro.analysis.detlint import Finding

__all__ = ["Baseline"]


@dataclasses.dataclass
class Baseline:
    entries: List[dict] = dataclasses.field(default_factory=lambda: [])

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls([])
        with open(path, "r", encoding="utf-8") as f:
            data = json.load(f)
        return cls(list(data.get("findings", [])))

    def save(self, path: str) -> None:
        payload = {
            "__comment__": (
                "Accepted static-analysis findings (see docs/"
                "static-analysis.md). Every entry needs a justification; "
                "regenerate with `python tools/lint.py --update-baseline`."),
            "version": 1,
            "findings": self.entries,
        }
        with open(path, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2, sort_keys=False)
            f.write("\n")

    @staticmethod
    def _key(entry_or_finding) -> Tuple[str, str, str]:
        if isinstance(entry_or_finding, Finding):
            return entry_or_finding.fingerprint
        e = entry_or_finding
        return (e.get("rule", ""), e.get("path", ""), e.get("snippet", ""))

    def split(self, findings: Sequence[Finding]
              ) -> Tuple[List[Finding], List[Finding], List[dict]]:
        """Partition ``findings`` into (new, accepted) and also return the
        baseline entries that matched nothing (stale — the code was fixed
        but the baseline kept the debt marker)."""
        budget = collections.Counter(self._key(e) for e in self.entries)
        new: List[Finding] = []
        accepted: List[Finding] = []
        for f in findings:
            if budget.get(f.fingerprint, 0) > 0:
                budget[f.fingerprint] -= 1
                accepted.append(f)
            else:
                new.append(f)
        stale = []
        for e in self.entries:
            k = self._key(e)
            if budget.get(k, 0) > 0:
                budget[k] -= 1
                stale.append(e)
        return new, accepted, stale

    def rebuilt_from(self, findings: Sequence[Finding]) -> "Baseline":
        """A new baseline holding exactly ``findings``, carrying over the
        justification of any entry whose fingerprint survives."""
        just = {}
        for e in self.entries:
            just.setdefault(self._key(e), e.get("justification", ""))
        entries = []
        for f in sorted(findings, key=lambda f: (f.path, f.rule, f.line)):
            entries.append({
                "rule": f.rule,
                "path": f.path,
                "snippet": f.snippet,
                "justification": just.get(f.fingerprint, "TODO: justify"),
            })
        return Baseline(entries)
