"""Determinism & numerics static analysis (the bitwise-equivalence police).

Every headline guarantee in this repro — scan engine bitwise-equal to the
Python loop, parallel == serial sweeps, traced == untraced runs — rests on a
determinism discipline (float64 scheduling arithmetic, seeded RNG, no
wall-clock or iteration-order leaks in engine code) that property tests can
only catch *after* a violation ships. This package enforces the contract
statically, in three layers:

  * :mod:`repro.analysis.detlint`       — AST rule engine (DET001-DET006)
    over ``src/`` and ``benchmarks/`` with inline suppressions and a
    committed baseline;
  * :mod:`repro.analysis.jaxpr_audit`   — traces the compiled artifacts
    named in the precision manifest to jaxprs and checks dtype contracts,
    a primitive denylist, and no-recompile guards;
  * :mod:`repro.analysis.pallas_audit`  — captures each ``kernels/*``
    ``pallas_call`` layout and verifies BlockSpec/grid divisibility,
    index-map bounds, the VMEM footprint budget, and explicit memory-space
    annotations.

``python tools/lint.py`` runs all three; see docs/static-analysis.md for
the rule catalogue and the suppression/baseline workflow.
"""

from repro.analysis.detlint import (  # noqa: F401
    DetlintConfig,
    Finding,
    lint_paths,
    lint_source,
)
from repro.analysis.baseline import Baseline  # noqa: F401
from repro.analysis.jaxpr_audit import (  # noqa: F401
    audit_artifact,
    audit_jaxpr,
    no_recompile_findings,
)
from repro.analysis.pallas_audit import audit_kernel, capture_pallas_calls  # noqa: F401
from repro.analysis.runner import run_suite  # noqa: F401

__all__ = [
    "Baseline",
    "DetlintConfig",
    "Finding",
    "audit_artifact",
    "audit_jaxpr",
    "audit_kernel",
    "capture_pallas_calls",
    "lint_paths",
    "lint_source",
    "no_recompile_findings",
    "run_suite",
]
