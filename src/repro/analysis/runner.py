"""Suite runner: the three analysis layers behind one entry point.

``run_suite`` is what ``tools/lint.py`` (and CI, and the tier-1
"repo-is-clean" test) calls: it runs the requested layers, subtracts the
committed baseline, and renders a report whose exit code is nonzero iff
non-baselined findings remain. Tests inject polluted manifests / kernel
registries to prove each layer turns a seeded violation into a nonzero
exit with file:line output.
"""

from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Sequence, Tuple

from repro.analysis.baseline import Baseline
from repro.analysis.detlint import (
    DetlintConfig,
    Finding,
    default_config,
    lint_paths,
)

__all__ = ["SuiteReport", "run_suite", "DEFAULT_LAYERS"]

DEFAULT_LAYERS = ("ast", "jaxpr", "pallas")


@dataclasses.dataclass
class SuiteReport:
    findings: List[Finding]              # everything the layers produced
    new: List[Finding]                   # not covered by the baseline
    accepted: List[Finding]              # baselined
    stale_baseline: List[dict]           # baseline entries matching nothing
    suppressed: List[Finding]            # inline-suppressed (AST layer)
    layers: Tuple[str, ...]
    files_scanned: int = 0

    @property
    def exit_code(self) -> int:
        return 1 if self.new else 0

    def format(self, verbose: bool = False) -> str:
        lines: List[str] = []
        for f in self.new:
            lines.append(f.format())
        if verbose:
            for f in self.accepted:
                lines.append(f"{f.format()}  [baselined]")
            for f in self.suppressed:
                lines.append(f"{f.format()}  [suppressed inline]")
        for e in self.stale_baseline:
            lines.append(
                f"{e.get('path')}: stale baseline entry "
                f"({e.get('rule')} {e.get('snippet')!r}) — the finding is "
                f"gone; run --update-baseline to drop it")
        lines.append(
            f"detlint: {self.files_scanned} files, layers "
            f"{'+'.join(self.layers)}: {len(self.new)} finding(s), "
            f"{len(self.accepted)} baselined, {len(self.suppressed)} "
            f"suppressed, {len(self.stale_baseline)} stale baseline "
            f"entr{'y' if len(self.stale_baseline) == 1 else 'ies'}")
        return "\n".join(lines)


def run_suite(
    root: str,
    layers: Sequence[str] = DEFAULT_LAYERS,
    *,
    paths: Optional[Sequence[str]] = None,
    config: Optional[DetlintConfig] = None,
    baseline_path: Optional[str] = None,
    update_baseline: bool = False,
    artifacts: Optional[Sequence] = None,
    recompile_guards: Optional[Sequence] = None,
    kernel_specs: Optional[Sequence] = None,
) -> SuiteReport:
    """Run the analysis layers over the repo at ``root``.

    ``artifacts`` / ``recompile_guards`` / ``kernel_specs`` default to the
    precision manifest; tests inject synthetic ones. ``paths`` restricts
    the AST layer to specific repo-relative files. With
    ``update_baseline``, the baseline file is rewritten from this run's
    findings and the report treats everything as accepted.
    """
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    files_scanned = 0

    if "ast" in layers:
        if config is None:
            config = default_config()
        from repro.analysis.detlint import iter_lint_files

        scan = list(paths) if paths is not None else list(
            iter_lint_files(root))
        files_scanned = len(scan)
        got, sup = lint_paths(root, scan, config)
        findings.extend(got)
        suppressed.extend(sup)

    if "jaxpr" in layers:
        from repro.analysis.jaxpr_audit import (
            audit_precision_manifest,
            audit_recompile_guards,
        )

        findings.extend(
            _relativize(audit_precision_manifest(artifacts), root))
        findings.extend(
            _relativize(audit_recompile_guards(recompile_guards), root))

    if "pallas" in layers:
        from repro.analysis.pallas_audit import audit_kernel_manifest

        findings.extend(_relativize(audit_kernel_manifest(kernel_specs),
                                    root))

    if baseline_path is None:
        baseline_path = os.path.join(root, "tools", "lint_baseline.json")
    baseline = Baseline.load(baseline_path)

    if update_baseline:
        baseline.rebuilt_from(findings).save(baseline_path)
        return SuiteReport(findings, [], findings, [], suppressed,
                           tuple(layers), files_scanned)

    new, accepted, stale = baseline.split(findings)
    return SuiteReport(findings, new, accepted, stale, suppressed,
                       tuple(layers), files_scanned)


def _relativize(findings: List[Finding], root: str) -> List[Finding]:
    """Rewrite absolute artifact paths (from inspect) repo-relative so the
    report prints clickable repo paths."""
    root = os.path.abspath(root)
    out = []
    for f in findings:
        path = f.path
        if os.path.isabs(path):
            try:
                rel = os.path.relpath(path, root)
            except ValueError:
                rel = path
            if not rel.startswith(".."):
                path = rel.replace(os.sep, "/")
        out.append(dataclasses.replace(f, path=path))
    return out
