"""detlint — AST rules for the determinism contract (Layer 1).

Each rule protects a specific bitwise guarantee (docs/static-analysis.md has
the full catalogue with rationale):

  DET001  unseeded module-level RNG (``np.random.*`` legacy API, stdlib
          ``random.*`` module functions) — global RNG state makes runs
          order- and import-dependent; use ``np.random.default_rng(seed)``.
  DET002  wall-clock reads (``time.time``/``perf_counter``/``monotonic``,
          ``datetime.now``) inside engine modules — simulated time is the
          only clock the engines may consult; wall-clock leaks break
          rerun-bitwise and traced==untraced guarantees.
  DET003  iteration over a ``set`` feeding numeric accumulation or trace
          emission — set order is salted per process; a sum or an appended
          record taken in set order differs across runs. (``dict`` is
          insertion-ordered since 3.7 and deliberately not flagged.)
  DET004  mutable default arguments — shared-across-calls state that makes
          results depend on call history.
  DET005  float32/float16/bfloat16 literals, casts, or dtypes in declared
          float64 scheduling paths (the precision manifest's
          ``FLOAT64_PATHS``) — a silent downcast on the scoring path voids
          the cross-engine bitwise contract.
  DET006  bare ``except:`` and ``is`` comparisons against literals —
          swallowed control-flow exceptions and identity-vs-equality bugs.

Suppression: append ``# detlint: disable=DET0xx`` (comma-separated list)
to the offending line. Repo-wide accepted findings live in the committed
baseline (see :mod:`repro.analysis.baseline`).
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable, List, Optional, Sequence, Tuple

__all__ = ["Finding", "DetlintConfig", "RULES", "lint_source", "lint_paths",
           "default_config", "iter_lint_files"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer finding, shared by all three layers.

    ``snippet`` is the stripped source line (or artifact detail for the
    jaxpr/Pallas layers): baselines match on ``(rule, path, snippet)`` so
    unrelated edits that shift line numbers do not invalidate them.
    """

    rule: str
    path: str
    line: int
    message: str
    snippet: str = ""

    @property
    def fingerprint(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.snippet)

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


RULES = {
    "DET001": "unseeded module-level RNG",
    "DET002": "wall-clock read in engine module",
    "DET003": "set iteration feeding accumulation/emission",
    "DET004": "mutable default argument",
    "DET005": "float32 in declared float64 path",
    "DET006": "bare except / 'is' on literal",
}


@dataclasses.dataclass(frozen=True)
class DetlintConfig:
    """Rule scoping (defaults come from the precision manifest).

    ``engine_modules``: repo-relative paths DET002 applies to.
    ``timing_allowlist``: ``(path, qualname)`` pairs where a wall-clock
        read is an explicit, documented timing context.
    ``float64_paths``: repo-relative prefixes under the float64 contract
        (DET005 scope).
    ``float32_allowances``: ``(path, qualname-prefix)`` pairs naming the
        declared float32 tier inside a float64 path (each carries a
        justification in the manifest).
    """

    engine_modules: Tuple[str, ...] = ()
    timing_allowlist: Tuple[Tuple[str, str], ...] = ()
    float64_paths: Tuple[str, ...] = ()
    float32_allowances: Tuple[Tuple[str, str], ...] = ()


def default_config() -> DetlintConfig:
    from repro.analysis import manifest

    return DetlintConfig(
        engine_modules=manifest.ENGINE_MODULES,
        timing_allowlist=tuple(
            (a.path, a.scope) for a in manifest.TIMING_ALLOWLIST),
        float64_paths=manifest.FLOAT64_PATHS,
        float32_allowances=tuple(
            (a.path, a.scope) for a in manifest.FLOAT32_ALLOWANCES),
    )


# -- rule data ---------------------------------------------------------------

# numpy legacy global-state API (np.random.<fn>). The Generator API
# (default_rng / Generator / SeedSequence / PCG64) is the seeded replacement
# and is never flagged.
_NP_GLOBAL_RNG = frozenset({
    "seed", "rand", "randn", "random", "random_sample", "ranf", "sample",
    "randint", "random_integers", "choice", "bytes", "shuffle", "permutation",
    "uniform", "normal", "standard_normal", "exponential", "poisson",
    "binomial", "beta", "gamma", "lognormal", "laplace", "pareto",
    "get_state", "set_state",
})

# stdlib random module-level functions (the hidden global Random instance).
# random.Random(seed) / SystemRandom are explicit instances and not flagged.
_STDLIB_RNG = frozenset({
    "seed", "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "gauss", "normalvariate", "lognormvariate",
    "expovariate", "betavariate", "gammavariate", "triangular",
    "vonmisesvariate", "paretovariate", "weibullvariate", "getrandbits",
    "randbytes", "getstate", "setstate",
})

_WALL_CLOCK = frozenset({
    "time.time", "time.time_ns", "time.perf_counter", "time.perf_counter_ns",
    "time.monotonic", "time.monotonic_ns", "time.process_time",
    "time.process_time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
})

_F32_ATTRS = frozenset({"float32", "float16", "bfloat16"})
_F32_STRINGS = frozenset({"float32", "float16", "bfloat16", "f32", "f16",
                          "bf16"})
_MUTABLE_FACTORIES = frozenset({"list", "dict", "set", "defaultdict", "deque",
                                "Counter", "OrderedDict"})
_EMIT_METHODS = frozenset({"append", "extend", "add", "record", "emit",
                           "write", "put"})

_SUPPRESS_RE = re.compile(r"#\s*detlint:\s*disable=([A-Z0-9,\s]+)")


def _suppressions(source: str) -> dict:
    """line number -> set of rule ids suppressed on that line."""
    out = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, lines: Sequence[str], config: DetlintConfig):
        self.path = path
        self.lines = lines
        self.config = config
        self.findings: List[Finding] = []
        self.scope: List[str] = []          # qualname stack
        self.set_names: List[set] = [set()]  # per-scope names bound to sets
        # import alias maps: local name -> canonical dotted module
        self.modules: dict = {}
        # names imported directly from `random` / `time` / `datetime`
        self.from_funcs: dict = {}

        self.in_f64_path = any(
            path.startswith(p) for p in config.float64_paths)
        self.is_engine = path in set(config.engine_modules)

    # -- helpers ------------------------------------------------------------

    def _emit(self, rule: str, node: ast.AST, message: str):
        line = getattr(node, "lineno", 1)
        snippet = ""
        if 1 <= line <= len(self.lines):
            snippet = self.lines[line - 1].strip()
        self.findings.append(Finding(rule, self.path, line, message, snippet))

    def _qualname(self) -> str:
        return ".".join(self.scope)

    def _dotted(self, node: ast.AST) -> Optional[str]:
        """Resolve an attribute chain to a canonical dotted name, mapping
        import aliases (``np`` -> ``numpy``) at the root."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            root = node.id
            canon = self.modules.get(root)
            if canon is None and root in self.from_funcs:
                canon = self.from_funcs[root]
                if parts:
                    return canon + "." + ".".join(reversed(parts))
                return canon
            parts.append(canon if canon is not None else root)
            return ".".join(reversed(parts))
        return None

    def _allowed_f32(self) -> bool:
        qn = self._qualname()
        for path, scope in self.config.float32_allowances:
            if path == self.path and (qn == scope or
                                      qn.startswith(scope + ".")):
                return True
        return False

    def _allowed_timing(self) -> bool:
        qn = self._qualname()
        for path, scope in self.config.timing_allowlist:
            if path == self.path and (qn == scope or
                                      qn.startswith(scope + ".")):
                return True
        return False

    # -- imports ------------------------------------------------------------

    def visit_Import(self, node: ast.Import):
        for alias in node.names:
            self.modules[alias.asname or alias.name.split(".")[0]] = (
                alias.name if alias.asname else alias.name.split(".")[0])
            if alias.asname:
                self.modules[alias.asname] = alias.name
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module is None:
            return
        for alias in node.names:
            local = alias.asname or alias.name
            full = f"{node.module}.{alias.name}"
            # `from numpy import random` binds a module; `from random import
            # randint` binds a function. Both resolve through one map.
            if alias.name in ("random",) and node.module in ("numpy", "jax"):
                self.modules[local] = full
            elif node.module in ("random", "time", "datetime"):
                self.from_funcs[local] = full
        self.generic_visit(node)

    # -- scope tracking ------------------------------------------------------

    def _visit_scoped(self, node, name: str):
        self.scope.append(name)
        self.set_names.append(set())
        self.generic_visit(node)
        self.set_names.pop()
        self.scope.pop()

    def visit_ClassDef(self, node):
        self._visit_scoped(node, node.name)

    def visit_FunctionDef(self, node):
        self._check_det004(node)
        self._visit_scoped(node, node.name)

    def visit_AsyncFunctionDef(self, node):
        self._check_det004(node)
        self._visit_scoped(node, node.name)

    # -- DET004 --------------------------------------------------------------

    def _check_det004(self, node):
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None]
        for d in defaults:
            mutable = isinstance(
                d, (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                    ast.SetComp))
            if (isinstance(d, ast.Call) and isinstance(d.func, ast.Name)
                    and d.func.id in _MUTABLE_FACTORIES):
                mutable = True
            if mutable:
                self._emit(
                    "DET004", d,
                    f"mutable default argument in {node.name}() is shared "
                    f"across calls; default to None and create inside",
                )

    # -- DET001 / DET002 (calls) ---------------------------------------------

    def visit_Call(self, node: ast.Call):
        dotted = self._dotted(node.func)
        if dotted:
            self._check_rng(node, dotted)
            self._check_clock(node, dotted)
        self.generic_visit(node)

    def _check_rng(self, node, dotted: str):
        parts = dotted.split(".")
        if (len(parts) >= 3 and parts[0] == "numpy" and parts[1] == "random"
                and parts[-1] in _NP_GLOBAL_RNG):
            self._emit(
                "DET001", node,
                f"{dotted}() draws from the global numpy RNG; use a seeded "
                f"np.random.default_rng(seed) generator",
            )
        elif (len(parts) == 2 and parts[0] == "random"
              and parts[1] in _STDLIB_RNG):
            self._emit(
                "DET001", node,
                f"{dotted}() draws from the hidden global random.Random; "
                f"use a seeded random.Random(seed) instance",
            )

    def _check_clock(self, node, dotted: str):
        if not self.is_engine or dotted not in _WALL_CLOCK:
            return
        if self._allowed_timing():
            return
        self._emit(
            "DET002", node,
            f"{dotted}() reads the wall clock inside an engine module; "
            f"engines must consume simulated/injected time only (or add "
            f"the enclosing function to the manifest TIMING_ALLOWLIST)",
        )

    # -- DET003 --------------------------------------------------------------

    def visit_Assign(self, node: ast.Assign):
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            if self._is_set_expr(node.value):
                self.set_names[-1].add(node.targets[0].id)
            else:
                self.set_names[-1].discard(node.targets[0].id)
        self.generic_visit(node)

    def _is_set_expr(self, node) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            return node.func.id in ("set", "frozenset")
        return False

    def _iter_is_set(self, node) -> bool:
        if self._is_set_expr(node):
            return True
        if isinstance(node, ast.Name):
            return any(node.id in names for names in self.set_names)
        return False

    def visit_For(self, node: ast.For):
        if self._iter_is_set(node.iter) and self._body_accumulates(node.body):
            self._emit(
                "DET003", node,
                "iterating a set in salted hash order feeds an accumulation "
                "or emission; iterate sorted(...) instead",
            )
        self.generic_visit(node)

    def _body_accumulates(self, body) -> bool:
        for stmt in body:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.AugAssign):
                    return True
                if (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Attribute)
                        and sub.func.attr in _EMIT_METHODS):
                    return True
        return False

    # -- DET005 --------------------------------------------------------------

    def visit_Attribute(self, node: ast.Attribute):
        if (self.in_f64_path and node.attr in _F32_ATTRS
                and not self._allowed_f32()):
            self._emit(
                "DET005", node,
                f".{node.attr} in a declared float64 scheduling path; the "
                f"bitwise cross-engine contract requires float64 (or a "
                f"manifest allowance with a tolerance-bound test)",
            )
        self.generic_visit(node)

    def _check_dtype_string(self, node: ast.Call):
        candidates = []
        for kw in node.keywords:
            if kw.arg == "dtype":
                candidates.append(kw.value)
        func = node.func
        if isinstance(func, ast.Attribute) and func.attr in ("astype", "view"):
            candidates.extend(node.args[:1])
        for c in candidates:
            if (isinstance(c, ast.Constant) and isinstance(c.value, str)
                    and c.value in _F32_STRINGS):
                self._emit(
                    "DET005", c,
                    f"dtype string {c.value!r} in a declared float64 "
                    f"scheduling path",
                )

    # -- DET006 --------------------------------------------------------------

    def visit_ExceptHandler(self, node: ast.ExceptHandler):
        if node.type is None:
            self._emit(
                "DET006", node,
                "bare except: swallows KeyboardInterrupt/SystemExit; catch "
                "Exception (or narrower)",
            )
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare):
        operands = [node.left] + list(node.comparators)
        for i, op in enumerate(node.ops):
            if isinstance(op, (ast.Is, ast.IsNot)):
                for side in (operands[i], operands[i + 1]):
                    if (isinstance(side, ast.Constant)
                            and side.value is not None
                            and side.value is not True
                            and side.value is not False):
                        self._emit(
                            "DET006", node,
                            f"'is' comparison against literal "
                            f"{side.value!r}; identity of interned values "
                            f"is an implementation detail — use ==",
                        )
                        break
        self.generic_visit(node)

    # DET005 dtype-string check rides on every call
    def generic_visit(self, node):
        if (isinstance(node, ast.Call) and self.in_f64_path
                and not self._allowed_f32()):
            self._check_dtype_string(node)
        super().generic_visit(node)


# -- entry points ------------------------------------------------------------


def lint_source(
    source: str, path: str, config: Optional[DetlintConfig] = None,
) -> Tuple[List[Finding], List[Finding]]:
    """Lint one file's source. Returns ``(findings, suppressed)`` where
    ``suppressed`` are findings silenced by an inline
    ``# detlint: disable=...`` comment on their line."""
    if config is None:
        config = default_config()
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("DET000", path, e.lineno or 1,
                        f"syntax error: {e.msg}")], []
    lines = source.splitlines()
    linter = _Linter(path, lines, config)
    linter.visit(tree)
    suppress = _suppressions(source)
    active, suppressed = [], []
    for f in sorted(linter.findings, key=lambda f: (f.line, f.rule)):
        if f.rule in suppress.get(f.line, ()):
            suppressed.append(f)
        else:
            active.append(f)
    return active, suppressed


def iter_lint_files(root: str,
                    subdirs: Sequence[str] = ("src", "benchmarks"),
                    ) -> Iterable[str]:
    """Yield repo-relative posix paths of the .py files detlint covers."""
    for sub in subdirs:
        base = os.path.join(root, sub)
        if os.path.isfile(base) and base.endswith(".py"):
            yield os.path.relpath(base, root).replace(os.sep, "/")
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
            for name in sorted(filenames):
                if name.endswith(".py"):
                    rel = os.path.relpath(os.path.join(dirpath, name), root)
                    yield rel.replace(os.sep, "/")


def lint_paths(
    root: str,
    paths: Optional[Sequence[str]] = None,
    config: Optional[DetlintConfig] = None,
) -> Tuple[List[Finding], List[Finding]]:
    """Lint ``paths`` (repo-relative; default: the full src/ + benchmarks/
    sweep) under ``root``. Returns ``(findings, suppressed)``."""
    if config is None:
        config = default_config()
    if paths is None:
        paths = list(iter_lint_files(root))
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    for rel in paths:
        with open(os.path.join(root, rel), "r", encoding="utf-8") as f:
            source = f.read()
        got, sup = lint_source(source, rel, config)
        findings.extend(got)
        suppressed.extend(sup)
    return findings, suppressed
