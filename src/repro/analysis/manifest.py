"""The precision manifest: every numerics contract in one declarative place.

Four declarations drive the analyzers (docs/static-analysis.md documents
the format):

  * **Path contracts** — ``FLOAT64_PATHS`` names the repo-relative prefixes
    whose scheduling arithmetic must stay float64 (DET005 scope);
    ``ENGINE_MODULES`` names the simulated-time engines where wall-clock
    reads are banned (DET002 scope); ``TIMING_ALLOWLIST`` /
    ``FLOAT32_ALLOWANCES`` carve out the documented exceptions, each with a
    justification that the docs render verbatim.
  * **Traced artifacts** — ``PRECISION_ARTIFACTS`` names the compiled
    functions the jaxpr auditor traces, with their dtype contract. A
    ``float64`` contract means *no* float32/float16/bfloat16 value may
    appear anywhere in the jaxpr; a ``float32`` contract is a declared
    downcast tier and carries the ``rtol`` bound that its tolerance test
    (``tests/test_analysis.py``) enforces against the float64 reference.
  * **Recompile guards** — ``RECOMPILE_GUARDS`` generalize the PR 4
    ``_cache_size`` test: sweeping traced operands (tau / clip / deadline
    matrices) through a compiled artifact must not grow its compile cache.
  * **Kernel envelopes** — ``KERNEL_SPECS`` gives each ``kernels/*``
    Pallas kernel a representative deployment shape and a VMEM budget; the
    Pallas auditor captures the real ``pallas_call`` layout at that shape
    and checks divisibility, index-map bounds, footprint, and explicit
    memory-space annotations.

Builders import jax lazily so the AST layer stays import-light.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

__all__ = [
    "Allowance", "ArtifactSpec", "RecompileGuard", "KernelSpec",
    "ENGINE_MODULES", "TIMING_ALLOWLIST", "FLOAT64_PATHS",
    "FLOAT32_ALLOWANCES", "PRECISION_ARTIFACTS", "RECOMPILE_GUARDS",
    "KERNEL_SPECS", "VMEM_BUDGET_BYTES",
]


@dataclasses.dataclass(frozen=True)
class Allowance:
    """A documented exception to a path contract, scoped to a qualname."""

    path: str           # repo-relative file
    scope: str          # enclosing qualname (prefix match)
    justification: str  # rendered in docs; required


@dataclasses.dataclass(frozen=True)
class ArtifactSpec:
    """A compiled artifact the jaxpr auditor traces.

    ``build()`` returns ``(fn, args, kwargs)``; the auditor runs
    ``jax.make_jaxpr(fn)(*args, **kwargs)`` (under ``enable_x64`` when
    ``x64``) and checks the dtype contract + primitive denylist.
    ``rtol`` is the declared kernel-vs-float64-reference error bound for
    ``float32``-contract artifacts (enforced by the tolerance test).
    """

    name: str
    dtype_contract: str                     # "float64" | "float32"
    build: Callable[[], Tuple[Any, tuple, dict]]
    x64: bool = True
    rtol: Optional[float] = None
    notes: str = ""


@dataclasses.dataclass(frozen=True)
class RecompileGuard:
    """A compiled artifact that must not recompile across a value sweep.

    ``build()`` returns ``(fn, calls)`` where ``fn`` exposes jax's
    ``_cache_size`` and ``calls`` is a list of ``(args, kwargs)``. The
    first call primes the cache; the remainder must not grow it.
    """

    name: str
    build: Callable[[], Tuple[Any, list]]
    x64: bool = False       # run the sweep under enable_x64
    notes: str = ""


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One ``kernels/*`` kernel with its audited deployment envelope."""

    name: str
    build: Callable[[], Tuple[Any, tuple, dict]]   # fn(*args, **kwargs)
    vmem_budget_bytes: int = 16 * 1024 * 1024      # ~one TPU core of VMEM
    notes: str = ""


VMEM_BUDGET_BYTES = 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# Path contracts (Layer 1 scope)
# ---------------------------------------------------------------------------

# Engines evolve *simulated* time; the only wall-clock they may see is
# injected (ServingEngine's `clock=` parameter lives in runtime/, not here).
ENGINE_MODULES: Tuple[str, ...] = (
    "src/repro/core/simulator.py",
    "src/repro/core/simfast.py",
    "src/repro/core/cluster.py",
    "src/repro/core/clusterfast.py",
    "src/repro/core/seedband.py",
    "src/repro/core/telemetry.py",
)

# (path, qualname, justification) triples for sanctioned wall-clock reads
# inside engine modules. Empty today: the engines are clean.
TIMING_ALLOWLIST: Tuple[Allowance, ...] = ()

# All scheduling arithmetic under core/ is float64-contract: the scan
# engine's bitwise equality, parallel==serial sweeps, and the golden fig4/
# fig12 metrics all assume IEEE-identical float64 ops. The stability-score
# ops wrapper is also in scope: it is the one sanctioned f64 -> f32
# boundary (scheduler world -> kernel world), and keeping it under DET005
# forces every downcast there to carry an inline suppression pointing at
# its tolerance bound.
FLOAT64_PATHS: Tuple[str, ...] = (
    "src/repro/core/",
    "src/repro/kernels/stability_score/ops.py",
)

FLOAT32_ALLOWANCES: Tuple[Allowance, ...] = (
    Allowance(
        "src/repro/core/scoring.py", "JnpScoringBackend.score",
        "the jnp backend is the declared float32 accelerated tier: inputs "
        "are downcast at this boundary only, decision equivalence vs the "
        "float64 reference is property-tested (tests/test_scoring.py) and "
        "the score error bound is pinned by the stability_score tolerance "
        "test (tests/test_analysis.py)."),
    Allowance(
        "src/repro/core/scoring.py", "PallasScoringBackend.score",
        "the Pallas backend feeds the float32 VMEM kernel "
        "(kernels/stability_score); same declared boundary and tolerance "
        "bound as the jnp backend."),
)


# ---------------------------------------------------------------------------
# Layer 2: traced artifacts
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    import jax

    return jax.ShapeDtypeStruct(shape, dtype)


def _build_lattice_scores():
    """Eq. 4-7 float64 reference scoring (shared by backends + scan)."""
    import numpy as np
    from repro.core.urgency import lattice_stability_scores

    m, q, n = 3, 8, 6
    return lattice_stability_scores, (
        _sds((m, q), np.float64), _sds((m, q), np.float64),
        _sds((n,), np.float64), _sds((n,), np.int64),
        _sds((n,), np.int64), 0.05, 10.0,
    ), {}


def _scan_chunk_key():
    from repro.core.simfast import _StaticKey

    # Tiny but fully exercising key: 2 models, 2 exits, greedy single-rung
    # ladder for caps 0..2, margin aux emission on.
    return _StaticKey(
        num_models=2, num_exits=2, max_queue=4, pad_len=8, chunk_steps=4,
        max_batch=2, ladder=((0,), (1,), (2,)), allowed=(True, True),
        fallback_exit=0, clip=10.0, factored=True, emit_aux=True,
    )


def _build_scan_step(factored: bool):
    import numpy as np
    from repro.core.simfast import _build_chunk_fn

    key = dataclasses.replace(_scan_chunk_key(), factored=factored)
    fn = _build_chunk_fn(key)
    lanes, m, e, p = 2, key.num_models, key.num_exits, key.pad_len
    b1, r = key.max_batch + 1, len(key.ladder[0])
    carry = (
        _sds((lanes,), np.float64), _sds((lanes, m), np.int32),
        _sds((lanes,), np.float64), _sds((lanes,), np.bool_),
        _sds((lanes,), np.bool_),
    )
    args = (
        carry,
        _sds((lanes, m, p, 2), np.float64),          # arrivals + exp factors
        _sds((m, b1, e, r), np.float64),             # belief latency by cap
        _sds((m, e, b1), np.float64),                # execution latency
        _sds((m,), np.float64),                      # tau_vec
        _sds((), np.float64),                        # horizon + drain cap
    )
    return fn, args, {}


def _cluster_chunk_key():
    from repro.core.clusterfast import _ClusterKey

    # Tiny but fully exercising key: 2 devices, 2 models, 2 exits, the
    # least-loaded dispatcher (drain-table backlog fold), a 2-arrival
    # burst, greedy single-rung ladder for caps 0..2.
    return _ClusterKey(
        num_devices=2, num_models=2, num_exits=2, max_queue=4, pad_len=8,
        chunk_steps=4, burst=2, max_batch=2, ladder=((0,), (1,), (2,)),
        allowed=(True, True), fallback_exit=0, clip=10.0, factored=True,
        dispatcher="least-loaded",
    )


def _build_cluster_step():
    import numpy as np
    from repro.core.clusterfast import _build_cluster_chunk_fn

    key = _cluster_chunk_key()
    fn = _build_cluster_chunk_fn(key)
    lanes = 2
    g, m, e, q, p = (key.num_devices, key.num_models, key.num_exits,
                     key.max_queue, key.pad_len)
    b1, r = key.max_batch + 1, len(key.ladder[0])
    carry = (
        _sds((lanes,), np.int32),                    # ai
        _sds((lanes, g, m, q), np.float64),          # qarr
        _sds((lanes, g, m, q), np.float64),          # qew
        _sds((lanes, g, m), np.int32),               # qhead
        _sds((lanes, g, m), np.int32),               # qlen
        _sds((lanes, g), np.float64),                # pend
        _sds((lanes, g), np.bool_),                  # inq
        _sds((lanes, g), np.bool_),                  # alive
        _sds((lanes, g), np.bool_),                  # done
        _sds((lanes, g), np.float64),                # clock
        _sds((lanes, g), np.float64),                # busy
        _sds((lanes,), np.int32),                    # rr
        _sds((lanes,), np.bool_),                    # blocked
        _sds((lanes,), np.bool_),                    # over
    )
    args = (
        carry,
        _sds((lanes, p), np.float64),                # arr_t
        _sds((lanes, p), np.int32),                  # arr_m
        _sds((lanes, p), np.float64),                # arr_ew
        _sds((g, m, b1, e, r), np.float64),          # lat_by_cap
        _sds((g, m, e, b1), np.float64),             # exec_lat
        _sds((g, m, q + 1), np.float64),             # drain_tab
        _sds((g, m), np.float64),                    # b1_final
        _sds((m,), np.float64),                      # tau_vec
        _sds((g, m), np.bool_),                      # placement mask
        _sds((), np.float64),                        # horizon + drain cap
        _sds((), np.float64),                        # failure barrier
    )
    return fn, args, {}


def _build_jnp_score():
    import numpy as np
    from repro.core.scoring import _jnp_score

    m, q, n = 3, 8, 6
    return _jnp_score, (
        _sds((m, q), np.float32), _sds((m, q), np.float32),
        _sds((n,), np.float32), _sds((n,), np.int32),
        _sds((n,), np.int32), _sds((), np.float32), _sds((), np.float32),
    ), {}


def _build_stability_kernel():
    import functools

    import numpy as np
    from repro.kernels.stability_score.kernel import stability_scores_kernel

    m, q, n = 4, 16, 12
    fn = functools.partial(
        stability_scores_kernel, tau=0.05, clip=10.0, block_m=8,
        interpret=True)
    return fn, (
        _sds((m, q), np.float32), _sds((m, q), np.float32),
        _sds((n,), np.float32), _sds((n,), np.int32), _sds((n,), np.int32),
    ), {}


PRECISION_ARTIFACTS: Tuple[ArtifactSpec, ...] = (
    ArtifactSpec(
        name="urgency.lattice_stability_scores",
        dtype_contract="float64",
        build=_build_lattice_scores,
        notes="Eq. 4-7 reference scoring: the oracle every backend and both "
              "engines are pinned against; any f32 here poisons everything "
              "downstream.",
    ),
    ArtifactSpec(
        name="simfast.scan_step[factored]",
        dtype_contract="float64",
        build=lambda: _build_scan_step(True),
        notes="the compiled serving round (factored-exponential scoring); "
              "bitwise-equal decisions/metrics vs the Python loop require "
              "pure float64.",
    ),
    ArtifactSpec(
        name="simfast.scan_step[direct]",
        dtype_contract="float64",
        build=lambda: _build_scan_step(False),
        notes="the compiled serving round on the direct Eq. 3 path (long-"
              "horizon fallback).",
    ),
    ArtifactSpec(
        name="clusterfast.scan_step[least-loaded]",
        dtype_contract="float64",
        build=_build_cluster_step,
        notes="the compiled cluster step (arrival burst + device round + "
              "dispatcher fold over [G,M,Q] rings); bitwise-equal decisions "
              "and metrics vs ClusterSimulator require pure float64 — the "
              "one-ulp idle poke and drain-table folds die in f32.",
    ),
    ArtifactSpec(
        name="scoring.jnp_backend",
        dtype_contract="float32",
        build=_build_jnp_score,
        x64=False,
        rtol=2e-4,
        notes="declared float32 tier (SchedulerConfig.backend='jnp'); "
              "decision-equivalence property-tested, score error bound "
              "enforced by the tolerance test.",
    ),
    ArtifactSpec(
        name="stability_score.kernel",
        dtype_contract="float32",
        build=_build_stability_kernel,
        x64=False,
        rtol=2e-4,
        notes="the Pallas kernel path downcasts cand_latency to float32 at "
              "the ops.py boundary (kernels/stability_score/ops.py) — "
              "declared here, bounded by the extreme-magnitude tolerance "
              "test in tests/test_analysis.py.",
    ),
)


# ---------------------------------------------------------------------------
# Layer 2: no-recompile guards
# ---------------------------------------------------------------------------


def _guard_stability_ops():
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.stability_score.ops import stability_scores

    rng = np.random.default_rng(41)
    m, q = 3, 8
    w = jnp.asarray(np.sort(rng.uniform(0, 0.1, (m, q)))[:, ::-1].copy(),
                    jnp.float32)
    mask = jnp.ones((m, q), jnp.float32)
    lat = jnp.asarray(rng.uniform(1e-3, 2e-2, m), jnp.float32)
    bat = jnp.asarray(rng.integers(1, 5, m), jnp.int32)
    calls = [((w, mask, lat, bat),
              dict(tau=tau, clip=clip, interpret=True))
             for tau in (0.019, 0.02, 0.05, 0.1) for clip in (5.0, 10.0)]
    # per-task deadline matrices: same shape family, varying values
    for scale in (0.02, 0.04, 0.08):
        tau_m = jnp.asarray(
            rng.uniform(0.5, 1.5, (m, q)) * scale, jnp.float32)
        calls.append(((w, mask, lat, bat),
                      dict(tau=tau_m, clip=10.0, interpret=True)))
    return stability_scores, calls


def _guard_jnp_score():
    import numpy as np
    import jax.numpy as jnp
    from repro.core.scoring import _jnp_score

    rng = np.random.default_rng(42)
    m, q, n = 3, 8, 6
    w = jnp.asarray(rng.uniform(0, 0.1, (m, q)), jnp.float32)
    mask = jnp.ones((m, q), jnp.float32)
    lat = jnp.asarray(rng.uniform(1e-3, 2e-2, n), jnp.float32)
    bat = jnp.asarray(rng.integers(1, 4, n), jnp.int32)
    cq = jnp.asarray(rng.integers(0, m, n), jnp.int32)
    calls = [((w, mask, lat, bat, cq, jnp.float32(tau), jnp.float32(clip)),
              {})
             for tau in (0.02, 0.03, 0.05, 0.08) for clip in (5.0, 10.0)]
    return _jnp_score, calls


def _guard_scan_chunk():
    import numpy as np
    from jax.experimental import enable_x64
    from repro.core.simfast import _build_chunk_fn

    key = _scan_chunk_key()
    fn = _build_chunk_fn(key)
    lanes, m, e, p = 2, key.num_models, key.num_exits, key.pad_len
    b1, r = key.max_batch + 1, len(key.ladder[0])
    rng = np.random.default_rng(43)
    with enable_x64():
        calls = []
        for tau in (0.05, 0.08, 0.12):
            for limit in (1.0, 2.0):
                arrivals = np.sort(rng.uniform(0, 0.5, (lanes, m, p)))
                arr = np.stack(
                    [arrivals, np.exp(-arrivals / tau)], axis=-1)
                carry = (
                    np.zeros(lanes), np.zeros((lanes, m), np.int32),
                    np.zeros(lanes), np.zeros(lanes, bool),
                    np.zeros(lanes, bool),
                )
                lat_by_cap = rng.uniform(1e-3, 2e-2, (m, b1, e, r))
                exec_lat = rng.uniform(1e-3, 2e-2, (m, e, b1))
                tau_vec = np.full(m, tau)
                calls.append(((carry, arr, lat_by_cap, exec_lat, tau_vec,
                               np.float64(limit)), {}))
    return fn, calls


RECOMPILE_GUARDS: Tuple[RecompileGuard, ...] = (
    RecompileGuard(
        name="stability_score.ops[tau/clip/deadline-matrix sweep]",
        build=_guard_stability_ops,
        notes="generalizes the PR 4 _cache_size test: SLO and clip sweeps "
              "(scalar and per-task matrix tau) must reuse one executable "
              "per shape family.",
    ),
    RecompileGuard(
        name="scoring._jnp_score[tau/clip sweep]",
        build=_guard_jnp_score,
        notes="every scheduler in a sweep shares this module-level jit; a "
              "recompile per SLO would serialize fig8-style sweeps.",
    ),
    RecompileGuard(
        name="simfast.chunk[tau/limit sweep]",
        build=_guard_scan_chunk,
        x64=True,
        notes="the compiled scan chunk is keyed only by _StaticKey; "
              "deadline and drain-cap values are traced operands.",
    ),
)


# ---------------------------------------------------------------------------
# Layer 3: kernel envelopes
# ---------------------------------------------------------------------------


def _kernel_flash_attention():
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.flash_attention.kernel import flash_attention_kernel

    rng = np.random.default_rng(1)
    b, h, kh, s, d = 1, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, kh, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, kh, s, d)), jnp.float32)
    return flash_attention_kernel, (q, k, v), dict(causal=True)


def _kernel_decode_attention():
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.decode_attention.kernel import decode_attention_kernel

    rng = np.random.default_rng(2)
    b, h, kh, s, d = 2, 4, 2, 1024, 64
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, kh, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, kh, s, d)), jnp.float32)
    lens = jnp.asarray(rng.integers(1, s + 1, b), jnp.int32)
    return decode_attention_kernel, (q, k, v, lens), {}


def _kernel_exit_head():
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.exit_head.kernel import exit_head_kernel

    rng = np.random.default_rng(3)
    t, d, v = 256, 512, 4096
    h = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(d,)) * 0.1 + 1.0, jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, v)) / np.sqrt(d), jnp.float32)
    return exit_head_kernel, (h, g, w), {}


def _kernel_rmsnorm():
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.rmsnorm.kernel import rmsnorm_kernel

    rng = np.random.default_rng(4)
    t, d = 512, 2048
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(d,)) * 0.2 + 1.0, jnp.float32)
    return rmsnorm_kernel, (x, g), {}


def _kernel_stability_score():
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.stability_score.kernel import stability_scores_kernel

    rng = np.random.default_rng(5)
    m, q, n = 4, 16, 12
    w = jnp.asarray(np.sort(rng.uniform(0, 0.1, (m, q)))[:, ::-1].copy(),
                    jnp.float32)
    mask = jnp.ones((m, q), jnp.float32)
    lat = jnp.asarray(rng.uniform(1e-3, 2e-2, n), jnp.float32)
    bat = jnp.asarray(rng.integers(1, 5, n), jnp.int32)
    cq = jnp.asarray(rng.integers(0, m, n), jnp.int32)
    return stability_scores_kernel, (w, mask, lat, bat, cq), dict(
        tau=0.05, clip=10.0, block_m=8)


KERNEL_SPECS: Tuple[KernelSpec, ...] = (
    KernelSpec(
        name="flash_attention",
        build=_kernel_flash_attention,
        notes="GQA causal prefill attention; audited at (1,4heads/2kv,512,"
              "64) with the default 256/512 blocks.",
    ),
    KernelSpec(
        name="decode_attention",
        build=_kernel_decode_attention,
        notes="split-K single-token decode over a 1024-entry cache; "
              "lengths ride in SMEM.",
    ),
    KernelSpec(
        name="exit_head",
        build=_kernel_exit_head,
        notes="fused norm+LM-head+confidence streaming a 4096-vocab slab "
              "in 1024-wide tiles.",
    ),
    KernelSpec(
        name="rmsnorm",
        build=_kernel_rmsnorm,
        notes="row-tiled, feature-resident at (512, 2048).",
    ),
    KernelSpec(
        name="stability_score",
        build=_kernel_stability_score,
        notes="the scheduler scoring kernel on a 12-candidate lattice over "
              "4 queues (pads N 12->16 for block_m=8).",
    ),
)
