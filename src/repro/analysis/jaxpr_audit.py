"""jaxpr auditor — dtype contracts, primitive hygiene, recompile guards.

Layer 2 of the static-analysis suite: the AST layer sees source text, but
the bitwise contract lives in what XLA actually compiles. This module
traces the artifacts named in the precision manifest to jaxprs and checks:

  * **dtype contract** — a ``float64``-contract artifact must not contain
    *any* float32/float16/bfloat16 value: no ``convert_element_type`` to a
    narrow float, no narrow constant, no narrow intermediate. (An implicit
    downcast is exactly how the bitwise guarantee dies silently: the op
    still runs, the numbers are just slightly wrong.)
  * **primitive denylist** — no host callbacks or debug prints inside hot
    paths (``pure_callback``/``io_callback``/``debug_callback``): they
    force host round-trips, break ``vmap``/donation assumptions, and make
    timing observable to the traced code.
  * **no-recompile guards** — generalizing the PR 4 ``_cache_size`` test:
    sweeping traced operands (tau / clip / deadline matrices / drain caps)
    through a compiled artifact must not grow its compile cache; a silent
    static-argification turns an O(1)-compile sweep into O(grid).

All checks recurse into nested jaxprs (pjit bodies, ``scan``/``while``/
``cond`` branches, custom-call subcomputations).
"""

from __future__ import annotations

import contextlib
import inspect
from typing import Any, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.detlint import Finding
from repro.analysis import manifest as _manifest

__all__ = ["audit_jaxpr", "audit_artifact", "audit_precision_manifest",
           "no_recompile_findings", "audit_recompile_guards",
           "NARROW_FLOATS", "DENYLISTED_PRIMITIVES"]

NARROW_FLOATS = ("float32", "float16", "bfloat16")

DENYLISTED_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "debug_print", "host_callback", "outside_call",
})


def _subjaxprs(params: dict) -> Iterable[Any]:
    """Yield every jaxpr nested in an eqn's params (pjit/scan/cond/...)."""
    for value in params.values():
        vals = value if isinstance(value, (tuple, list)) else (value,)
        for v in vals:
            if hasattr(v, "jaxpr"):        # ClosedJaxpr
                yield v.jaxpr
            elif hasattr(v, "eqns"):       # raw Jaxpr
                yield v


def _walk_eqns(jaxpr) -> Iterable[Any]:
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _subjaxprs(eqn.params):
            yield from _walk_eqns(sub)


def _artifact_location(fn) -> Tuple[str, int]:
    """Best-effort source location for a traced artifact (for file:line
    output); falls back to the manifest itself."""
    target = fn
    for attr in ("func", "__wrapped__"):
        while hasattr(target, attr):
            target = getattr(target, attr)
    try:
        path = inspect.getsourcefile(target) or "<unknown>"
        _, line = inspect.getsourcelines(target)
        return path, line
    except (TypeError, OSError):
        return "src/repro/analysis/manifest.py", 1


def audit_jaxpr(jaxpr, *, name: str, dtype_contract: str = "float64",
                path: str = "<traced>", line: int = 1) -> List[Finding]:
    """Check one (closed) jaxpr against its declared dtype contract and the
    primitive denylist. Returns findings (empty == clean)."""
    inner = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    findings: List[Finding] = []
    seen_dtype: set = set()
    seen_prim: set = set()
    for eqn in _walk_eqns(inner):
        prim = eqn.primitive.name
        if prim in DENYLISTED_PRIMITIVES and prim not in seen_prim:
            seen_prim.add(prim)
            findings.append(Finding(
                "JXP002", path, line,
                f"artifact {name!r} contains denylisted primitive "
                f"{prim!r} (host callback / debug print in a hot path)",
                snippet=f"{name}::{prim}"))
        if dtype_contract == "float64":
            for var in eqn.outvars:
                dtype = getattr(getattr(var, "aval", None), "dtype", None)
                if dtype is not None and str(dtype) in NARROW_FLOATS:
                    sig = (prim, str(dtype))
                    if sig in seen_dtype:
                        continue
                    seen_dtype.add(sig)
                    findings.append(Finding(
                        "JXP001", path, line,
                        f"artifact {name!r} declares float64 but primitive "
                        f"{prim!r} produces {dtype} — a silent downcast on "
                        f"a bitwise-contract path",
                        snippet=f"{name}::{prim}->{dtype}"))
    if dtype_contract == "float64" and hasattr(jaxpr, "consts"):
        for const in jaxpr.consts:
            dtype = str(getattr(const, "dtype", ""))
            if dtype in NARROW_FLOATS:
                findings.append(Finding(
                    "JXP001", path, line,
                    f"artifact {name!r} declares float64 but closes over a "
                    f"{dtype} constant",
                    snippet=f"{name}::const->{dtype}"))
                break
    return findings


def audit_artifact(spec) -> List[Finding]:
    """Trace one manifest :class:`~repro.analysis.manifest.ArtifactSpec`
    and audit the resulting jaxpr."""
    import jax
    from jax.experimental import enable_x64

    ctx = enable_x64() if spec.x64 else contextlib.nullcontext()
    with ctx:
        fn, args, kwargs = spec.build()
        try:
            jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
        except Exception as e:  # tracing failure is itself a finding
            path, line = _artifact_location(fn)
            return [Finding(
                "JXP000", path, line,
                f"artifact {spec.name!r} failed to trace: {e}",
                snippet=f"{spec.name}::trace-error")]
    path, line = _artifact_location(fn)
    return audit_jaxpr(jaxpr, name=spec.name,
                       dtype_contract=spec.dtype_contract,
                       path=path, line=line)


def audit_precision_manifest(
    artifacts: Optional[Sequence] = None,
) -> List[Finding]:
    """Audit every artifact in the precision manifest (or an injected
    list — tests use this to prove a polluted artifact is caught)."""
    if artifacts is None:
        artifacts = _manifest.PRECISION_ARTIFACTS
    findings: List[Finding] = []
    for spec in artifacts:
        findings.extend(audit_artifact(spec))
    return findings


# ---------------------------------------------------------------------------
# no-recompile guards
# ---------------------------------------------------------------------------


def _cache_size_of(fn) -> Optional[int]:
    probe = getattr(fn, "_cache_size", None)
    if probe is not None:
        return probe()
    # functools.partial over a jitted fn
    inner = getattr(fn, "func", None)
    if inner is not None and hasattr(inner, "_cache_size"):
        return inner._cache_size()
    return None


def no_recompile_findings(guard) -> List[Finding]:
    """Run one :class:`~repro.analysis.manifest.RecompileGuard` sweep.

    The first call primes the compile cache (new shape families are
    legitimate compiles); every subsequent call must hit it. Returns a
    finding if the cache grew after priming, or if the target exposes no
    cache to measure (a guard silently measuring nothing is itself a bug).
    """
    import contextlib as _ctx

    from jax.experimental import enable_x64

    ctx = enable_x64() if getattr(guard, "x64", False) else (
        _ctx.nullcontext())
    with ctx:
        fn, calls = guard.build()
        if not calls:
            return [Finding(
                "JXP003", "src/repro/analysis/manifest.py", 1,
                f"recompile guard {guard.name!r} produced no calls",
                snippet=f"{guard.name}::empty")]
        # Prime every distinct (shape, structure) family: sweeps are allowed
        # one compile per family, never one per value.
        primed: set = set()
        pending = []
        for args, kwargs in calls:
            key = _call_signature(args, kwargs)
            if key not in primed:
                primed.add(key)
                fn(*args, **kwargs)
            else:
                pending.append((args, kwargs))
        before = _cache_size_of(fn)
        if before is None:
            return [Finding(
                "JXP003", "src/repro/analysis/manifest.py", 1,
                f"recompile guard {guard.name!r}: target exposes no "
                f"_cache_size (not a jitted function?)",
                snippet=f"{guard.name}::no-cache")]
        for args, kwargs in pending:
            fn(*args, **kwargs)
        after = _cache_size_of(fn)
    if after > before:
        path, line = _artifact_location(fn)
        return [Finding(
            "JXP003", path, line,
            f"recompile guard {guard.name!r}: compile cache grew "
            f"{before}->{after} across a traced-operand sweep (a value "
            f"became static; sweeps now recompile per value)",
            snippet=f"{guard.name}::recompiled")]
    return []


def _call_signature(args, kwargs) -> tuple:
    """Shape/dtype/structure fingerprint of one call (value-independent)."""

    def leaf(x):
        shape = getattr(x, "shape", None)
        if shape is not None:
            return ("arr", tuple(shape), str(getattr(x, "dtype", "?")))
        return ("lit", type(x).__name__)

    return (tuple(leaf(a) for a in args),
            tuple(sorted((k, leaf(v)) for k, v in kwargs.items())))


def audit_recompile_guards(guards: Optional[Sequence] = None) -> List[Finding]:
    if guards is None:
        guards = _manifest.RECOMPILE_GUARDS
    findings: List[Finding] = []
    for guard in guards:
        findings.extend(no_recompile_findings(guard))
    return findings
