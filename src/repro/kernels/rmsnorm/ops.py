"""Public wrapper for the RMSNorm kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_kernel
from repro.kernels.rmsnorm.ref import rmsnorm_ref


@functools.partial(jax.jit, static_argnames=("eps", "block_t", "interpret",
                                             "use_kernel"))
def rmsnorm(x, gain, *, eps: float = 1e-6, block_t: int = 256,
            interpret: bool = False, use_kernel: bool = True):
    """RMSNorm over the last dim of a 2D input."""
    if not use_kernel:
        return rmsnorm_ref(x, gain, eps)
    return rmsnorm_kernel(x, gain, eps=eps, block_t=block_t,
                          interpret=interpret)
