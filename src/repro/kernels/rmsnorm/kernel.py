"""RMSNorm as a Pallas TPU kernel: row-tiled, feature-resident.

grid = (T/bt,); block [bt, D] with the full feature dim resident so the
mean-square reduction is a single VMEM pass; fp32 accumulation, output in
the input dtype. D up to 8k at bt=256 is ~8 MB fp32 — inside v5e VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * g[None, :]).astype(
        o_ref.dtype)


def rmsnorm_kernel(x, gain, *, eps: float = 1e-6, block_t: int = 256,
                   interpret: bool = False):
    """x [T, D]; gain [D] -> [T, D]."""
    t, d = x.shape
    bt = min(block_t, t)
    assert t % bt == 0, (t, bt)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda it: (it, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((d,), lambda it: (0,),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda it: (it, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=interpret,
    )(x, gain)
