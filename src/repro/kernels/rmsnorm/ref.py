"""Pure-jnp oracle for RMSNorm — re-exports the model substrate's
implementation so the kernel validates against exactly what models use."""

from repro.models.common import rms_norm as rmsnorm_ref

__all__ = ["rmsnorm_ref"]
