"""Pure-jnp oracle for causal GQA flash attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal: bool = True) -> jax.Array:
    """q [B, H, S, D]; k, v [B, K, S, D] with H % K == 0. fp32 softmax.

    Returns [B, H, S, D] in q.dtype.
    """
    b, h, s, d = q.shape
    kh = k.shape[1]
    g = h // kh
    qg = q.reshape(b, kh, g, s, d)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qg, k).astype(jnp.float32)
    scores *= 1.0 / jnp.sqrt(d).astype(jnp.float32)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, v)
    return out.reshape(b, h, s, d)
