"""Causal GQA flash attention as a Pallas TPU kernel.

Tiling: grid = (B, H, S/bq, S/bk), sequential in the last (kv) dimension —
the TPU grid executes minor dimensions in order, so the online-softmax
state (m, l, acc) lives in VMEM scratch and carries across kv steps.
Block shapes: q [bq, D], k/v [bk, D] — with bq=256, bk=512, D<=256 the
working set is ~(256+2*512)*256*2B + 256*256*4B ~ 0.9 MB, comfortably in
the ~16 MB v5e VMEM, and every matmul dim is a multiple of the 128-lane
MXU tile. Causality skips fully-masked kv blocks via @pl.when (the grid
step still issues, but no FLOPs flow).

GQA is expressed in the index maps: query head h reads kv head h // group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, scale: float, bq: int, bk: int, causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip kv blocks strictly above the causal diagonal
    run = (not causal) or (ik * bk <= iq * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)            # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                             # [bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])                 # [bq, bk]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, block_q: int = 256, block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q [B, H, S, D]; k, v [B, K, S, D]. Returns [B, H, S, D]."""
    b, h, s, d = q.shape
    kh = k.shape[1]
    group = h // kh
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    grid = (b, h, s // bq, s // bk)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _attn_kernel, scale=scale, bq=bq, bk=bk, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max m
            pltpu.VMEM((bq,), jnp.float32),       # running denom l
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
