"""Public wrapper for the flash-attention kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret", "use_kernel"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 512, interpret: bool = False,
                    use_kernel: bool = True):
    """Causal GQA attention, heads-first layout ([B, H, S, D] /
    [B, K, S, D]). ``use_kernel=False`` falls back to the jnp oracle
    (the CPU/dry-run path)."""
    if not use_kernel:
        return flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_kernel(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret)
