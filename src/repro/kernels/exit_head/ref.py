"""Pure-jnp oracle for the fused exit head.

exit_head(h, g, W) = (argmax, max_logit, logsumexp) of
``rmsnorm(h; g) @ W`` — everything the early-exit decision needs (top-1
prediction + softmax confidence = exp(max - lse)) without materialising the
[T, V] logits in HBM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def exit_head_ref(h, gain, w, eps: float = 1e-6):
    """h [T, D]; gain [D]; w [D, V] ->
    (argmax [T] int32, max_logit [T] f32, lse [T] f32)."""
    hf = h.astype(jnp.float32)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)
    normed = hf * jax.lax.rsqrt(var + eps) * gain.astype(jnp.float32)
    logits = normed @ w.astype(jnp.float32)             # [T, V]
    return (
        jnp.argmax(logits, axis=-1).astype(jnp.int32),
        jnp.max(logits, axis=-1),
        jax.nn.logsumexp(logits, axis=-1),
    )


def confidence_from(max_logit, lse):
    """Top-1 softmax probability (the paper-style exit confidence)."""
    return jnp.exp(max_logit - lse)
