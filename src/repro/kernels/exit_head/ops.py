"""Public wrapper for the fused exit head."""

from __future__ import annotations

import functools

import jax

from repro.kernels.exit_head.kernel import exit_head_kernel
from repro.kernels.exit_head.ref import confidence_from, exit_head_ref


@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "eps",
                                             "interpret", "use_kernel"))
def exit_head(h, gain, w, *, block_t: int = 256, block_v: int = 1024,
              eps: float = 1e-6, interpret: bool = False,
              use_kernel: bool = True):
    """Fused rmsnorm + unembedding + top-1/confidence.

    h [T, D]; gain [D]; w [D, V] -> (argmax [T] i32, max [T] f32, lse [T]).
    ``confidence = exp(max - lse)``.
    """
    if not use_kernel:
        return exit_head_ref(h, gain, w, eps=eps)
    return exit_head_kernel(h, gain, w, block_t=block_t, block_v=block_v,
                            eps=eps, interpret=interpret)


__all__ = ["exit_head", "confidence_from"]
