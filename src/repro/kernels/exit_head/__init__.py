from repro.kernels.exit_head.ops import exit_head

__all__ = ["exit_head"]
