"""Fused exit head as a Pallas TPU kernel.

Every scheduler-controlled early exit runs norm + LM-head + confidence.
Materialising [T, V] logits (V up to 200k) costs a round trip to HBM that
dwarfs the decision itself; this kernel streams the vocab dimension in
VMEM-resident tiles and keeps only O(T) running statistics:

  grid = (T/bt, V/bv), sequential in the vocab dimension;
  blocks: h [bt, D] (revisited each vocab step), W [D, bv];
  scratch: running max / argmax / logsumexp accumulators [bt].

RMSNorm is fused: recomputed per vocab tile from the VMEM-resident h block
(cheaper than a second pass or an extra scratch buffer of normed h).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _exit_head_kernel(h_ref, g_ref, w_ref, idx_ref, mx_ref, lse_ref,
                      m_ref, a_ref, l_ref, *, bt: int, bv: int, eps: float):
    iv = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        a_ref[...] = jnp.zeros_like(a_ref)
        l_ref[...] = jnp.zeros_like(l_ref)

    h = h_ref[...].astype(jnp.float32)                  # [bt, D]
    g = g_ref[...].astype(jnp.float32)                  # [D]
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    normed = h * jax.lax.rsqrt(var + eps) * g[None, :]
    w = w_ref[...].astype(jnp.float32)                  # [D, bv]
    logits = jax.lax.dot_general(
        normed, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # [bt, bv]

    blk_max = jnp.max(logits, axis=1)                   # [bt]
    blk_arg = iv * bv + jnp.argmax(logits, axis=1).astype(jnp.int32)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, blk_max)
    # running logsumexp
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(logits - m_new[:, None]), axis=1)
    # running argmax (strictly-greater keeps the first occurrence, matching
    # jnp.argmax tie semantics across ordered blocks)
    take = blk_max > m_prev
    a_ref[...] = jnp.where(take, blk_arg, a_ref[...])
    m_ref[...] = m_new

    @pl.when(iv == nv - 1)
    def _finish():
        idx_ref[...] = a_ref[...]
        mx_ref[...] = m_ref[...]
        lse_ref[...] = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))


def exit_head_kernel(h, gain, w, *, block_t: int = 256, block_v: int = 1024,
                     eps: float = 1e-6, interpret: bool = False):
    """h [T, D]; gain [D]; w [D, V] -> (argmax [T], max [T], lse [T])."""
    t, d = h.shape
    v = w.shape[1]
    bt = min(block_t, t)
    bv = min(block_v, v)
    assert t % bt == 0 and v % bv == 0, (t, bt, v, bv)
    grid = (t // bt, v // bv)

    kernel = functools.partial(_exit_head_kernel, bt=bt, bv=bv, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda it, iv: (it, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((d,), lambda it, iv: (0,),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((d, bv), lambda it, iv: (0, iv),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda it, iv: (it,),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((bt,), lambda it, iv: (it,),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((bt,), lambda it, iv: (it,),
                         memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.int32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),   # running max
            pltpu.VMEM((bt,), jnp.int32),     # running argmax
            pltpu.VMEM((bt,), jnp.float32),   # running sumexp
        ],
        interpret=interpret,
    )(h, gain, w)
