from repro.kernels.stability_score.ops import stability_scores

__all__ = ["stability_scores"]
