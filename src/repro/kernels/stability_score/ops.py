"""Public wrapper for the stability-score kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.stability_score.kernel import stability_scores_kernel
from repro.kernels.stability_score.ref import (
    lattice_stability_scores_ref,
    stability_scores_ref,
)


# tau and clip are *traced* operands: a fig8-style SLO sweep (or a clip
# ablation) reuses one compiled executable across every value instead of
# recompiling per deadline (pinned by a _cache_size check in
# tests/test_scoring.py). Only layout/shape knobs stay static.
@functools.partial(jax.jit, static_argnames=("block_m", "interpret",
                                             "use_kernel"))
def stability_scores(w, mask, cand_latency, cand_batch, cand_queue=None,
                     *, tau, clip=10.0, block_m: int = 8,
                     interpret: bool = False, use_kernel: bool = True):
    """Score a flattened candidate lattice in one fused pass (Eq. 3-7).

    w, mask [M, maxQ] (FIFO-sorted waits + validity); cand_latency [N];
    cand_batch [N]; cand_queue [N] maps each candidate to the queue it
    serves (None = the greedy one-candidate-per-queue layout with N == M).
    ``tau`` is the scalar SLO or an [M, maxQ] per-task deadline matrix
    (heterogeneous SLOs; aligned with ``w``, broadcast over candidates).
    Returns [N] predicted post-decision stability scores.
    """
    if not use_kernel:
        if cand_queue is None:
            return stability_scores_ref(w, mask, cand_latency, cand_batch,
                                        tau, clip)
        return lattice_stability_scores_ref(
            w, mask, cand_latency, cand_batch, cand_queue, tau, clip)
    if cand_queue is not None:
        cand_queue = cand_queue.astype(jax.numpy.int32)
    # cand_latency is deliberately downcast f64 -> f32 at the kernel
    # boundary: the kernel computes in float32 throughout, and this path is
    # a declared-f32 artifact ("stability_score.kernel") in the precision
    # manifest (src/repro/analysis/manifest.py) with an rtol=2e-4 bound
    # against the f64 reference, exercised at extreme tau/latency
    # magnitudes by tests/test_analysis.py::TestStabilityDowncastTolerance.
    return stability_scores_kernel(
        w, mask, cand_latency.astype(jax.numpy.float32),  # detlint: disable=DET005
        cand_batch.astype(jax.numpy.int32), cand_queue,
        tau=tau, clip=clip, block_m=block_m, interpret=interpret)
