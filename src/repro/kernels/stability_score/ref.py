"""Pure-jnp oracle for candidate stability scoring — re-exports the core
implementation (paper Eq. 3-7) so the kernel tests validate against the
exact scheduler semantics. ``stability_scores_ref`` is the greedy
one-candidate-per-queue layout; ``lattice_stability_scores_ref`` scores a
flattened (model, exit, batch) lattice via a candidate->queue index map."""

from repro.core.urgency import (
    candidate_stability_scores as stability_scores_ref,
    lattice_stability_scores as lattice_stability_scores_ref,
)

__all__ = ["stability_scores_ref", "lattice_stability_scores_ref"]
