"""Pure-jnp oracle for candidate stability scoring — re-exports the core
implementation (paper Eq. 3-7) so the kernel tests validate against the
exact scheduler semantics."""

from repro.core.urgency import candidate_stability_scores as stability_scores_ref

__all__ = ["stability_scores_ref"]
