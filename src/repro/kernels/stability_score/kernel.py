"""Candidate stability scoring (paper Eq. 3-7) as a Pallas TPU kernel.

The scheduler evaluates N candidate decisions per round; each candidate n
rescores *every* queued task under the predicted wait shift L_n — an
O(N * M * maxQ) fused pass. Candidates are a flattened (model, exit, batch)
lattice: ``cand_queue[n]`` names the queue candidate n would serve, so the
paper's one-candidate-per-queue greedy (N == M, cand_queue == arange) and
the joint lattice (N == sum over queues of |ladder| * |exits|) share one
kernel. At edge scale (M ~ 3) this is trivia, but the vectorised serving
tier (hundreds of colocated models / per-tenant queues) makes it a
per-round hot spot on the host: fusing exp/clip/mask/row-sum into one VMEM
pass keeps the scheduling quantum in the microsecond range.

Deadlines: ``tau`` is an ``[M, Q]`` per-task deadline matrix held in VMEM
alongside the wait matrix and broadcast over the candidate axis
(heterogeneous-SLO workloads); scalar-SLO callers pass the filled matrix
the ops wrapper builds for them — bitwise-identical to dividing by the
scalar. ``clip`` rides along as a (1, 1) traced scalar so an SLO/clip sweep
never recompiles (see ops.py).

Tiling: grid = (N/bn,); per step the full wait/tau matrices [M, Q] sit in
VMEM (tens of KB for realistic M*Q) against a [bn] slab of candidates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _score_kernel(w_ref, mask_ref, tau_ref, clip_ref, lat_ref, batch_ref,
                  queue_ref, out_ref, *, bn: int):
    w = w_ref[...].astype(jnp.float32)                  # [M, Q]
    mask = mask_ref[...].astype(jnp.float32)            # [M, Q]
    tau = tau_ref[...].astype(jnp.float32)              # [M, Q]
    clip = clip_ref[0, 0]                               # traced scalar
    lat = lat_ref[...].astype(jnp.float32)              # [bn]
    batch = batch_ref[...]                              # [bn] int32
    queue = queue_ref[...]                              # [bn] int32
    m_count, q = w.shape
    log_clip = jnp.log(clip)

    # shifted urgency for each candidate in the slab: [bn, M, Q]
    shifted = w[None] + lat[:, None, None]
    urg = jnp.minimum(
        jnp.exp(jnp.minimum(shifted / tau[None] - 1.0, log_clip)), clip
    ) * mask[None]
    total = jnp.sum(urg, axis=(1, 2))                   # [bn]

    # served tasks (B oldest of the candidate's target queue) are removed
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (bn, m_count, q), 1)
    pos_ids = jax.lax.broadcasted_iota(jnp.int32, (bn, m_count, q), 2)
    own = row_ids == queue[:, None, None]
    served = own & (pos_ids < batch[:, None, None])
    removed = jnp.sum(urg * served.astype(jnp.float32), axis=(1, 2))

    out_ref[...] = total - removed


def stability_scores_kernel(w, mask, cand_latency, cand_batch,
                            cand_queue=None, *, tau, clip=10.0,
                            block_m: int = 8, interpret: bool = False):
    """w, mask [M, Q]; cand_latency [N] f32; cand_batch, cand_queue [N] i32
    -> [N] f32. ``cand_queue=None`` means the one-candidate-per-queue greedy
    layout (N == M, candidate n serves queue n). ``tau`` is a scalar SLO or
    an [M, Q] per-task deadline matrix; ``clip`` a (traced) scalar."""
    m, q = w.shape
    if cand_queue is None:
        cand_queue = jnp.arange(m, dtype=jnp.int32)
    # scalar tau -> filled matrix (bitwise-identical to scalar division);
    # matrix tau is forwarded as-is.
    tau = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (m, q))
    clip = jnp.asarray(clip, jnp.float32).reshape(1, 1)
    n = cand_latency.shape[0]
    bn = min(block_m, n)
    # pad N to a multiple of bn (padded candidates score garbage; sliced off)
    pad = (-n) % bn
    if pad:
        cand_latency = jnp.pad(cand_latency, (0, pad))
        cand_batch = jnp.pad(cand_batch, (0, pad))
        cand_queue = jnp.pad(cand_queue, (0, pad))
    np_ = n + pad
    grid = (np_ // bn,)

    kernel = functools.partial(_score_kernel, bn=bn)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, q), lambda ic: (0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((m, q), lambda ic: (0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((m, q), lambda ic: (0, 0),
                         memory_space=pltpu.VMEM),
            # traced clip scalar: control-flow-style operand, SMEM-resident
            pl.BlockSpec((1, 1), lambda ic: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bn,), lambda ic: (ic,),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((bn,), lambda ic: (ic,),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((bn,), lambda ic: (ic,),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((bn,), lambda ic: (ic,),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=interpret,
    )(w, mask, tau, clip, cand_latency, cand_batch, cand_queue)
    return out[:n]
