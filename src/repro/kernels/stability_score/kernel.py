"""Candidate stability scoring (paper Eq. 3-7) as a Pallas TPU kernel.

The scheduler evaluates M candidate decisions per round; each candidate m
rescoreas *every* queued task under the predicted wait shift L_m — an
O(M^2 * maxQ) fused pass. At edge scale (M ~ 3) this is trivia, but the
vectorised serving tier (hundreds of colocated models / per-tenant queues)
makes it a per-round hot spot on the host: fusing exp/clip/mask/row-sum
into one VMEM pass keeps the scheduling quantum in the microsecond range.

Tiling: grid = (M/bm,); per step the full wait matrix [M, Q] sits in VMEM
(tens of KB for realistic M*Q) against a [bm] slab of candidates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(w_ref, mask_ref, lat_ref, batch_ref, out_ref,
                  *, tau: float, clip: float, bm: int):
    ic = pl.program_id(0)
    w = w_ref[...].astype(jnp.float32)                  # [M, Q]
    mask = mask_ref[...].astype(jnp.float32)            # [M, Q]
    lat = lat_ref[...].astype(jnp.float32)              # [bm]
    batch = batch_ref[...]                              # [bm] int32
    m_count, q = w.shape
    log_clip = jnp.log(clip)

    # shifted urgency for each candidate in the slab: [bm, M, Q]
    shifted = w[None] + lat[:, None, None]
    urg = jnp.minimum(
        jnp.exp(jnp.minimum(shifted / tau - 1.0, log_clip)), clip
    ) * mask[None]
    total = jnp.sum(urg, axis=(1, 2))                   # [bm]

    # served tasks (B oldest of the candidate's own queue) are removed
    slab = jax.lax.broadcasted_iota(jnp.int32, (bm, m_count, q), 0)
    cand_rows = ic * bm + slab                          # global candidate row
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (bm, m_count, q), 1)
    pos_ids = jax.lax.broadcasted_iota(jnp.int32, (bm, m_count, q), 2)
    own = (row_ids == cand_rows)
    served = own & (pos_ids < batch[:, None, None])
    removed = jnp.sum(urg * served.astype(jnp.float32), axis=(1, 2))

    out_ref[...] = total - removed


def stability_scores_kernel(w, mask, cand_latency, cand_batch,
                            *, tau: float, clip: float = 10.0,
                            block_m: int = 8, interpret: bool = False):
    """w, mask [M, Q]; cand_latency [M] f32; cand_batch [M] i32 -> [M] f32."""
    m, q = w.shape
    bm = min(block_m, m)
    # pad M to a multiple of bm
    pad = (-m) % bm
    if pad:
        cand_latency = jnp.pad(cand_latency, (0, pad))
        cand_batch = jnp.pad(cand_batch, (0, pad))
    mp = m + pad
    grid = (mp // bm,)

    kernel = functools.partial(_score_kernel, tau=tau, clip=clip, bm=bm)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, q), lambda ic: (0, 0)),
            pl.BlockSpec((m, q), lambda ic: (0, 0)),
            pl.BlockSpec((bm,), lambda ic: (ic,)),
            pl.BlockSpec((bm,), lambda ic: (ic,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda ic: (ic,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=interpret,
    )(w, mask, cand_latency, cand_batch)
    return out[:m]
