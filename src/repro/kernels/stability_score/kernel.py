"""Candidate stability scoring (paper Eq. 3-7) as a Pallas TPU kernel.

The scheduler evaluates N candidate decisions per round; each candidate n
rescores *every* queued task under the predicted wait shift L_n — an
O(N * M * maxQ) fused pass. Candidates are a flattened (model, exit, batch)
lattice: ``cand_queue[n]`` names the queue candidate n would serve, so the
paper's one-candidate-per-queue greedy (N == M, cand_queue == arange) and
the joint lattice (N == sum over queues of |ladder| * |exits|) share one
kernel. At edge scale (M ~ 3) this is trivia, but the vectorised serving
tier (hundreds of colocated models / per-tenant queues) makes it a
per-round hot spot on the host: fusing exp/clip/mask/row-sum into one VMEM
pass keeps the scheduling quantum in the microsecond range.

Tiling: grid = (N/bn,); per step the full wait matrix [M, Q] sits in VMEM
(tens of KB for realistic M*Q) against a [bn] slab of candidates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(w_ref, mask_ref, lat_ref, batch_ref, queue_ref, out_ref,
                  *, tau: float, clip: float, bn: int):
    w = w_ref[...].astype(jnp.float32)                  # [M, Q]
    mask = mask_ref[...].astype(jnp.float32)            # [M, Q]
    lat = lat_ref[...].astype(jnp.float32)              # [bn]
    batch = batch_ref[...]                              # [bn] int32
    queue = queue_ref[...]                              # [bn] int32
    m_count, q = w.shape
    log_clip = jnp.log(clip)

    # shifted urgency for each candidate in the slab: [bn, M, Q]
    shifted = w[None] + lat[:, None, None]
    urg = jnp.minimum(
        jnp.exp(jnp.minimum(shifted / tau - 1.0, log_clip)), clip
    ) * mask[None]
    total = jnp.sum(urg, axis=(1, 2))                   # [bn]

    # served tasks (B oldest of the candidate's target queue) are removed
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (bn, m_count, q), 1)
    pos_ids = jax.lax.broadcasted_iota(jnp.int32, (bn, m_count, q), 2)
    own = row_ids == queue[:, None, None]
    served = own & (pos_ids < batch[:, None, None])
    removed = jnp.sum(urg * served.astype(jnp.float32), axis=(1, 2))

    out_ref[...] = total - removed


def stability_scores_kernel(w, mask, cand_latency, cand_batch,
                            cand_queue=None, *, tau: float, clip: float = 10.0,
                            block_m: int = 8, interpret: bool = False):
    """w, mask [M, Q]; cand_latency [N] f32; cand_batch, cand_queue [N] i32
    -> [N] f32. ``cand_queue=None`` means the one-candidate-per-queue greedy
    layout (N == M, candidate n serves queue n)."""
    m, q = w.shape
    if cand_queue is None:
        cand_queue = jnp.arange(m, dtype=jnp.int32)
    n = cand_latency.shape[0]
    bn = min(block_m, n)
    # pad N to a multiple of bn (padded candidates score garbage; sliced off)
    pad = (-n) % bn
    if pad:
        cand_latency = jnp.pad(cand_latency, (0, pad))
        cand_batch = jnp.pad(cand_batch, (0, pad))
        cand_queue = jnp.pad(cand_queue, (0, pad))
    np_ = n + pad
    grid = (np_ // bn,)

    kernel = functools.partial(_score_kernel, tau=tau, clip=clip, bn=bn)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, q), lambda ic: (0, 0)),
            pl.BlockSpec((m, q), lambda ic: (0, 0)),
            pl.BlockSpec((bn,), lambda ic: (ic,)),
            pl.BlockSpec((bn,), lambda ic: (ic,)),
            pl.BlockSpec((bn,), lambda ic: (ic,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda ic: (ic,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=interpret,
    )(w, mask, cand_latency, cand_batch, cand_queue)
    return out[:n]
