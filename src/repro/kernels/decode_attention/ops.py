"""Public wrapper for the decode-attention kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("block_s", "interpret",
                                             "use_kernel"))
def decode_attention(q, k, v, lengths, *, block_s: int = 512,
                     interpret: bool = False, use_kernel: bool = True):
    """One-token KV-cache attention. q [B, H, D]; k, v [B, K, S, D];
    lengths [B]. ``use_kernel=False`` -> jnp oracle."""
    if not use_kernel:
        return decode_attention_ref(q, k, v, lengths)
    return decode_attention_kernel(q, k, v, lengths, block_s=block_s,
                                   interpret=interpret)
