"""Pure-jnp oracle for single-token KV-cache (decode) attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths) -> jax.Array:
    """q [B, H, D]; k, v [B, K, S, D]; lengths [B] valid cache prefix.

    Returns [B, H, D] in q.dtype (fp32 softmax accumulation).
    """
    b, h, d = q.shape
    kh, s = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, d)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg, k).astype(jnp.float32)
    scores *= 1.0 / jnp.sqrt(d).astype(jnp.float32)
    valid = jnp.arange(s)[None, :] < lengths[:, None]          # [B, S]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bksd->bkgd", probs, v)
    return out.reshape(b, h, d)
