"""Single-token KV-cache attention (decode) as a Pallas TPU kernel.

Decode attention is HBM-bandwidth-bound: each step streams the whole KV
cache once and does O(S*D) FLOPs on it. The kernel splits the cache
sequence into blocks (split-K), carries online-softmax partials in VMEM
scratch across the sequential kv grid dimension, and masks the invalid
cache tail with the per-row ``lengths``.

Tiling: grid = (B, H, S/bs); blocks k/v [bs, D] (bs=512 default), the
single query row [1, D] stays resident. The q row is broadcast against the
kv block on the MXU via a [1, D] x [D, bs] dot.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, bs: int):
    ib = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]

    # skip blocks entirely beyond the valid prefix
    @pl.when(ik * bs < length)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # [1, D]
        k = k_ref[0, 0].astype(jnp.float32)            # [bs, D]
        v = v_ref[0, 0].astype(jnp.float32)            # [bs, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [1, bs]
        pos = ik * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                          # [1, bs]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)         # [1, D]

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_kernel(
    q: jax.Array, k: jax.Array, v: jax.Array, lengths: jax.Array,
    *, block_s: int = 512, interpret: bool = False,
) -> jax.Array:
    """q [B, H, D]; k, v [B, K, S, D]; lengths [B] int32 -> [B, H, D]."""
    b, h, d = q.shape
    kh, s = k.shape[1], k.shape[2]
    group = h // kh
    bs = min(block_s, s)
    assert s % bs == 0, (s, bs)
    grid = (b, h, s // bs)
    scale = 1.0 / (d ** 0.5)

    q4 = q[:, :, None, :]                               # [B, H, 1, D]
    kernel = functools.partial(_decode_kernel, scale=scale, bs=bs)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_, ik: (b_,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, d), lambda b_, h_, ik: (b_, h_, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h_, ik, g=group: (b_, h_ // g, ik, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h_, ik, g=group: (b_, h_ // g, ik, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda b_, h_, ik: (b_, h_, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q4, k, v)
    return out[:, :, 0, :]
