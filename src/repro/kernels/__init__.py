"""Pallas TPU kernels for the serving hot spots.

Each kernel package has three modules:
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (shape plumbing, interpret switch)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels are validated with ``interpret=True`` on CPU (the container has no
TPU); the model forward paths use the jnp reference implementations so the
dry-run HLO stays analyzable, and real-TPU deployments flip
``use_flash_kernel`` (see DESIGN.md §6).
"""
