"""Request / decision / completion record types for the EdgeServing core.

These are deliberately tiny, allocation-cheap host-side records: the online
scheduler runs on the host between accelerator quanta, so every byte and
branch here is on the serving critical path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(slots=True)
class Request:
    """A single inference request enqueued to a model's service queue.

    Attributes:
      req_id:    globally unique, monotone id (also used as FIFO tiebreak).
      model:     index of the target model queue in ``[0, M)``.
      arrival:   arrival wall-clock time in seconds.
      data_id:   opaque payload index (e.g. CIFAR test index / prompt id).
      deadline:  optional per-request latency budget in seconds (relative to
                 ``arrival``). ``None`` means "use the global SLO tau" — the
                 paper's single-deadline setting. Workload scenarios attach
                 per-queue SLO vectors here (see ``repro.core.workloads``),
                 and the value flows through snapshot urgency, Eq. 6
                 feasibility, and violation accounting end to end.
    """

    req_id: int
    model: int
    arrival: float
    data_id: int = 0
    deadline: Optional[float] = None


@dataclasses.dataclass(slots=True)
class Decision:
    """A scheduling decision ``(m*, e*, B*)`` (paper Eq. 5-7).

    Attributes:
      model:      selected model queue ``m*``.
      exit_idx:   selected early-exit point ``e*`` as an index into the
                  model's exit list (0 = shallowest, E-1 = final).
      batch_size: selected batch size ``B*`` (number of requests dequeued).
      predicted_latency: profile-table latency ``L(m*, e*, B*)`` in seconds.
      stability_score:   predicted system stability score ``S_{m*}`` under
                  this decision (lower = more stable); NaN for schedulers
                  that do not compute one.
    """

    model: int
    exit_idx: int
    batch_size: int
    predicted_latency: float
    stability_score: float = float("nan")


@dataclasses.dataclass(slots=True)
class Completion:
    """A completed request with its end-to-end accounting.

    ``total_latency = queueing + service`` (paper Eq. 1):
    ``T_i = w_i + t_i``.
    """

    req_id: int
    model: int
    arrival: float
    dispatch: float
    finish: float
    exit_idx: int
    batch_size: int
    deadline: Optional[float] = None  # per-request SLO override (seconds)

    @property
    def queueing(self) -> float:
        return self.dispatch - self.arrival

    @property
    def service(self) -> float:
        return self.finish - self.dispatch

    @property
    def total_latency(self) -> float:
        return self.finish - self.arrival

    def violates(self, slo: float) -> bool:
        """Deadline check: the request's own deadline wins over the global
        ``slo`` when set (heterogeneous-SLO workloads)."""
        tau = self.deadline if self.deadline is not None else slo
        return self.total_latency > tau


@dataclasses.dataclass(slots=True)
class ServingTrace:
    """One dispatched accelerator quantum (for timelines / debugging)."""

    t_start: float
    t_end: float
    decision: Decision
    queue_lengths: Optional[tuple] = None
