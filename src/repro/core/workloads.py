"""Workload scenarios: arrival-process generators beyond stationary Poisson.

The paper evaluates EdgeServing only under stationary Poisson arrivals with a
single global SLO (Sec. VI-A). The stability score's whole pitch, though, is
predicting *future* queue impact — which is only stressed by non-stationary,
bursty traffic of the kind the edge-serving literature treats as the defining
workload (He et al., "Adaptive Scheduling for Edge-Assisted DNN Serving";
Yang et al., "DeepRT"). This module provides a common :class:`ArrivalProcess`
interface and five generators:

  * :class:`PoissonProcess`    — the paper's stationary default (refactored
    from ``repro.core.traffic``, which stays import-compatible);
  * :class:`MMPPProcess`       — two-state Markov-modulated Poisson (on-off
    bursts, mean rate preserved);
  * :class:`DiurnalProcess`    — sinusoid-modulated rate (day/night cycle,
    compressed to simulation timescales);
  * :class:`FlashCrowdProcess` — a flash-crowd spike multiplying the rate of
    selected models inside a window;
  * :class:`TraceReplayProcess`— deterministic replay of a recorded trace
    (round-trips through :func:`record_trace`).

Every generator is seed-deterministic (``generate(horizon, seed)`` always
yields the same trace), emits the existing :class:`~repro.core.request.Request`
type sorted by arrival time with monotone ``req_id``, and can stamp a
per-queue SLO vector onto ``Request.deadline`` so heterogeneous deadlines
flow end-to-end through snapshot urgency, Eq. 6, and violation accounting.

See ``docs/workloads.md`` for each process's generative model, parameters,
and burstiness index, and ``benchmarks/fig13_workloads.py`` for the
cross-scenario policy sweep.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.request import Request

__all__ = [
    "ArrivalProcess",
    "PoissonProcess",
    "MMPPProcess",
    "DiurnalProcess",
    "FlashCrowdProcess",
    "TraceColumns",
    "TraceReplayProcess",
    "SCENARIOS",
    "columns_from_requests",
    "make_scenario",
    "record_trace",
    "interarrival_cov",
    "burstiness_index",
]


# ---------------------------------------------------------------------------
# Columnar traces (the scan engines' native format)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceColumns:
    """One arrival trace as columnar arrays instead of ``Request`` objects.

    The compiled scan engines flatten a ``Request`` lane straight back into
    arrays, so for thousand-seed bands the per-request Python objects are
    pure overhead (at 10^4 requests/lane, materialising them costs more
    than the scan itself). ``ArrivalProcess.generate_columns`` produces
    this form directly; ``repro.core.simfast`` / ``clusterfast`` accept it
    wherever a ``Request`` lane is accepted, with bitwise-identical
    results (``req_id`` is the row index, exactly ``generate()``'s
    numbering). Indexing materialises single ``Request`` objects on
    demand, so completion-keeping paths keep working.
    """

    arrival: np.ndarray             # [n] float64, sorted ascending
    model: np.ndarray               # [n] int64 queue index
    data_id: np.ndarray             # [n] int64
    deadline: Optional[np.ndarray]  # [n] float64, NaN = no deadline; or None

    def __len__(self) -> int:
        return len(self.model)

    def __getitem__(self, i: int) -> Request:
        dl = None
        if self.deadline is not None:
            d = self.deadline[i]
            dl = None if np.isnan(d) else float(d)
        return Request(
            req_id=int(i),
            model=int(self.model[i]),
            arrival=float(self.arrival[i]),
            data_id=int(self.data_id[i]),
            deadline=dl,
        )

    def __iter__(self):
        return (self[i] for i in range(len(self)))


def columns_from_requests(requests: Sequence[Request]) -> TraceColumns:
    """Columnar view of an existing ``Request`` lane (shared fallback)."""
    n = len(requests)
    arrival = np.fromiter(
        (r.arrival for r in requests), dtype=np.float64, count=n)
    model = np.fromiter((r.model for r in requests), dtype=np.int64, count=n)
    data = np.fromiter(
        (r.data_id for r in requests), dtype=np.int64, count=n)
    if all(r.deadline is None for r in requests):
        deadline = None
    else:
        deadline = np.fromiter(
            (np.nan if r.deadline is None else r.deadline for r in requests),
            dtype=np.float64, count=n,
        )
    return TraceColumns(arrival=arrival, model=model, data_id=data,
                        deadline=deadline)


# ---------------------------------------------------------------------------
# Interface
# ---------------------------------------------------------------------------


class ArrivalProcess:
    """Seed-deterministic generator of a merged, time-sorted Request trace.

    Args:
      rates:     per-model *mean* arrival rates (req/s); zero-rate models
                 receive no traffic.
      deadlines: optional per-model SLO vector (seconds); stamped onto each
                 generated request's ``deadline``. ``None`` keeps the global
                 SLO fallback (the paper's setting).
    """

    name = "base"

    def __init__(
        self,
        rates: Sequence[float],
        deadlines: Optional[Sequence[float]] = None,
    ):
        self.rates = [float(r) for r in rates]
        if deadlines is not None:
            deadlines = [float(d) for d in deadlines]
            assert len(deadlines) == len(self.rates), (
                "deadlines must give one SLO per model"
            )
        self.deadlines = deadlines

    @property
    def num_models(self) -> int:
        return len(self.rates)

    def mean_rate(self, m: int) -> float:
        """Long-run mean arrival rate of model ``m`` (req/s)."""
        return self.rates[m]

    def generate(
        self, horizon: float, seed: int = 0, data_pool: int = 10_000
    ) -> List[Request]:
        """Arrivals in ``[0, horizon)``, time-sorted, ``req_id`` monotone."""
        raise NotImplementedError

    def generate_columns(
        self, horizon: float, seed: int = 0, data_pool: int = 10_000
    ) -> TraceColumns:
        """The same trace as :meth:`generate`, as :class:`TraceColumns`.

        Bitwise-identical to columnising ``generate()``'s output — same
        RNG draws, same sort order — but skips ``Request``
        materialisation and the Python tuple sort, which dominate
        generation cost at scan-engine scale. Processes that build their
        trace some other way than ``_event_tuples`` fall back through
        ``generate()``.
        """
        events = self._event_tuples(horizon, seed, data_pool)
        if events is None:
            return columns_from_requests(
                self.generate(horizon, seed=seed, data_pool=data_pool))
        return self._finalize_columns(events)

    # -- shared assembly ----------------------------------------------------

    def _event_tuples(
        self, horizon: float, seed: int, data_pool: int
    ) -> Optional[List[tuple]]:
        """Unsorted ``[(t, m, data_id)]`` events, or None if the subclass
        assembles requests directly (column generation then falls back)."""
        return None

    def _finalize_columns(self, events: List[tuple]) -> TraceColumns:
        """Columnar counterpart of :meth:`_finalize`: ``lexsort`` on
        ``(t, m, data_id)`` reproduces the tuple sort order exactly."""
        n = len(events)
        t = np.fromiter((e[0] for e in events), dtype=np.float64, count=n)
        m = np.fromiter((e[1] for e in events), dtype=np.int64, count=n)
        d = np.fromiter((e[2] for e in events), dtype=np.int64, count=n)
        order = np.lexsort((d, m, t))
        t, m, d = t[order], m[order], d[order]
        dl = self.deadlines
        deadline = (
            None if dl is None
            else np.asarray(dl, dtype=np.float64)[m]
        )
        return TraceColumns(arrival=t, model=m, data_id=d, deadline=deadline)

    def _finalize(self, events: List[tuple]) -> List[Request]:
        """``[(t, m, data_id)]`` -> sorted Request list with deadlines."""
        events.sort()
        dl = self.deadlines
        return [
            Request(
                req_id=i,
                model=m,
                arrival=t,
                data_id=int(d),
                deadline=None if dl is None else dl[m],
            )
            for i, (t, m, d) in enumerate(events)
        ]

    def _piecewise_events(
        self,
        rng: np.random.Generator,
        segments: Sequence[Tuple[float, float, float]],
        data_pool: int,
    ) -> List[tuple]:
        """Poisson events under a piecewise-constant rate multiplier.

        ``segments`` is ``[(t0, t1, mult)]`` covering the horizon; within each
        segment model ``m`` arrives as Poisson at ``rates[m] * mult`` (count ~
        Poisson(rate*dur), times i.i.d. uniform — the standard construction).
        """
        events: List[tuple] = []
        for m, lam in enumerate(self.rates):
            if lam > 0:
                events.extend(
                    _segment_poisson(rng, m, lam, segments, data_pool)
                )
        return events


def _segment_poisson(
    rng: np.random.Generator,
    model: int,
    lam: float,
    segments: Sequence[Tuple[float, float, float]],
    data_pool: int,
) -> List[tuple]:
    """One model's ``(t, model, data_id)`` events over rate segments."""
    events: List[tuple] = []
    for t0, t1, mult in segments:
        dur = t1 - t0
        if dur <= 0 or mult <= 0:
            continue
        n = int(rng.poisson(lam * mult * dur))
        times = rng.uniform(t0, t1, size=n)
        data = rng.integers(0, data_pool, size=n)
        events.extend(zip(times.tolist(), [model] * n, data.tolist()))
    return events


# ---------------------------------------------------------------------------
# Poisson (paper Sec. VI-A) — the algorithm formerly in core/traffic.py
# ---------------------------------------------------------------------------


class PoissonProcess(ArrivalProcess):
    """Stationary independent Poisson arrivals per model (the paper default).

    The generation algorithm is the one ``traffic.poisson_arrivals`` has
    always used (exponential gaps, vectorised with slack then trimmed), so
    traces for a given seed are unchanged by the refactor.
    """

    name = "poisson"

    def _event_tuples(
        self, horizon: float, seed: int, data_pool: int
    ) -> List[tuple]:
        rng = np.random.default_rng(seed)
        events: List[tuple] = []
        for m, lam in enumerate(self.rates):
            if lam <= 0:
                continue
            # Expected count + slack, then trim: cheaper than a Python loop.
            n_expect = int(lam * horizon * 1.25 + 50)
            gaps = rng.exponential(1.0 / lam, size=n_expect)
            times = np.cumsum(gaps)
            while times[-1] < horizon:  # extremely unlikely; extend defensively
                extra = rng.exponential(1.0 / lam, size=n_expect)
                times = np.concatenate([times, times[-1] + np.cumsum(extra)])
            times = times[times < horizon]
            data = rng.integers(0, data_pool, size=len(times))
            events.extend(zip(times.tolist(), [m] * len(times), data.tolist()))
        return events

    def generate(
        self, horizon: float, seed: int = 0, data_pool: int = 10_000
    ) -> List[Request]:
        return self._finalize(self._event_tuples(horizon, seed, data_pool))


# ---------------------------------------------------------------------------
# MMPP: two-state on-off bursts
# ---------------------------------------------------------------------------


class MMPPProcess(ArrivalProcess):
    """Two-state Markov-modulated Poisson process (bursty on-off traffic).

    A single modulating chain (shared by all models, so bursts hit every
    queue together — the hard case for a multi-queue scheduler) alternates
    between ON and OFF states with exponential holding times. In the ON
    state every rate is multiplied by ``burst``; the OFF multiplier is
    derived so the long-run mean rate equals ``rates``:

        duty * burst + (1 - duty) * off = 1
        =>  off = (1 - duty * burst) / (1 - duty)      (requires duty*burst <= 1)

    Args:
      burst: ON-state rate multiplier (> 1).
      duty:  long-run fraction of time spent ON.
      cycle: mean ON+OFF cycle length in seconds (mean ON holding time is
             ``duty * cycle``, mean OFF is ``(1 - duty) * cycle``).
    """

    name = "mmpp"

    def __init__(
        self,
        rates: Sequence[float],
        burst: float = 3.0,
        duty: float = 0.25,
        cycle: float = 2.0,
        deadlines: Optional[Sequence[float]] = None,
    ):
        super().__init__(rates, deadlines)
        assert burst >= 1.0 and 0.0 < duty < 1.0 and cycle > 0.0
        assert burst * duty <= 1.0, (
            "mean-preserving OFF rate would be negative: need burst*duty <= 1"
        )
        self.burst = float(burst)
        self.duty = float(duty)
        self.cycle = float(cycle)
        self.off = (1.0 - self.duty * self.burst) / (1.0 - self.duty)

    def _segments(
        self, rng: np.random.Generator, horizon: float
    ) -> List[Tuple[float, float, float]]:
        segs: List[Tuple[float, float, float]] = []
        t = 0.0
        on = bool(rng.random() < self.duty)  # stationary start state
        while t < horizon:
            mean = self.duty * self.cycle if on else (1.0 - self.duty) * self.cycle
            dur = float(rng.exponential(mean))
            segs.append((t, min(t + dur, horizon), self.burst if on else self.off))
            t += dur
            on = not on
        return segs

    def _event_tuples(
        self, horizon: float, seed: int, data_pool: int
    ) -> List[tuple]:
        rng = np.random.default_rng(seed)
        segs = self._segments(rng, horizon)
        return self._piecewise_events(rng, segs, data_pool)

    def generate(
        self, horizon: float, seed: int = 0, data_pool: int = 10_000
    ) -> List[Request]:
        return self._finalize(self._event_tuples(horizon, seed, data_pool))


# ---------------------------------------------------------------------------
# Diurnal: sinusoid-modulated rate
# ---------------------------------------------------------------------------


class DiurnalProcess(ArrivalProcess):
    """Sinusoid-modulated Poisson (a day/night cycle at simulation scale).

        rate_m(t) = rates[m] * (1 + depth * sin(2π t / period + phase))

    Generated by thinning (Lewis & Shedler): homogeneous candidates at the
    peak rate ``rates[m] * (1 + depth)``, each accepted with probability
    ``rate_m(t) / peak``. The long-run mean over whole periods is ``rates``.

    Args:
      period: modulation period in seconds (paper horizons are ~10-20 s, so
              the default compresses a "day" into 10 s).
      depth:  modulation depth in [0, 1); 0 degenerates to Poisson.
      phase:  phase offset in radians (models share one phase: load peaks
              together, like evening traffic).
    """

    name = "diurnal"

    def __init__(
        self,
        rates: Sequence[float],
        period: float = 10.0,
        depth: float = 0.8,
        phase: float = -math.pi / 2,  # start at the trough: ramp up, peak, ramp down
        deadlines: Optional[Sequence[float]] = None,
    ):
        super().__init__(rates, deadlines)
        assert period > 0.0 and 0.0 <= depth < 1.0
        self.period = float(period)
        self.depth = float(depth)
        self.phase = float(phase)

    def _mult(self, t: np.ndarray) -> np.ndarray:
        return 1.0 + self.depth * np.sin(
            2.0 * math.pi * t / self.period + self.phase
        )

    def _event_tuples(
        self, horizon: float, seed: int, data_pool: int
    ) -> List[tuple]:
        rng = np.random.default_rng(seed)
        events: List[tuple] = []
        peak = 1.0 + self.depth
        for m, lam in enumerate(self.rates):
            if lam <= 0:
                continue
            n_cand = int(rng.poisson(lam * peak * horizon))
            cand = rng.uniform(0.0, horizon, size=n_cand)
            accept = rng.random(n_cand) < self._mult(cand) / peak
            times = cand[accept]
            data = rng.integers(0, data_pool, size=len(times))
            events.extend(
                zip(times.tolist(), [m] * len(times), data.tolist())
            )
        return events

    def generate(
        self, horizon: float, seed: int = 0, data_pool: int = 10_000
    ) -> List[Request]:
        return self._finalize(self._event_tuples(horizon, seed, data_pool))


# ---------------------------------------------------------------------------
# Flash crowd: rate spike in a window
# ---------------------------------------------------------------------------


class FlashCrowdProcess(ArrivalProcess):
    """Baseline Poisson plus a flash-crowd spike (unforeseen surge).

    Inside ``[spike_start, spike_start + spike_duration)`` the rate of every
    spiked model is multiplied by ``magnitude``; outside it traffic is the
    stationary baseline. Unlike MMPP/diurnal the *mean* rate rises above
    ``rates`` — a flash crowd is extra load, not redistributed load.

    ``spike_start``/``spike_duration`` may be ``None`` to default to 40% and
    10% of the horizon at generate() time.

    Args:
      magnitude:    rate multiplier during the spike (>= 1).
      spike_models: model indices hit by the spike (default: all models —
                    a correlated crowd; pass e.g. ``(0,)`` for a one-queue
                    hotspot, the case that stresses cross-queue scheduling).
    """

    name = "flash-crowd"

    def __init__(
        self,
        rates: Sequence[float],
        spike_start: Optional[float] = None,
        spike_duration: Optional[float] = None,
        magnitude: float = 5.0,
        spike_models: Optional[Sequence[int]] = None,
        deadlines: Optional[Sequence[float]] = None,
    ):
        super().__init__(rates, deadlines)
        assert magnitude >= 1.0
        self.spike_start = spike_start
        self.spike_duration = spike_duration
        self.magnitude = float(magnitude)
        self.spike_models = (
            None if spike_models is None else tuple(int(m) for m in spike_models)
        )

    def _window(self, horizon: float) -> Tuple[float, float]:
        start = 0.4 * horizon if self.spike_start is None else self.spike_start
        dur = 0.1 * horizon if self.spike_duration is None else self.spike_duration
        return start, min(start + dur, horizon)

    def _event_tuples(
        self, horizon: float, seed: int, data_pool: int
    ) -> List[tuple]:
        rng = np.random.default_rng(seed)
        t0, t1 = self._window(horizon)
        spiked = (
            set(range(self.num_models))
            if self.spike_models is None
            else set(self.spike_models)
        )
        events: List[tuple] = []
        for m, lam in enumerate(self.rates):
            if lam <= 0:
                continue
            mag = self.magnitude if m in spiked else 1.0
            segs = [(0.0, t0, 1.0), (t0, t1, mag), (t1, horizon, 1.0)]
            events.extend(_segment_poisson(rng, m, lam, segs, data_pool))
        return events

    def generate(
        self, horizon: float, seed: int = 0, data_pool: int = 10_000
    ) -> List[Request]:
        return self._finalize(self._event_tuples(horizon, seed, data_pool))


# ---------------------------------------------------------------------------
# Trace replay
# ---------------------------------------------------------------------------


def record_trace(requests: Sequence[Request]) -> List[tuple]:
    """Serialize requests to plain ``(arrival, model, data_id, deadline)``
    tuples — JSON-friendly, and the exact inverse of replaying them."""
    return [(r.arrival, r.model, r.data_id, r.deadline) for r in requests]


class TraceReplayProcess(ArrivalProcess):
    """Deterministic replay of a recorded arrival trace.

    Construct from either an explicit ``trace`` (``record_trace`` output, or
    bare ``(arrival, model)`` pairs) or a ``source`` process whose generated
    trace is recorded and replayed through the serialization round-trip —
    proving the record/replay path end-to-end while behaving exactly like
    the source. Replay ignores entries at or past the horizon and re-issues
    ``req_id`` sequentially in time order.

    Args:
      time_scale: multiply recorded timestamps (e.g. 0.5 compresses a trace
                  to double its arrival intensity).
    """

    name = "trace-replay"

    def __init__(
        self,
        trace: Optional[Sequence[tuple]] = None,
        source: Optional[ArrivalProcess] = None,
        time_scale: float = 1.0,
        deadlines: Optional[Sequence[float]] = None,
    ):
        assert (trace is None) != (source is None), (
            "exactly one of trace/source must be given"
        )
        if trace is not None:
            num_models = 1 + max((int(e[1]) for e in trace), default=0)
        else:
            num_models = source.num_models
        super().__init__([0.0] * num_models, deadlines)
        self.trace = None if trace is None else [tuple(e) for e in trace]
        self.source = source
        self.time_scale = float(time_scale)

    def mean_rate(self, m: int) -> float:
        if self.source is not None:
            return self.source.mean_rate(m) / self.time_scale
        return self.rates[m]  # unknown for bare traces

    def generate(
        self, horizon: float, seed: int = 0, data_pool: int = 10_000
    ) -> List[Request]:
        trace = self.trace
        if trace is None:
            inner = self.source.generate(
                horizon / self.time_scale, seed=seed, data_pool=data_pool
            )
            trace = record_trace(inner)
        dl = self.deadlines
        entries = []
        for e in trace:
            t = float(e[0]) * self.time_scale
            if t >= horizon:
                continue
            m = int(e[1])
            data = int(e[2]) if len(e) > 2 else 0
            deadline = e[3] if len(e) > 3 else None
            if deadline is None and dl is not None:
                deadline = dl[m]
            entries.append((t, m, data, deadline))
        entries.sort()
        return [
            Request(req_id=i, model=m, arrival=t, data_id=d, deadline=dead)
            for i, (t, m, d, dead) in enumerate(entries)
        ]


# ---------------------------------------------------------------------------
# Burstiness diagnostics
# ---------------------------------------------------------------------------


def interarrival_cov(requests: Sequence[Request], model: Optional[int] = None) -> float:
    """Coefficient of variation (std/mean) of interarrival times.

    1.0 for Poisson; > 1 for bursty (MMPP, flash-crowd) processes. Pass
    ``model`` to restrict to one queue's substream, else the merged trace.
    """
    times = np.array(
        [r.arrival for r in requests if model is None or r.model == model]
    )
    gaps = np.diff(times)
    if len(gaps) < 2 or gaps.mean() == 0:
        return 0.0
    return float(gaps.std() / gaps.mean())


def burstiness_index(requests: Sequence[Request], model: Optional[int] = None) -> float:
    """Squared interarrival CoV — the renewal-process burstiness index
    (1 = Poisson, > 1 = bursty, < 1 = regular)."""
    return interarrival_cov(requests, model) ** 2


# ---------------------------------------------------------------------------
# Scenario registry
# ---------------------------------------------------------------------------


def _replayed_mmpp(rates, deadlines=None, **kwargs) -> TraceReplayProcess:
    """The fig13 'trace-replay' scenario: record an MMPP trace and replay it
    through the serialization round-trip."""
    return TraceReplayProcess(
        source=MMPPProcess(rates, **kwargs), deadlines=deadlines
    )


SCENARIOS: Dict[str, Callable[..., ArrivalProcess]] = {
    "poisson": PoissonProcess,
    "mmpp": MMPPProcess,
    "diurnal": DiurnalProcess,
    "flash-crowd": FlashCrowdProcess,
    "trace-replay": _replayed_mmpp,
}


def make_scenario(
    name: str,
    rates: Sequence[float],
    deadlines: Optional[Sequence[float]] = None,
    **kwargs,
) -> ArrivalProcess:
    """Instantiate a registered scenario by name with per-model ``rates``
    (and optionally a per-model SLO vector + scenario-specific kwargs)."""
    try:
        factory = SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; available: {sorted(SCENARIOS)}"
        ) from None
    return factory(rates, deadlines=deadlines, **kwargs)
