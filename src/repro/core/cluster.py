"""Cluster serving: multi-device dispatch over per-device Algorithm-1 schedulers.

The paper's scheduler shares *one* accelerator; the ROADMAP's north star is
heavy traffic sharded across many. This module scales the single-device
story out deterministically: G devices each run their own Algorithm-1
scheduler (any registered policy) over their *own* :class:`ProfileTable` —
including heterogeneous fleets mixing fast and slow hardware — behind a
first-class :class:`Dispatcher` policy family shared with the live
:class:`repro.runtime.router.ReplicaRouter`.

Three layers (see ``docs/cluster.md``):

  * **Dispatchers** route each arrival to one eligible device through the
    abstract :class:`DeviceLoadView` (the live router and the simulator both
    implement it, so the selection math is written once):
    ``round-robin``, ``jsq`` (join-shortest-queue by queued tasks),
    ``least-loaded`` (capacity-weighted expected drain time — the
    ReplicaRouter default), and ``stability-aware`` — a power-of-d sampler
    that routes to the device whose predicted per-device stability-score
    delta (Eq. 3 urgency the request will have accrued at its predicted
    completion on that device) is smallest.
  * **Placement**: a :class:`DeviceSpec` may restrict which models a device
    hosts; the dispatcher only considers devices hosting the request's
    model. Every device keeps one FIFO queue per *global* model index, so a
    single-device cluster is literally the single-device simulator.
  * **ClusterSimulator**: a global time-ordered event loop (failure <
    arrival < device-round at equal timestamps, then device id) in which
    each device reproduces ``ServingSimulator``'s per-round semantics
    exactly — a G=1 cluster is bitwise-identical to the single-device
    simulator on the same trace (tested).

Failure semantics: at a device's ``fail_at`` time it is marked dead and
excluded from dispatch; its in-flight quantum completes (results are
delivered), and its queued requests are immediately re-dispatched through
the dispatcher to surviving eligible devices in (arrival, req_id) order,
keeping their original arrival times (honest waiting-time accounting). If a
model has no surviving host, its requests strand and count as residual.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.adaptive import (
    AdaptConfig,
    DriftModel,
    OnlineProfiler,
    make_profiler,
)
from repro.core.baselines import make_scheduler
from repro.core.metrics import DeviceMetrics, ServingMetrics, summarize
from repro.core.profile import ProfileTable
from repro.core.queues import QueueSnapshot, ServiceQueue
from repro.core.request import Completion, Request
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.simulator import service_noise_multiplier
from repro.core.telemetry import Trace, Tracer, decision_margin
from repro.core.urgency import DEFAULT_CLIP, urgency_np

__all__ = [
    "ClusterResult",
    "ClusterSimulator",
    "DeviceLoadView",
    "DeviceSpec",
    "Dispatcher",
    "DISPATCHERS",
    "FLEETS",
    "JoinShortestQueueDispatcher",
    "LeastLoadedDispatcher",
    "RoundRobinDispatcher",
    "StabilityAwareDispatcher",
    "drain_estimate",
    "make_dispatcher",
    "make_fleet",
]

# Idle wake-ups advance by one float64 ulp (np.nextafter), matching
# ServingSimulator's idle-advance so a G=1 cluster schedules at
# bit-identical timestamps (waits feed the stability score directly).
# A fixed epsilon would stall below float64 resolution at large t.


# ---------------------------------------------------------------------------
# Closed-form drain estimate (shared with ReplicaRouter.backlog_from_scheduler)
# ---------------------------------------------------------------------------


def drain_cell(
    scheduler: Scheduler, model: int, qlen: int,
    exit_idx: Optional[int] = None,
) -> float:
    """Drain time of one ``(model, qlen)`` queue in isolation.

    Closed form over the Eq. 5 rule ``B* = min(|Q|, B_cap)``: the queue
    drains as ``n // B_cap`` full batches plus one remainder rung, so the
    O(queue-length) serve-loop collapses to a quotient and a lookup.
    ``B_cap`` is read from the policy itself (``scheduler.batch_size``), so a
    bs=1 ablation or a small-``B_max`` deployment advertises its true
    (slower) drain time. The closed form is used only for policies running
    the stock Eq. 5 implementation (where it is provably exact); a policy
    that *overrides* ``batch_size`` with its own ladder is served out
    exactly by the O(queue-length) loop instead. Exit defaults to the
    deepest (conservative).
    """
    table = scheduler.table
    e = table.num_exits - 1 if exit_idx is None else exit_idx
    n = int(qlen)
    if n <= 0:
        return 0.0
    if type(scheduler).batch_size is not Scheduler.batch_size:
        sub = 0.0  # custom ladder: serve it out exactly
        while n > 0:
            b = scheduler.batch_size(n)
            sub += table(model, e, b)
            n -= b
        return sub
    cap = scheduler.batch_size(n)
    full, rem = divmod(n, cap)
    sub = full * table(model, e, cap)
    if rem:
        sub += table(model, e, rem)
    return sub


def drain_estimate(
    scheduler: Scheduler, qlens: Sequence[int], exit_idx: Optional[int] = None
) -> float:
    """Expected time to drain ``qlens`` under the scheduler's batch ladder:
    one :func:`drain_cell` per queue, accumulated per-model-subtotal-first
    so the sum is a fixed left-to-right fold over model index. The compiled
    cluster engine (``repro.core.clusterfast``) precomputes a
    ``[model, qlen]`` table of drain_cell values and replays the identical
    fold, so dispatcher backlog comparisons agree bitwise across engines
    (results differ from a fully interleaved accumulation only in float
    summation order, pinned to 1e-12 by a regression test in
    ``tests/test_router.py``)."""
    total = 0.0
    for m, n in enumerate(qlens):
        if int(n) <= 0:
            continue
        total += drain_cell(scheduler, m, n, exit_idx)
    return total


# ---------------------------------------------------------------------------
# Dispatcher policy family
# ---------------------------------------------------------------------------


class DeviceLoadView:
    """What a dispatcher may observe about the fleet.

    Implemented by both :class:`ClusterSimulator` (live queue state, exact
    drain estimates) and :class:`repro.runtime.router.ReplicaRouter`
    (reported backlogs, straggler-scaled). All methods are O(1)-ish per
    device; dispatchers touch O(G) (or O(d) for power-of-d) per request.
    """

    def healthy(self, d: int) -> bool:
        raise NotImplementedError

    def effective_backlog(self, d: int) -> float:
        """Expected seconds until device ``d`` drains its current work,
        scaled by its capacity/straggler multiplier."""
        raise NotImplementedError

    def total_queued(self, d: int) -> int:
        """Number of requests currently queued on device ``d``."""
        raise NotImplementedError

    def predicted_completion(self, d: int, model: int) -> float:
        """Predicted end-to-end latency a ``model`` request dispatched now
        would see on device ``d`` (backlog + its own service time there)."""
        raise NotImplementedError


class Dispatcher:
    """Maps one arrival to one eligible device. Stateful dispatchers
    (round-robin counter, power-of-d RNG) are reset per experiment via
    :meth:`reset` so sweep cells stay hermetic. ``deadline`` is the
    request's own SLO when it carries one (heterogeneous-SLO workloads);
    load-only policies ignore it."""

    name = "base"

    def reset(self, seed: int = 0) -> None:
        pass

    def pick(self, model: int, eligible: Sequence[int],
             view: DeviceLoadView, deadline: Optional[float] = None) -> int:
        raise NotImplementedError


class RoundRobinDispatcher(Dispatcher):
    """Cycle through eligible devices, blind to load and capacity."""

    name = "round-robin"

    def __init__(self):
        self._i = 0

    def reset(self, seed: int = 0) -> None:
        self._i = 0

    def pick(self, model, eligible, view, deadline=None):
        d = eligible[self._i % len(eligible)]
        self._i += 1
        return d


class JoinShortestQueueDispatcher(Dispatcher):
    """Fewest queued requests wins (ties -> lowest device id). Blind to
    device speed: on heterogeneous fleets a short queue on slow hardware
    still means a long wait — exactly what fig14's het leg exposes."""

    name = "jsq"

    def pick(self, model, eligible, view, deadline=None):
        return min(eligible, key=lambda d: (view.total_queued(d), d))


class LeastLoadedDispatcher(Dispatcher):
    """Capacity-weighted least-loaded: smallest straggler/capacity-scaled
    expected drain time (ties -> lowest device id). This is the selection
    rule :class:`repro.runtime.router.ReplicaRouter` has always used; it now
    lives here so the simulator and the live router share one implementation.
    """

    name = "least-loaded"

    def pick(self, model, eligible, view, deadline=None):
        return min(eligible, key=lambda d: (view.effective_backlog(d), d))


class StabilityAwareDispatcher(Dispatcher):
    """Power-of-d stability-aware dispatch.

    Samples ``d`` distinct eligible devices (seeded RNG; classic
    power-of-d-choices keeps per-request cost O(d) while capturing most of
    the benefit of a full scan) and routes to the one whose predicted
    per-device stability-score delta is smallest: the Eq. 3 urgency
    ``f(T_hat) = min(exp(T_hat / tau - 1), C)`` the request will have
    accrued at its predicted completion ``T_hat`` on that device — i.e. the
    request's own contribution to that device's stability score at service
    time. ``tau`` is the request's own deadline when it carries one
    (heterogeneous-SLO workloads), else the constructor ``slo``.

    Because f is monotone non-decreasing in ``T_hat`` for the request's
    single tau, ``argmin f(T_hat)`` equals ``argmin T_hat`` — so the pick
    is computed directly on predicted completion (no exponentials on the
    dispatch path; ``slo``/``clip`` define the delta's interpretation and
    the :func:`delta` helper, not the routing arithmetic). Ties resolve by
    device id.

    Unlike JSQ/round-robin this sees *through* heterogeneity: a 3x-slower
    device inflates ``T_hat`` via both its drain time and its own service
    term, so the dispatcher prices the SLO impact of the placement, not just
    the queue length.
    """

    name = "stability-aware"

    def __init__(self, slo: float = 0.050, power_d: int = 2,
                 clip: float = DEFAULT_CLIP):
        assert power_d >= 1
        self.slo = float(slo)
        self.power_d = int(power_d)
        self.clip = float(clip)
        self._rng = np.random.default_rng(0xD15B)

    def reset(self, seed: int = 0) -> None:
        self._rng = np.random.default_rng(seed ^ 0xD15B)

    def delta(self, t_hat: float, deadline: Optional[float] = None) -> float:
        """The priced per-device stability-score delta f(T_hat) — what the
        argmin below minimises (via the monotone shortcut on T_hat)."""
        tau = self.slo if deadline is None else deadline
        return float(urgency_np(np.asarray(t_hat), tau, self.clip))

    def pick(self, model, eligible, view, deadline=None):
        k = min(self.power_d, len(eligible))
        if k == len(eligible):
            sample = list(eligible)
        else:
            idx = self._rng.choice(len(eligible), size=k, replace=False)
            sample = [eligible[int(i)] for i in sorted(idx)]
        # argmin of the stability delta == argmin of predicted completion
        # (f monotone for one tau); ties break toward the lower device id.
        return min(sample,
                   key=lambda d: (view.predicted_completion(d, model), d))


DISPATCHERS: Dict[str, Callable[..., Dispatcher]] = {
    "round-robin": RoundRobinDispatcher,
    "jsq": JoinShortestQueueDispatcher,
    "least-loaded": LeastLoadedDispatcher,
    "stability-aware": StabilityAwareDispatcher,
}


def make_dispatcher(name: str, slo: float = 0.050, power_d: int = 2,
                    clip: float = DEFAULT_CLIP) -> Dispatcher:
    """Policy factory (the dispatcher twin of ``make_scheduler``)."""
    try:
        cls = DISPATCHERS[name]
    except KeyError:
        raise ValueError(
            f"unknown dispatcher {name!r}; available: {sorted(DISPATCHERS)}"
        ) from None
    if cls is StabilityAwareDispatcher:
        return StabilityAwareDispatcher(slo=slo, power_d=power_d, clip=clip)
    return cls()


# ---------------------------------------------------------------------------
# Fleet construction
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One device in a cluster.

    Attributes:
      table:   the device's own execution :class:`ProfileTable` (heterogeneous
               fleets mix differently-scaled tables).
      name:    display name (defaults to the table's platform).
      models:  placement map — global model indices this device hosts;
               ``None`` = full replication (hosts every model).
      fail_at: optional wall-clock time (seconds) at which the device dies
               mid-run (see module docstring for the failover semantics).
      drift:   optional per-device ground-truth drift on true service times
               (``repro.core.adaptive.DriftModel``); re-seeded per run from
               the cluster seed and the device id, so fleets drift
               independently but deterministically.
    """

    table: ProfileTable
    name: str = ""
    models: Optional[Tuple[int, ...]] = None
    fail_at: Optional[float] = None
    drift: Optional[DriftModel] = None

    def label(self, d: int) -> str:
        return self.name or self.table.meta.get("platform", f"device{d}")


def _homogeneous(size: int, base: ProfileTable) -> List[DeviceSpec]:
    return [DeviceSpec(base, name=f"dev{d}") for d in range(size)]


def _heterogeneous(size: int, base: ProfileTable) -> List[DeviceSpec]:
    """Alternate full-speed and Jetson-class (3.2x latency-scaled) devices,
    starting fast — the paper's RTX 3080 : GTX 1650 platform gap (Sec. VI-G).
    """
    slow = base.scaled(3.2, "jetson-class")
    return [
        DeviceSpec(base if d % 2 == 0 else slow,
                   name=f"dev{d}-{'fast' if d % 2 == 0 else 'slow'}")
        for d in range(size)
    ]


FLEETS: Dict[str, Callable[[int, ProfileTable], List[DeviceSpec]]] = {
    "homogeneous": _homogeneous,
    "heterogeneous": _heterogeneous,
}


def make_fleet(name: str, size: int, base: ProfileTable,
               fail_at: Sequence[Tuple[int, float]] = (),
               drift: Sequence[Tuple[int, DriftModel]] = ()) -> List[DeviceSpec]:
    """Build a named fleet of ``size`` devices from a base table;
    ``fail_at`` is an optional ``[(device, time)]`` failure schedule and
    ``drift`` an optional ``[(device, DriftModel)]`` drift assignment
    (give each device its *own* model instance — burst caches are
    per-instance; the simulator re-seeds them per device at run start)."""
    try:
        builder = FLEETS[name]
    except KeyError:
        raise ValueError(
            f"unknown fleet {name!r}; available: {sorted(FLEETS)}"
        ) from None
    assert size >= 1
    devices = builder(size, base)
    for d, t in fail_at:
        assert 0 <= d < size, f"fail_at device {d} outside fleet of {size}"
        devices[d] = dataclasses.replace(devices[d], fail_at=float(t))
    for d, dm in drift:
        assert 0 <= d < size, f"drift device {d} outside fleet of {size}"
        devices[d] = dataclasses.replace(devices[d], drift=dm)
    return devices


# ---------------------------------------------------------------------------
# The cluster simulator
# ---------------------------------------------------------------------------


class _Device:
    """One device's serving engine: per-round semantics mirror
    ``ServingSimulator.run`` exactly (snapshot -> prune -> decide -> occupy),
    driven by the cluster's global event loop instead of a private clock."""

    __slots__ = (
        "spec", "scheduler", "table", "queues", "rng", "noise_cov",
        "completions", "busy_time", "dropped", "dispatched", "alive",
        "pending_at", "in_quantum", "clock", "done", "profiler",
    )

    def __init__(self, spec: DeviceSpec, scheduler: Scheduler,
                 num_models: int, rng: np.random.Generator,
                 noise_cov: float,
                 profiler: Optional["OnlineProfiler"] = None):
        self.spec = spec
        self.scheduler = scheduler
        self.table = spec.table
        self.queues = [ServiceQueue(m) for m in range(num_models)]
        self.rng = rng
        self.noise_cov = noise_cov
        self.profiler = profiler  # per-device online adaptation (optional)
        self.completions: List[Completion] = []
        self.busy_time = 0.0
        self.dropped = 0
        self.dispatched = 0
        self.alive = True
        self.pending_at: Optional[float] = None  # next scheduling-round time
        self.in_quantum = False  # pending_at is a quantum end (exact time)
        self.clock = 0.0         # last event time processed (for span)
        self.done = False        # passed the drain cap; never schedules again

    def queued(self) -> int:
        return sum(len(q) for q in self.queues)

    def service_time(self, m: int, e: int, batch: int, t: float = 0.0) -> float:
        base = self.table(m, e, batch)
        if self.spec.drift is not None:
            base *= self.spec.drift.multiplier(t)
        if self.noise_cov > 0:
            base *= service_noise_multiplier(self.rng, self.noise_cov)
        return base

    def poke(self, t: float) -> None:
        """An arrival landed at ``t`` while this device may be idle: make
        sure a scheduling round runs one ulp past ``t`` (the single-device
        simulator's idle-advance), unless one is already due earlier or a
        quantum is in flight (its end-round will see the queue)."""
        if self.done or not self.alive or self.in_quantum:
            return
        wake = np.nextafter(t, np.inf)
        if self.pending_at is None or wake < self.pending_at:
            self.pending_at = wake


@dataclasses.dataclass
class ClusterResult:
    """Aggregate + per-device outcome of one cluster experiment."""

    metrics: ServingMetrics          # per_device rollup populated
    completions: List[Completion]    # merged, sorted by (finish, req_id)
    span: float
    trace: Optional[Trace] = None    # telemetry timeline (tracer attached)

    @property
    def dispatch_counts(self) -> Tuple[int, ...]:
        """Requests routed per device (view over ``metrics.per_device``)."""
        return tuple(d.dispatched for d in self.metrics.per_device)


class ClusterSimulator(DeviceLoadView):
    """Deterministic discrete-event simulator for a G-device cluster.

    Every device runs its own scheduler instance (``policy`` via
    ``make_scheduler``) over its own profile table; the ``dispatcher``
    assigns each arrival to one device hosting its model at the arrival
    time, reading live fleet state through the :class:`DeviceLoadView`
    protocol this class implements.
    """

    def __init__(
        self,
        devices: Sequence[DeviceSpec],
        policy: str = "edgeserving",
        config: Optional[SchedulerConfig] = None,
        dispatcher: Optional[Dispatcher] = None,
        num_models: Optional[int] = None,
        service_noise_cov: float = 0.0,
        seed: int = 0,
        drain_cap: float = 600.0,
        adapt: Optional[AdaptConfig] = None,
        tracer: Optional[Tracer] = None,
    ):
        assert len(devices) >= 1
        self.specs = list(devices)
        self.config = config or SchedulerConfig()
        self.policy = policy
        self.dispatcher = dispatcher or LeastLoadedDispatcher()
        self.num_models = num_models or self.specs[0].table.num_models
        self.noise_cov = service_noise_cov
        self.seed = seed
        self.drain_cap = drain_cap
        # Per-device online adaptation: each device's completions feed its
        # own OnlineProfiler over its own table (None = static tables).
        self.adapt = adapt
        # Record-only telemetry; None (default) skips every branch. Records
        # carry the owning device id; failover/strand events land too.
        self.tracer = tracer
        # placement: model -> device ids hosting it
        self.placement: List[List[int]] = [
            [d for d, s in enumerate(self.specs)
             if s.models is None or m in s.models]
            for m in range(self.num_models)
        ]
        for m, hosts in enumerate(self.placement):
            assert hosts, f"model {m} is placed on no device"
        self._devs: List[_Device] = []
        self._now = 0.0

    # -- DeviceLoadView --------------------------------------------------------

    def healthy(self, d: int) -> bool:
        return self._devs[d].alive

    def effective_backlog(self, d: int) -> float:
        dev = self._devs[d]
        remaining = max(dev.pending_at - self._now, 0.0) if dev.in_quantum else 0.0
        return remaining + drain_estimate(dev.scheduler,
                                          [len(q) for q in dev.queues])

    def total_queued(self, d: int) -> int:
        return self._devs[d].queued()

    def predicted_completion(self, d: int, model: int) -> float:
        # Price with the device's *current belief* (its scheduler's table),
        # not the cold-start spec table: under online adaptation the drain
        # term already reads the refreshed table via drain_estimate, and a
        # throttled device must advertise its learned slowdown to the
        # dispatcher too. Without adaptation both tables are one object.
        dev = self._devs[d]
        belief = dev.scheduler.table
        e_final = belief.num_exits - 1
        return self.effective_backlog(d) + belief(model, e_final, 1)

    # -- event loop ------------------------------------------------------------

    def run(
        self,
        arrivals: List[Request],
        horizon: float,
        warmup_tasks: int = 100,
    ) -> ClusterResult:
        # fresh per-run state (devices, dispatcher, rngs, drift, profilers):
        # run() is rerunnable
        for d, spec in enumerate(self.specs):
            if spec.drift is not None:
                spec.drift.reset((self.seed + 7919 * d) ^ 0xD21F)
        self._devs = [
            _Device(
                spec,
                make_scheduler(self.policy, spec.table, self.config),
                self.num_models,
                np.random.default_rng((self.seed + 7919 * d) ^ 0x5EED),
                self.noise_cov,
                profiler=make_profiler(spec.table, self.adapt),
            )
            for d, spec in enumerate(self.specs)
        ]
        self.dispatcher.reset(self.seed)
        self._now = 0.0
        if self.tracer is not None:
            self.tracer.reset()  # rerun-determinism, like the RNG re-seeds
        fails = sorted(
            (s.fail_at, d) for d, s in enumerate(self.specs)
            if s.fail_at is not None
        )
        fi = 0
        ai = 0
        n_arr = len(arrivals)
        lost = 0  # stranded: no surviving host for the model
        cap_t = horizon + self.drain_cap

        while True:
            # next event: (time, kind, idx); kind order at equal time is
            # failure(0) < arrival(1) < device-round(2) — arrivals must be
            # visible to a round at the same timestamp (ingest uses <= t).
            best = None
            if fi < len(fails):
                best = (fails[fi][0], 0, fails[fi][1])
            if ai < n_arr:
                ev = (arrivals[ai].arrival, 1, ai)
                if best is None or ev < best:
                    best = ev
            for d, dev in enumerate(self._devs):
                if dev.pending_at is not None:
                    ev = (dev.pending_at, 2, d)
                    if best is None or ev < best:
                        best = ev
            if best is None:
                break
            t, kind, idx = best
            self._now = t
            if kind == 0:
                fi += 1
                lost += self._fail(idx, t)
            elif kind == 1:
                ai += 1
                lost += self._dispatch(arrivals[idx], t)
            else:
                self._round(idx, t, cap_t)

        # -- rollup -----------------------------------------------------------
        merged = sorted(
            (c for dev in self._devs for c in dev.completions),
            key=lambda c: (c.finish, c.req_id),
        )
        owner = {}
        for d, dev in enumerate(self._devs):
            for c in dev.completions:
                owner[c.req_id] = d
        span = max(max((dev.clock for dev in self._devs), default=0.0), horizon)
        residual = (
            sum(dev.queued() for dev in self._devs) + (n_arr - ai) + lost
        )
        dropped = sum(dev.dropped for dev in self._devs)
        busy = sum(dev.busy_time for dev in self._devs)
        metrics = summarize(
            merged,
            self.specs[0].table,  # accuracy A(m, e) is model-intrinsic
            self.config.slo,
            warmup_tasks=warmup_tasks,
            busy_time=busy,
            span=span,
            residual_queue=residual,
            dropped=dropped,
        )
        metrics = dataclasses.replace(
            metrics,
            utilization=(busy / (span * len(self._devs))) if span > 0 else 0.0,
            per_device=self._per_device(merged, owner, metrics.warmup_used, span),
        )
        trace = None
        if self.tracer is not None:
            for d, dev in enumerate(self._devs):  # still queued at run end
                for q in dev.queues:
                    for req in q.pending():
                        self.tracer.record_residual(
                            req, self.config.slo, device=d)
            for req in arrivals[ai:]:  # never ingested (past the drain cap)
                self.tracer.record_residual(req, self.config.slo, device=-1)
            trace = self.tracer.freeze(
                engine="cluster", num_models=self.num_models,
                num_devices=len(self._devs), slo=self.config.slo,
                horizon=horizon, span=span,
                warmup_used=metrics.warmup_used, n_arrivals=n_arr)
        return ClusterResult(metrics=metrics, completions=merged, span=span,
                             trace=trace)

    # -- event handlers --------------------------------------------------------

    def _eligible(self, model: int) -> List[int]:
        return [d for d in self.placement[model] if self._devs[d].alive]

    def _dispatch(self, req: Request, t: float) -> int:
        """Route one request; returns 1 if it stranded (no live host)."""
        eligible = self._eligible(req.model)
        if not eligible:
            if self.tracer is not None:  # stranded = residual, no device
                self.tracer.record_residual(req, self.config.slo, device=-1)
            return 1
        d = eligible[0] if len(eligible) == 1 else self.dispatcher.pick(
            req.model, eligible, self, deadline=req.deadline)
        dev = self._devs[d]
        dev.queues[req.model].push(req)
        dev.dispatched += 1
        dev.poke(t)
        return 0

    def _fail(self, d: int, t: float) -> int:
        """Kill device ``d``; failover its queue. Returns stranded count."""
        # No clock bump: the clock tracks serving activity for the span /
        # throughput denominators, and an idle death occupies no time (a
        # mid-quantum one gets its clock from the quantum-end round).
        dev = self._devs[d]
        dev.alive = False
        if not dev.in_quantum:
            dev.pending_at = None  # cancel any idle wake; in-flight quantum
            # (if any) still completes and its end-round goes dormant.
        orphans: List[Request] = []
        for q in dev.queues:
            orphans.extend(q.pop_batch(len(q)))
        orphans.sort(key=lambda r: (r.arrival, r.req_id))
        if self.tracer is not None:
            self.tracer.record_event(t, "device-failure", device=d,
                                     orphans=len(orphans))
        stranded = sum(self._dispatch(r, t) for r in orphans)
        if self.tracer is not None:
            self.tracer.record_event(
                t, "failover", device=d,
                requeued=len(orphans) - stranded, stranded=stranded)
        return stranded

    def _round(self, d: int, t: float, cap_t: float) -> None:
        """One scheduling round on device ``d`` at time ``t`` — the body of
        ``ServingSimulator.run``'s while-loop, minus the clock bookkeeping
        the global event loop now owns."""
        dev = self._devs[d]
        dev.pending_at = None
        ending_quantum, dev.in_quantum = dev.in_quantum, False
        dev.clock = max(dev.clock, t)
        if dev.done or (ending_quantum and not dev.alive):
            return
        if t > cap_t:
            dev.done = True
            return
        tracer = self.tracer
        snapshot = QueueSnapshot.take(dev.queues, t)
        shed = dev.scheduler.prune(snapshot)
        if shed:
            n_shed = 0
            for m, n in shed:
                popped = dev.queues[m].pop_batch(n)
                n_shed += len(popped)
                if tracer is not None:
                    for req in popped:
                        tracer.record_drop(req, t, self.config.slo, device=d)
            dev.dropped += n_shed
            if dev.profiler is not None:
                dev.profiler.observe_dropped(n_shed)
            if tracer is not None and n_shed:
                tracer.record_event(t, "shed", device=d, n=n_shed)
            snapshot = QueueSnapshot.take(dev.queues, t)
        decision = dev.scheduler.decide(snapshot)
        if decision is None:
            # Idle. Arrivals poke the device themselves; the only wake the
            # device must self-schedule is a deferred-batching due time.
            if dev.queued() and hasattr(dev.scheduler, "next_wake"):
                wake = dev.scheduler.next_wake(snapshot)
                if wake is not None:
                    dev.pending_at = np.nextafter(max(t, wake), np.inf)
            return
        service = dev.service_time(decision.model, decision.exit_idx,
                                   decision.batch_size, t)
        batch = dev.queues[decision.model].pop_batch(decision.batch_size)
        assert len(batch) == decision.batch_size, "scheduler overdrew queue"
        t_end = t + service
        dev.busy_time += service
        for req in batch:
            dev.completions.append(Completion(
                req_id=req.req_id,
                model=req.model,
                arrival=req.arrival,
                dispatch=t,
                finish=t_end,
                exit_idx=decision.exit_idx,
                batch_size=decision.batch_size,
                deadline=req.deadline,
            ))
        if tracer is not None:
            tracer.record_decision(
                t, decision, t_end,
                tuple(snapshot.qlens()),
                tuple(snapshot.w_max(m) for m in range(self.num_models)),
                margin=decision_margin(dev.scheduler, snapshot),
                device=d,
            )
            for req in batch:
                tracer.record_completion(
                    req, t, t_end, decision.exit_idx, decision.batch_size,
                    self.config.slo, device=d)
        if dev.profiler is not None:
            refreshed = dev.profiler.ingest_quantum(
                decision.model, decision.exit_idx, decision.batch_size,
                service, t_end, batch, self.config.slo)
            if refreshed is not None:
                dev.scheduler.table = refreshed
                if tracer is not None:
                    tracer.record_refresh(t_end, dev.profiler, device=d)
        dev.pending_at = t_end
        dev.in_quantum = True

    # -- per-device rollup -----------------------------------------------------

    def _per_device(
        self,
        merged: List[Completion],
        owner: Dict[int, int],
        warmup_used: int,
        span: float,
    ) -> Tuple[DeviceMetrics, ...]:
        done = merged[warmup_used:]
        out = []
        for d, dev in enumerate(self._devs):
            mine = [c for c in done if owner[c.req_id] == d]
            # One summarize() per device (warmup already taken globally):
            # the violation / P95 / exit-depth rules stay written once, so
            # the rollup cannot drift from the aggregate's accounting.
            dm = summarize(mine, dev.table, self.config.slo, warmup_tasks=0,
                           dropped=dev.dropped)
            out.append(DeviceMetrics(
                device=d,
                name=dev.spec.label(d),
                num_completed=len(mine),
                dispatched=dev.dispatched,
                dropped=dev.dropped,
                violation_ratio=dm.violation_ratio,
                p95_latency=dm.p95_latency,
                mean_exit_depth=dm.mean_exit_depth,
                utilization=float(dev.busy_time / span) if span > 0 else 0.0,
                alive=dev.alive,
            ))
        return tuple(out)
