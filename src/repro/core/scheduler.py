"""The EdgeServing online scheduler (paper Sec. V, Algorithm 1).

One-step-greedy deadline-aware scheduling: per non-empty queue, pick
``B* = min(|Q_m|, B_max)`` (Eq. 5) and the deepest SLO-feasible exit
``e*`` (Eq. 6); predict the post-decision queue state (all other tasks wait
``L(m, e*, B*)`` longer); score it with the stability score (Eq. 4); and
serve the candidate minimising the predicted score (Eq. 7).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.profile import ProfileTable
from repro.core.queues import QueueSnapshot
from repro.core.request import Decision
from repro.core.scoring import make_scoring_backend
from repro.core.urgency import DEFAULT_CLIP, urgency_np


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Shared knobs for all scheduling policies.

    Attributes:
      slo:        per-request latency deadline tau (seconds).
      max_batch:  B_max (paper default: 10).
      clip:       urgency clip C (paper example: 10).
      allowed_exits: optional subset of exit indices the scheduler may use
                  (paper Fig. 7 exit-configuration study); None = all.
      lattice:    False (default) = the paper-exact Eq. 5 batch rule
                  ``B* = min(|Q_m|, B_max)``; True = batch size becomes a
                  scheduling degree of freedom: each queue contributes one
                  candidate per ladder rung and the stability score picks
                  the global argmin over the joint (model, exit, batch)
                  lattice (beyond-paper extension; see docs/scheduler.md).
      batch_ladder: explicit lattice rungs; rungs above the Eq. 5 cap are
                  dropped and the cap itself is always included. None =
                  geometric ladder {1, 2, 4, ...} up to the cap.
      backend:    stability-score scoring engine for the Algorithm-1
                  schedulers: ``numpy`` (default; float64 host reference),
                  ``jnp`` (jit/XLA), ``pallas``, or ``pallas-interpret``
                  (see ``repro.core.scoring`` and docs/scheduler.md
                  "Scoring backends"). All backends understand per-task
                  deadlines; baselines ignore the knob.
    """

    slo: float = 0.050
    max_batch: int = 10
    clip: float = DEFAULT_CLIP
    allowed_exits: Optional[Tuple[int, ...]] = None
    lattice: bool = False
    batch_ladder: Optional[Tuple[int, ...]] = None
    backend: str = "numpy"


class Scheduler:
    """Base class: a policy maps a queue snapshot to a Decision."""

    name = "base"

    def __init__(self, table: ProfileTable, config: SchedulerConfig):
        self.table = table
        self.config = config
        self.scoring = make_scoring_backend(config.backend)
        exits = config.allowed_exits or tuple(range(table.num_exits))
        # Deduplicate + sort shallow->deep once; Eq. 6 scans deep->shallow.
        self._exits = tuple(sorted(set(exits)))
        assert self._exits, "at least one exit point must be allowed"
        assert all(0 <= e < table.num_exits for e in self._exits)

    # -- shared sub-procedures (Eq. 5 / Eq. 6) -------------------------------

    def batch_size(self, qlen: int) -> int:
        """Eq. 5: B* = min(|Q_m|, B_max)."""
        return min(qlen, self.config.max_batch)

    def batch_candidates(self, qlen: int) -> Tuple[int, ...]:
        """Candidate batch sizes for a queue of length ``qlen``.

        Greedy (``config.lattice=False``): the single Eq. 5 batch. Lattice:
        the configured ladder clipped to the Eq. 5 cap, cap always included,
        ordered descending so equal-score ties resolve toward serving more.
        """
        cap = self.batch_size(qlen)
        if cap <= 0:
            return ()
        if not self.config.lattice:
            return (cap,)
        if self.config.batch_ladder is not None:
            rungs = {int(b) for b in self.config.batch_ladder if 1 <= b <= cap}
        else:
            rungs = set()
            b = 1
            while b < cap:
                rungs.add(b)
                b *= 2
        rungs.add(cap)
        return tuple(sorted(rungs, reverse=True))

    def select_exit(
        self, m: int, w_max: float, batch: int, tau: Optional[float] = None
    ) -> Tuple[int, float]:
        """Eq. 6: deepest allowed exit with ``w_max + L(m,e,B) <= tau``.

        Falls back to the *shallowest* allowed exit when no exit is feasible
        (the task will violate regardless; minimising service time minimises
        collateral damage to other queues — paper Sec. VI-D shows the fast
        fallback exit is what sustains SLO compliance).

        ``tau`` defaults to the global SLO; heterogeneous-SLO workloads pass
        the head-of-line task's own deadline (``snapshot.oldest_tau``).

        Returns: (exit_idx, L(m, exit_idx, batch)).
        """
        if tau is None:
            tau = self.config.slo
        for e in reversed(self._exits):
            lat = self.table(m, e, batch)
            if w_max + lat <= tau:
                return e, lat
        e0 = self._exits[0]
        return e0, self.table(m, e0, batch)

    def candidate(self, snapshot: QueueSnapshot, m: int) -> Tuple[int, int, float]:
        """(B*, e*, L) for queue ``m`` under Eq. 5 + Eq. 6 (the oldest task's
        own deadline bounds feasibility under heterogeneous SLOs)."""
        batch = self.batch_size(snapshot.qlen(m))
        exit_idx, lat = self.select_exit(
            m, snapshot.w_max(m), batch,
            tau=snapshot.oldest_tau(m, self.config.slo),
        )
        return batch, exit_idx, lat

    # -- shared candidate enumeration + scoring (Eq. 5/6 -> Eq. 4/7) ---------

    def enumerate_candidates(
        self, snapshot: QueueSnapshot
    ) -> "tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]":
        """Flatten the feasible (m, e, B) lattice for this snapshot.

        The one candidate-enumeration step shared by every Algorithm-1
        scheduler: with ``config.lattice=False`` each non-empty queue
        contributes exactly its Eq. 5 candidate (the greedy layout); with
        the lattice on, one candidate per ladder rung. Returns
        ``(cand_queue, cand_batch, cand_exit, cand_latency, cand_wmax)``
        arrays of equal length N, in (queue asc, batch desc) order. Exits
        follow the Eq. 6 deepest-feasible/fallback rule at each rung's
        latency, bounded by the head-of-line task's own deadline.
        """
        queues: List[int] = []
        batches: List[int] = []
        exits: List[int] = []
        lats: List[float] = []
        wmaxes: List[float] = []
        for m in snapshot.nonempty():
            w_max = snapshot.w_max(m)
            tau_m = snapshot.oldest_tau(m, self.config.slo)
            for b in self.batch_candidates(snapshot.qlen(m)):
                e, lat = self.select_exit(m, w_max, b, tau=tau_m)
                queues.append(m)
                batches.append(b)
                exits.append(e)
                lats.append(lat)
                wmaxes.append(w_max)
        return (
            np.asarray(queues, dtype=np.int64),
            np.asarray(batches, dtype=np.int64),
            np.asarray(exits, dtype=np.int64),
            np.asarray(lats, dtype=np.float64),
            np.asarray(wmaxes, dtype=np.float64),
        )

    def score_candidates(
        self,
        snapshot: QueueSnapshot,
        cand_latency: np.ndarray,
        cand_batch: np.ndarray,
        cand_queue: np.ndarray,
    ) -> np.ndarray:
        """One scoring entry point for all backends (Sec. V-C prediction +
        Eq. 4): per-task deadlines ride along as an [M, maxQ] tau matrix
        when the snapshot carries any, else the scalar SLO fast path."""
        w, mask = snapshot.padded()
        tau = (snapshot.padded_taus(self.config.slo)
               if snapshot.has_deadlines else self.config.slo)
        return self.scoring.score(
            w, mask, cand_latency, cand_batch, cand_queue,
            tau, self.config.clip)

    def decide_scored(self, snapshot: QueueSnapshot) -> Optional[Decision]:
        """The shared Algorithm-1 decision path: enumerate -> score through
        the configured backend -> Eq. 7 argmin (ties -> larger w_max, then
        candidate order: more urgent queue first, then larger batch)."""
        cand_queue, batches, exits, lats, w_maxes = self.enumerate_candidates(
            snapshot)
        if len(cand_queue) == 0:
            return None
        scores = self.score_candidates(snapshot, lats, batches, cand_queue)
        order = np.lexsort((-w_maxes, scores))
        i = int(order[0])
        return Decision(
            model=int(cand_queue[i]),
            exit_idx=int(exits[i]),
            batch_size=int(batches[i]),
            predicted_latency=float(lats[i]),
            stability_score=float(scores[i]),
        )

    # -- policy ---------------------------------------------------------------

    def decide(self, snapshot: QueueSnapshot) -> Optional[Decision]:
        """Return the decision for this round, or None if all queues empty."""
        raise NotImplementedError

    def prune(self, snapshot: QueueSnapshot) -> "list[tuple[int, int]]":
        """Optional admission control: ``[(model, n_oldest_to_drop), ...]``.

        EdgeServing never rejects requests (late tasks still run and count
        as violations); Symphony sheds expired requests under overload.
        """
        return []


class EdgeServingScheduler(Scheduler):
    """Algorithm 1: stability-score deadline-aware model selection.

    With the default ``backend="numpy"`` this is the paper-exact Python
    loop (the reference the vectorised/accelerated paths are tested
    against); any other backend routes through the shared
    ``decide_scored`` path so accelerated scoring is one config switch
    away for every Algorithm-1 scheduler.
    """

    name = "edgeserving"

    def batch_candidates(self, qlen: int) -> Tuple[int, ...]:
        """The paper-exact policy always uses the single Eq. 5 batch —
        `config.lattice` upgrades ``make_scheduler("edgeserving")`` to
        :class:`LatticeEdgeServingScheduler` rather than altering this
        class, so the accelerated `decide_scored` route enumerates exactly
        the candidates the reference loop scores (backend choice can never
        change this policy's decisions)."""
        cap = self.batch_size(qlen)
        return (cap,) if cap > 0 else ()

    def decide(self, snapshot: QueueSnapshot) -> Optional[Decision]:
        if self.scoring.name != "numpy":
            return self.decide_scored(snapshot)
        nonempty = snapshot.nonempty()
        if not nonempty:
            return None
        tau, clip = self.config.slo, self.config.clip
        het = snapshot.has_deadlines  # per-task tau arrays (scalar otherwise)
        taus = {m: snapshot.taus(m, tau) for m in nonempty} if het else None

        # Urgency is additive across queues, so precompute per-queue wait
        # arrays once; each candidate shifts *all* surviving tasks by L_m.
        best: Optional[Decision] = None
        for m in nonempty:
            batch, exit_idx, lat = self.candidate(snapshot, m)
            # Queue status prediction (Sec. V-C): served tasks removed; all
            # remaining tasks in every queue wait lat longer.
            score = 0.0
            for m2 in nonempty:
                w = snapshot.waits[m2]
                t = taus[m2] if het else tau
                if m2 == m:
                    w = w[batch:]  # FIFO: the batch oldest tasks are served
                    if het:
                        t = t[batch:]
                if len(w):
                    score += float(urgency_np(w + lat, t, clip).sum())
            if (
                best is None
                or score < best.stability_score
                or (
                    score == best.stability_score
                    and snapshot.w_max(m) > snapshot.w_max(best.model)
                )
            ):
                best = Decision(
                    model=m,
                    exit_idx=exit_idx,
                    batch_size=batch,
                    predicted_latency=lat,
                    stability_score=score,
                )
        return best


class VectorizedEdgeServingScheduler(Scheduler):
    """Numerically identical to EdgeServingScheduler, vectorised.

    Beyond-paper engineering: one O(M^2 * maxQ) padded-matrix evaluation
    per round instead of Python loops, dispatched through the configured
    :class:`repro.core.scoring.ScoringBackend` (numpy float64 by default —
    bitwise-identical to the historical implementation — or jnp/Pallas for
    the many-queue regime).
    """

    name = "edgeserving-vec"

    def decide(self, snapshot: QueueSnapshot) -> Optional[Decision]:
        return self.decide_scored(snapshot)


class LatticeEdgeServingScheduler(VectorizedEdgeServingScheduler):
    """Joint (model, exit, batch) candidate-lattice scheduling.

    Beyond-paper extension of Algorithm 1: instead of fixing
    ``B* = min(|Q_m|, B_max)`` (Eq. 5) and searching only over models, every
    non-empty queue contributes one candidate per batch-ladder rung (see
    ``Scheduler.batch_candidates``), each with its own Eq. 6 deepest-feasible
    exit at that batch's latency. All candidates are scored with the same
    Sec. V-C queue-status prediction in one padded pass through the
    configured scoring backend (numpy / jnp / the fused
    ``repro.kernels.stability_score`` lattice kernel), and the global
    argmin wins.

    Why this helps under tight deadlines: a smaller-than-Eq.-5 batch has a
    lower service latency L, which (a) shifts every other queue's tasks less
    — less collateral urgency — and (b) can make a deeper exit feasible for
    the served tasks. The stability score already prices exactly this
    trade-off; the lattice merely exposes the action space to it (cf. BCEdge
    / D-STACK adaptive batching). With the lattice restricted to the single
    Eq. 5 rung this scheduler is decision-identical to
    ``VectorizedEdgeServingScheduler`` (tested).

    Candidate order is (queue ascending, batch descending), and score ties
    resolve by (larger w_max, then candidate order) — so ties prefer the
    more urgent queue, then serving more tasks, exactly generalising the
    greedy tiebreak.
    """

    name = "edgeserving-lattice"

    def __init__(self, table: ProfileTable, config: SchedulerConfig):
        # The class *is* the lattice policy: force the switch on so that
        # make_scheduler("edgeserving-lattice") with a default config does
        # not silently degenerate to the greedy single-rung ladder.
        if not config.lattice:
            config = dataclasses.replace(config, lattice=True)
        super().__init__(table, config)
