"""Urgency activation and stability score (paper Eq. 3-4).

The urgency of a queued task with queueing time ``w`` under SLO deadline
``tau`` is

    f(w) = min(exp(w / tau - 1), C)                                (Eq. 3)

-- exponential because remaining slack shrinks super-linearly as ``w``
approaches ``tau``; normalised so that ``f(tau) = 1`` for any SLO; clipped
at ``C`` so tasks already far beyond the deadline (``w > tau (1 + ln C)``)
cannot dominate and starve the remaining queues.

The *stability score* of the whole system is the sum of urgencies over all
queued tasks of all models:

    S = sum_m sum_{i in Q_m} f(w_{m,i})                            (Eq. 4)

Both a NumPy (host scheduler hot path) and a jnp (vectorised / jit-able)
implementation are provided; `repro.kernels.stability_score` provides the
fused Pallas version used when scoring many candidates at once.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

# Paper: "tasks already far beyond the SLO (e.g. w > tau(1+ln 10) ~ 3.3 tau)"
# => the running example uses C = 10.
DEFAULT_CLIP = 10.0


# ---------------------------------------------------------------------------
# NumPy host path (used inside the per-round scheduler loop)
# ---------------------------------------------------------------------------

def urgency_np(w: np.ndarray, tau: float, clip: float = DEFAULT_CLIP) -> np.ndarray:
    """Eq. 3 on a NumPy array of queueing times (seconds).

    Implemented as exp(min(w/tau - 1, ln C)) == min(exp(w/tau - 1), C) to
    stay overflow-free for arbitrarily late tasks.
    """
    return np.minimum(np.exp(np.minimum(w / tau - 1.0, np.log(clip))), clip)


def stability_score_np(
    waits: "list[np.ndarray]", tau: float, clip: float = DEFAULT_CLIP
) -> float:
    """Eq. 4 over a list of per-queue queueing-time arrays."""
    total = 0.0
    for w in waits:
        if len(w):
            total += float(urgency_np(np.asarray(w, dtype=np.float64), tau, clip).sum())
    return total


# ---------------------------------------------------------------------------
# jnp path (jit-able; used by the vectorised scheduler and as the oracle for
# the Pallas stability_score kernel)
# ---------------------------------------------------------------------------

def urgency(w: jax.Array, tau: float, clip: float = DEFAULT_CLIP) -> jax.Array:
    """Eq. 3 as a jnp expression (supports batching/vmap/jit).

    exp(min(., ln C)) form: overflow-free for arbitrarily late tasks.
    """
    return jnp.minimum(jnp.exp(jnp.minimum(w / tau - 1.0, jnp.log(clip))), clip)


def stability_score(
    w: jax.Array, mask: jax.Array, tau: float, clip: float = DEFAULT_CLIP
) -> jax.Array:
    """Eq. 4 over a padded ``[M, maxQ]`` wait matrix with validity mask.

    Args:
      w:    ``[M, maxQ]`` queueing times, arbitrary values at padded slots.
      mask: ``[M, maxQ]`` 1.0 for real tasks, 0.0 for padding.
    Returns: scalar stability score.
    """
    return jnp.sum(urgency(w, tau, clip) * mask)


def candidate_stability_scores(
    w: jax.Array,
    mask: jax.Array,
    cand_latency: jax.Array,
    cand_batch: jax.Array,
    tau: float,
    clip: float = DEFAULT_CLIP,
) -> jax.Array:
    """Score every candidate model choice in one shot (vectorised Eq. 4-7).

    Under candidate ``m`` the scheduler hypothetically serves the ``B_m``
    oldest tasks of queue ``m`` for ``L_m = L(m, e*_m, B*_m)`` seconds.
    Prediction (paper Sec. V-C "Queue Status Prediction"):
      * served tasks are removed;
      * every other task (same queue beyond ``B_m``, and all other queues)
        has its queueing time extended by ``L_m``.

    Args:
      w:            ``[M, maxQ]`` FIFO-sorted (oldest first) wait matrix.
      mask:         ``[M, maxQ]`` validity mask.
      cand_latency: ``[M]`` per-candidate profiled latency ``L_m``.
      cand_batch:   ``[M]`` per-candidate batch size ``B_m`` (int).
    Returns:
      ``[M]`` stability score ``S_m`` for each candidate. Candidates with
      empty queues still get a (meaningless) score; callers mask them.
    """
    m_count, max_q = w.shape
    pos = jnp.arange(max_q)[None, :]                      # [1, maxQ]
    served = pos < cand_batch[:, None]                    # [M, maxQ] rows=candidate

    # f(w + L_m) for all tasks, per candidate: [M(cand), M(queue), maxQ]
    shifted = w[None, :, :] + cand_latency[:, None, None]
    urg = jnp.minimum(
        jnp.exp(jnp.minimum(shifted / tau - 1.0, jnp.log(clip))), clip
    ) * mask[None, :, :]

    total = jnp.sum(urg, axis=(1, 2))                     # [M] sum over everything
    # subtract the served (removed) tasks of the candidate's own queue
    own = urg[jnp.arange(m_count), jnp.arange(m_count), :]  # [M, maxQ]
    removed = jnp.sum(own * served * mask, axis=1)        # [M]
    return total - removed
