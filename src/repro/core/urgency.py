"""Urgency activation and stability score (paper Eq. 3-4).

The urgency of a queued task with queueing time ``w`` under SLO deadline
``tau`` is

    f(w) = min(exp(w / tau - 1), C)                                (Eq. 3)

-- exponential because remaining slack shrinks super-linearly as ``w``
approaches ``tau``; normalised so that ``f(tau) = 1`` for any SLO; clipped
at ``C`` so tasks already far beyond the deadline (``w > tau (1 + ln C)``)
cannot dominate and starve the remaining queues.

The *stability score* of the whole system is the sum of urgencies over all
queued tasks of all models:

    S = sum_m sum_{i in Q_m} f(w_{m,i})                            (Eq. 4)

Both a NumPy (host scheduler hot path) and a jnp (vectorised / jit-able)
implementation are provided; `repro.kernels.stability_score` provides the
fused Pallas version used when scoring many candidates at once.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

# Paper: "tasks already far beyond the SLO (e.g. w > tau(1+ln 10) ~ 3.3 tau)"
# => the running example uses C = 10.
DEFAULT_CLIP = 10.0


# ---------------------------------------------------------------------------
# NumPy host path (used inside the per-round scheduler loop)
# ---------------------------------------------------------------------------

def urgency_np(w: np.ndarray, tau, clip: float = DEFAULT_CLIP) -> np.ndarray:
    """Eq. 3 on a NumPy array of queueing times (seconds).

    ``tau`` is the global SLO scalar, or an array broadcastable against ``w``
    of per-task deadlines (heterogeneous-SLO workloads; everything is
    elementwise so both forms share one code path).

    Implemented as exp(min(w/tau - 1, ln C)) == min(exp(w/tau - 1), C) to
    stay overflow-free for arbitrarily late tasks.
    """
    return np.minimum(np.exp(np.minimum(w / tau - 1.0, np.log(clip))), clip)


def stability_score_np(
    waits: "list[np.ndarray]", tau: float, clip: float = DEFAULT_CLIP
) -> float:
    """Eq. 4 over a list of per-queue queueing-time arrays."""
    total = 0.0
    for w in waits:
        if len(w):
            total += float(urgency_np(np.asarray(w, dtype=np.float64), tau, clip).sum())
    return total


# ---------------------------------------------------------------------------
# jnp path (jit-able; used by the vectorised scheduler and as the oracle for
# the Pallas stability_score kernel)
# ---------------------------------------------------------------------------

def urgency(w: jax.Array, tau: float, clip: float = DEFAULT_CLIP) -> jax.Array:
    """Eq. 3 as a jnp expression (supports batching/vmap/jit).

    exp(min(., ln C)) form: overflow-free for arbitrarily late tasks.
    """
    return jnp.minimum(jnp.exp(jnp.minimum(w / tau - 1.0, jnp.log(clip))), clip)


def stability_score(
    w: jax.Array, mask: jax.Array, tau: float, clip: float = DEFAULT_CLIP
) -> jax.Array:
    """Eq. 4 over a padded ``[M, maxQ]`` wait matrix with validity mask.

    Args:
      w:    ``[M, maxQ]`` queueing times, arbitrary values at padded slots.
      mask: ``[M, maxQ]`` 1.0 for real tasks, 0.0 for padding.
    Returns: scalar stability score.
    """
    return jnp.sum(urgency(w, tau, clip) * mask)


def lattice_stability_scores(
    w: jax.Array,
    mask: jax.Array,
    cand_latency: jax.Array,
    cand_batch: jax.Array,
    cand_queue: jax.Array,
    tau,
    clip: float = DEFAULT_CLIP,
) -> jax.Array:
    """Score a flattened (model, exit, batch) candidate lattice (Eq. 4-7).

    Generalises :func:`candidate_stability_scores` from one candidate per
    queue to an arbitrary list of ``N`` candidates, each tagged with the
    queue it would serve: candidate ``n`` hypothetically serves the
    ``B_n = cand_batch[n]`` oldest tasks of queue ``cand_queue[n]`` for
    ``L_n = cand_latency[n]`` seconds. Prediction (paper Sec. V-C "Queue
    Status Prediction"):
      * served tasks are removed;
      * every other task (same queue beyond ``B_n``, and all other queues)
        has its queueing time extended by ``L_n``.

    Args:
      w:            ``[M, maxQ]`` FIFO-sorted (oldest first) wait matrix.
      mask:         ``[M, maxQ]`` validity mask.
      cand_latency: ``[N]`` per-candidate profiled latency ``L_n``.
      cand_batch:   ``[N]`` per-candidate batch size ``B_n`` (int).
      cand_queue:   ``[N]`` queue index each candidate serves (int in [0, M)).
      tau:          global SLO scalar, or an ``[M, maxQ]`` matrix of
                    per-task deadlines aligned with ``w`` (heterogeneous-SLO
                    workloads; broadcast over the candidate axis).
    Returns:
      ``[N]`` stability score ``S_n`` for each candidate.
    """
    max_q = w.shape[1]
    n = cand_latency.shape[0]
    pos = jnp.arange(max_q)[None, :]                      # [1, maxQ]
    served = pos < cand_batch[:, None]                    # [N, maxQ]
    tau_b = tau[None, :, :] if jnp.ndim(tau) == 2 else tau

    # f(w + L_n) for all tasks, per candidate: [N, M, maxQ]
    shifted = w[None, :, :] + cand_latency[:, None, None]
    urg = jnp.minimum(
        jnp.exp(jnp.minimum(shifted / tau_b - 1.0, jnp.log(clip))), clip
    ) * mask[None, :, :]

    total = jnp.sum(urg, axis=(1, 2))                     # [N] sum over everything
    # subtract the served (removed) tasks of the candidate's target queue
    # (own is already masked via urg, matching the Pallas kernel op-for-op)
    own = urg[jnp.arange(n), cand_queue, :]               # [N, maxQ]
    removed = jnp.sum(own * served, axis=1)
    return total - removed


def candidate_stability_scores(
    w: jax.Array,
    mask: jax.Array,
    cand_latency: jax.Array,
    cand_batch: jax.Array,
    tau,
    clip: float = DEFAULT_CLIP,
) -> jax.Array:
    """Score every candidate model choice in one shot (vectorised Eq. 4-7).

    The Eq. 5/Eq. 6 special case of :func:`lattice_stability_scores`:
    exactly one candidate per queue, candidate ``m`` serving queue ``m``.

    Args:
      w:            ``[M, maxQ]`` FIFO-sorted (oldest first) wait matrix.
      mask:         ``[M, maxQ]`` validity mask.
      cand_latency: ``[M]`` per-candidate profiled latency ``L_m``.
      cand_batch:   ``[M]`` per-candidate batch size ``B_m`` (int).
    Returns:
      ``[M]`` stability score ``S_m`` for each candidate. Candidates with
      empty queues still get a (meaningless) score; callers mask them.
    """
    m_count = w.shape[0]
    return lattice_stability_scores(
        w, mask, cand_latency, cand_batch, jnp.arange(m_count), tau, clip
    )
