"""EdgeServing core: the paper's primary contribution in host-framework form.

Deadline-aware multi-DNN serving under time-division accelerator sharing:
FIFO service queues, the offline profile table L(m, e, B), the stability
score (Eq. 3-4), the one-step-greedy online scheduler (Algorithm 1), the
baseline/ablation policies, and the event-driven serving simulator that the
paper-figure benchmarks run on.
"""

from repro.core.baselines import (
    SCHEDULERS,
    AllEarlyScheduler,
    AllFinalDeadlineAwareScheduler,
    AllFinalScheduler,
    EarlyExitEDFScheduler,
    EarlyExitLQFScheduler,
    NoBatchingScheduler,
    SymphonyScheduler,
    make_scheduler,
)
from repro.core.cluster import (
    DISPATCHERS,
    FLEETS,
    ClusterResult,
    ClusterSimulator,
    DeviceLoadView,
    DeviceSpec,
    Dispatcher,
    JoinShortestQueueDispatcher,
    LeastLoadedDispatcher,
    RoundRobinDispatcher,
    StabilityAwareDispatcher,
    drain_estimate,
    make_dispatcher,
    make_fleet,
)
from repro.core.metrics import (
    DeviceMetrics,
    ModelMetrics,
    ServingMetrics,
    summarize,
)
from repro.core.profile import ProfileTable
from repro.core.queues import QueueSnapshot, ServiceQueue
from repro.core.request import Completion, Decision, Request, ServingTrace
from repro.core.scheduler import (
    EdgeServingScheduler,
    LatticeEdgeServingScheduler,
    Scheduler,
    SchedulerConfig,
    VectorizedEdgeServingScheduler,
)
from repro.core.scoring import (
    SCORING_BACKENDS,
    ScoringBackend,
    make_scoring_backend,
)
from repro.core.simulator import ServingSimulator, SimResult, run_experiment
from repro.core.sweep import SweepResult, SweepRunner, SweepSpec
from repro.core.traffic import paper_rate_vector, poisson_arrivals
from repro.core.workloads import (
    SCENARIOS,
    ArrivalProcess,
    DiurnalProcess,
    FlashCrowdProcess,
    MMPPProcess,
    PoissonProcess,
    TraceReplayProcess,
    burstiness_index,
    interarrival_cov,
    make_scenario,
    record_trace,
)
from repro.core.urgency import (
    DEFAULT_CLIP,
    candidate_stability_scores,
    lattice_stability_scores,
    stability_score,
    stability_score_np,
    urgency,
    urgency_np,
)

__all__ = [
    "SCENARIOS",
    "SCHEDULERS",
    "SCORING_BACKENDS",
    "AllEarlyScheduler",
    "AllFinalDeadlineAwareScheduler",
    "AllFinalScheduler",
    "ArrivalProcess",
    "ClusterResult",
    "ClusterSimulator",
    "Completion",
    "Decision",
    "DEFAULT_CLIP",
    "DeviceLoadView",
    "DeviceMetrics",
    "DeviceSpec",
    "Dispatcher",
    "DISPATCHERS",
    "DiurnalProcess",
    "EarlyExitEDFScheduler",
    "EarlyExitLQFScheduler",
    "EdgeServingScheduler",
    "FLEETS",
    "FlashCrowdProcess",
    "JoinShortestQueueDispatcher",
    "LatticeEdgeServingScheduler",
    "LeastLoadedDispatcher",
    "MMPPProcess",
    "ModelMetrics",
    "NoBatchingScheduler",
    "PoissonProcess",
    "ProfileTable",
    "QueueSnapshot",
    "Request",
    "RoundRobinDispatcher",
    "Scheduler",
    "SchedulerConfig",
    "ScoringBackend",
    "ServiceQueue",
    "ServingMetrics",
    "ServingSimulator",
    "ServingTrace",
    "SimResult",
    "StabilityAwareDispatcher",
    "SweepResult",
    "SweepRunner",
    "SweepSpec",
    "SymphonyScheduler",
    "TraceReplayProcess",
    "VectorizedEdgeServingScheduler",
    "burstiness_index",
    "candidate_stability_scores",
    "drain_estimate",
    "interarrival_cov",
    "lattice_stability_scores",
    "make_dispatcher",
    "make_fleet",
    "make_scenario",
    "make_scheduler",
    "make_scoring_backend",
    "paper_rate_vector",
    "poisson_arrivals",
    "record_trace",
    "run_experiment",
    "stability_score",
    "stability_score_np",
    "summarize",
    "urgency",
    "urgency_np",
]
