"""EdgeServing core: the paper's primary contribution in host-framework form.

Deadline-aware multi-DNN serving under time-division accelerator sharing:
FIFO service queues, the offline profile table L(m, e, B), the stability
score (Eq. 3-4), the one-step-greedy online scheduler (Algorithm 1), the
baseline/ablation policies, and the event-driven serving simulator that the
paper-figure benchmarks run on.
"""

from repro.core.baselines import (
    SCHEDULERS,
    AllEarlyScheduler,
    AllFinalDeadlineAwareScheduler,
    AllFinalScheduler,
    EarlyExitEDFScheduler,
    EarlyExitLQFScheduler,
    NoBatchingScheduler,
    SymphonyScheduler,
    make_scheduler,
)
from repro.core.metrics import ModelMetrics, ServingMetrics, summarize
from repro.core.profile import ProfileTable
from repro.core.queues import QueueSnapshot, ServiceQueue
from repro.core.request import Completion, Decision, Request, ServingTrace
from repro.core.scheduler import (
    EdgeServingScheduler,
    LatticeEdgeServingScheduler,
    Scheduler,
    SchedulerConfig,
    VectorizedEdgeServingScheduler,
)
from repro.core.simulator import ServingSimulator, SimResult, run_experiment
from repro.core.sweep import SweepResult, SweepRunner, SweepSpec
from repro.core.traffic import paper_rate_vector, poisson_arrivals
from repro.core.workloads import (
    SCENARIOS,
    ArrivalProcess,
    DiurnalProcess,
    FlashCrowdProcess,
    MMPPProcess,
    PoissonProcess,
    TraceReplayProcess,
    burstiness_index,
    interarrival_cov,
    make_scenario,
    record_trace,
)
from repro.core.urgency import (
    DEFAULT_CLIP,
    candidate_stability_scores,
    lattice_stability_scores,
    stability_score,
    stability_score_np,
    urgency,
    urgency_np,
)

__all__ = [
    "SCENARIOS",
    "SCHEDULERS",
    "AllEarlyScheduler",
    "AllFinalDeadlineAwareScheduler",
    "AllFinalScheduler",
    "ArrivalProcess",
    "Completion",
    "Decision",
    "DEFAULT_CLIP",
    "DiurnalProcess",
    "EarlyExitEDFScheduler",
    "EarlyExitLQFScheduler",
    "EdgeServingScheduler",
    "FlashCrowdProcess",
    "LatticeEdgeServingScheduler",
    "MMPPProcess",
    "ModelMetrics",
    "NoBatchingScheduler",
    "PoissonProcess",
    "ProfileTable",
    "QueueSnapshot",
    "Request",
    "Scheduler",
    "SchedulerConfig",
    "ServiceQueue",
    "ServingMetrics",
    "ServingSimulator",
    "ServingTrace",
    "SimResult",
    "SweepResult",
    "SweepRunner",
    "SweepSpec",
    "SymphonyScheduler",
    "TraceReplayProcess",
    "VectorizedEdgeServingScheduler",
    "burstiness_index",
    "candidate_stability_scores",
    "interarrival_cov",
    "lattice_stability_scores",
    "make_scenario",
    "make_scheduler",
    "paper_rate_vector",
    "poisson_arrivals",
    "record_trace",
    "run_experiment",
    "stability_score",
    "stability_score_np",
    "summarize",
    "urgency",
    "urgency_np",
]
