"""Serving telemetry: decision/request/event timelines behind one tracer.

``ServingMetrics`` answers *how much* went wrong over a window; nothing in
the repo could answer *why* — which scheduling decisions, against which
queue state, produced a violation spike. This module adds a record-only
:class:`Tracer` threaded through every serving engine (the Python
``ServingSimulator``, the ``ClusterSimulator``, the compiled
``repro.core.simfast`` scan engine, and the live
``repro.runtime.server.ServingEngine``), capturing three record kinds:

  * :class:`DecisionRecord` — one per dispatched quantum: time, device, the
    chosen (model, exit, batch), the winning stability score and the
    *decision margin* (runner-up candidate score minus the winner's — how
    contested the Eq. 7 argmin was), and the per-queue depth / oldest-age
    snapshot the scheduler actually saw.
  * :class:`RequestSpan` — one per *arrival*: arrival -> dispatch ->
    completion (or drop, or residual), with the effective deadline and the
    signed slack. Span accounting is conservative by construction:
    ``len(trace.spans) == arrivals == completed + dropped + residual``.
  * :class:`TraceEvent` — discrete happenings: device failure/failover,
    Symphony shedding, ``OnlineProfiler`` table refreshes,
    ``SafetyController`` multiplier changes, scan-engine overflow retries,
    live-engine counters.

Tracing is **off by default and zero-cost when off**: every producer guards
on ``tracer is not None``, the tracer only ever *appends to Python lists*
(it never reads the RNG, never touches float state the engines compute
with), so decisions and ``ServingMetrics`` are bitwise-identical with
tracing on or off — property-tested in ``tests/test_telemetry.py`` on both
the Python and scan engines.

Consumers: :func:`timeline_metrics` (time-binned violation / queue-depth /
utilization / exit-depth rollups), :func:`export_chrome_trace` (Chrome
trace-event JSON loadable in Perfetto: quanta as duration events per device
track, decisions/events as instants, request lifecycles as async spans),
:func:`export_ndjson` / :func:`load_ndjson` (lossless line-oriented
interchange, the ``tools/tracestats.py`` CLI's native format), and the
``benchmarks/fig16_timeline.py`` flash-crowd anatomy study. Design notes:
``docs/observability.md``.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.baselines import (
    AllFinalDeadlineAwareScheduler,
    NoBatchingScheduler,
)
from repro.core.queues import QueueSnapshot
from repro.core.request import Decision, Request
from repro.core.scheduler import (
    EdgeServingScheduler,
    LatticeEdgeServingScheduler,
    Scheduler,
    VectorizedEdgeServingScheduler,
)

__all__ = [
    "DecisionRecord",
    "EVENT_KINDS",
    "RequestSpan",
    "TimelineMetrics",
    "Trace",
    "TraceEvent",
    "Tracer",
    "decision_margin",
    "export_chrome_trace",
    "export_ndjson",
    "load_ndjson",
    "timeline_metrics",
]

TRACE_VERSION = 1

#: The shared event vocabulary (sims and live runs emit the same kinds, so
#: one ``tools/tracestats.py`` invocation reads either).
EVENT_KINDS = (
    "device-failure",    # a DeviceSpec.fail_at fired
    "failover",          # the dead device's queue was re-dispatched
    "shed",              # admission control dropped expired requests
    "overflow-retry",    # scan engine doubled its max_queue window
    "profiler-refresh",  # OnlineProfiler handed the scheduler a new table
    "safety-multiplier", # SafetyController moved its multiplier
    "engine-counters",   # live-engine run() exit summary
)

#: Span lifecycle outcomes.
SPAN_COMPLETED = "completed"
SPAN_DROPPED = "dropped"
SPAN_RESIDUAL = "residual"


# ---------------------------------------------------------------------------
# Record types
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecisionRecord:
    """One dispatched scheduling decision and the state it was made against.

    ``margin`` is the runner-up candidate's stability score minus the
    winner's (>= 0): 0 means the Eq. 7 argmin was a structural tie decided
    by the tiebreak, ``inf`` means there was only one candidate, ``NaN``
    means the policy is outside the Algorithm-1 scored family (LQF / EDF /
    Symphony decide by other rules). ``score``/``margin`` come from the
    engine's own scoring pass, so they may differ at the ulp level between
    engines (summation order); everything else is bitwise.
    """

    t: float                        # dispatch time (snapshot time)
    device: int                     # 0 for single-accelerator runs
    model: int
    exit_idx: int
    batch_size: int
    predicted_latency: float        # scheduler-belief L(m, e, B)
    t_end: float                    # quantum end (t + executed service)
    score: float                    # winning stability score (NaN if unscored)
    margin: float                   # runner-up score - winning score
    queue_depths: Tuple[int, ...]   # per-queue length at decision time
    oldest_ages: Tuple[float, ...]  # per-queue w_max at decision time


@dataclasses.dataclass(frozen=True)
class RequestSpan:
    """One request's lifecycle: arrival -> dispatch -> completion/drop.

    ``status``: ``"completed"`` (served; ``finish`` is the quantum end),
    ``"dropped"`` (shed by admission control; ``finish`` is the drop time,
    ``dispatch``/``exit_idx`` are NaN/-1), or ``"residual"`` (never served
    before the run ended; ``dispatch``/``finish``/``slack`` are NaN).
    ``slack = deadline - (finish - arrival)``: negative means the request
    violated its effective deadline.
    """

    req_id: int
    model: int
    device: int                     # -1 when never assigned to a device
    arrival: float
    dispatch: float
    finish: float
    deadline: float                 # effective (own deadline or global SLO)
    slack: float
    exit_idx: int
    batch_size: int
    status: str


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """A discrete happening on a device timeline (see :data:`EVENT_KINDS`)."""

    t: float
    kind: str
    device: int = 0
    payload: Tuple[Tuple[str, object], ...] = ()

    def payload_dict(self) -> Dict[str, object]:
        return dict(self.payload)


@dataclasses.dataclass(frozen=True)
class Trace:
    """A frozen telemetry timeline (what ``Tracer.freeze`` returns and what
    ``SimResult.trace`` / ``ClusterResult.trace`` carry)."""

    decisions: Tuple[DecisionRecord, ...]
    spans: Tuple[RequestSpan, ...]
    events: Tuple[TraceEvent, ...]
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def span_counts(self) -> Dict[str, int]:
        """``{status: count}`` over the spans (conservation check helper)."""
        out = {SPAN_COMPLETED: 0, SPAN_DROPPED: 0, SPAN_RESIDUAL: 0}
        for s in self.spans:
            out[s.status] = out.get(s.status, 0) + 1
        return out

    @property
    def num_devices(self) -> int:
        if "num_devices" in self.meta:
            return int(self.meta["num_devices"])  # engines stamp this
        devs = [r.device for r in self.decisions]
        return (max(devs) + 1) if devs else 1

    def end_time(self) -> float:
        """Last timestamp anywhere in the trace (fallback: meta ``span``)."""
        t = float(self.meta.get("span", 0.0))
        for r in self.decisions:
            t = max(t, r.t_end)
        for s in self.spans:
            if math.isfinite(s.finish):
                t = max(t, s.finish)
        for e in self.events:
            if math.isfinite(e.t):
                t = max(t, e.t)
        return t


# ---------------------------------------------------------------------------
# The tracer
# ---------------------------------------------------------------------------


class Tracer:
    """Record-only telemetry sink threaded through the serving engines.

    The tracer is deliberately inert: it appends records to lists and does
    nothing else — no RNG, no arithmetic shared with the engine's decision
    path — so attaching one cannot change decisions or metrics (the
    bitwise guarantee ``tests/test_telemetry.py`` pins). Engines call
    :meth:`reset` at the top of ``run()`` so a rerun re-records from
    scratch (rerun-determinism, like the simulator's RNG re-seed).
    """

    def __init__(self) -> None:
        self.decisions: List[DecisionRecord] = []
        self.spans: List[RequestSpan] = []
        self.events: List[TraceEvent] = []
        self._safety_mult: Dict[int, float] = {}  # last seen, per device

    def reset(self) -> None:
        self.decisions.clear()
        self.spans.clear()
        self.events.clear()
        self._safety_mult.clear()

    # -- producers -----------------------------------------------------------

    def record_decision(
        self,
        t: float,
        decision: Decision,
        t_end: float,
        queue_depths: Tuple[int, ...],
        oldest_ages: Tuple[float, ...],
        margin: float = float("nan"),
        device: int = 0,
    ) -> None:
        self.decisions.append(DecisionRecord(
            t=t,
            device=device,
            model=decision.model,
            exit_idx=decision.exit_idx,
            batch_size=decision.batch_size,
            predicted_latency=decision.predicted_latency,
            t_end=t_end,
            score=decision.stability_score,
            margin=margin,
            queue_depths=queue_depths,
            oldest_ages=oldest_ages,
        ))

    def record_completion(self, req: Request, dispatch: float, finish: float,
                          exit_idx: int, batch_size: int, default_slo: float,
                          device: int = 0) -> None:
        tau = default_slo if req.deadline is None else req.deadline
        self.spans.append(RequestSpan(
            req_id=req.req_id, model=req.model, device=device,
            arrival=req.arrival, dispatch=dispatch, finish=finish,
            deadline=tau, slack=tau - (finish - req.arrival),
            exit_idx=exit_idx, batch_size=batch_size, status=SPAN_COMPLETED,
        ))

    def record_drop(self, req: Request, t: float, default_slo: float,
                    device: int = 0) -> None:
        tau = default_slo if req.deadline is None else req.deadline
        self.spans.append(RequestSpan(
            req_id=req.req_id, model=req.model, device=device,
            arrival=req.arrival, dispatch=float("nan"), finish=t,
            deadline=tau, slack=tau - (t - req.arrival),
            exit_idx=-1, batch_size=0, status=SPAN_DROPPED,
        ))

    def record_residual(self, req: Request, default_slo: float,
                        device: int = -1) -> None:
        tau = default_slo if req.deadline is None else req.deadline
        self.spans.append(RequestSpan(
            req_id=req.req_id, model=req.model, device=device,
            arrival=req.arrival, dispatch=float("nan"), finish=float("nan"),
            deadline=tau, slack=float("nan"),
            exit_idx=-1, batch_size=0, status=SPAN_RESIDUAL,
        ))

    def record_event(self, t: float, kind: str, device: int = 0,
                     **payload) -> None:
        self.events.append(TraceEvent(
            t=t, kind=kind, device=device,
            payload=tuple(payload.items()),
        ))

    def record_refresh(self, t: float, profiler, device: int = 0) -> None:
        """One ``OnlineProfiler`` table refresh; also detects and emits
        ``SafetyController`` multiplier changes since the last refresh."""
        self.record_event(
            t, "profiler-refresh", device=device,
            observations=int(profiler.num_observations),
            drift_ratio=float(profiler.drift_ratio),
        )
        if profiler.safety is not None:
            mult = float(profiler.safety.multiplier)
            last = self._safety_mult.get(device)
            if last is not None and mult != last:
                self.record_event(t, "safety-multiplier", device=device,
                                  previous=last, multiplier=mult)
            self._safety_mult[device] = mult

    # -- finalisation --------------------------------------------------------

    def freeze(self, **meta) -> Trace:
        """Snapshot the recorded timeline as an immutable :class:`Trace`.
        ``meta`` should carry at least ``engine`` / ``num_models`` /
        ``num_devices`` / ``slo`` / ``horizon`` / ``span`` /
        ``warmup_used`` / ``n_arrivals`` (the engines do)."""
        meta.setdefault("version", TRACE_VERSION)
        return Trace(
            decisions=tuple(self.decisions),
            spans=tuple(self.spans),
            events=tuple(self.events),
            meta=meta,
        )


# ---------------------------------------------------------------------------
# Decision margin (shared by the Python engines; the scan engine computes
# the identical quantity inside its compiled step)
# ---------------------------------------------------------------------------

# The Algorithm-1 scored family: decisions are the Eq. 7 argmin over the
# shared enumerate/score path, so re-scoring the snapshot reproduces the
# candidate scores the decision ranked. Exact types (mirrors
# ``simfast._SUPPORTED_SCHEDULERS``): an unknown subclass may decide by
# other rules, where a "margin" would be meaningless.
_SCORED_FAMILY = (
    EdgeServingScheduler,
    VectorizedEdgeServingScheduler,
    LatticeEdgeServingScheduler,
    AllFinalDeadlineAwareScheduler,
    NoBatchingScheduler,
)


def decision_margin(scheduler: Scheduler, snapshot: QueueSnapshot) -> float:
    """Runner-up candidate score minus the winner's for this snapshot.

    Computed by re-scoring through the scheduler's own shared
    ``enumerate_candidates`` / ``score_candidates`` path (read-only; the
    snapshot is immutable), so tracing never perturbs the decision itself.
    Returns ``inf`` with a single candidate, 0.0 on an exact score tie, and
    ``NaN`` for policies outside the Algorithm-1 scored family. The margin
    reflects the *vectorised* scoring pass, which can differ from the
    paper-exact loop's accumulated score at the ulp level (the repo's
    decision-equivalence tests pin that both rank candidates identically).
    """
    if type(scheduler) not in _SCORED_FAMILY:
        return float("nan")
    cand_queue, batches, exits, lats, _w = scheduler.enumerate_candidates(
        snapshot)
    n = len(cand_queue)
    if n == 0:
        return float("nan")
    if n == 1:
        return float("inf")
    scores = scheduler.score_candidates(snapshot, lats, batches, cand_queue)
    two = np.partition(np.asarray(scores, dtype=np.float64), 1)[:2]
    return float(two[1] - two[0])


# ---------------------------------------------------------------------------
# Time-binned rollups
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TimelineMetrics:
    """Per-bin rollups computed from a :class:`Trace`.

    Completions are attributed to the bin their *finish* lands in (drops to
    their drop time, decisions/queue depths to their dispatch time);
    everything past the last edge clips into the final bin so totals are
    conserved. With ``warmup`` matching the aggregate's ``warmup_used``,
    :meth:`aggregate_violation_ratio` reproduces
    ``ServingMetrics.violation_ratio`` exactly (tested).
    """

    edges: np.ndarray            # [K+1] bin edges, seconds
    completed: np.ndarray        # [K] post-warmup completions per bin
    late: np.ndarray             # [K] of those, deadline violations
    dropped: np.ndarray          # [K] shed requests per bin
    violation_ratio: np.ndarray  # [K] (late+dropped)/(completed+dropped)
    queue_depth: np.ndarray      # [K] mean total queued at decision times
    utilization: np.ndarray      # [K] busy fraction (quantum-bin overlap)
    mean_exit_depth: np.ndarray  # [K] 1..E over completions in bin

    @property
    def num_bins(self) -> int:
        return len(self.completed)

    @property
    def centers(self) -> np.ndarray:
        return 0.5 * (self.edges[:-1] + self.edges[1:])

    def aggregate_violation_ratio(self) -> float:
        """``(sum(late) + sum(dropped)) / (sum(completed) + sum(dropped))``
        — the same Eq. 2 accounting ``summarize`` applies."""
        done = int(self.completed.sum())
        drop = int(self.dropped.sum())
        late = int(self.late.sum())
        if done + drop == 0:
            return 0.0
        return float((late + drop) / (done + drop))


def timeline_metrics(
    trace: Trace,
    num_bins: int = 40,
    t_end: Optional[float] = None,
    warmup: Optional[int] = None,
) -> TimelineMetrics:
    """Bin a trace into ``num_bins`` equal windows over ``[0, t_end]``.

    ``t_end`` defaults to the trace's own end time; ``warmup`` (defaults to
    the trace's ``meta["warmup_used"]``) excludes the first N completions
    *in finish order* from the violation / exit-depth accounting, matching
    ``summarize``'s warmup rule so the binned ratios sum back to the
    aggregate exactly.
    """
    assert num_bins >= 1
    if warmup is None:
        warmup = int(trace.meta.get("warmup_used", 0))
    T = float(t_end if t_end is not None else trace.end_time())
    T = max(T, 1e-12)
    edges = np.linspace(0.0, T, num_bins + 1)

    def _bin(times: np.ndarray) -> np.ndarray:
        return np.clip(np.searchsorted(edges, times, side="right") - 1,
                       0, num_bins - 1)

    comp = [s for s in trace.spans if s.status == SPAN_COMPLETED]
    comp.sort(key=lambda s: s.finish)  # cluster merges are per-device
    comp = comp[warmup:]
    drops = [s for s in trace.spans if s.status == SPAN_DROPPED]

    completed = np.zeros(num_bins, dtype=np.int64)
    late = np.zeros(num_bins, dtype=np.int64)
    exit_sum = np.zeros(num_bins, dtype=np.float64)
    if comp:
        fin = np.array([s.finish for s in comp])
        slack = np.array([s.slack for s in comp])
        exits = np.array([s.exit_idx for s in comp], dtype=np.int64)
        b = _bin(fin)
        completed = np.bincount(b, minlength=num_bins)
        late = np.bincount(b[slack < 0], minlength=num_bins)
        exit_sum = np.bincount(b, weights=exits + 1.0, minlength=num_bins)
    dropped = np.zeros(num_bins, dtype=np.int64)
    if drops:
        dropped = np.bincount(_bin(np.array([s.finish for s in drops])),
                              minlength=num_bins)

    depth = np.full(num_bins, np.nan)
    busy = np.zeros(num_bins, dtype=np.float64)
    if trace.decisions:
        t0 = np.array([r.t for r in trace.decisions])
        t1 = np.array([r.t_end for r in trace.decisions])
        totals = np.array([sum(r.queue_depths) for r in trace.decisions],
                          dtype=np.float64)
        b = _bin(t0)
        counts = np.bincount(b, minlength=num_bins)
        sums = np.bincount(b, weights=totals, minlength=num_bins)
        np.divide(sums, counts, out=depth, where=counts > 0)
        # busy seconds per bin: overlap of each quantum with each window
        lo = np.maximum(edges[:-1][:, None], t0[None, :])
        hi = np.minimum(edges[1:][:, None], np.minimum(t1, T)[None, :])
        busy = np.clip(hi - lo, 0.0, None).sum(axis=1)

    width = T / num_bins
    util = busy / (width * trace.num_devices)
    denom = completed + dropped
    viol = np.full(num_bins, np.nan)
    np.divide(late + dropped, denom, out=viol, where=denom > 0)
    return TimelineMetrics(
        edges=edges, completed=completed, late=late, dropped=dropped,
        violation_ratio=viol, queue_depth=depth, utilization=util,
        mean_exit_depth=np.divide(
            exit_sum, completed, out=np.full(num_bins, np.nan),
            where=completed > 0),
    )


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------


def _enc(v):
    """JSON-safe scalar: non-finite floats become tagged strings (NDJSON is
    lossless; strict JSON has no NaN/Infinity literals)."""
    if isinstance(v, float) and not math.isfinite(v):
        if math.isnan(v):
            return "NaN"
        return "Infinity" if v > 0 else "-Infinity"
    return v


def _dec(v):
    if v in ("NaN", "Infinity", "-Infinity"):
        return float(v.replace("Infinity", "inf"))
    return v


def export_ndjson(trace: Trace, path: str) -> str:
    """Write the trace as newline-delimited JSON (one record per line; the
    first line is the meta header). Lossless: :func:`load_ndjson` restores
    an equal :class:`Trace`. This is ``tools/tracestats.py``'s native
    format."""
    with open(path, "w") as f:
        json.dump({"type": "meta",
                   **{k: _enc(v) for k, v in trace.meta.items()}}, f)
        f.write("\n")
        for r in trace.decisions:
            json.dump({
                "type": "decision", "t": r.t, "device": r.device,
                "model": r.model, "exit": r.exit_idx, "batch": r.batch_size,
                "lat": r.predicted_latency, "t_end": r.t_end,
                "score": _enc(r.score), "margin": _enc(r.margin),
                "depths": list(r.queue_depths),
                "ages": list(r.oldest_ages),
            }, f)
            f.write("\n")
        for s in trace.spans:
            json.dump({
                "type": "span", "req": s.req_id, "model": s.model,
                "device": s.device, "arrival": s.arrival,
                "dispatch": _enc(s.dispatch), "finish": _enc(s.finish),
                "deadline": s.deadline, "slack": _enc(s.slack),
                "exit": s.exit_idx, "batch": s.batch_size,
                "status": s.status,
            }, f)
            f.write("\n")
        for e in trace.events:
            json.dump({
                "type": "event", "t": _enc(e.t), "kind": e.kind,
                "device": e.device,
                "payload": {k: _enc(v) for k, v in e.payload},
            }, f)
            f.write("\n")
    return path


def load_ndjson(path: str) -> Trace:
    """Read a :func:`export_ndjson` file back into a :class:`Trace`."""
    decisions: List[DecisionRecord] = []
    spans: List[RequestSpan] = []
    events: List[TraceEvent] = []
    meta: Dict[str, object] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            kind = d.pop("type")
            if kind == "meta":
                meta = {k: _dec(v) for k, v in d.items()}
            elif kind == "decision":
                decisions.append(DecisionRecord(
                    t=d["t"], device=d["device"], model=d["model"],
                    exit_idx=d["exit"], batch_size=d["batch"],
                    predicted_latency=d["lat"], t_end=d["t_end"],
                    score=_dec(d["score"]), margin=_dec(d["margin"]),
                    queue_depths=tuple(d["depths"]),
                    oldest_ages=tuple(d["ages"]),
                ))
            elif kind == "span":
                spans.append(RequestSpan(
                    req_id=d["req"], model=d["model"], device=d["device"],
                    arrival=d["arrival"], dispatch=_dec(d["dispatch"]),
                    finish=_dec(d["finish"]), deadline=d["deadline"],
                    slack=_dec(d["slack"]), exit_idx=d["exit"],
                    batch_size=d["batch"], status=d["status"],
                ))
            elif kind == "event":
                events.append(TraceEvent(
                    t=_dec(d["t"]), kind=d["kind"], device=d["device"],
                    payload=tuple(d["payload"].items()),
                ))
            else:
                raise ValueError(f"unknown NDJSON record type {kind!r}")
    return Trace(decisions=tuple(decisions), spans=tuple(spans),
                 events=tuple(events), meta=meta)


def _chrome_args(d: Dict[str, object]) -> Dict[str, object]:
    """Chrome args must be strict JSON: non-finite floats become null."""
    return {
        k: (None if isinstance(v, float) and not math.isfinite(v) else v)
        for k, v in d.items()
    }


def export_chrome_trace(trace: Trace, path: str) -> str:
    """Write Chrome trace-event JSON loadable in Perfetto / chrome://tracing.

    Layout: pid 1 holds one thread per device carrying the dispatched
    quanta as complete (``X``) duration events plus a ``decision`` instant
    (score / margin / queue depths) at each dispatch; pid 2 holds request
    lifecycles as async ``b``/``e`` span pairs keyed by request id (async
    events overlap cleanly, which batched requests always do), with
    residual requests as instants; discrete :class:`TraceEvent`\\ s are
    instants on their device's pid-1 track. Timestamps are microseconds.
    Strict JSON throughout (``allow_nan=False``): Perfetto's parser
    rejects bare ``NaN`` literals.
    """
    us = 1e6
    ev: List[Dict[str, object]] = [
        {"ph": "M", "name": "process_name", "pid": 1, "ts": 0,
         "args": {"name": "devices (quanta + decisions)"}},
        {"ph": "M", "name": "process_name", "pid": 2, "ts": 0,
         "args": {"name": "requests (lifecycle spans)"}},
    ]
    devices = sorted(
        {r.device for r in trace.decisions}
        | {e.device for e in trace.events}
        | {s.device for s in trace.spans if s.device >= 0}
        | {0}
    )
    for d in devices:
        ev.append({"ph": "M", "name": "thread_name", "pid": 1, "tid": d,
                   "ts": 0, "args": {"name": f"device {d}"}})
        ev.append({"ph": "M", "name": "thread_name", "pid": 2, "tid": d,
                   "ts": 0, "args": {"name": f"device {d} requests"}})
    for r in trace.decisions:
        ev.append({
            "ph": "X", "pid": 1, "tid": r.device, "cat": "quantum",
            "name": f"m{r.model}/e{r.exit_idx}/B{r.batch_size}",
            "ts": r.t * us, "dur": max((r.t_end - r.t) * us, 0.0),
            "args": _chrome_args({
                "score": r.score, "margin": r.margin,
                "predicted_latency_ms": r.predicted_latency * 1e3,
                "queue_depths": list(r.queue_depths),
            }),
        })
        ev.append({
            "ph": "i", "s": "t", "pid": 1, "tid": r.device,
            "cat": "decision", "name": "decision", "ts": r.t * us,
            "args": _chrome_args({
                "model": r.model, "exit": r.exit_idx,
                "batch": r.batch_size, "score": r.score,
                "margin": r.margin,
                "queue_depths": list(r.queue_depths),
                "oldest_ages_ms": [a * 1e3 for a in r.oldest_ages],
            }),
        })
    for s in trace.spans:
        tid = max(s.device, 0)
        if s.status == SPAN_RESIDUAL:
            ev.append({
                "ph": "i", "s": "t", "pid": 2, "tid": tid, "cat": "residual",
                "name": "residual", "ts": s.arrival * us,
                "args": {"req": s.req_id, "model": s.model},
            })
            continue
        sid = f"0x{s.req_id:x}"
        ev.append({
            "ph": "b", "pid": 2, "tid": tid, "cat": "request", "id": sid,
            "name": f"m{s.model}", "ts": s.arrival * us,
            "args": _chrome_args({
                "req": s.req_id, "model": s.model, "status": s.status,
                "deadline_ms": s.deadline * 1e3, "slack_ms": s.slack * 1e3,
                "exit": s.exit_idx, "batch": s.batch_size,
            }),
        })
        ev.append({
            "ph": "e", "pid": 2, "tid": tid, "cat": "request", "id": sid,
            "name": f"m{s.model}", "ts": s.finish * us,
        })
    for e in trace.events:
        t = e.t if math.isfinite(e.t) else trace.end_time()
        ev.append({
            "ph": "i", "s": "t", "pid": 1, "tid": max(e.device, 0),
            "cat": "event", "name": e.kind, "ts": t * us,
            "args": _chrome_args(dict(e.payload)),
        })
    doc = {
        "traceEvents": ev,
        "displayTimeUnit": "ms",
        "otherData": {k: str(v) for k, v in trace.meta.items()},
    }
    with open(path, "w") as f:
        json.dump(doc, f, allow_nan=False)
    return path
