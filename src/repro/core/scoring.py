"""Scoring backends: one Sec. V-C scoring entry point, four engines.

Every Algorithm-1 scheduler reduces each round to the same computation —
score a flattened candidate list against the padded queue state (Eq. 4 +
the Sec. V-C queue-status prediction) and take the argmin. This module
makes that computation a first-class, swappable **backend** selected by
``SchedulerConfig.backend``:

  * ``numpy``            — the host-NumPy padded pass (default; float64,
                           bitwise-identical to the historical vectorised
                           schedulers; fastest at edge scale, M ~ 3).
  * ``jnp``              — ``jax.jit``-compiled XLA expression (float32;
                           fused + multithreaded; wins from M ≳ 64, see
                           ``benchmarks/micro_scheduler.py``).
  * ``pallas``           — the fused ``repro.kernels.stability_score``
                           Pallas kernel (TPU).
  * ``pallas-interpret`` — the same kernel in interpret mode (runs on
                           CPU-only hosts/CI; semantics-identical to
                           ``pallas``).

All four accept a scalar SLO **or** an ``[M, maxQ]`` per-task deadline
matrix (heterogeneous-SLO workloads) — the accelerated backends are no
longer deadline-blind. Decision equivalence across backends (greedy and
lattice layouts, scalar and per-task tau) is property-tested in
``tests/test_scoring.py``; the float32 backends match the float64 reference
scores to ~1e-6 relative, which is orders of magnitude below the score
gaps that separate real candidates.
"""

from __future__ import annotations

import functools
from typing import Dict, Type, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.urgency import DEFAULT_CLIP, lattice_stability_scores

__all__ = ["ScoringBackend", "SCORING_BACKENDS", "make_scoring_backend"]

TauLike = Union[float, np.ndarray]


class ScoringBackend:
    """Scores a flattened candidate lattice against a padded queue state.

    One entry point for all Algorithm-1 schedulers: candidate ``n``
    hypothetically serves the ``cand_batch[n]`` oldest tasks of queue
    ``cand_queue[n]`` for ``cand_latency[n]`` seconds; the backend returns
    the predicted post-decision stability score of each candidate
    (Eq. 4-7). Backends are stateless and cheap to construct; schedulers
    hold one instance.
    """

    name = "base"

    def score(
        self,
        w: np.ndarray,
        mask: np.ndarray,
        cand_latency: np.ndarray,
        cand_batch: np.ndarray,
        cand_queue: np.ndarray,
        tau: TauLike,
        clip: float = DEFAULT_CLIP,
    ) -> np.ndarray:
        """``w``/``mask`` are the ``[M, maxQ]`` float64 padded waits and
        validity mask (``QueueSnapshot.padded``); ``cand_*`` are the ``[N]``
        candidate arrays (``Scheduler.enumerate_candidates``); ``tau`` is
        the scalar SLO or the ``[M, maxQ]`` per-task deadline matrix
        (``QueueSnapshot.padded_taus``). Returns ``[N]`` host scores."""
        raise NotImplementedError


class NumpyScoringBackend(ScoringBackend):
    """Host float64 reference — op-for-op the historical
    ``VectorizedEdgeServingScheduler`` / lattice scoring pass, so the
    default backend is bitwise-identical to the pre-backend schedulers."""

    name = "numpy"

    def score(self, w, mask, cand_latency, cand_batch, cand_queue, tau,
              clip=DEFAULT_CLIP):
        n = len(cand_queue)
        max_q = w.shape[1]
        tau_b = tau[None, :, :] if np.ndim(tau) == 2 else tau
        shifted = w[None, :, :] + cand_latency[:, None, None]
        urg = np.minimum(
            np.exp(np.minimum(shifted / tau_b - 1.0, np.log(clip))), clip
        ) * mask[None, :, :]
        total = urg.sum(axis=(1, 2))
        pos = np.arange(max_q)[None, :]
        # float64: this is the declared-f64 reference path (0/1 indicator,
        # so the old f32 cast was value-exact, but DET005 bans the pattern)
        served = (pos < cand_batch[:, None]).astype(np.float64)
        own = urg[np.arange(n), cand_queue, :]
        return total - (own * served).sum(axis=1)


# One module-level jitted scorer so every JnpScoringBackend instance (and
# every scheduler in a sweep) shares a single compile cache; tau/clip are
# traced, so an SLO sweep reuses one executable per input shape.
@jax.jit
def _jnp_score(w, mask, cand_latency, cand_batch, cand_queue, tau, clip):
    return lattice_stability_scores(
        w, mask, cand_latency, cand_batch, cand_queue, tau, clip)


class JnpScoringBackend(ScoringBackend):
    """XLA-compiled float32 scoring (the jit twin of the numpy backend)."""

    name = "jnp"

    def score(self, w, mask, cand_latency, cand_batch, cand_queue, tau,
              clip=DEFAULT_CLIP):
        tau_dev = (jnp.asarray(tau, jnp.float32) if np.ndim(tau) == 2
                   else jnp.float32(tau))
        out = _jnp_score(
            jnp.asarray(w, jnp.float32),
            jnp.asarray(mask, jnp.float32),
            jnp.asarray(cand_latency, jnp.float32),
            jnp.asarray(cand_batch, jnp.int32),
            jnp.asarray(cand_queue, jnp.int32),
            tau_dev,
            jnp.float32(clip),
        )
        return np.asarray(out)


class PallasScoringBackend(ScoringBackend):
    """Fused single-launch scoring via ``repro.kernels.stability_score``."""

    name = "pallas"
    interpret = False

    def __init__(self, block_m: int = 8):
        self.block_m = block_m

    def score(self, w, mask, cand_latency, cand_batch, cand_queue, tau,
              clip=DEFAULT_CLIP):
        # local import: keep core importable even if the kernels package is
        # stripped from a minimal deployment
        from repro.kernels.stability_score.ops import stability_scores

        tau_dev = (jnp.asarray(tau, jnp.float32) if np.ndim(tau) == 2
                   else jnp.float32(tau))
        out = stability_scores(
            jnp.asarray(w, jnp.float32),
            jnp.asarray(mask, jnp.float32),
            jnp.asarray(cand_latency, jnp.float32),
            jnp.asarray(cand_batch, jnp.int32),
            jnp.asarray(cand_queue, jnp.int32),
            tau=tau_dev,
            clip=jnp.float32(clip),
            block_m=self.block_m,
            interpret=self.interpret,
        )
        return np.asarray(out)


class PallasInterpretScoringBackend(PallasScoringBackend):
    """Interpret-mode Pallas: same kernel semantics on CPU-only hosts."""

    name = "pallas-interpret"
    interpret = True


SCORING_BACKENDS: Dict[str, Type[ScoringBackend]] = {
    "numpy": NumpyScoringBackend,
    "jnp": JnpScoringBackend,
    "pallas": PallasScoringBackend,
    "pallas-interpret": PallasInterpretScoringBackend,
}


@functools.lru_cache(maxsize=None)
def make_scoring_backend(name: str) -> ScoringBackend:
    """Backend factory (cached: backends are stateless singletons)."""
    try:
        cls = SCORING_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown scoring backend {name!r}; "
            f"available: {sorted(SCORING_BACKENDS)}"
        ) from None
    return cls()
