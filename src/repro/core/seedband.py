"""Thousand-seed confidence bands over the compiled scan engines.

The paper's headline numbers (SLO violation ratio, P95 latency) are
single-seed point estimates. This module exploits the vmap seed axis of
``core/simfast.py`` / ``core/clusterfast.py`` to rerun a serving cell at
every seed in a band and attach uncertainty to each reported metric:

- :func:`simulate_scan_seedband` — single-device cells. One arrival
  trace per seed (same scenario, same rates), all lanes through
  ``simulate_scan_batch`` in fixed-size chunks, one
  :class:`~repro.core.metrics.ServingMetrics` per seed.
- :func:`simulate_cluster_scan_seedband` — fleet cells through
  ``simulate_cluster_scan_batch`` (``keep_completions=False`` so the
  per-seed rollup never materialises completion objects).
- :func:`summarize_band` — per-metric roll-up: mean, sample sd, a
  normal-approximation CI on the mean (width shrinks ~1/sqrt(n)), and
  the empirical P2.5/P97.5 percentile band across seeds (width reflects
  seed-to-seed spread and does *not* shrink with n).
- :func:`compare_bands` — two-sample z test on the mean gap between two
  seed columns (e.g. stability-aware vs JSQ violation ratio), reporting
  whether the gap is significant at the band level.

Determinism: the per-seed columns are a pure function of (scenario,
seeds, cell parameters). Chunking the seed axis changes how many lanes
share one XLA launch but not any lane's result — the batch engines are
lane-independent — so columns are bitwise-stable across chunk sizes,
reruns, and vmap-vs-loop execution (property-tested in
``tests/test_seedband.py``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .metrics import ServingMetrics
from .workloads import ArrivalProcess

__all__ = [
    "BandSummary",
    "GapSummary",
    "SeedBandResult",
    "compare_bands",
    "simulate_cluster_scan_seedband",
    "simulate_scan_seedband",
    "summarize_band",
]

#: Default number of lanes per XLA launch. Bounds the [N, M, Q] scoring
#: temporaries of a launch; results are chunk-size invariant.
DEFAULT_CHUNK = 64

#: Metrics fig17 puts bands on by default.
BAND_FIELDS = ("violation_ratio", "p95_latency")


def _z_for_level(level: float) -> float:
    """Two-sided standard-normal quantile: P(|Z| <= z) = level.

    Solved by bisection on ``erf`` (no scipy in the image); |error| is
    below 1e-12 which is far inside the Monte-Carlo noise it scales.
    """
    if not 0.0 < level < 1.0:
        raise ValueError(f"confidence level must be in (0, 1), got {level}")
    lo, hi = 0.0, 16.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if math.erf(mid / math.sqrt(2.0)) < level:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


@dataclasses.dataclass(frozen=True)
class BandSummary:
    """Uncertainty roll-up of one metric across a seed band."""

    n: int
    mean: float
    sd: float            # sample standard deviation (ddof=1; 0.0 if n < 2)
    ci_lo: float         # normal-approx CI on the mean: mean +- z*sd/sqrt(n)
    ci_hi: float
    band_lo: float       # empirical percentile band across seeds
    band_hi: float       # (P2.5 / P97.5 at the default 95% level)
    level: float = 0.95

    @property
    def ci_width(self) -> float:
        return self.ci_hi - self.ci_lo

    def __str__(self) -> str:
        return (f"{self.mean:.6g} ± {0.5 * self.ci_width:.2g} "
                f"[band {self.band_lo:.6g}, {self.band_hi:.6g}] (n={self.n})")


def summarize_band(values: Sequence[float], level: float = 0.95) -> BandSummary:
    """Mean, mean-CI, and percentile band of one per-seed metric column."""
    col = np.asarray(values, dtype=np.float64)
    if col.ndim != 1 or col.size == 0:
        raise ValueError("summarize_band expects a non-empty 1-D column")
    n = int(col.size)
    mean = float(col.mean())
    sd = float(col.std(ddof=1)) if n > 1 else 0.0
    z = _z_for_level(level)
    half = z * sd / math.sqrt(n) if n > 1 else 0.0
    tail = 100.0 * (1.0 - level) / 2.0
    band_lo, band_hi = np.percentile(col, [tail, 100.0 - tail])
    return BandSummary(
        n=n, mean=mean, sd=sd,
        ci_lo=mean - half, ci_hi=mean + half,
        band_lo=float(band_lo), band_hi=float(band_hi),
        level=level,
    )


@dataclasses.dataclass(frozen=True)
class GapSummary:
    """Two-sample z test on the mean gap between two seed columns."""

    gap: float           # mean(a) - mean(b)
    ci_lo: float
    ci_hi: float
    significant: bool    # CI excludes zero at ``level``
    level: float = 0.95

    def __str__(self) -> str:
        verdict = "significant" if self.significant else "not significant"
        return (f"gap {self.gap:+.6g} "
                f"[{self.ci_lo:+.6g}, {self.ci_hi:+.6g}] ({verdict})")


def compare_bands(
    a: Sequence[float], b: Sequence[float], level: float = 0.95
) -> GapSummary:
    """Is mean(a) - mean(b) distinguishable from zero at ``level``?"""
    ca = np.asarray(a, dtype=np.float64)
    cb = np.asarray(b, dtype=np.float64)
    if ca.size < 2 or cb.size < 2:
        raise ValueError("compare_bands needs at least 2 seeds per side")
    gap = float(ca.mean() - cb.mean())
    se = math.sqrt(ca.var(ddof=1) / ca.size + cb.var(ddof=1) / cb.size)
    half = _z_for_level(level) * se
    return GapSummary(
        gap=gap, ci_lo=gap - half, ci_hi=gap + half,
        significant=not (gap - half <= 0.0 <= gap + half),
        level=level,
    )


@dataclasses.dataclass(frozen=True)
class SeedBandResult:
    """Per-seed ``ServingMetrics`` columns for one serving cell."""

    seeds: Tuple[int, ...]
    metrics: Tuple[ServingMetrics, ...]   # one per seed, same order

    def column(self, field: str) -> np.ndarray:
        """One metric as a float64 column over the seed axis."""
        return np.array(
            [getattr(m, field) for m in self.metrics], dtype=np.float64
        )

    def band(self, field: str, level: float = 0.95) -> BandSummary:
        return summarize_band(self.column(field), level)

    def bands(
        self, fields: Sequence[str] = BAND_FIELDS, level: float = 0.95
    ) -> Dict[str, BandSummary]:
        return {f: self.band(f, level) for f in fields}


def _lanes_for(
    process: ArrivalProcess, horizon: float, seeds: Sequence[int]
) -> List:
    # Columnar lanes: at 10^3 seeds, materialising Request objects costs
    # more than the scan itself; generate_columns is bitwise-identical.
    return [process.generate_columns(horizon, seed=int(s)) for s in seeds]


def _chunked(seq: Sequence, size: int):
    for i in range(0, len(seq), size):
        yield seq[i:i + size]


def simulate_scan_seedband(
    scheduler,
    table,
    process: ArrivalProcess,
    horizon: float,
    seeds: Sequence[int],
    chunk: int = DEFAULT_CHUNK,
    **kwargs,
) -> SeedBandResult:
    """Single-device cell at every seed in ``seeds``.

    One arrival trace per seed via ``process.generate(horizon, seed)``,
    run through ``simulate_scan_batch`` in chunks of ``chunk`` lanes.
    Extra kwargs flow to the batch engine (``keep_completions`` defaults
    to False: the band only needs metrics columns).
    """
    from .simfast import simulate_scan_batch

    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    kwargs.setdefault("keep_completions", False)
    lanes = _lanes_for(process, horizon, seeds)
    out: List[ServingMetrics] = []
    for block in _chunked(lanes, chunk):
        results = simulate_scan_batch(
            scheduler, table, block, horizon, **kwargs
        )
        out.extend(r.metrics for r in results)
    return SeedBandResult(seeds=tuple(int(s) for s in seeds),
                          metrics=tuple(out))


def simulate_cluster_scan_seedband(
    devices,
    process: ArrivalProcess,
    horizon: float,
    seeds: Sequence[int],
    chunk: int = DEFAULT_CHUNK,
    **kwargs,
) -> SeedBandResult:
    """Fleet cell at every seed in ``seeds`` via the compiled cluster scan.

    Extra kwargs flow to ``simulate_cluster_scan_batch`` (``dispatcher``,
    ``policy``, ``power_d``, ...); ``keep_completions`` defaults to False
    so a 10^3-seed band never materialises completion objects.
    """
    from .clusterfast import simulate_cluster_scan_batch

    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    kwargs.setdefault("keep_completions", False)
    lanes = _lanes_for(process, horizon, seeds)
    out: List[ServingMetrics] = []
    for block in _chunked(lanes, chunk):
        results = simulate_cluster_scan_batch(
            devices, block, horizon, **kwargs
        )
        out.extend(r.metrics for r in results)
    return SeedBandResult(seeds=tuple(int(s) for s in seeds),
                          metrics=tuple(out))
