"""FIFO service queues and the scheduler's snapshot view (paper Sec. III).

Each model is backed by a dedicated FIFO queue. Requests arrive continuously
and are enqueued regardless of accelerator state; the scheduler sees a
*snapshot* of per-task queueing times at each scheduling round.
"""

from __future__ import annotations

from collections import deque
from typing import Iterable, List, Optional, Sequence

import numpy as np

from repro.core.request import Request


class ServiceQueue:
    """FIFO queue for one model; O(1) enqueue/dequeue, O(n) snapshot."""

    __slots__ = ("model", "_q", "_n_deadline")

    def __init__(self, model: int):
        self.model = model
        self._q: deque = deque()
        self._n_deadline = 0  # queued requests carrying a per-request deadline

    def __len__(self) -> int:
        return len(self._q)

    def push(self, req: Request) -> None:
        self._q.append(req)
        if req.deadline is not None:
            self._n_deadline += 1

    def pop_batch(self, batch_size: int) -> List[Request]:
        """Dequeue the ``batch_size`` oldest requests (FIFO)."""
        n = min(batch_size, len(self._q))
        out = [self._q.popleft() for _ in range(n)]
        if self._n_deadline:
            self._n_deadline -= sum(1 for r in out if r.deadline is not None)
        return out

    @property
    def has_deadlines(self) -> bool:
        return self._n_deadline > 0

    def arrivals(self) -> np.ndarray:
        """``[n]`` arrival times, oldest first."""
        return np.fromiter(
            (r.arrival for r in self._q), dtype=np.float64, count=len(self._q)
        )

    def waits(self, now: float) -> np.ndarray:
        """``[n]`` queueing times at ``now``, oldest (largest wait) first."""
        return now - self.arrivals()

    def deadlines(self) -> np.ndarray:
        """``[n]`` per-task deadlines, ``NaN`` where the request has none
        (callers substitute the global SLO; FIFO order matches ``waits``)."""
        return np.fromiter(
            (np.nan if r.deadline is None else r.deadline for r in self._q),
            dtype=np.float64,
            count=len(self._q),
        )

    def peek_oldest(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    def pending(self) -> List[Request]:
        """Queued requests, FIFO order (read-only copy; telemetry uses this
        for residual-span accounting at end of run)."""
        return list(self._q)


class QueueSnapshot:
    """Immutable per-round view consumed by schedulers.

    Attributes:
      now:    snapshot wall-clock time (seconds).
      waits:  list of M float64 arrays, FIFO order (index 0 = oldest task,
              i.e. the maximum queueing time ``w_max`` of that queue).
      deadlines: ``None`` when every queued task uses the global SLO (the
              common case — schedulers then take a scalar-tau fast path that
              is bitwise-identical to the pre-deadline code), else a list of
              M float64 arrays aligned with ``waits`` where ``NaN`` marks
              "use the global SLO".
    """

    __slots__ = ("now", "waits", "deadlines", "_padded_cache", "_tau_cache")

    def __init__(
        self,
        now: float,
        waits: Sequence[np.ndarray],
        deadlines: Optional[Sequence[np.ndarray]] = None,
    ):
        self.now = now
        self.waits = list(waits)
        self.deadlines = list(deadlines) if deadlines is not None else None
        self._padded_cache = None  # lazily built default padded() view
        self._tau_cache = None     # (default_tau, [M, maxQ] matrix)

    @property
    def num_models(self) -> int:
        return len(self.waits)

    def qlen(self, m: int) -> int:
        return len(self.waits[m])

    def qlens(self) -> List[int]:
        return [len(w) for w in self.waits]

    def w_max(self, m: int) -> float:
        return float(self.waits[m][0]) if len(self.waits[m]) else 0.0

    def nonempty(self) -> List[int]:
        return [m for m, w in enumerate(self.waits) if len(w)]

    def total_tasks(self) -> int:
        return sum(len(w) for w in self.waits)

    # -- per-task deadlines (heterogeneous-SLO workloads) --------------------

    @property
    def has_deadlines(self) -> bool:
        return self.deadlines is not None

    def taus(self, m: int, default: float) -> np.ndarray:
        """``[n]`` effective per-task deadlines for queue ``m`` (FIFO order):
        the request's own deadline where set, ``default`` otherwise."""
        if self.deadlines is None:
            return np.full(len(self.waits[m]), default)
        d = self.deadlines[m]
        return np.where(np.isnan(d), default, d)

    def oldest_tau(self, m: int, default: float) -> float:
        """Effective deadline of queue ``m``'s oldest task (Eq. 6 uses the
        head-of-line task's budget; ``default`` for empty queues)."""
        if self.deadlines is None or not len(self.deadlines[m]):
            return default
        d = float(self.deadlines[m][0])
        return default if np.isnan(d) else d

    def padded_taus(self, default: float) -> np.ndarray:
        """``[M, maxQ]`` effective-deadline matrix aligned with ``padded()``
        (``default`` at padded slots; cached per ``default``)."""
        if self._tau_cache is not None and self._tau_cache[0] == default:
            return self._tau_cache[1]
        _, mask = self.padded()
        tau = np.full(mask.shape, default, dtype=np.float64)
        if self.deadlines is not None:
            cap = mask.shape[1]
            for m, d in enumerate(self.deadlines):
                n = min(len(d), cap)
                if n:
                    tau[m, :n] = np.where(np.isnan(d[:n]), default, d[:n])
        self._tau_cache = (default, tau)
        return tau

    def padded(
        self, max_q: Optional[int] = None, dtype=np.float64
    ) -> "tuple[np.ndarray, np.ndarray]":
        """Padded ``([M, maxQ] waits, [M, maxQ] mask)`` for vectorised scoring.

        The default view (``max_q=None``, float64) is built once and reused:
        the snapshot is immutable, and the lattice scheduler, the vectorised
        greedy, and A/B comparisons all score off the same matrices.
        """
        if max_q is None and dtype is np.float64:
            if self._padded_cache is None:
                self._padded_cache = self._build_padded(None, np.float64)
            return self._padded_cache
        return self._build_padded(max_q, dtype)

    def _build_padded(
        self, max_q: Optional[int], dtype
    ) -> "tuple[np.ndarray, np.ndarray]":
        m_count = len(self.waits)
        cap = max_q or max((len(w) for w in self.waits), default=0)
        cap = max(cap, 1)
        w = np.zeros((m_count, cap), dtype=dtype)
        mask = np.zeros((m_count, cap), dtype=dtype)
        for m, wq in enumerate(self.waits):
            n = min(len(wq), cap)
            w[m, :n] = wq[:n]
            mask[m, :n] = 1.0
        return w, mask

    @staticmethod
    def take(queues: Iterable[ServiceQueue], now: float) -> "QueueSnapshot":
        qs = list(queues)
        deadlines = None
        if any(q.has_deadlines for q in qs):
            deadlines = [q.deadlines() for q in qs]
        return QueueSnapshot(now, [q.waits(now) for q in qs], deadlines)
