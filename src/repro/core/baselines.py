"""Baseline and ablation scheduling policies (paper Sec. VI-A, VI-H).

Baselines:
  * All-Final   -- LQF model selection, always deepest exit, B = min(|Q|, Bmax).
  * All-Early   -- LQF model selection, always shallowest exit.
  * Symphony    -- deferred deadline-driven batching: each queue is dispatched
                   (at the final exit) only once its oldest request approaches
                   the SLO deadline, maximising batch size; queues are
                   scheduled independently of one another.

Ablations (each removes exactly one EdgeServing component):
  * Early-Exit+LQF  -- Eq. 5/6 exit+batch selection, LQF model selection.
  * Early-Exit+EDF  -- Eq. 5/6 exit+batch selection, EDF model selection.
  * All-Final+Deadline-Aware -- stability-score selection, exits pinned final.
  * Ours+bs=1       -- full scheduler with dynamic batching disabled.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.profile import ProfileTable
from repro.core.queues import QueueSnapshot
from repro.core.request import Decision
from repro.core.scheduler import (
    EdgeServingScheduler,
    LatticeEdgeServingScheduler,
    Scheduler,
    SchedulerConfig,
)


class _FixedExitLQF(Scheduler):
    """Longest-queue-first with a pinned exit point (paper's non-adaptive
    baselines). Ties broken toward the queue with the oldest task."""

    _pinned_exit: int = -1  # index into allowed exits (-1 = deepest)

    def decide(self, snapshot: QueueSnapshot) -> Optional[Decision]:
        nonempty = snapshot.nonempty()
        if not nonempty:
            return None
        m = max(nonempty, key=lambda i: (snapshot.qlen(i), snapshot.w_max(i)))
        batch = self.batch_size(snapshot.qlen(m))
        exit_idx = self._exits[self._pinned_exit]
        return Decision(
            model=m,
            exit_idx=exit_idx,
            batch_size=batch,
            predicted_latency=self.table(m, exit_idx, batch),
        )


class AllFinalScheduler(_FixedExitLQF):
    name = "all-final"
    _pinned_exit = -1


class AllEarlyScheduler(_FixedExitLQF):
    name = "all-early"
    _pinned_exit = 0


class SymphonyScheduler(Scheduler):
    """Deferred batching a la Symphony [7] (paper's strongest baseline).

    Each model queue is considered independently; a queue becomes *due* when
    its oldest request can only just finish within the SLO if dispatched now
    at the final exit (with a small headroom), or when a full batch has
    accumulated. Among due queues, the earliest-deadline queue is served.
    When nothing is due, the scheduler idles (deferred batching) and reports
    the next wake-up time so the runtime can sleep precisely.
    """

    name = "symphony"

    def __init__(
        self,
        table: ProfileTable,
        config: SchedulerConfig,
        headroom: float = 0.10,
    ):
        super().__init__(table, config)
        # headroom is a fraction of tau reserved for dispatch jitter.
        self.headroom = headroom * config.slo
        self._final = self._exits[-1]

    def _due(self, snapshot: QueueSnapshot, m: int) -> bool:
        batch = self.batch_size(snapshot.qlen(m))
        if batch >= self.config.max_batch:
            return True  # full batch: deferring further cannot help throughput
        lat = self.table(m, self._final, batch)
        tau = snapshot.oldest_tau(m, self.config.slo)
        return snapshot.w_max(m) + lat >= tau - self.headroom

    def decide(self, snapshot: QueueSnapshot) -> Optional[Decision]:
        nonempty = snapshot.nonempty()
        if not nonempty:
            return None
        due = [m for m in nonempty if self._due(snapshot, m)]
        if not due:
            return None  # defer; runtime sleeps until next_wake()
        # earliest effective deadline first among due queues
        m = min(
            due,
            key=lambda i: snapshot.oldest_tau(i, self.config.slo)
            - snapshot.w_max(i)
            - self.table(i, self._final, self.batch_size(snapshot.qlen(i))),
        )
        batch = self.batch_size(snapshot.qlen(m))
        return Decision(
            model=m,
            exit_idx=self._final,
            batch_size=batch,
            predicted_latency=self.table(m, self._final, batch),
        )

    def next_wake(self, snapshot: QueueSnapshot) -> Optional[float]:
        """Absolute time at which some queue first becomes due (or None)."""
        wakes = []
        for m in snapshot.nonempty():
            batch = self.batch_size(snapshot.qlen(m))
            lat = self.table(m, self._final, batch)
            tau = snapshot.oldest_tau(m, self.config.slo)
            slack = tau - self.headroom - lat - snapshot.w_max(m)
            wakes.append(snapshot.now + max(slack, 0.0))
        return min(wakes) if wakes else None

    def prune(self, snapshot: QueueSnapshot) -> "list[tuple[int, int]]":
        """Symphony sheds requests whose deadline has already passed when its
        deferred batching cannot keep pace with arrivals (paper Sec. I)."""
        drops = []
        for m in snapshot.nonempty():
            w = snapshot.waits[m]  # FIFO order: oldest (largest wait) first
            if snapshot.has_deadlines:
                # Per-task deadlines: shed the expired FIFO prefix (pop_batch
                # can only remove the oldest tasks).
                expired = w > snapshot.taus(m, self.config.slo)
                n = len(w) if expired.all() else int(np.argmin(expired))
            else:
                n = int(np.searchsorted(-w, -self.config.slo, side="left"))
            if n > 0:
                drops.append((m, n))
        return drops


class EarlyExitLQFScheduler(Scheduler):
    """Ablation: profile-based exit selection + longest-queue-first."""

    name = "earlyexit-lqf"

    def decide(self, snapshot: QueueSnapshot) -> Optional[Decision]:
        nonempty = snapshot.nonempty()
        if not nonempty:
            return None
        m = max(nonempty, key=lambda i: (snapshot.qlen(i), snapshot.w_max(i)))
        batch, exit_idx, lat = self.candidate(snapshot, m)
        return Decision(m, exit_idx, batch, lat)


class EarlyExitEDFScheduler(Scheduler):
    """Ablation: profile-based exit selection + earliest-deadline-first.

    EDF selects the model whose oldest queued task has the least remaining
    SLO slack (tau - w_max), ignoring the system-wide impact of serving it.
    """

    name = "earlyexit-edf"

    def decide(self, snapshot: QueueSnapshot) -> Optional[Decision]:
        nonempty = snapshot.nonempty()
        if not nonempty:
            return None
        m = min(
            nonempty,
            key=lambda i: snapshot.oldest_tau(i, self.config.slo)
            - snapshot.w_max(i),
        )
        batch, exit_idx, lat = self.candidate(snapshot, m)
        return Decision(m, exit_idx, batch, lat)


class AllFinalDeadlineAwareScheduler(EdgeServingScheduler):
    """Ablation: stability-score model selection, early exit disabled."""

    name = "allfinal-deadline-aware"

    def __init__(self, table: ProfileTable, config: SchedulerConfig):
        final_only = dataclasses.replace(
            config, allowed_exits=(table.num_exits - 1,)
        )
        super().__init__(table, final_only)


class NoBatchingScheduler(EdgeServingScheduler):
    """Ablation: full scheduler with dynamic batching disabled (B = 1)."""

    name = "ours-bs1"

    def __init__(self, table: ProfileTable, config: SchedulerConfig):
        super().__init__(table, dataclasses.replace(config, max_batch=1))


SCHEDULERS = {
    "edgeserving": EdgeServingScheduler,
    "edgeserving-lattice": LatticeEdgeServingScheduler,
    "all-final": AllFinalScheduler,
    "all-early": AllEarlyScheduler,
    "symphony": SymphonyScheduler,
    "earlyexit-lqf": EarlyExitLQFScheduler,
    "earlyexit-edf": EarlyExitEDFScheduler,
    "allfinal-deadline-aware": AllFinalDeadlineAwareScheduler,
    "ours-bs1": NoBatchingScheduler,
}


def make_scheduler(name: str, table: ProfileTable, config: SchedulerConfig) -> Scheduler:
    try:
        cls = SCHEDULERS[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; available: {sorted(SCHEDULERS)}"
        ) from None
    # config.lattice upgrades the flagship policy to the joint
    # (model, exit, batch) lattice; baselines/ablations are unaffected.
    if config.lattice and cls is EdgeServingScheduler:
        cls = LatticeEdgeServingScheduler
    return cls(table, config)
