"""Offline profile table L(m, e, B) and accuracy table A(m, e) (paper Sec. IV).

The profile table is the contract between the offline phase and the online
scheduler: under time-division sharing, profiled latency *is* runtime
latency (paper reports CoV < 3%), so a single dense ``[M, E, B]`` array of
seconds fully specifies the scheduler's latency model.

Three builders are provided:

  * ``ProfileTable.measure``           -- wall-clock measurement of real
    callables (the faithful path; used on CPU for ResNet/LM reduced models
    and on a real TPU for deployment).
  * ``ProfileTable.paper_rtx3080``     -- a synthetic table calibrated to the
    paper's published RTX 3080 characteristics (Fig. 2 trends + the Fig. 4
    saturation point); used by the paper-figure benchmarks so that the
    scheduling dynamics are reproduced quantitatively.
  * ``ProfileTable.from_roofline``     -- analytic TPU profile from compiled
    HLO cost analysis (see ``repro.launch.roofline``): latency =
    max(compute/197T, bytes/819G, coll_bytes/link_bw) + dispatch overhead.
    This is the TPU-native adaptation of the paper's offline profiler.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ProfileTable:
    """Dense latency/accuracy profile.

    Attributes:
      model_names: length-M model identifiers.
      exit_names:  length-E exit identifiers, shallowest -> deepest
                   (e.g. ["layer1", "layer2", "layer3", "final"]).
      batch_sizes: length-B increasing batch sizes (paper: 1..10).
      latency:     ``[M, E, B]`` float64 seconds (P95 or mean per builder).
      accuracy:    ``[M, E]`` float64 top-1 accuracy in [0, 1].
      meta:        free-form provenance (platform, builder, date).
    """

    model_names: tuple
    exit_names: tuple
    batch_sizes: tuple
    latency: np.ndarray
    accuracy: np.ndarray
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        m, e, b = len(self.model_names), len(self.exit_names), len(self.batch_sizes)
        assert self.latency.shape == (m, e, b), self.latency.shape
        assert self.accuracy.shape == (m, e), self.accuracy.shape
        assert np.all(self.latency > 0), "latencies must be positive"
        # FIFO batching monotonicity: serving more items never gets cheaper.
        assert np.all(np.diff(self.latency, axis=2) >= -1e-12), (
            "latency must be non-decreasing in batch size"
        )

    # -- lookup ------------------------------------------------------------

    @property
    def num_models(self) -> int:
        return len(self.model_names)

    @property
    def num_exits(self) -> int:
        return len(self.exit_names)

    @property
    def max_batch(self) -> int:
        return int(self.batch_sizes[-1])

    def __call__(self, m: int, e: int, batch: int) -> float:
        """L(m, e, B) in seconds. ``batch`` is the actual batch size."""
        b_idx = int(np.searchsorted(self.batch_sizes, batch))
        b_idx = min(b_idx, len(self.batch_sizes) - 1)
        return float(self.latency[m, e, b_idx])

    def latencies_for_batch(self, m: int, batch: int) -> np.ndarray:
        """``[E]`` latency column for one model at one batch size."""
        b_idx = min(
            int(np.searchsorted(self.batch_sizes, batch)), len(self.batch_sizes) - 1
        )
        return self.latency[m, :, b_idx]

    def acc(self, m: int, e: int) -> float:
        return float(self.accuracy[m, e])

    # -- derived views -----------------------------------------------------

    def scaled(self, factor: float, name: str = "") -> "ProfileTable":
        """A platform-rescaled copy (used for cross-platform studies)."""
        return dataclasses.replace(
            self,
            latency=self.latency * factor,
            meta={**self.meta, "scaled_by": factor, "platform": name or
                  self.meta.get("platform", "") + f"*{factor:g}"},
        )

    def with_safety(self, multiplier: float) -> "ProfileTable":
        """A copy with every latency inflated by a safety ``multiplier``.

        The static headroom knob of the offline phase (paper Sec. IV-B
        records P95 for the same reason): analytic tables
        (``from_roofline``) and mean-based estimates use it to absorb
        measurement optimism. The *adaptive* twin is
        ``repro.core.adaptive.SafetyController``, which tunes this
        multiplier online from observed violation headroom.
        """
        return dataclasses.replace(self, latency=self.latency * multiplier)

    def with_batch_saturation(self, knee: int, slope: float = 0.85) -> "ProfileTable":
        """Model accelerator batch saturation past ``knee`` (BCEdge regime).

        Up to batch ``knee`` the original curve applies (batching is cheap);
        beyond it each extra item costs ``slope`` * the batch-1 latency —
        the compute-saturated regime where throughput no longer improves
        with batch size. This is the regime in which batch size becomes a
        real scheduling degree of freedom (see the lattice scheduler and
        ``benchmarks/fig12_lattice.py``).
        """
        assert 1 <= knee <= self.max_batch and slope > 0
        bsz = np.asarray(self.batch_sizes, dtype=np.float64)
        # index by batch-size *value*, not position: the grid need not be
        # contiguous (measure()/from_roofline accept arbitrary ladders)
        k_idx = int(np.searchsorted(self.batch_sizes, knee, side="right")) - 1
        assert k_idx >= 0, "knee below the smallest profiled batch"
        per_item = self.latency[:, :, 0:1] / float(self.batch_sizes[0])
        extra = np.maximum(bsz[None, None, :] - knee, 0.0) * slope
        saturated = self.latency[:, :, k_idx:k_idx + 1] + per_item * extra
        lat = np.where(bsz[None, None, :] <= knee, self.latency, saturated)
        lat = np.maximum.accumulate(lat, axis=2)
        return dataclasses.replace(
            self, latency=lat,
            meta={**self.meta, "batch_knee": knee, "batch_slope": slope},
        )

    def restrict_exits(self, exit_indices: Sequence[int]) -> "ProfileTable":
        """Keep only a subset of exits (paper Fig. 7 exit-configuration study)."""
        idx = list(exit_indices)
        return dataclasses.replace(
            self,
            exit_names=tuple(self.exit_names[i] for i in idx),
            latency=self.latency[:, idx, :],
            accuracy=self.accuracy[:, idx],
        )

    def select_models(self, model_indices: Sequence[int]) -> "ProfileTable":
        """Deployment mix view (paper Fig. 9 model-combination study)."""
        idx = list(model_indices)
        return dataclasses.replace(
            self,
            model_names=tuple(self.model_names[i] for i in idx),
            latency=self.latency[idx],
            accuracy=self.accuracy[idx],
        )

    # -- (de)serialisation ---------------------------------------------------

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(
                {
                    "model_names": list(self.model_names),
                    "exit_names": list(self.exit_names),
                    "batch_sizes": list(self.batch_sizes),
                    "latency": self.latency.tolist(),
                    "accuracy": self.accuracy.tolist(),
                    "meta": self.meta,
                },
                f,
            )

    @staticmethod
    def load(path: str) -> "ProfileTable":
        with open(path) as f:
            d = json.load(f)
        return ProfileTable(
            model_names=tuple(d["model_names"]),
            exit_names=tuple(d["exit_names"]),
            batch_sizes=tuple(d["batch_sizes"]),
            latency=np.asarray(d["latency"], dtype=np.float64),
            accuracy=np.asarray(d["accuracy"], dtype=np.float64),
            meta=d.get("meta", {}),
        )

    # -- builders ------------------------------------------------------------

    @staticmethod
    def measure(
        model_names: Sequence[str],
        exit_names: Sequence[str],
        batch_sizes: Sequence[int],
        run_fn: Callable[[int, int, int], None],
        accuracy: Optional[np.ndarray] = None,
        repeats: int = 20,
        warmup: int = 3,
        percentile: float = 95.0,
        meta: Optional[dict] = None,
    ) -> "ProfileTable":
        """Wall-clock profiling of ``run_fn(m, e, B)`` (paper Sec. IV-B).

        ``run_fn`` must execute one full inference for configuration
        ``(m, e, B)`` and block until complete (jax: ``block_until_ready``).
        Records the ``percentile`` latency over ``repeats`` runs after
        ``warmup`` discarded runs, exactly like the paper's profiler; batch
        monotonicity is re-enforced against measurement noise
        (``np.maximum.accumulate``). The resulting table is a point-in-time
        snapshot of the device — under thermal/DVFS/contention drift it is
        the *cold start* that ``repro.core.adaptive.OnlineProfiler``
        refreshes from observed completions.
        """
        m_n, e_n, b_n = len(model_names), len(exit_names), len(batch_sizes)
        lat = np.zeros((m_n, e_n, b_n), dtype=np.float64)
        for mi in range(m_n):
            for ei in range(e_n):
                for bi, bsz in enumerate(batch_sizes):
                    for _ in range(warmup):
                        run_fn(mi, ei, bsz)
                    samples = np.empty(repeats)
                    for r in range(repeats):
                        t0 = time.perf_counter()
                        run_fn(mi, ei, bsz)
                        samples[r] = time.perf_counter() - t0
                    lat[mi, ei, bi] = np.percentile(samples, percentile)
        # enforce batch monotonicity against measurement noise
        lat = np.maximum.accumulate(lat, axis=2)
        if accuracy is None:
            accuracy = np.full((m_n, e_n), np.nan)
        return ProfileTable(
            tuple(model_names), tuple(exit_names), tuple(batch_sizes),
            lat, np.asarray(accuracy, dtype=np.float64),
            meta={**(meta or {}), "builder": "measure", "percentile": percentile},
        )

    @staticmethod
    def paper_rtx3080() -> "ProfileTable":
        """Synthetic table calibrated to the paper's RTX 3080 numbers.

        Calibration targets (paper Sec. IV-C + Sec. VI-B):
          * batch 1 -> 10 raises latency ~2-3x (not 10x);
          * final exit of ResNet152 ~6-8x slower than its layer1 exit;
          * model ordering R50 < R101 < R152, gap widest at final;
          * All-Final saturates near lambda_152 ~ 140 req/s under the 3:2:1
            traffic ratio with B_max = 10 (utilisation = 1 at ~143 req/s with
            the constants below -- see tests/test_profile.py).
        """
        model_names = ("resnet50", "resnet101", "resnet152")
        exit_names = ("layer1", "layer2", "layer3", "final")
        batch_sizes = tuple(range(1, 11))
        # Batch-1 latency (ms); exit cost fractions approximate cumulative
        # bottleneck-stage FLOPs of each backbone with a stem offset.
        base_final_ms = np.array([2.8, 5.2, 7.6])        # R50, R101, R152 @ final
        exit_frac = np.array(
            [
                [0.22, 0.35, 0.62, 1.00],   # ResNet50  (final/layer1 ~ 4.5x)
                [0.16, 0.27, 0.66, 1.00],   # ResNet101 (~6.3x)
                [0.135, 0.24, 0.68, 1.00],  # ResNet152 (~7.4x: "6-8x")
            ]
        )
        bsz = np.arange(1, 11, dtype=np.float64)
        # L(B) = L(1) * (1 + slope*(B-1)); slope=1/6 -> 2.5x at B=10 ("2-3x").
        batch_curve = 1.0 + (bsz - 1.0) / 6.0
        lat_ms = (
            base_final_ms[:, None, None]
            * exit_frac[:, :, None]
            * batch_curve[None, None, :]
        )
        accuracy = np.array(
            [
                [0.076, 0.121, 0.308, 0.744],   # Table I, ResNet50
                [0.074, 0.145, 0.543, 0.779],   # ResNet101
                [0.073, 0.172, 0.474, 0.780],   # ResNet152
            ]
        )
        return ProfileTable(
            model_names, exit_names, batch_sizes, lat_ms * 1e-3, accuracy,
            meta={"builder": "paper_rtx3080", "platform": "rtx3080-calibrated"},
        )

    @staticmethod
    def paper_gtx1650() -> "ProfileTable":
        """GTX 1650-calibrated table: ~3.2x slower than the 3080 (paper VI-G)."""
        return ProfileTable.paper_rtx3080().scaled(3.2, "gtx1650-calibrated")

    @staticmethod
    def paper_jetson_orin_nano() -> "ProfileTable":
        """Jetson Orin Nano-calibrated: ~7x slower; paper uses tau=100 ms."""
        return ProfileTable.paper_rtx3080().scaled(7.0, "jetson-orin-nano-calibrated")

    @staticmethod
    def from_roofline(
        model_names: Sequence[str],
        exit_names: Sequence[str],
        batch_sizes: Sequence[int],
        terms_fn: Callable[[int, int, int], "tuple[float, float, float]"],
        accuracy: Optional[np.ndarray] = None,
        dispatch_overhead_s: float = 15e-6,
        safety: float = 1.05,
        meta: Optional[dict] = None,
    ) -> "ProfileTable":
        """Analytic TPU profile: L = safety * (max(3 roofline terms) + overhead).

        ``terms_fn(m, e, B)`` returns (compute_s, memory_s, collective_s) for
        that configuration, typically derived from ``compiled.cost_analysis()``
        of the dry-run (see repro.launch.roofline).
        """
        m_n, e_n, b_n = len(model_names), len(exit_names), len(batch_sizes)
        lat = np.zeros((m_n, e_n, b_n))
        for mi in range(m_n):
            for ei in range(e_n):
                for bi, bsz in enumerate(batch_sizes):
                    c, h, l = terms_fn(mi, ei, bsz)
                    lat[mi, ei, bi] = safety * (max(c, h, l) + dispatch_overhead_s)
        lat = np.maximum.accumulate(lat, axis=2)
        if accuracy is None:
            accuracy = np.full((m_n, e_n), np.nan)
        return ProfileTable(
            tuple(model_names), tuple(exit_names), tuple(batch_sizes),
            lat, np.asarray(accuracy, dtype=np.float64),
            meta={**(meta or {}), "builder": "roofline", "safety": safety},
        )
