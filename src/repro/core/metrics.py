"""Serving metrics (paper Sec. VI): SLO violation ratio, tail latency,
exit-depth distribution, and lookup-based effective accuracy."""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.profile import ProfileTable
from repro.core.request import Completion


@dataclasses.dataclass(frozen=True)
class ModelMetrics:
    """Per-model (per-queue) breakdown of a serving window.

    Bursty workloads concentrate damage on individual queues; the aggregate
    violation ratio hides which queue absorbed it. One entry per model index
    in ``ServingMetrics.per_model`` makes it visible.
    """

    model: int
    num_completed: int
    violation_ratio: float
    p50_latency: float
    p95_latency: float
    mean_queueing: float
    mean_exit_depth: float


@dataclasses.dataclass(frozen=True)
class DeviceMetrics:
    """Per-device breakdown of a cluster serving window.

    One entry per device in ``ServingMetrics.per_device`` (cluster runs
    only; empty for single-accelerator experiments). ``dispatched`` counts
    requests routed to the device (including failover re-dispatches), so
    ``dispatched - num_completed`` exposes skew between what a dispatcher
    assigned and what the device actually finished post-warmup.
    ``violation_ratio`` counts the device's shed requests as violations,
    the same ``(late + dropped) / (done + dropped)`` rule as the aggregate.
    """

    device: int
    name: str
    num_completed: int
    dispatched: int
    dropped: int
    violation_ratio: float
    p95_latency: float
    mean_exit_depth: float
    utilization: float
    alive: bool


@dataclasses.dataclass(frozen=True)
class ServingMetrics:
    """Aggregate results over a serving window (post-warmup completions)."""

    num_completed: int
    violation_ratio: float          # Eq. 2
    p50_latency: float
    p95_latency: float
    p99_latency: float
    mean_latency: float
    mean_queueing: float
    mean_exit_depth: float          # 1..E (paper Fig. 5)
    mean_accuracy: float            # Table-I-lookup average (paper Sec. VI-C)
    throughput: float               # completed req/s over the measured span
    utilization: float              # accelerator busy fraction
    mean_batch: float
    residual_queue: int             # tasks still queued at the end (overload)
    dropped: int = 0                # shed requests (Symphony); count as violations
    warmup_used: int = 0            # completions actually excluded (post-clamp)
    per_model: "tuple[ModelMetrics, ...]" = ()
    per_device: "tuple[DeviceMetrics, ...]" = ()  # cluster runs only

    def row(self) -> dict:
        return dataclasses.asdict(self)


def summarize(
    completions: Sequence[Completion],
    table: ProfileTable,
    slo: float,
    warmup_tasks: int = 100,
    busy_time: float = 0.0,
    span: float = 0.0,
    residual_queue: int = 0,
    model_map: Optional[Sequence[int]] = None,
    dropped: int = 0,
) -> ServingMetrics:
    """Aggregate a completion log.

    Args:
      completions: completion records ordered by finish time.
      table:       profile table used for accuracy lookup.
      slo:         deadline tau in seconds (fallback when a completion has no
                   per-request ``deadline`` of its own).
      warmup_tasks: paper excludes the first 100 completed tasks. For runs
                   shorter than the warmup this is clamped to half the
                   completion count, so a short run reports honest non-zero
                   metrics instead of silently collapsing to all zeros; the
                   exclusion actually applied is surfaced as ``warmup_used``.
      busy_time:   accelerator-occupied seconds (for utilisation).
      span:        wall-clock span of the experiment in seconds.
      model_map:   optional mapping completion.model -> profile row (used by
                   deployment-mix studies where queue i serves table row j).
      dropped:     shed requests; counted as violations (a dropped request
                   certainly misses its deadline).
    """
    completions = list(completions)
    if warmup_tasks >= len(completions):
        warmup_tasks = len(completions) // 2
    done = completions[warmup_tasks:]
    if not done:
        # (late + dropped) / (done + dropped) with done empty: every
        # accounted request was shed, and a dropped request certainly
        # missed its deadline.
        return ServingMetrics(
            num_completed=0,
            violation_ratio=1.0 if dropped else 0.0,
            p50_latency=0.0,
            p95_latency=0.0, p99_latency=0.0, mean_latency=0.0,
            mean_queueing=0.0, mean_exit_depth=0.0, mean_accuracy=0.0,
            throughput=0.0, utilization=0.0, mean_batch=0.0,
            residual_queue=residual_queue, dropped=dropped, warmup_used=0,
        )
    lat = np.array([c.total_latency for c in done])
    queue = np.array([c.queueing for c in done])
    exits = np.array([c.exit_idx for c in done])
    batches = np.array([c.batch_size for c in done])
    models = np.array([c.model for c in done])
    taus = np.array(
        [slo if c.deadline is None else c.deadline for c in done]
    )
    rows = (
        np.array([model_map[c.model] for c in done])
        if model_map is not None
        else models
    )
    acc = table.accuracy[rows, exits]
    if np.all(np.isnan(acc)):  # measured tables may carry no accuracy data
        acc = np.zeros_like(acc)
    violated = lat > taus
    late = int(np.sum(violated))

    per_model = []
    for m in np.unique(models):
        sel = models == m
        per_model.append(ModelMetrics(
            model=int(m),
            num_completed=int(sel.sum()),
            violation_ratio=float(violated[sel].mean()),
            p50_latency=float(np.percentile(lat[sel], 50)),
            p95_latency=float(np.percentile(lat[sel], 95)),
            mean_queueing=float(queue[sel].mean()),
            mean_exit_depth=float(exits[sel].mean() + 1.0),
        ))

    return ServingMetrics(
        num_completed=len(done),
        violation_ratio=float((late + dropped) / (len(done) + dropped)),
        p50_latency=float(np.percentile(lat, 50)),
        p95_latency=float(np.percentile(lat, 95)),
        p99_latency=float(np.percentile(lat, 99)),
        mean_latency=float(lat.mean()),
        mean_queueing=float(queue.mean()),
        mean_exit_depth=float(exits.mean() + 1.0),
        mean_accuracy=float(np.nanmean(acc)),
        throughput=float(len(done) / span) if span > 0 else 0.0,
        utilization=float(busy_time / span) if span > 0 else 0.0,
        mean_batch=float(batches.mean()),
        residual_queue=residual_queue,
        dropped=dropped,
        warmup_used=warmup_tasks,
        per_model=tuple(per_model),
    )
