"""Serving metrics (paper Sec. VI): SLO violation ratio, tail latency,
exit-depth distribution, and lookup-based effective accuracy."""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.profile import ProfileTable
from repro.core.request import Completion


@dataclasses.dataclass(frozen=True)
class ServingMetrics:
    """Aggregate results over a serving window (post-warmup completions)."""

    num_completed: int
    violation_ratio: float          # Eq. 2
    p50_latency: float
    p95_latency: float
    p99_latency: float
    mean_latency: float
    mean_queueing: float
    mean_exit_depth: float          # 1..E (paper Fig. 5)
    mean_accuracy: float            # Table-I-lookup average (paper Sec. VI-C)
    throughput: float               # completed req/s over the measured span
    utilization: float              # accelerator busy fraction
    mean_batch: float
    residual_queue: int             # tasks still queued at the end (overload)
    dropped: int = 0                # shed requests (Symphony); count as violations

    def row(self) -> dict:
        return dataclasses.asdict(self)


def summarize(
    completions: Sequence[Completion],
    table: ProfileTable,
    slo: float,
    warmup_tasks: int = 100,
    busy_time: float = 0.0,
    span: float = 0.0,
    residual_queue: int = 0,
    model_map: Optional[Sequence[int]] = None,
    dropped: int = 0,
) -> ServingMetrics:
    """Aggregate a completion log.

    Args:
      completions: completion records ordered by finish time.
      table:       profile table used for accuracy lookup.
      slo:         deadline tau in seconds.
      warmup_tasks: paper excludes the first 100 completed tasks.
      busy_time:   accelerator-occupied seconds (for utilisation).
      span:        wall-clock span of the experiment in seconds.
      model_map:   optional mapping completion.model -> profile row (used by
                   deployment-mix studies where queue i serves table row j).
      dropped:     shed requests; counted as violations (a dropped request
                   certainly misses its deadline).
    """
    done = list(completions)[warmup_tasks:]
    if not done:
        return ServingMetrics(0, 0.0, *([0.0] * 9), residual_queue, dropped)
    lat = np.array([c.total_latency for c in done])
    queue = np.array([c.queueing for c in done])
    exits = np.array([c.exit_idx for c in done])
    batches = np.array([c.batch_size for c in done])
    rows = (
        np.array([model_map[c.model] for c in done])
        if model_map is not None
        else np.array([c.model for c in done])
    )
    acc = table.accuracy[rows, exits]
    if np.all(np.isnan(acc)):  # measured tables may carry no accuracy data
        acc = np.zeros_like(acc)
    late = int(np.sum(lat > slo))
    return ServingMetrics(
        num_completed=len(done),
        violation_ratio=float((late + dropped) / (len(done) + dropped)),
        p50_latency=float(np.percentile(lat, 50)),
        p95_latency=float(np.percentile(lat, 95)),
        p99_latency=float(np.percentile(lat, 99)),
        mean_latency=float(lat.mean()),
        mean_queueing=float(queue.mean()),
        mean_exit_depth=float(exits.mean() + 1.0),
        mean_accuracy=float(np.nanmean(acc)),
        throughput=float(len(done) / span) if span > 0 else 0.0,
        utilization=float(busy_time / span) if span > 0 else 0.0,
        mean_batch=float(batches.mean()),
        residual_queue=residual_queue,
        dropped=dropped,
    )
