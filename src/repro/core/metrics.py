"""Serving metrics (paper Sec. VI): SLO violation ratio, tail latency,
exit-depth distribution, and lookup-based effective accuracy."""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.profile import ProfileTable
from repro.core.request import Completion


@dataclasses.dataclass(frozen=True)
class ModelMetrics:
    """Per-model (per-queue) breakdown of a serving window.

    Bursty workloads concentrate damage on individual queues; the aggregate
    violation ratio hides which queue absorbed it. One entry per model index
    in ``ServingMetrics.per_model`` makes it visible.
    """

    model: int
    num_completed: int
    violation_ratio: float
    p50_latency: float
    p95_latency: float
    mean_queueing: float
    mean_exit_depth: float


@dataclasses.dataclass(frozen=True)
class DeviceMetrics:
    """Per-device breakdown of a cluster serving window.

    One entry per device in ``ServingMetrics.per_device`` (cluster runs
    only; empty for single-accelerator experiments). ``dispatched`` counts
    requests routed to the device (including failover re-dispatches), so
    ``dispatched - num_completed`` exposes skew between what a dispatcher
    assigned and what the device actually finished post-warmup.
    ``violation_ratio`` counts the device's shed requests as violations,
    the same ``(late + dropped) / (done + dropped)`` rule as the aggregate.
    """

    device: int
    name: str
    num_completed: int
    dispatched: int
    dropped: int
    violation_ratio: float
    p95_latency: float
    mean_exit_depth: float
    utilization: float
    alive: bool


@dataclasses.dataclass(frozen=True)
class ServingMetrics:
    """Aggregate results over a serving window (post-warmup completions)."""

    num_completed: int
    violation_ratio: float          # Eq. 2
    p50_latency: float
    p95_latency: float
    p99_latency: float
    mean_latency: float
    mean_queueing: float
    mean_exit_depth: float          # 1..E (paper Fig. 5)
    mean_accuracy: float            # Table-I-lookup average (paper Sec. VI-C)
    throughput: float               # completed req/s over the measured span
    utilization: float              # accelerator busy fraction
    mean_batch: float
    residual_queue: int             # tasks still queued at the end (overload)
    dropped: int = 0                # shed requests (Symphony); count as violations
    warmup_used: int = 0            # completions actually excluded (post-clamp)
    per_model: "tuple[ModelMetrics, ...]" = ()
    per_device: "tuple[DeviceMetrics, ...]" = ()  # cluster runs only

    def row(self) -> dict:
        return dataclasses.asdict(self)


def summarize(
    completions: Sequence[Completion],
    table: ProfileTable,
    slo: float,
    warmup_tasks: int = 100,
    busy_time: float = 0.0,
    span: float = 0.0,
    residual_queue: int = 0,
    model_map: Optional[Sequence[int]] = None,
    dropped: int = 0,
) -> ServingMetrics:
    """Aggregate a completion log.

    Args:
      completions: completion records ordered by finish time.
      table:       profile table used for accuracy lookup.
      slo:         deadline tau in seconds (fallback when a completion has no
                   per-request ``deadline`` of its own).
      warmup_tasks: paper excludes the first 100 completed tasks. For runs
                   shorter than the warmup this is clamped to half the
                   completion count, so a short run reports honest non-zero
                   metrics instead of silently collapsing to all zeros; the
                   exclusion actually applied is surfaced as ``warmup_used``.
      busy_time:   accelerator-occupied seconds (for utilisation).
      span:        wall-clock span of the experiment in seconds.
      model_map:   optional mapping completion.model -> profile row (used by
                   deployment-mix studies where queue i serves table row j).
      dropped:     shed requests; counted as violations (a dropped request
                   certainly misses its deadline).
    """
    completions = list(completions)
    return summarize_arrays(
        models=np.array([c.model for c in completions], dtype=np.int64),
        exits=np.array([c.exit_idx for c in completions], dtype=np.int64),
        batches=np.array([c.batch_size for c in completions], dtype=np.int64),
        latencies=np.array([c.total_latency for c in completions]),
        queueings=np.array([c.queueing for c in completions]),
        taus=np.array(
            [slo if c.deadline is None else c.deadline for c in completions]
        ),
        table=table,
        warmup_tasks=warmup_tasks,
        busy_time=busy_time,
        span=span,
        residual_queue=residual_queue,
        model_map=model_map,
        dropped=dropped,
    )


def summarize_arrays(
    models: np.ndarray,
    exits: np.ndarray,
    batches: np.ndarray,
    latencies: np.ndarray,
    queueings: np.ndarray,
    taus: np.ndarray,
    table: ProfileTable,
    warmup_tasks: int = 100,
    busy_time: float = 0.0,
    span: float = 0.0,
    residual_queue: int = 0,
    model_map: Optional[Sequence[int]] = None,
    dropped: int = 0,
) -> ServingMetrics:
    """Array-native :func:`summarize`: one aligned column per completion
    field, ordered by finish time. ``summarize`` delegates here, and the
    compiled fast path (``repro.core.simfast``) feeds its reconstructed
    completion arrays in directly — one accounting implementation serves
    both engines. ``taus`` is the per-completion effective deadline
    (the request's own, or the global SLO where it has none)."""
    n_total = len(models)
    if warmup_tasks >= n_total:
        warmup_tasks = n_total // 2
    if n_total - warmup_tasks <= 0:
        # (late + dropped) / (done + dropped) with done empty: every
        # accounted request was shed, and a dropped request certainly
        # missed its deadline.
        return ServingMetrics(
            num_completed=0,
            violation_ratio=1.0 if dropped else 0.0,
            p50_latency=0.0,
            p95_latency=0.0, p99_latency=0.0, mean_latency=0.0,
            mean_queueing=0.0, mean_exit_depth=0.0, mean_accuracy=0.0,
            throughput=0.0, utilization=0.0, mean_batch=0.0,
            residual_queue=residual_queue, dropped=dropped, warmup_used=0,
        )
    sl = slice(warmup_tasks, None)
    lat = np.asarray(latencies, dtype=np.float64)[sl]
    queue = np.asarray(queueings, dtype=np.float64)[sl]
    exits = np.asarray(exits, dtype=np.int64)[sl]
    batches = np.asarray(batches, dtype=np.int64)[sl]
    models = np.asarray(models, dtype=np.int64)[sl]
    taus = np.asarray(taus, dtype=np.float64)[sl]
    done = lat  # alias for the count below
    rows = (
        np.asarray(model_map, dtype=np.int64)[models]
        if model_map is not None
        else models
    )
    acc = table.accuracy[rows, exits]
    if np.all(np.isnan(acc)):  # measured tables may carry no accuracy data
        acc = np.zeros_like(acc)
    violated = lat > taus
    late = int(np.sum(violated))

    # One stable sort replaces a boolean-mask pass per model: the sorted
    # order groups each model's completions into one contiguous slice.
    per_model = []
    order = np.argsort(models, kind="stable")
    groups, counts = np.unique(models[order], return_counts=True)
    bounds = np.concatenate(([0], np.cumsum(counts)))
    lat_o, queue_o = lat[order], queue[order]
    exits_o, viol_o = exits[order], violated[order]
    for gi, m in enumerate(groups):
        sel = slice(bounds[gi], bounds[gi + 1])
        pm_p50, pm_p95 = np.percentile(lat_o[sel], [50, 95])
        per_model.append(ModelMetrics(
            model=int(m),
            num_completed=int(counts[gi]),
            violation_ratio=float(viol_o[sel].mean()),
            p50_latency=float(pm_p50),
            p95_latency=float(pm_p95),
            mean_queueing=float(queue_o[sel].mean()),
            mean_exit_depth=float(exits_o[sel].mean() + 1.0),
        ))

    p50, p95, p99 = np.percentile(lat, [50, 95, 99])
    return ServingMetrics(
        num_completed=len(done),
        violation_ratio=float((late + dropped) / (len(done) + dropped)),
        p50_latency=float(p50),
        p95_latency=float(p95),
        p99_latency=float(p99),
        mean_latency=float(lat.mean()),
        mean_queueing=float(queue.mean()),
        mean_exit_depth=float(exits.mean() + 1.0),
        mean_accuracy=float(np.nanmean(acc)),
        throughput=float(len(done) / span) if span > 0 else 0.0,
        utilization=float(busy_time / span) if span > 0 else 0.0,
        mean_batch=float(batches.mean()),
        residual_queue=residual_queue,
        dropped=dropped,
        warmup_used=warmup_tasks,
        per_model=tuple(per_model),
    )
