"""Event-driven time-division serving simulator (paper Sec. III + VI).

The simulator and the live serving loop (``repro.runtime.server``) share the
same queues, snapshot, scheduler, and metrics code; the only difference is
where service time comes from -- here it is the profile table (optionally
with the paper's measured <3% CoV noise), live it is the accelerator.

Semantics reproduced from the paper:
  * requests arrive continuously and are enqueued regardless of accelerator
    state (arrivals during a quantum are visible at the next round);
  * scheduling happens only when the accelerator is idle; the chosen batch
    occupies it exclusively for L(m, e, B) seconds (time-division);
  * no admission control: late tasks still run and count as violations;
  * each experiment runs ``horizon`` seconds of arrivals (paper: 20 s) and
    then drains; the first ``warmup_tasks`` completions are excluded.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.adaptive import AdaptConfig, DriftModel, make_profiler
from repro.core.metrics import ServingMetrics, summarize
from repro.core.profile import ProfileTable
from repro.core.queues import QueueSnapshot, ServiceQueue
from repro.core.request import Completion, Request, ServingTrace
from repro.core.scheduler import Scheduler
from repro.core.telemetry import Trace, Tracer, decision_margin
from repro.core.traffic import poisson_arrivals


@dataclasses.dataclass
class SimResult:
    metrics: ServingMetrics
    completions: List[Completion]
    traces: List[ServingTrace]
    span: float
    adapted_table: Optional[ProfileTable] = None  # final online-profiler view
    trace: Optional[Trace] = None  # telemetry timeline (tracer attached)


def service_noise_multiplier(rng: np.random.Generator, cov: float) -> float:
    """Mean-1 lognormal service-time multiplier at coefficient of variation
    ``cov`` (paper: CoV < 3%). Shared by the single-device and cluster
    simulators so their noise streams stay formula-identical."""
    sigma = np.sqrt(np.log1p(cov**2))
    return float(rng.lognormal(-0.5 * sigma**2, sigma))


class ServingSimulator:
    """Deterministic discrete-event simulator for one serving experiment."""

    def __init__(
        self,
        scheduler: Scheduler,
        table: ProfileTable,
        num_models: Optional[int] = None,
        service_noise_cov: float = 0.0,
        model_map: Optional[Sequence[int]] = None,
        seed: int = 0,
        drain_cap: float = 600.0,
        drift: Optional[DriftModel] = None,
        adapt: Optional[AdaptConfig] = None,
        tracer: Optional[Tracer] = None,
    ):
        """Args:
          scheduler: the policy under test (its table may be a restricted
            view; ``table`` here is the ground-truth execution table).
          num_models: number of service queues (defaults to table rows).
          service_noise_cov: multiplicative lognormal service-time noise
            (paper measures CoV < 3%; 0 = fully deterministic).
          model_map: queue index -> execution-table row (deployment mixes).
          drain_cap: hard wall-clock cap on post-horizon draining.
          drift: optional ground-truth drift on *true* service times
            (``repro.core.adaptive``); the scheduler's table is untouched,
            so it decides with stale estimates unless ``adapt`` is on.
          adapt: optional online-adaptation config: observed quantum
            service times feed an ``OnlineProfiler`` over the scheduler's
            table, which is swapped for a refreshed view on the configured
            cadence. ``None`` for both knobs is bitwise the stock simulator.
          tracer: optional ``repro.core.telemetry.Tracer``. Record-only:
            with a tracer attached, decisions and metrics are bitwise
            identical to an untraced run (property-tested); ``None`` (the
            default) skips every telemetry branch entirely.
        """
        self.scheduler = scheduler
        self.table = table
        self.num_models = num_models or table.num_models
        self.noise_cov = service_noise_cov
        self.model_map = list(model_map) if model_map is not None else None
        self.rng = np.random.default_rng(seed ^ 0x5EED)
        self.drain_cap = drain_cap
        self.drift = drift
        self.adapt = adapt
        self.tracer = tracer
        self._seed = seed

    def _exec_row(self, m: int) -> int:
        return self.model_map[m] if self.model_map is not None else m

    def _service_time(self, m: int, e: int, batch: int, t: float = 0.0) -> float:
        base = self.table(self._exec_row(m), e, batch)
        if self.drift is not None:
            base *= self.drift.multiplier(t)
        if self.noise_cov > 0:
            base *= service_noise_multiplier(self.rng, self.noise_cov)
        return base

    def run(
        self,
        arrivals: List[Request],
        horizon: float,
        warmup_tasks: int = 100,
        keep_traces: bool = False,
    ) -> SimResult:
        queues = [ServiceQueue(m) for m in range(self.num_models)]
        completions: List[Completion] = []
        traces: List[ServingTrace] = []
        busy = 0.0
        dropped = 0
        t = 0.0
        next_arrival = 0  # index into the time-sorted arrival list
        n_arr = len(arrivals)
        # The noise stream is re-seeded per run, like drift below: a second
        # run() on the same instance with service_noise_cov > 0 must replay
        # the identical multiplier sequence, not continue the first run's
        # stream (rerun-bitwise determinism; tests/test_simulator.py).
        self.rng = np.random.default_rng(self._seed ^ 0x5EED)
        # Drift is re-seeded per run (not per construction): a model shared
        # across simulators cannot cross-contaminate their streams, and
        # run() stays deterministic under reruns.
        if self.drift is not None:
            self.drift.reset(self._seed ^ 0xD21F)
        # Online adaptation: the profiler adapts the *scheduler's* belief
        # (which may be a restricted view); the execution table stays the
        # ground truth. The original belief is restored on exit so run()
        # stays rerunnable / sweep cells hermetic.
        profiler = make_profiler(self.scheduler.table, self.adapt)
        static_table = self.scheduler.table
        # Telemetry is record-only: every branch below guards on the tracer
        # and only ever appends to its lists, so decisions / RNG draws /
        # metrics are bitwise identical with or without one attached.
        tracer = self.tracer
        if tracer is not None:
            tracer.reset()  # rerun-determinism, like the RNG re-seed above
        slo = self.scheduler.config.slo

        def ingest(upto: float) -> int:
            nonlocal next_arrival
            while next_arrival < n_arr and arrivals[next_arrival].arrival <= upto:
                r = arrivals[next_arrival]
                queues[r.model].push(r)
                next_arrival += 1
            return next_arrival

        while True:
            ingest(t)
            snapshot = QueueSnapshot.take(queues, t)
            shed = self.scheduler.prune(snapshot)
            if shed:
                n_shed = 0
                for m, n in shed:
                    popped = queues[m].pop_batch(n)
                    n_shed += len(popped)
                    if tracer is not None:
                        for req in popped:
                            tracer.record_drop(req, t, slo)
                dropped += n_shed
                if profiler is not None:
                    profiler.observe_dropped(n_shed)
                if tracer is not None and n_shed:
                    tracer.record_event(t, "shed", n=n_shed)
                snapshot = QueueSnapshot.take(queues, t)
            decision = self.scheduler.decide(snapshot)

            if decision is None:
                # Idle: sleep until the scheduler's requested wake or the
                # next arrival, whichever is earlier.
                wake = None
                if hasattr(self.scheduler, "next_wake"):
                    wake = self.scheduler.next_wake(snapshot)
                next_t = arrivals[next_arrival].arrival if next_arrival < n_arr else None
                candidates = [x for x in (wake, next_t) if x is not None]
                if not candidates:
                    break  # no work will ever appear again
                # Strict progress: a fixed epsilon falls below half a
                # float64 ulp once t >= 16384 s (e.g. trace replay with
                # wall-clock offsets) and the loop spins forever on a
                # scheduler whose next_wake keeps returning the same
                # instant; one-ulp advance makes progress at any magnitude.
                t = np.nextafter(max(t, min(candidates)), np.inf)
                if t > horizon + self.drain_cap:
                    break
                continue

            service = self._service_time(decision.model, decision.exit_idx,
                                         decision.batch_size, t)
            batch = queues[decision.model].pop_batch(decision.batch_size)
            assert len(batch) == decision.batch_size, "scheduler overdrew queue"
            t_end = t + service
            busy += service
            for req in batch:
                completions.append(
                    Completion(
                        req_id=req.req_id,
                        model=req.model,
                        arrival=req.arrival,
                        dispatch=t,
                        finish=t_end,
                        exit_idx=decision.exit_idx,
                        batch_size=decision.batch_size,
                        deadline=req.deadline,
                    )
                )
            if tracer is not None:
                tracer.record_decision(
                    t, decision, t_end,
                    tuple(snapshot.qlens()),
                    tuple(snapshot.w_max(m) for m in range(self.num_models)),
                    margin=decision_margin(self.scheduler, snapshot),
                )
                for req in batch:
                    tracer.record_completion(
                        req, t, t_end, decision.exit_idx,
                        decision.batch_size, slo)
            if profiler is not None:
                refreshed = profiler.ingest_quantum(
                    decision.model, decision.exit_idx, decision.batch_size,
                    service, t_end, batch, self.scheduler.config.slo)
                if refreshed is not None:
                    self.scheduler.table = refreshed
                    if tracer is not None:
                        tracer.record_refresh(t_end, profiler)
            if keep_traces:
                traces.append(
                    ServingTrace(t, t_end, decision, tuple(snapshot.qlens()))
                )
            t = t_end
            if t > horizon + self.drain_cap:
                break

        adapted = None
        if profiler is not None:
            adapted = profiler.materialize()
            self.scheduler.table = static_table  # hermetic: rerunnable cell
        residual = sum(len(q) for q in queues) + (n_arr - next_arrival)
        span = max(t, horizon)
        metrics = summarize(
            completions,
            self.table,
            self.scheduler.config.slo,
            warmup_tasks=warmup_tasks,
            busy_time=busy,
            span=span,
            residual_queue=residual,
            model_map=self.model_map,
            dropped=dropped,
        )
        trace = None
        if tracer is not None:
            # Never served (still queued at run end, or never ingested):
            # device=-1 throughout — a residual was never assigned a
            # quantum, and the scan engine reconstructs the same spans.
            for q in queues:
                for req in q.pending():
                    tracer.record_residual(req, slo, device=-1)
            for req in arrivals[next_arrival:]:
                tracer.record_residual(req, slo, device=-1)
            trace = tracer.freeze(
                engine="python", num_models=self.num_models, num_devices=1,
                slo=slo, horizon=horizon, span=span,
                warmup_used=metrics.warmup_used, n_arrivals=n_arr)
        return SimResult(metrics, completions, traces, span,
                         adapted_table=adapted, trace=trace)


def run_experiment(
    scheduler: Scheduler,
    table: ProfileTable,
    rates: Sequence[float],
    horizon: float = 20.0,
    seed: int = 0,
    warmup_tasks: int = 100,
    service_noise_cov: float = 0.0,
    model_map: Optional[Sequence[int]] = None,
    keep_traces: bool = False,
    process: Optional[object] = None,
    drift: Optional[DriftModel] = None,
    adapt: Optional[AdaptConfig] = None,
    tracer: Optional[Tracer] = None,
) -> SimResult:
    """One full serving experiment: arrivals -> simulate -> metrics.

    ``process`` is an optional ``repro.core.workloads.ArrivalProcess``; the
    default is the paper's stationary Poisson traffic at ``rates``.
    ``drift`` / ``adapt`` / ``tracer`` thread straight into
    :class:`ServingSimulator` (device drift on true service times / online
    profile adaptation / record-only telemetry).
    """
    if process is not None:
        arrivals = process.generate(horizon, seed=seed)
    else:
        arrivals = poisson_arrivals(rates, horizon, seed=seed)
    sim = ServingSimulator(
        scheduler,
        table,
        num_models=len(rates),
        service_noise_cov=service_noise_cov,
        model_map=model_map,
        seed=seed,
        drift=drift,
        adapt=adapt,
        tracer=tracer,
    )
    return sim.run(arrivals, horizon, warmup_tasks=warmup_tasks,
                   keep_traces=keep_traces)
