"""Online profile adaptation under device drift (beyond paper Sec. IV).

The paper measures the 120-cell L(m, e, B) table once, offline, and assumes
it stays valid for the whole serving session (Sec. IV-B: "profiled latency
is runtime latency", CoV < 3%). Real edge devices drift: thermal throttling
ramps service times up over minutes, DVFS governors step clock speeds,
co-located workloads inject contention bursts — exactly the variability
that breaks static latency estimates in Adaptive Scheduling for
Edge-Assisted DNN Serving (He et al.) and that BCEdge (Zhang et al.)
answers with runtime-adaptive profiling. This module closes that gap with
three pieces, threaded end to end through the simulator
(``repro.core.simulator``), the cluster (``repro.core.cluster``), the sweep
harness (``repro.core.sweep``), and the live engine
(``repro.runtime.server``):

  * :class:`OnlineProfiler` — maintains per-(m, e, B) EWMA-mean and
    streaming-P95 service-time estimates from observed batch completions and
    materialises refreshed :class:`~repro.core.profile.ProfileTable` views
    on a configurable cadence, so ``ProfileTable.measure`` becomes the
    *cold start* rather than the whole story.
  * The :class:`DriftModel` family — seed-deterministic ground-truth
    multipliers on *true* service time (thermal-throttle ramp, DVFS step
    change, contention interference bursts) so the execution environment
    can diverge from the table the scheduler decides with.
  * :class:`SafetyController` — adjusts the table's safety multiplier from
    observed violation headroom (the adaptive twin of the static
    ``ProfileTable.with_safety`` knob).

With drift and adaptation both disabled the serving stack is bitwise
unchanged (tested in ``tests/test_adaptive.py``); the static-vs-adaptive
study is ``benchmarks/fig15_drift.py``. See docs/architecture.md
"Paper → code map" and docs/runtime.md "Online adaptation".
"""

from __future__ import annotations

import bisect
import dataclasses
from collections import deque
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.profile import ProfileTable

__all__ = [
    "AdaptConfig",
    "ContentionDrift",
    "DriftModel",
    "DRIFTS",
    "DVFSStepDrift",
    "OnlineProfiler",
    "SafetyController",
    "ThermalThrottleDrift",
    "make_drift",
    "make_profiler",
]


# ---------------------------------------------------------------------------
# Drift models: ground-truth service-time multipliers
# ---------------------------------------------------------------------------


class DriftModel:
    """Seed-deterministic multiplier on true service time at time ``t``.

    The simulator/cluster apply ``multiplier(t)`` to the execution table's
    latency at each quantum's dispatch time — the *scheduler* never sees it
    directly; it can only observe the inflated completions (which is what
    :class:`OnlineProfiler` adapts from). ``multiplier`` must be a
    deterministic function of ``(seed, t)`` regardless of query order, so
    sweeps stay parallel ≡ serial bitwise.
    """

    name = "base"

    def reset(self, seed: int = 0) -> None:
        """Re-seed any internal randomness; deterministic models no-op."""

    def multiplier(self, t: float) -> float:
        """True-service multiplier at wall-clock time ``t`` (≥ some ε > 0)."""
        raise NotImplementedError


class ThermalThrottleDrift(DriftModel):
    """Thermal-throttle ramp: 1.0 until ``onset``, then a linear ramp to
    ``peak`` over ``ramp`` seconds, flat afterwards (sustained-load edge
    boards; cf. He et al. Sec. II measurement of Jetson throttling)."""

    name = "thermal-throttle"

    def __init__(self, onset: float = 2.0, ramp: float = 3.0,
                 peak: float = 2.0):
        assert ramp > 0 and peak > 0
        self.onset = float(onset)
        self.ramp = float(ramp)
        self.peak = float(peak)

    def multiplier(self, t: float) -> float:
        if t <= self.onset:
            return 1.0
        frac = min((t - self.onset) / self.ramp, 1.0)
        return 1.0 + (self.peak - 1.0) * frac


class DVFSStepDrift(DriftModel):
    """DVFS step changes: piecewise-constant multiplier, 1.0 before the
    first step; each ``(time, factor)`` step holds until the next."""

    name = "dvfs-step"

    def __init__(self, steps: Tuple[Tuple[float, float], ...] = ((3.0, 1.6),)):
        steps = tuple((float(t), float(f)) for t, f in steps)
        assert all(f > 0 for _, f in steps)
        self.steps = tuple(sorted(steps))
        self._times = [t for t, _ in self.steps]

    def multiplier(self, t: float) -> float:
        i = bisect.bisect_right(self._times, t)
        return 1.0 if i == 0 else self.steps[i - 1][1]


class ContentionDrift(DriftModel):
    """Co-located contention: seed-deterministic interference bursts.

    Burst start gaps are exponential with mean ``1 / burst_rate``; each
    burst lasts ``burst_duration`` seconds and multiplies service time by
    ``magnitude``. Windows are generated lazily from the seeded RNG in time
    order and cached, so ``multiplier(t)`` is a pure function of
    ``(seed, t)`` no matter the query order.
    """

    name = "contention"

    def __init__(self, burst_rate: float = 0.25, burst_duration: float = 1.0,
                 magnitude: float = 2.0, seed: int = 0):
        assert burst_rate > 0 and burst_duration > 0 and magnitude > 0
        self.burst_rate = float(burst_rate)
        self.burst_duration = float(burst_duration)
        self.magnitude = float(magnitude)
        self.reset(seed)

    def reset(self, seed: int = 0) -> None:
        self._rng = np.random.default_rng(seed ^ 0xD21F7)
        self._starts: list = []   # burst start times, ascending
        self._frontier = 0.0      # windows generated up to here

    def _extend(self, upto: float) -> None:
        while self._frontier <= upto:
            gap = float(self._rng.exponential(1.0 / self.burst_rate))
            start = self._frontier + gap
            self._starts.append(start)
            self._frontier = start + self.burst_duration
        # ``_frontier`` always sits at the end of the last generated burst,
        # so every t below it is classified from cached windows only.

    def multiplier(self, t: float) -> float:
        self._extend(t)
        i = bisect.bisect_right(self._starts, t)
        if i and t < self._starts[i - 1] + self.burst_duration:
            return self.magnitude
        return 1.0


DRIFTS: Dict[str, Callable[..., DriftModel]] = {
    "thermal-throttle": ThermalThrottleDrift,
    "dvfs-step": DVFSStepDrift,
    "contention": ContentionDrift,
}


def make_drift(name: Optional[str], **kwargs) -> Optional[DriftModel]:
    """Drift-model factory (the drift twin of ``make_scheduler``).

    ``None`` / ``"none"`` return ``None`` — the stock, drift-free serving
    path, guaranteed bitwise-identical to the pre-adaptation code.
    """
    if name is None or name == "none":
        assert not kwargs, "drift kwargs given without a drift model"
        return None
    try:
        cls = DRIFTS[name]
    except KeyError:
        raise ValueError(
            f"unknown drift model {name!r}; available: "
            f"{sorted(DRIFTS) + ['none']}"
        ) from None
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# Safety controller: violation-headroom feedback on the safety multiplier
# ---------------------------------------------------------------------------


class SafetyController:
    """Adaptive safety multiplier from observed violation headroom.

    The static path picks a fixed P95-style multiplier once
    (``ProfileTable.with_safety``, ``from_roofline(safety=...)``); this
    controller closes the loop instead: it tracks an EWMA of the violation
    indicator over completed requests and nudges the multiplier up
    (multiplicative increase, capped at ``max_mult``) while violations run
    above ``target``, decaying it back toward ``min_mult`` when observed
    headroom shows the table is already conservative enough. Deterministic:
    the multiplier is a pure fold over the observation stream.
    """

    def __init__(self, target: float = 0.01, alpha: float = 0.05,
                 up: float = 1.02, down: float = 1.005,
                 min_mult: float = 1.0, max_mult: float = 1.5):
        assert 0 < alpha <= 1 and up >= 1 and down >= 1
        assert 0 < min_mult <= max_mult
        self.target = float(target)
        self.alpha = float(alpha)
        self.up = float(up)
        self.down = float(down)
        self.min_mult = float(min_mult)
        self.max_mult = float(max_mult)
        self.multiplier = float(min_mult)
        self.violation_ewma = 0.0
        self.num_observed = 0

    def observe(self, latency: float, deadline: float) -> None:
        """Fold one completion's (total latency, effective deadline) in."""
        self._fold(latency > deadline)

    def observe_violation(self) -> None:
        """Fold one certain violation (a shed/dropped request — the metrics
        layer counts every drop as a violation, so the controller must)."""
        self._fold(True)

    def _fold(self, late: bool) -> None:
        self.violation_ewma += self.alpha * (
            (1.0 if late else 0.0) - self.violation_ewma)
        self.num_observed += 1
        if self.violation_ewma > self.target:
            self.multiplier = min(self.multiplier * self.up, self.max_mult)
        elif self.violation_ewma < 0.5 * self.target:
            self.multiplier = max(self.multiplier / self.down, self.min_mult)


# ---------------------------------------------------------------------------
# Online profiler: streaming per-cell estimates -> refreshed ProfileTables
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    """Knobs for online profile adaptation (hashable: rides in SweepSpec).

    Attributes:
      alpha:         EWMA smoothing for the per-cell mean and the global
                     drift-ratio estimate.
      window:        streaming-P95 window (samples kept per (m, e, B) cell).
      refresh_every: cadence (seconds of serving time) between materialised
                     table refreshes handed to the scheduler.
      mode:          which estimate the refreshed table carries per observed
                     cell: ``"p95"`` (window percentile; the paper's offline
                     profiler records P95 too) or ``"mean"`` (EWMA).
      min_samples:   observations a cell needs before its estimate replaces
                     the cold-start value.
      propagate:     scale *unobserved* cells by the global EWMA drift ratio
                     (observed / cold-start); device-wide drift like thermal
                     throttling then reaches cells the scheduler rarely runs.
      safety:        enable the :class:`SafetyController` feedback loop on
                     the materialised table's safety multiplier.
      safety_target: the controller's violation-rate setpoint.
    """

    alpha: float = 0.25
    window: int = 64
    refresh_every: float = 0.5
    mode: str = "p95"
    min_samples: int = 3
    propagate: bool = True
    safety: bool = False
    safety_target: float = 0.01


class OnlineProfiler:
    """Streaming per-(m, e, B) service-time estimator over a cold-start table.

    ``observe`` folds each completed quantum's measured service time into a
    per-cell EWMA mean and a bounded last-``window`` sample buffer (the
    streaming P95); ``materialize`` renders the current belief as a fresh
    :class:`ProfileTable` (estimates where a cell has ≥ ``min_samples``
    observations, drift-ratio-propagated cold-start values elsewhere, batch
    monotonicity re-enforced exactly like ``ProfileTable.measure``);
    ``maybe_refresh`` rate-limits materialisation to ``refresh_every``
    seconds of serving time. This is the runtime-adaptive profiling loop of
    BCEdge grafted onto the paper's Sec. IV-B offline profiler: the offline
    table is the cold start, observations take over cell by cell.
    """

    def __init__(self, base: ProfileTable, config: AdaptConfig = AdaptConfig()):
        assert config.mode in ("p95", "mean"), config.mode
        assert 0 < config.alpha <= 1 and config.window >= 1
        assert config.refresh_every > 0 and config.min_samples >= 1
        self.base = base
        self.config = config
        shape = base.latency.shape
        self._count = np.zeros(shape, dtype=np.int64)
        self._ewma = np.zeros(shape, dtype=np.float64)
        self._windows: Dict[Tuple[int, int, int], deque] = {}
        self._ratio: Optional[float] = None  # global EWMA of observed/base
        self._last_refresh = 0.0
        self._dirty = False
        self.safety = (
            SafetyController(target=config.safety_target)
            if config.safety else None
        )

    # -- ingestion -----------------------------------------------------------

    def _cell(self, m: int, e: int, batch: int) -> Tuple[int, int, int]:
        b_idx = int(np.searchsorted(self.base.batch_sizes, batch))
        return m, e, min(b_idx, len(self.base.batch_sizes) - 1)

    def observe(self, m: int, e: int, batch: int, service: float,
                now: float) -> None:
        """Fold one quantum's measured service time (seconds) into the
        (m, e, batch) cell's estimators at serving time ``now``."""
        assert service > 0, "service times must be positive"
        cell = self._cell(m, e, batch)
        a = self.config.alpha
        if self._count[cell] == 0:
            self._ewma[cell] = service
        else:
            self._ewma[cell] += a * (service - self._ewma[cell])
        self._count[cell] += 1
        win = self._windows.get(cell)
        if win is None:
            win = self._windows[cell] = deque(maxlen=self.config.window)
        win.append(service)
        ratio = service / float(self.base.latency[cell])
        self._ratio = (
            ratio if self._ratio is None
            else self._ratio + a * (ratio - self._ratio)
        )
        self._dirty = True

    def observe_latency(self, latency: float, deadline: float) -> None:
        """Feed one completion's end-to-end latency vs its effective
        deadline to the safety controller (no-op when safety is off)."""
        if self.safety is not None:
            self.safety.observe(latency, deadline)

    def observe_dropped(self, n: int) -> None:
        """Feed ``n`` shed requests to the safety controller as certain
        violations, keeping its stream consistent with ``summarize()``'s
        ``(late + dropped) / (done + dropped)`` accounting (no-op when
        safety is off)."""
        if self.safety is not None:
            for _ in range(int(n)):
                self.safety.observe_violation()

    def ingest_quantum(self, m: int, e: int, batch_size: int, service: float,
                       now: float, batch, default_slo: float
                       ) -> Optional[ProfileTable]:
        """The one per-quantum feedback step shared by the simulator, the
        cluster, and the live engine: fold the (m, e, B) service sample in
        (skipped if the measured service rounds to ≤ 0 — possible under a
        coarse live clock), feed each served request's latency-vs-deadline
        to the safety controller, and return the cadence-gated refreshed
        table for the caller to swap into its scheduler (``None`` = keep).
        ``batch`` is the list of served Requests; ``default_slo`` fills in
        for requests without a per-request deadline."""
        if service > 0:
            self.observe(m, e, batch_size, service, now)
        if self.safety is not None:
            for req in batch:
                self.safety.observe(
                    now - req.arrival,
                    default_slo if req.deadline is None else req.deadline)
        return self.maybe_refresh(now)

    # -- inspection ----------------------------------------------------------

    @property
    def num_observations(self) -> int:
        return int(self._count.sum())

    @property
    def drift_ratio(self) -> float:
        """Global EWMA of observed / cold-start service time (1.0 = no
        drift seen yet)."""
        return 1.0 if self._ratio is None else float(self._ratio)

    def cell_stats(self, m: int, e: int, batch: int
                   ) -> Tuple[int, float, float]:
        """(count, EWMA mean, window P95) for one (m, e, batch) cell;
        estimates are 0.0 until the cell has been observed."""
        cell = self._cell(m, e, batch)
        n = int(self._count[cell])
        if n == 0:
            return 0, 0.0, 0.0
        p95 = float(np.percentile(np.asarray(self._windows[cell]), 95.0))
        return n, float(self._ewma[cell]), p95

    # -- materialisation -----------------------------------------------------

    def materialize(self) -> ProfileTable:
        """Render the current belief as a fresh :class:`ProfileTable`.

        Cells with ≥ ``min_samples`` observations carry their streaming
        estimate (``mode``); the rest keep the cold-start value, scaled by
        the global drift ratio when ``propagate`` is on. Batch monotonicity
        is re-enforced (``np.maximum.accumulate``, as in
        ``ProfileTable.measure``) and the safety controller's multiplier is
        applied last.
        """
        cfg = self.config
        lat = self.base.latency.copy()
        if cfg.propagate and self._ratio is not None:
            lat *= self._ratio
        seen = self._count >= cfg.min_samples
        if cfg.mode == "mean":
            lat[seen] = self._ewma[seen]
        else:
            for cell, win in self._windows.items():
                if seen[cell]:
                    lat[cell] = np.percentile(np.asarray(win), 95.0)
        lat = np.maximum.accumulate(lat, axis=2)
        table = dataclasses.replace(
            self.base, latency=lat,
            meta={**self.base.meta, "builder": "online",
                  "observations": self.num_observations,
                  "drift_ratio": self.drift_ratio},
        )
        if self.safety is not None and self.safety.multiplier > 1.0:
            table = table.with_safety(self.safety.multiplier)
        return table

    def maybe_refresh(self, now: float) -> Optional[ProfileTable]:
        """Materialise a refreshed table iff ``refresh_every`` seconds of
        serving time have passed since the last refresh *and* new
        observations arrived; else ``None`` (the scheduler keeps its
        current table)."""
        if not self._dirty or now - self._last_refresh < self.config.refresh_every:
            return None
        self._last_refresh = now
        self._dirty = False
        return self.materialize()


def make_profiler(base: ProfileTable,
                  config: Optional[AdaptConfig]) -> Optional[OnlineProfiler]:
    """Build an :class:`OnlineProfiler` from an :class:`AdaptConfig`
    (``None`` config = adaptation off; the stock static-table path)."""
    return None if config is None else OnlineProfiler(base, config)
