"""Parallel sweep harness for serving experiments.

Every paper figure is a grid sweep — (policy × scenario × seed × rate) —
and until now every ``benchmarks/fig*.py`` ran it single-process, one
simulation at a time. :class:`SweepRunner` fans the grid across worker
processes while guaranteeing that **parallel results are bitwise-identical
to serial**:

  * each grid cell is hermetic: the arrival trace, the scheduler, and the
    simulator's noise stream are all re-seeded inside the cell from the
    cell's own :class:`SweepSpec` (no shared PRNG stream whose consumption
    order could depend on scheduling);
  * results are returned in grid order regardless of completion order;
  * workers are plain ``ProcessPoolExecutor`` processes using the ``spawn``
    start method (fork-safety: the parent may hold live JAX/XLA threads).

``ServingMetrics`` is a frozen dataclass of floats/ints/tuples, so
"bitwise-identical" is checked with plain ``==`` (asserted in
``tests/test_sweep.py``).

Typical use (see ``benchmarks/common.sweep_rows`` for the benchmark glue)::

    runner = SweepRunner(ProfileTable.paper_rtx3080())
    specs = runner.grid(policies=("edgeserving", "all-final"),
                        scenarios=("poisson", "mmpp"),
                        rates=(100.0, 200.0), seeds=(7,))
    results = runner.run(specs, workers=8)   # == runner.run(specs, workers=1)
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import itertools
import multiprocessing
import os
import time
from typing import List, Optional, Sequence, Tuple

from repro.core.adaptive import AdaptConfig, make_drift
from repro.core.baselines import make_scheduler
from repro.core.cluster import ClusterSimulator, make_dispatcher, make_fleet
from repro.core.metrics import ServingMetrics
from repro.core.profile import ProfileTable
from repro.core.scheduler import SchedulerConfig
from repro.core.simulator import ServingSimulator
from repro.core.telemetry import Trace, Tracer
from repro.core.traffic import paper_rate_vector
from repro.core.workloads import make_scenario

__all__ = ["SweepSpec", "SweepResult", "SweepRunner"]


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One hermetic grid cell: everything that varies across a sweep.

    ``rate`` is the paper's scalar traffic intensity (λ₁₅₂), expanded through
    ``paper_rate_vector``; pass an explicit per-model ``rates`` tuple to
    override. ``scenario`` names a ``repro.core.workloads.SCENARIOS`` entry;
    ``scenario_kwargs`` (a tuple of (key, value) pairs, to stay hashable)
    parameterises it. ``deadlines`` is an optional per-model SLO vector.
    ``backend`` selects the stability-score scoring engine
    (``repro.core.scoring``: numpy / jnp / pallas / pallas-interpret) for
    the cell's Algorithm-1 schedulers — cluster cells pass it to every
    per-device scheduler — so a whole sweep or fleet can run accelerated
    scoring with one field.

    Cluster cells: setting ``fleet`` (a ``repro.core.cluster.FLEETS`` name)
    switches the cell from the single-device simulator to a
    :class:`ClusterSimulator` of ``fleet_size`` devices built from the
    runner's table, routed by ``dispatcher``; ``fail_at`` is an optional
    ``((device, time), ...)`` failure schedule. All fields stay hashable /
    picklable, so cluster grids fan across workers with the same
    parallel ≡ serial bitwise guarantee.

    Drift / adaptation (``repro.core.adaptive``): ``drift`` names a
    ``DRIFTS`` model (or ``"none"``) applied to true service times —
    every device of a cluster cell gets its own instance, independently
    re-seeded — with ``drift_kwargs`` as hashable (key, value) pairs;
    ``adapt`` is an optional :class:`AdaptConfig` switching the cell's
    scheduler(s) from the static cold-start table to online-profiled
    refreshes. Both default to off, which is bitwise the stock cell.

    ``engine`` selects the simulation engine: ``"python"`` (default) is the
    reference event loop in ``repro.core.simulator``; ``"scan"`` runs the
    cell through the compiled ``jax.lax.scan`` fast path
    (``repro.core.simfast``), decision-equivalent for stock Poisson +
    greedy/lattice cells and loudly ``ScanEngineUnsupported`` for
    everything the compiled state layout cannot express (fleets, drift,
    adaptation, service noise, trace replay, non-numpy scoring backends,
    non-whitelisted policies).
    """

    policy: str
    scenario: str = "poisson"
    rate: float = 100.0
    seed: int = 7
    slo: float = 0.050
    max_batch: int = 10
    horizon: float = 10.0
    warmup_tasks: int = 100
    rates: Optional[Tuple[float, ...]] = None
    deadlines: Optional[Tuple[float, ...]] = None
    scenario_kwargs: Tuple[Tuple[str, object], ...] = ()
    label: str = ""
    fleet: Optional[str] = None          # None = single-device cell
    fleet_size: int = 1
    dispatcher: str = "least-loaded"
    power_d: int = 2                     # stability-aware power-of-d fan-in
    fail_at: Tuple[Tuple[int, float], ...] = ()
    backend: str = "numpy"
    drift: Optional[str] = None          # DRIFTS name; None/"none" = stock
    drift_kwargs: Tuple[Tuple[str, object], ...] = ()
    adapt: Optional[AdaptConfig] = None  # None = static scheduler table
    engine: str = "python"               # "python" | "scan" (compiled run)
    trace: bool = False                  # attach a telemetry Tracer
                                         # (record-only; decisions/metrics
                                         # stay bitwise-identical)

    def rate_vector(self) -> List[float]:
        if self.rates is not None:
            return list(self.rates)
        return paper_rate_vector(self.rate)

    def title(self) -> str:
        if self.label:
            return self.label
        policy = self.policy
        if self.backend != "numpy":
            policy = f"{policy}[{self.backend}]"
        if self.engine != "python":
            policy = f"{policy}[{self.engine}]"
        base = f"{policy}/{self.scenario}/lam{self.rate:g}/seed{self.seed}"
        if self.drift is not None and self.drift != "none":
            base = f"{base}/drift-{self.drift}"
        if self.adapt is not None:
            base = f"{base}/adapt"
        if self.fleet is not None:
            base = f"{self.dispatcher}/{self.fleet}x{self.fleet_size}/{base}"
        return base


@dataclasses.dataclass(frozen=True)
class SweepResult:
    spec: SweepSpec
    metrics: ServingMetrics
    us_per_call: float  # wall microseconds spent on this cell (in its worker)
    trace: Optional[Trace] = None  # telemetry timeline (spec.trace=True)


def _run_cell(runner: "SweepRunner", spec: SweepSpec) -> SweepResult:
    """Module-level trampoline so the pool can pickle the call."""
    return runner.run_cell(spec)


class SweepRunner:
    """Fans a sweep grid across processes; serial ≡ parallel, bitwise.

    The runner holds the per-sweep invariants (execution table, optional
    restricted scheduler table, deployment map, service-noise CoV); the
    :class:`SweepSpec` holds everything that varies cell to cell. Both are
    picklable, which is the only requirement for the process fan-out.
    """

    def __init__(
        self,
        table: ProfileTable,
        sched_table: Optional[ProfileTable] = None,
        model_map: Optional[Sequence[int]] = None,
        service_noise_cov: float = 0.0,
        data_pool: int = 10_000,
    ):
        self.table = table
        self.sched_table = sched_table
        self.model_map = list(model_map) if model_map is not None else None
        self.service_noise_cov = service_noise_cov
        self.data_pool = data_pool

    # -- grid construction ---------------------------------------------------

    def grid(
        self,
        policies: Sequence[str],
        scenarios: Sequence[str] = ("poisson",),
        rates: Sequence[float] = (100.0,),
        seeds: Sequence[int] = (7,),
        **common,
    ) -> List[SweepSpec]:
        """The full (policy × scenario × rate × seed) product, in that
        nesting order; ``common`` fixes the remaining SweepSpec fields.

        Policies sharing a (scenario, rate, seed) cell see identical arrival
        traces — sweeps are paired comparisons by construction.
        """
        return [
            SweepSpec(policy=p, scenario=sc, rate=r, seed=s, **common)
            for p, sc, r, s in itertools.product(policies, scenarios, rates, seeds)
        ]

    def cluster_grid(
        self,
        dispatchers: Sequence[str],
        fleets: Sequence[Tuple[str, int]],
        scenarios: Sequence[str] = ("poisson",),
        rates: Sequence[float] = (100.0,),
        seeds: Sequence[int] = (7,),
        policy: str = "edgeserving",
        **common,
    ) -> List[SweepSpec]:
        """The (dispatcher × fleet × scenario × rate × seed) cluster product,
        dispatcher-major; ``fleets`` are ``(FLEETS name, size)`` pairs.
        Dispatchers sharing a (fleet, scenario, rate, seed) cell see
        identical arrival traces — paired comparisons by construction.
        """
        return [
            SweepSpec(policy=policy, dispatcher=dp, fleet=fl, fleet_size=fs,
                      scenario=sc, rate=r, seed=s, **common)
            for dp, (fl, fs), sc, r, s in itertools.product(
                dispatchers, fleets, scenarios, rates, seeds)
        ]

    # -- execution -----------------------------------------------------------

    def run_cell(self, spec: SweepSpec) -> SweepResult:
        """One serving experiment, fully determined by (runner, spec)."""
        t0 = time.perf_counter()
        rates = spec.rate_vector()
        cfg = SchedulerConfig(slo=spec.slo, max_batch=spec.max_batch,
                              backend=spec.backend)
        if spec.engine == "scan":
            return self._run_cell_scan(spec, rates, cfg, t0)
        if spec.engine != "python":
            raise ValueError(
                f"unknown SweepSpec.engine {spec.engine!r}; "
                f"expected 'python' or 'scan'"
            )
        process = make_scenario(
            spec.scenario, rates, deadlines=spec.deadlines,
            **dict(spec.scenario_kwargs),
        )
        arrivals = process.generate(
            spec.horizon, seed=spec.seed, data_pool=self.data_pool
        )
        tracer = Tracer() if spec.trace else None
        if spec.fleet is not None:
            if self.sched_table is not None or self.model_map is not None:
                raise NotImplementedError(
                    "cluster cells build per-device schedulers from the "
                    "fleet's own tables; a runner-level sched_table / "
                    "model_map would be silently ignored — use a "
                    "fleet-less spec or encode the view in the fleet's "
                    "DeviceSpecs via ClusterSimulator directly"
                )
            # One drift instance per device (burst caches are per-instance);
            # ClusterSimulator re-seeds each from (seed, device id).
            fleet_drift = tuple(
                (d, make_drift(spec.drift, **dict(spec.drift_kwargs)))
                for d in range(spec.fleet_size)
            ) if spec.drift not in (None, "none") else ()
            sim = ClusterSimulator(
                make_fleet(spec.fleet, spec.fleet_size, self.table,
                           fail_at=spec.fail_at, drift=fleet_drift),
                policy=spec.policy,
                config=cfg,
                dispatcher=make_dispatcher(spec.dispatcher, slo=spec.slo,
                                           power_d=spec.power_d),
                num_models=len(rates),
                service_noise_cov=self.service_noise_cov,
                seed=spec.seed,
                adapt=spec.adapt,
                tracer=tracer,
            )
            res = sim.run(arrivals, spec.horizon,
                          warmup_tasks=spec.warmup_tasks)
        else:
            if (spec.fail_at or spec.fleet_size != 1
                    or spec.dispatcher != "least-loaded"):
                raise ValueError(
                    "cluster-only SweepSpec fields (fail_at / fleet_size / "
                    "dispatcher) require fleet=<FLEETS name>; a single-device "
                    "cell would silently ignore them"
                )
            sched = make_scheduler(
                spec.policy, self.sched_table or self.table, cfg)
            single = ServingSimulator(
                sched,
                self.table,
                num_models=len(rates),
                service_noise_cov=self.service_noise_cov,
                model_map=self.model_map,
                seed=spec.seed,
                drift=make_drift(spec.drift, **dict(spec.drift_kwargs)),
                adapt=spec.adapt,
                tracer=tracer,
            )
            res = single.run(arrivals, spec.horizon,
                             warmup_tasks=spec.warmup_tasks)
        us = (time.perf_counter() - t0) * 1e6
        return SweepResult(spec, res.metrics, us, trace=res.trace)

    def _run_cell_scan(self, spec: SweepSpec, rates: List[float],
                       cfg: SchedulerConfig, t0: float) -> SweepResult:
        """``engine="scan"``: the cell through the compiled fast path
        (``repro.core.simfast`` for single-device cells,
        ``repro.core.clusterfast`` when ``spec.fleet`` is set). Decision-
        equivalent to the Python engine for the supported configurations;
        everything the scan state layouts cannot express is rejected
        loudly here (or by the engines' own validation) rather than
        approximated."""
        from repro.core.simfast import ScanEngineUnsupported, simulate_scan

        unsupported = []
        if spec.drift not in (None, "none"):
            unsupported.append(f"device drift ({spec.drift})")
        if spec.adapt is not None:
            unsupported.append("online profile adaptation")
        if self.service_noise_cov > 0:
            unsupported.append("service-time noise")
        if spec.scenario == "trace-replay":
            unsupported.append("trace replay")
        if spec.backend != "numpy":
            unsupported.append(f"the {spec.backend!r} scoring backend")
        if unsupported:
            raise ScanEngineUnsupported(
                f"SweepSpec.engine='scan' does not support "
                f"{', '.join(unsupported)}; run this cell with the "
                f"Python engine (engine='python')"
            )
        process = make_scenario(
            spec.scenario, rates, deadlines=spec.deadlines,
            **dict(spec.scenario_kwargs),
        )
        arrivals = process.generate(
            spec.horizon, seed=spec.seed, data_pool=self.data_pool
        )
        if spec.fleet is not None:
            from repro.core.clusterfast import simulate_cluster_scan

            if self.sched_table is not None or self.model_map is not None:
                raise NotImplementedError(
                    "cluster cells build per-device schedulers from the "
                    "fleet's own tables; a runner-level sched_table / "
                    "model_map would be silently ignored — use a "
                    "fleet-less spec or encode the view in the fleet's "
                    "DeviceSpecs via ClusterSimulator directly"
                )
            res = simulate_cluster_scan(
                make_fleet(spec.fleet, spec.fleet_size, self.table,
                           fail_at=spec.fail_at),
                arrivals,
                spec.horizon,
                policy=spec.policy,
                config=cfg,
                dispatcher=spec.dispatcher,
                power_d=spec.power_d,
                num_models=len(rates),
                warmup_tasks=spec.warmup_tasks,
                seed=spec.seed,
                tracer=Tracer() if spec.trace else None,
            )
            us = (time.perf_counter() - t0) * 1e6
            return SweepResult(spec, res.metrics, us, trace=res.trace)
        if (spec.fail_at or spec.fleet_size != 1
                or spec.dispatcher != "least-loaded"):
            raise ValueError(
                "cluster-only SweepSpec fields (fail_at / fleet_size / "
                "dispatcher) require fleet=<FLEETS name>; a single-device "
                "cell would silently ignore them"
            )
        sched = make_scheduler(spec.policy, self.sched_table or self.table, cfg)
        res = simulate_scan(
            sched,
            self.table,
            arrivals,
            spec.horizon,
            num_models=len(rates),
            warmup_tasks=spec.warmup_tasks,
            model_map=self.model_map,
            tracer=Tracer() if spec.trace else None,
        )
        us = (time.perf_counter() - t0) * 1e6
        return SweepResult(spec, res.metrics, us, trace=res.trace)

    def run(
        self, specs: Sequence[SweepSpec], workers: Optional[int] = 1
    ) -> List[SweepResult]:
        """Run the grid; results are in ``specs`` order.

        ``workers=1`` runs serially in-process; ``workers=None`` uses one
        worker per CPU (capped at the grid size). Parallel output is
        bitwise-identical to serial — only ``us_per_call`` (wall timing)
        differs between runs.

        Like any ``spawn``-based multiprocessing client, ``workers > 1``
        needs an importable ``__main__`` (a script or pytest — not a REPL
        heredoc).
        """
        specs = list(specs)
        if not specs:
            return []
        if workers is None:
            workers = os.cpu_count() or 1
        workers = max(1, min(int(workers), len(specs)))
        if workers == 1:
            return [self.run_cell(s) for s in specs]
        # spawn, not fork: the parent typically holds live JAX/XLA threads
        # whose locks a forked child would inherit mid-flight.
        ctx = multiprocessing.get_context("spawn")
        with concurrent.futures.ProcessPoolExecutor(
            max_workers=workers, mp_context=ctx
        ) as pool:
            futures = [pool.submit(_run_cell, self, s) for s in specs]
            return [f.result() for f in futures]
