"""Compiled cluster simulator: G per-device schedulers behind one scan.

``repro.core.cluster.ClusterSimulator`` is a pure-Python global event loop:
fine for one fig14 cell, ~20x too slow for thousand-seed confidence bands.
This module compiles the whole cluster run the way ``repro.core.simfast``
compiled the single-device run: fixed-shape array state, one jitted
``lax.scan`` step per *global event* (failure < arrival < device-round at
equal timestamps, then device id — the reference loop's exact ordering),
``jax.vmap`` across independent lanes (seeds x rates).

State layout (per lane):

  * per-(device, model) FIFO queues become ring buffers ``qarr/qew[G, M, Q]``
    with ``qhead/qlen[G, M]`` cursors — unlike the single-device engine the
    queue contents cannot be a window into the sorted arrival array, because
    the dispatcher interleaves arrivals across devices dynamically and
    failover re-pushes orphans out of arrival order;
  * the arrival stream stays one sorted ``[n]`` array; the carry's ``ai``
    cursor is the reference loop's arrival index;
  * device timers: ``pend[G]`` (next scheduling-round time, ``+inf`` = none),
    ``inq[G]`` (a quantum is in flight), ``alive/done[G]``, ``clock/busy[G]``;
  * one int32 round-robin counter (the only dispatcher state that survives
    compilation — see the dispatcher matrix below).

One scan step processes an *arrival burst* plus at most one round: up to
``K`` consecutive arrivals are dispatched first (compiled dispatcher pick
-> ring push -> one-ulp ``nextafter`` poke; each iteration re-checks that
the next event really is an arrival, so a poked wake-up correctly
interrupts the burst), then — if the next event is a device round — the
earliest pending device runs one Algorithm-1 scheduling round (ingest ->
Eq. 5/6 candidate lattice -> Sec. V-C scoring -> Eq. 7 argmin with the
reference tiebreak -> ring pop, quantum occupancy). Folding arrivals into
the round step is pure batching: every per-event computation is identical
to the one-event-per-step layout, but the [candidates x models x queue]
scoring tensor — the dominant per-step cost — is evaluated once per round
instead of once per event, which is what makes thousand-seed cluster
bands affordable at fig14 arrival rates. The per-round math is the
``simfast`` step re-derived for ring-buffer queues and per-device tables;
scoring uses the same factored-exponential fast path / direct
``lattice_stability_scores`` pair, under the same float64 range gate.

Compiled dispatcher family (`SUPPORTED_DISPATCHERS`):

  * ``round-robin`` — cumsum-rank pick over the eligible mask; the counter
    lives in the carry and (like the reference) does *not* advance when a
    single eligible device short-circuits the pick;
  * ``jsq`` — masked integer argmin of queued counts (ties -> lowest id);
  * ``least-loaded`` — masked argmin of the capacity-weighted backlog: the
    in-flight quantum remainder plus a precomputed ``[G, M, Q+1]``
    ``drain_cell`` table folded left-to-right over models, replaying
    ``drain_estimate``'s accumulation order bit-for-bit;
  * ``stability-aware`` — backlog plus the final-exit unit-batch belief
    ``b1_final[G, M]`` (the monotone shortcut the reference documents), but
    only as a *full scan* (``power_d >= fleet size``): the ``k <
    len(eligible)`` branch draws ``numpy.Generator.choice`` samples that
    have no fixed-shape compiled equivalent, so genuine power-of-d
    subsampling is rejected loudly instead of approximated.

Failure/failover runs as host-segmented barriers: the scan freezes every
lane at the next ``fail_at`` time (events strictly before the barrier
execute; the frozen step is a no-op), the host pulls the carry, kills the
device, re-dispatches its orphans in (arrival, req_id) order through a
numpy mirror of the *identical* pick arithmetic (same IEEE ops, same
tiebreaks, shared round-robin counter via the carry), pushes them into the
rings, and resumes the scan at the next barrier. Queue *identity* (which
request sits where) never enters the carry: the host reconstructs it from
the emitted step codes — pushes and pops per (device, model) are both
chronological, so the k-th pop is the k-th push and completions fall out of
pure order bookkeeping, no re-simulation.

Decisions, ``ServingMetrics`` and completions are **bitwise** equal to the
Python ``ClusterSimulator`` on the supported family (property-tested through
``tests/engine_conformance.py``), and a G=1 fleet collapses bitwise to the
single-device ``simulate_scan`` (closing the PR 3 / PR 6 triangle).

Deliberately unsupported (rejected via :class:`ScanEngineUnsupported`,
never approximated): schedulers outside the Algorithm-1 family, non-numpy
scoring backends, per-device drift / online adaptation / service noise,
power-of-d subsampling (above), heterogeneous exit counts, per-request
deadlines varying within a model, and telemetry tracers (the cluster scan
does not reconstruct cluster timelines — use the Python engine to trace;
see docs/simulator.md "Compiled cluster tier").
"""

from __future__ import annotations

import dataclasses
import functools
import operator
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.baselines import make_scheduler
from repro.core.cluster import (
    DISPATCHERS,
    ClusterResult,
    DeviceSpec,
    drain_cell,
)
from repro.core.metrics import DeviceMetrics, summarize, summarize_arrays
from repro.core.request import Completion, Request
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.simfast import (
    _FACTORED_RANGE,
    _MAX_QUEUE_DEFAULT,
    _Lane,
    _build_ladder,
    _dense_latency,
    _pow2,
    _unpack_lane,
    _validate_scheduler,
    ScanEngineUnsupported,
)
from repro.core.telemetry import Tracer
from repro.core.urgency import lattice_stability_scores
from repro.core.workloads import TraceColumns

__all__ = [
    "SUPPORTED_DISPATCHERS",
    "simulate_cluster_scan",
    "simulate_cluster_scan_batch",
]

SUPPORTED_DISPATCHERS = ("round-robin", "jsq", "least-loaded",
                         "stability-aware")

# Arrivals absorbed per scan step before the (expensive) scoring round.
# Purely a throughput knob: any value produces identical decisions.
_BURST = 8


# ---------------------------------------------------------------------------
# Compiled chunk
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _ClusterKey:
    """Everything that shapes the compiled cluster step (jit-cache key)."""

    num_devices: int
    num_models: int
    num_exits: int
    max_queue: int        # Q: ring capacity per (device, model)
    pad_len: int          # P: padded arrival-stream length
    chunk_steps: int      # S: lax.scan length per launch
    burst: int            # K: arrivals absorbed per step before the round
    max_batch: int
    ladder: Tuple[Tuple[int, ...], ...]
    allowed: Tuple[bool, ...]
    fallback_exit: int
    clip: float
    factored: bool
    dispatcher: str


@functools.lru_cache(maxsize=32)
def _build_cluster_chunk_fn(key: _ClusterKey):
    """Compile one chunk: every lane advances ``chunk_steps`` global events.
    Returns (carry', (code, t)) with ys stacked step-major."""
    G, M, E, Q = (key.num_devices, key.num_models, key.num_exits,
                  key.max_queue)
    ladder = jnp.asarray(np.array(key.ladder, dtype=np.int32))   # [B+1, R]
    R = int(ladder.shape[1])
    N = M * R
    allowed = jnp.asarray(np.array(key.allowed, dtype=bool))     # [E]
    e0 = key.fallback_exit
    clip = key.clip
    Bmax = key.max_batch
    n_idx = jnp.arange(N)
    cand_queue = jnp.repeat(jnp.arange(M), R)                    # [N]
    pos_q = jnp.arange(Q)[None, :]                               # [1, Q]
    IBIG = jnp.iinfo(jnp.int32).max

    def run_chunk(carry, arr_t, arr_m, arr_ew, lat_by_cap, exec_lat,
                  drain_tab, b1_final, tau_vec, place, limit, barrier):
        # carry (one lane):
        #   ai i32; qarr/qew [G, M, Q] f64; qhead/qlen [G, M] i32;
        #   pend [G] f64 (+inf = no round pending); inq/alive/done [G] bool;
        #   clock/busy [G] f64; rr i32; blocked bool; over bool.
        # arr_t/arr_m/arr_ew: [P] arrival stream (time, model, exp(-a/tau)),
        #   +inf / 0 padded. lat_by_cap: [G, M, B+1, E, R]; exec_lat:
        #   [G, M, E, B+1]; drain_tab: [G, M, Q+1] drain_cell lookup;
        #   b1_final: [G, M] final-exit unit-batch belief; place: [G, M]
        #   placement mask; limit = horizon + drain_cap; barrier = next
        #   failure time (+inf on the last segment).

        def arrival_once(ai, qarr, qew, qhead, qlen, pend, inq, alive,
                         done, rr, over):
            """Process the next event iff it is an unfrozen arrival.

            Exact replay of the reference dispatch: compiled dispatcher
            pick -> ring push -> one-ulp ``nextafter`` poke. Re-derives
            ``is_arr`` from the *current* carry, so an earlier poke in the
            same burst correctly hands control back to the round branch.
            """
            t_arr = arr_t[ai]
            mdl = arr_m[ai]
            t_rnd = jnp.min(pend)
            # kind order at equal time: arrival(1) < device-round(2), so the
            # arrival wins ties; failures(0) are the host barriers, which
            # freeze every event with t >= barrier (events *at* the failure
            # time run after it, exactly the reference's (t, kind) order).
            is_arr = t_arr <= t_rnd
            upd_a = is_arr & (t_arr < barrier) & ~over

            elig = jnp.take(place, mdl, axis=1) & alive          # [G]
            n_elig = jnp.sum(elig.astype(jnp.int32))
            any_elig = n_elig > 0
            single = n_elig == 1
            if key.dispatcher in ("least-loaded", "stability-aware"):
                # effective_backlog: quantum remainder + drain_estimate's
                # left-to-right per-model fold (bitwise — see drain_tab).
                remv = jnp.where(inq, jnp.maximum(pend - t_arr, 0.0), 0.0)
                acc = jnp.zeros((G,), jnp.float64)
                for mm in range(M):
                    acc = acc + jnp.take_along_axis(
                        drain_tab[:, mm, :], qlen[:, mm][:, None], axis=1
                    )[:, 0]
                backlog = remv + acc
            if key.dispatcher == "round-robin":
                rank = jnp.cumsum(elig.astype(jnp.int32))
                want = (rr % jnp.maximum(n_elig, 1)) + 1
                pick_multi = jnp.argmax(elig & (rank == want))
            elif key.dispatcher == "jsq":
                qtot = jnp.sum(qlen, axis=1)
                pick_multi = jnp.argmin(jnp.where(elig, qtot, IBIG))
            elif key.dispatcher == "least-loaded":
                pick_multi = jnp.argmin(jnp.where(elig, backlog, jnp.inf))
            else:  # stability-aware as a full scan (power_d >= G)
                pred = backlog + jnp.take(b1_final, mdl, axis=1)
                pick_multi = jnp.argmin(jnp.where(elig, pred, jnp.inf))
            # one eligible device short-circuits the pick (reference
            # `_dispatch`): no argmin, and no round-robin advance.
            d_pick = jnp.where(single, jnp.argmax(elig),
                               pick_multi).astype(jnp.int32)
            if key.dispatcher == "round-robin":
                rr = jnp.where(upd_a & any_elig & ~single, rr + 1, rr)

            do_push = upd_a & any_elig
            len_dm = qlen[d_pick, mdl]
            over = over | (do_push & (len_dm >= Q))
            slot = (qhead[d_pick, mdl] + len_dm) % Q
            qarr = qarr.at[d_pick, mdl, slot].set(
                jnp.where(do_push, t_arr, qarr[d_pick, mdl, slot]))
            qew = qew.at[d_pick, mdl, slot].set(
                jnp.where(do_push, arr_ew[ai], qew[d_pick, mdl, slot]))
            qlen = qlen.at[d_pick, mdl].add(
                jnp.where(do_push, 1, 0).astype(jnp.int32))
            # poke: one-ulp wake unless a quantum is in flight or the device
            # passed the drain cap (eligibility already implies alive).
            can_poke = do_push & ~done[d_pick] & ~inq[d_pick]
            wake = jnp.nextafter(t_arr, jnp.inf)
            pend = pend.at[d_pick].set(
                jnp.where(can_poke, jnp.minimum(pend[d_pick], wake),
                          pend[d_pick]))
            ai = jnp.where(upd_a, ai + 1, ai)
            code = jnp.where(
                upd_a,
                jnp.where(any_elig, -(d_pick + 1), 0),
                1,
            ).astype(jnp.int32)
            return ai, qarr, qew, qlen, pend, rr, over, code, t_arr

        def step(c, _):
            (ai, qarr, qew, qhead, qlen, pend, inq, alive, done,
             clock, busy, rr, blocked, over) = c

            # ---- arrival burst: up to K dispatches before the round ----
            codes_k, ts_k = [], []
            for _k in range(key.burst):
                (ai, qarr, qew, qlen, pend, rr, over, code_k,
                 t_k) = arrival_once(ai, qarr, qew, qhead, qlen, pend, inq,
                                     alive, done, rr, over)
                codes_k.append(code_k)
                ts_k.append(t_k)

            t_arr = arr_t[ai]
            t_rnd = jnp.min(pend)
            d_rnd = jnp.argmin(pend).astype(jnp.int32)
            is_arr = t_arr <= t_rnd
            t_evt = jnp.where(is_arr, t_arr, t_rnd)
            frozen = ~(t_evt < barrier)
            upd_r = ~frozen & ~over & ~is_arr

            # ---- device round: Algorithm 1 on the ring queues ----
            ending = inq[d_rnd]
            pend = pend.at[d_rnd].set(jnp.where(upd_r, jnp.inf,
                                                pend[d_rnd]))
            inq = inq.at[d_rnd].set(jnp.where(upd_r, False, inq[d_rnd]))
            clock = clock.at[d_rnd].set(
                jnp.where(upd_r, jnp.maximum(clock[d_rnd], t_rnd),
                          clock[d_rnd]))
            skip = done[d_rnd] | (ending & ~alive[d_rnd])
            over_cap = t_rnd > limit
            done = done.at[d_rnd].set(
                jnp.where(upd_r & ~skip & over_cap, True, done[d_rnd]))
            sched_on = upd_r & ~skip & ~over_cap

            ql = qlen[d_rnd]                                     # [M]
            qh = qhead[d_rnd]                                    # [M]
            gather = (qh[:, None] + jnp.arange(Q)[None, :]) % Q  # [M, Q]
            warr = jnp.take_along_axis(qarr[d_rnd], gather, axis=1)
            wew = jnp.take_along_axis(qew[d_rnd], gather, axis=1)
            mask_b = pos_q < ql[:, None]                         # [M, Q]
            # w_max is the FIFO head's wait (QueueSnapshot.w_max): after a
            # failover push the ring is no longer arrival-sorted, and the
            # reference reads the head, not the max.
            w_max = jnp.where(ql > 0, t_rnd - warr[:, 0], 0.0)   # [M]
            cap = jnp.minimum(ql, Bmax)
            batches = ladder[cap]                                # [M, R]
            valid = (batches > 0).reshape(-1)                    # [N]
            lat_sel = jnp.take_along_axis(
                lat_by_cap[d_rnd], cap[:, None, None, None], axis=1
            )[:, 0]                                              # [M, E, R]
            e_ax = jnp.arange(E)[None, :, None]
            feas = (
                (w_max[:, None, None] + lat_sel <= tau_vec[:, None, None])
                & allowed[None, :, None]
            )
            deepest = jnp.max(jnp.where(feas, e_ax, -1), axis=1)  # [M, R]
            e_sel = jnp.where(deepest >= 0, deepest, e0)
            lat_cand = jnp.sum(
                jnp.where(e_sel[:, None, :] == e_ax, lat_sel, 0.0), axis=1
            )                                                    # [M, R]
            cand_batch = batches.reshape(-1)
            cand_lat = lat_cand.reshape(-1)
            if key.factored:
                amp = jnp.exp(
                    (t_rnd + cand_lat[:, None]) / tau_vec[None, :] - 1.0
                )                                                # [N, M]
                urg = jnp.where(
                    mask_b[None, :, :],
                    jnp.minimum(amp[:, :, None] * wew[None, :, :], clip),
                    0.0,
                )
                total = jnp.sum(urg, axis=(1, 2))
                own = urg[n_idx, cand_queue, :]
                removed = jnp.sum(
                    jnp.where(pos_q < cand_batch[:, None], own, 0.0), axis=1
                )
                scores = total - removed
            else:
                w = jnp.where(mask_b, t_rnd - warr, 0.0)
                scores = lattice_stability_scores(
                    w, mask_b.astype(jnp.float64), cand_lat, cand_batch,
                    cand_queue, tau_vec[:, None], clip,
                )
            scores_v = jnp.where(valid, scores, jnp.inf)
            best = jnp.min(scores_v)
            wm_c = jnp.repeat(w_max, R)
            tie = valid & (scores_v == best)
            wm_best = jnp.max(jnp.where(tie, wm_c, -jnp.inf))
            pick = jnp.argmax(tie & (wm_c == wm_best))
            has_work = jnp.any(valid)

            m_star = cand_queue[pick].astype(jnp.int32)
            e_star = e_sel.reshape(-1)[pick].astype(jnp.int32)
            b_star = cand_batch[pick]
            service = exec_lat[d_rnd, m_star, e_star, b_star]
            t_end = t_rnd + service
            is_disp = sched_on & has_work
            qhead = qhead.at[d_rnd, m_star].set(
                jnp.where(is_disp, (qh[m_star] + b_star) % Q,
                          qhead[d_rnd, m_star]))
            qlen = qlen.at[d_rnd, m_star].add(
                jnp.where(is_disp, -b_star, 0))
            busy = busy.at[d_rnd].add(jnp.where(is_disp, service, 0.0))
            pend = pend.at[d_rnd].set(
                jnp.where(is_disp, t_end, pend[d_rnd]))
            inq = inq.at[d_rnd].set(jnp.where(is_disp, True, inq[d_rnd]))
            code_r = jnp.where(
                is_disp,
                2 + d_rnd + G * (m_star + M * (e_star + E * b_star)),
                1,
            ).astype(jnp.int32)

            blocked = blocked | frozen | over
            c2 = (ai, qarr, qew, qhead, qlen, pend, inq, alive, done,
                  clock, busy, rr, blocked, over)
            # ys slots are in execution order: K arrival slots, then the
            # round slot; the host parser consumes the flattened stream.
            code_vec = jnp.stack(
                codes_k + [jnp.where(upd_r, code_r, jnp.int32(1))])
            t_vec = jnp.stack(ts_k + [t_evt])
            return c2, (code_vec, t_vec)

        return lax.scan(step, carry, None, length=key.chunk_steps, unroll=2)

    fn = jax.vmap(
        run_chunk,
        in_axes=((0,) * 14, 0, 0, 0, None, None, None, None, None, None,
                 None, None),
    )
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# Host-side mirror: queue identity, failover, reconstruction
# ---------------------------------------------------------------------------


class _LaneParse:
    """Order bookkeeping for one lane, rebuilt from the emitted step codes.

    ``push[d][m]`` / ``pops[d][m]`` are chronological, and the rings are
    FIFO, so the k-th popped request of a (device, model) pair is its k-th
    pushed one — completions are pure position math, never a re-simulation.
    """

    __slots__ = ("ai", "push", "pops", "stranded", "lost", "dispatched")

    def __init__(self, G: int, M: int):
        self.ai = 0
        self.push: List[List[List[np.ndarray]]] = [
            [[] for _ in range(M)] for _ in range(G)]
        self.pops: List[List[List[Tuple[np.ndarray, ...]]]] = [
            [[] for _ in range(M)] for _ in range(G)]
        self.stranded: List[np.ndarray] = []
        self.lost = 0
        self.dispatched = np.zeros(G, dtype=np.int64)

    def pop_total(self, d: int, m: int) -> int:
        return int(sum(int(p[2].sum()) for p in self.pops[d][m]))

    def queued(self, d: int, m: int) -> np.ndarray:
        """Request indices still queued on (d, m), FIFO order."""
        pushed = (np.concatenate(self.push[d][m])
                  if self.push[d][m] else np.empty(0, np.int64))
        return pushed[self.pop_total(d, m):]


def _parse_chunk(ps: _LaneParse, codes: np.ndarray, ts: np.ndarray,
                 G: int, M: int, E: int, arr_model: np.ndarray) -> None:
    """Fold one chunk's (code, t) stream into the lane mirror (vectorised:
    one boolean-mask pass per touched (device, model) pair)."""
    ev = codes != 1
    if not ev.any():
        return
    codes = codes[ev]
    ts = ts[ev]
    is_a = codes <= 0
    ka = int(is_a.sum())
    # arrival events appear in global arrival order: the j-th one of this
    # chunk is request ps.ai + j.
    if ka:
        acodes = codes[is_a]
        gi = ps.ai + np.arange(ka, dtype=np.int64)
        routed = acodes <= -1
        devs = (-(acodes + 1)).astype(np.int64)
        mods = arr_model[gi]
        if routed.any():
            ps.dispatched += np.bincount(devs[routed], minlength=G)
            pair = devs[routed] * M + mods[routed]
            gir = gi[routed]
            for p in np.unique(pair):
                d, m = divmod(int(p), M)
                ps.push[d][m].append(gir[pair == p])
        if (~routed).any():
            ps.stranded.append(gi[~routed])
            ps.lost += int((~routed).sum())
        ps.ai += ka
    rnd = codes >= 2
    if rnd.any():
        v = (codes[rnd] - 2).astype(np.int64)
        d = v % G
        u = v // G
        m = u % M
        e = (u // M) % E
        b = u // (M * E)
        t = ts[rnd]
        pair = d * M + m
        for p in np.unique(pair):
            dd, mm = divmod(int(p), M)
            sel = pair == p
            ps.pops[dd][mm].append((t[sel], e[sel], b[sel]))


def _host_backlog(d: int, t: float, pend: np.ndarray, inq: np.ndarray,
                  qlen: np.ndarray, drain_tab: np.ndarray, M: int) -> float:
    """numpy mirror of the compiled effective_backlog (same IEEE op order)."""
    rem = (max(float(pend[d]) - t, 0.0) if bool(inq[d]) else 0.0)
    acc = 0.0
    for mm in range(M):
        acc = acc + float(drain_tab[d, mm, int(qlen[d, mm])])
    return rem + acc


def _host_fail(ps: _LaneParse, st: dict, d_fail: int, t: float,
               lane: _Lane, ew_lane: np.ndarray, reqid: np.ndarray,
               placement: Sequence[Sequence[int]], dispatcher: str,
               drain_tab: np.ndarray, b1_final: np.ndarray, Q: int,
               M: int) -> bool:
    """Kill ``d_fail`` at barrier time ``t`` and failover its queue through
    the same pick arithmetic the compiled step runs. Mutates the numpy carry
    views in ``st`` and the lane mirror. Returns True on ring overflow
    (caller retries the whole run with a wider ring)."""
    alive, done, inq, pend = st["alive"], st["done"], st["inq"], st["pend"]
    qarr, qew, qhead, qlen = st["qarr"], st["qew"], st["qhead"], st["qlen"]
    alive[d_fail] = False
    if not bool(inq[d_fail]):
        pend[d_fail] = np.inf
    orphans = []
    for m in range(M):
        idxs = ps.queued(d_fail, m)
        if len(idxs):
            orphans.append(idxs)
        # truncate the mirror to the consumed prefix; the ring empties
        consumed = ps.pop_total(d_fail, m)
        pushed = (np.concatenate(ps.push[d_fail][m])
                  if ps.push[d_fail][m] else np.empty(0, np.int64))
        ps.push[d_fail][m] = [pushed[:consumed]] if consumed else []
        qlen[d_fail, m] = 0
    if not orphans:
        return False
    orph = np.concatenate(orphans)
    order = np.lexsort((reqid[orph], lane.arrival[orph]))
    orph = orph[order]
    wake = np.nextafter(t, np.inf)
    for ridx in orph:
        ridx = int(ridx)
        m = int(lane.model[ridx])
        elig = [dd for dd in placement[m] if bool(alive[dd])]
        if not elig:
            ps.stranded.append(np.array([ridx], dtype=np.int64))
            ps.lost += 1
            continue
        if len(elig) == 1:
            pick = elig[0]
        elif dispatcher == "round-robin":
            pick = elig[st["rr"] % len(elig)]
            st["rr"] += 1
        elif dispatcher == "jsq":
            pick = min(elig, key=lambda dd: (int(qlen[dd].sum()), dd))
        elif dispatcher == "least-loaded":
            pick = min(elig, key=lambda dd: (
                _host_backlog(dd, t, pend, inq, qlen, drain_tab, M), dd))
        else:  # stability-aware full scan
            pick = min(elig, key=lambda dd: (
                _host_backlog(dd, t, pend, inq, qlen, drain_tab, M)
                + float(b1_final[dd, m]), dd))
        if int(qlen[pick, m]) >= Q:
            return True  # ring overflow: retry wider
        slot = (int(qhead[pick, m]) + int(qlen[pick, m])) % Q
        qarr[pick, m, slot] = lane.arrival[ridx]
        qew[pick, m, slot] = ew_lane[ridx]
        qlen[pick, m] += 1
        ps.push[pick][m].append(np.array([ridx], dtype=np.int64))
        ps.dispatched[pick] += 1
        if not bool(done[pick]) and not bool(inq[pick]):
            pend[pick] = min(float(pend[pick]), wake)
    return False


def _rollup(lane: _Lane, ps: _LaneParse, specs: Sequence[DeviceSpec],
            cfg: SchedulerConfig, exec_lat: np.ndarray, reqid: np.ndarray,
            clock_row: np.ndarray, busy_row: np.ndarray,
            qlen_row: np.ndarray, alive_row: np.ndarray, horizon: float,
            warmup_tasks: int, keep_completions: bool) -> ClusterResult:
    """Reference-identical rollup: merged (finish, req_id) completion order,
    shared-span utilisation, per-device summarize() slices."""
    G = len(specs)
    M = len(lane.tau_vec)
    cols_m, cols_e, cols_b, cols_ri, cols_t0, cols_t1, cols_own = (
        [], [], [], [], [], [], [])
    for d in range(G):
        for m in range(M):
            plist = ps.pops[d][m]
            if not plist:
                continue
            t = np.concatenate([p[0] for p in plist])
            e = np.concatenate([p[1] for p in plist])
            b = np.concatenate([p[2] for p in plist])
            total = int(b.sum())
            pushed = (np.concatenate(ps.push[d][m])
                      if ps.push[d][m] else np.empty(0, np.int64))
            ridx = pushed[:total]
            # finish = t + L(d, m, e, B): the identical IEEE add the scan
            # performed when it occupied the quantum.
            fin = t + exec_lat[d, m, e, b]
            cols_m.append(np.full(total, m, dtype=np.int64))
            cols_e.append(np.repeat(e, b))
            cols_b.append(np.repeat(b, b))
            cols_ri.append(ridx)
            cols_t0.append(np.repeat(t, b))
            cols_t1.append(np.repeat(fin, b))
            cols_own.append(np.full(total, d, dtype=np.int64))
    if cols_m:
        model = np.concatenate(cols_m)
        exits = np.concatenate(cols_e)
        batch = np.concatenate(cols_b)
        ridx = np.concatenate(cols_ri)
        disp = np.concatenate(cols_t0)
        fin = np.concatenate(cols_t1)
        own = np.concatenate(cols_own)
        rid = reqid[ridx]
        order = np.lexsort((rid, fin))
        model, exits, batch = model[order], exits[order], batch[order]
        ridx, disp, fin = ridx[order], disp[order], fin[order]
        own, rid = own[order], rid[order]
    else:
        model = exits = batch = ridx = own = rid = np.empty(0, np.int64)
        disp = fin = np.empty(0, np.float64)

    span = max(max(float(c) for c in clock_row), horizon)
    residual = int(qlen_row.sum()) + ps.lost
    busy = sum(float(x) for x in busy_row)
    arrival = lane.arrival[ridx]

    if keep_completions:
        comps = [
            Completion(
                req_id=int(rid[i]), model=int(model[i]),
                arrival=float(arrival[i]), dispatch=float(disp[i]),
                finish=float(fin[i]), exit_idx=int(exits[i]),
                batch_size=int(batch[i]),
                deadline=lane.requests[int(ridx[i])].deadline,
            )
            for i in range(len(model))
        ]
        metrics = summarize(
            comps, specs[0].table, cfg.slo, warmup_tasks=warmup_tasks,
            busy_time=busy, span=span, residual_queue=residual, dropped=0,
        )
    else:
        comps = []
        metrics = summarize_arrays(
            models=model, exits=exits, batches=batch,
            latencies=fin - arrival, queueings=disp - arrival,
            taus=lane.tau_vec[model] if len(model) else np.empty(0),
            table=specs[0].table, warmup_tasks=warmup_tasks,
            busy_time=busy, span=span, residual_queue=residual, dropped=0,
        )

    wu = metrics.warmup_used
    own_done = own[wu:]
    per_dev = []
    for d in range(G):
        sel = own_done == d
        nd = int(sel.sum())
        if keep_completions:
            mine = [c for c, keep in zip(comps[wu:], sel) if keep]
            dm = summarize(mine, specs[d].table, cfg.slo, warmup_tasks=0,
                           dropped=0)
        else:
            dm = summarize_arrays(
                models=model[wu:][sel], exits=exits[wu:][sel],
                batches=batch[wu:][sel],
                latencies=(fin - arrival)[wu:][sel],
                queueings=(disp - arrival)[wu:][sel],
                taus=lane.tau_vec[model[wu:][sel]] if nd else np.empty(0),
                table=specs[d].table, warmup_tasks=0, dropped=0,
            )
        per_dev.append(DeviceMetrics(
            device=d, name=specs[d].label(d), num_completed=nd,
            dispatched=int(ps.dispatched[d]), dropped=0,
            violation_ratio=dm.violation_ratio, p95_latency=dm.p95_latency,
            mean_exit_depth=dm.mean_exit_depth,
            utilization=float(float(busy_row[d]) / span) if span > 0
            else 0.0,
            alive=bool(alive_row[d]),
        ))
    metrics = dataclasses.replace(
        metrics,
        utilization=(busy / (span * G)) if span > 0 else 0.0,
        per_device=tuple(per_dev),
    )
    return ClusterResult(metrics=metrics, completions=comps, span=span,
                         trace=None)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def _validate_cluster(specs: Sequence[DeviceSpec], dispatcher: str,
                      power_d: int, tracer, scheds: Sequence[Scheduler],
                      noise_cov: float) -> None:
    G = len(specs)
    if dispatcher not in DISPATCHERS:
        raise ValueError(
            f"unknown dispatcher {dispatcher!r}; "
            f"available: {sorted(DISPATCHERS)}"
        )
    if dispatcher == "stability-aware" and power_d < G:
        raise ScanEngineUnsupported(
            f"stability-aware power-of-d subsampling (power_d={power_d} < "
            f"fleet size {G}) draws numpy Generator.choice samples with no "
            f"fixed-shape compiled equivalent; the scan engine supports "
            f"stability-aware only as a full scan (power_d >= fleet size) "
            f"— use the Python ClusterSimulator for true power-of-d"
        )
    if tracer is not None:
        raise ScanEngineUnsupported(
            "the cluster scan engine does not reconstruct telemetry "
            "timelines (documented loud-reject; see docs/simulator.md) — "
            "trace cluster runs with the Python ClusterSimulator"
        )
    if noise_cov > 0:
        raise ScanEngineUnsupported(
            "service-time noise draws per-quantum RNG the compiled step "
            "does not reproduce; use the Python engine"
        )
    E = specs[0].table.num_exits
    for d, spec in enumerate(specs):
        if spec.drift is not None:
            raise ScanEngineUnsupported(
                f"device {d} carries a DriftModel; per-device drift needs "
                f"the Python ClusterSimulator"
            )
        if spec.table.num_exits != E:
            raise ScanEngineUnsupported(
                f"device {d} has {spec.table.num_exits} exits but device 0 "
                f"has {E}; the compiled lattice is one fixed [E] axis"
            )
    for sched in scheds:
        _validate_scheduler(sched)


def simulate_cluster_scan_batch(
    devices: Sequence[DeviceSpec],
    arrival_lanes: Sequence[Sequence[Request]],
    horizon: float,
    policy: str = "edgeserving",
    config: Optional[SchedulerConfig] = None,
    dispatcher: str = "least-loaded",
    power_d: int = 2,
    num_models: Optional[int] = None,
    warmup_tasks: int = 100,
    seed: int = 0,
    drain_cap: float = 600.0,
    max_queue: Optional[int] = None,
    keep_completions: bool = True,
    factored: Optional[bool] = None,
    service_noise_cov: float = 0.0,
    tracer: Optional[Tracer] = None,
) -> List[ClusterResult]:
    """Run one cluster experiment per arrival lane, all lanes side by side
    in one jitted, vmapped ``lax.scan`` — the compiled twin of
    ``ClusterSimulator(devices, ...).run(lane, horizon)`` (``seed`` is
    accepted for signature parity; the supported family draws no RNG).
    Returns one :class:`ClusterResult` per lane, in order. Unsupported
    features raise :class:`ScanEngineUnsupported`; see the module docstring
    for the dispatcher matrix and the failover protocol.

    ``keep_completions=False`` skips building per-request ``Completion``
    objects and computes the identical metrics through ``summarize_arrays``
    (the proven-equal array twin) — the seed-band path uses this to stay
    vectorised at 10^3 lanes.
    """
    specs = list(devices)
    G = len(specs)
    assert G >= 1
    cfg = config or SchedulerConfig()
    M = num_models or specs[0].table.num_models
    scheds = [make_scheduler(policy, s.table, cfg) for s in specs]
    _validate_cluster(specs, dispatcher, power_d, tracer, scheds,
                      service_noise_cov)
    placement = [
        [d for d, s in enumerate(specs)
         if s.models is None or m in s.models]
        for m in range(M)
    ]
    for m, hosts in enumerate(placement):
        assert hosts, f"model {m} is placed on no device"

    lanes = [_unpack_lane(lane, M, cfg.slo) for lane in arrival_lanes]
    if not lanes:
        return []
    tau_vec = lanes[0].tau_vec
    for lane in lanes[1:]:
        if not np.array_equal(lane.tau_vec, tau_vec):
            raise ScanEngineUnsupported(
                "all lanes of one cluster scan batch must share the same "
                "per-model deadline vector (split differing lanes into "
                "separate calls)"
            )

    E = specs[0].table.num_exits
    Bmax = cfg.max_batch
    ladder = _build_ladder(scheds[0], Bmax)
    allowed = tuple(e in scheds[0]._exits for e in range(E))
    # Per-device tables: scheduler belief == execution ground truth in the
    # cluster tier (no sched_table / model_map deployment mixing here).
    dense = np.stack([
        _dense_latency(s.table, list(range(M)), E, Bmax) for s in specs
    ])                                                   # [G, M, E, B+1]
    exec_lat = dense
    ladder_np = np.array(ladder, dtype=np.int64)
    lat_by_cap = np.ascontiguousarray(np.stack([
        dense[d][:, :, ladder_np].transpose(0, 2, 1, 3) for d in range(G)
    ]))                                                  # [G, M, B+1, E, R]
    b1_final = np.array(
        [[s.table(m, E - 1, 1) for m in range(M)] for s in specs],
        dtype=np.float64,
    )
    place_np = np.zeros((G, M), dtype=bool)
    for m, hosts in enumerate(placement):
        for d in hosts:
            place_np[d, m] = True

    n_total_max = max((len(lane.model) for lane in lanes), default=0)
    n_qmax = max(
        (max((len(ix) for ix in lane.by_model), default=0)
         for lane in lanes),
        default=0,
    )
    last_arrival = max(
        (lane.arrival[-1] for lane in lanes if len(lane.arrival)),
        default=0.0,
    )
    if factored is None:
        factored = bool(last_arrival / tau_vec.min() <= _FACTORED_RANGE)

    reqids = [
        np.arange(len(lane.requests), dtype=np.int64)
        if isinstance(lane.requests, TraceColumns)   # req_id == row index
        else np.fromiter(map(operator.attrgetter("req_id"), lane.requests),
                         dtype=np.int64, count=len(lane.requests))
        for lane in lanes
    ]
    fails = sorted(
        (float(s.fail_at), d) for d, s in enumerate(specs)
        if s.fail_at is not None
    )
    barrier_groups: List[Tuple[float, List[int]]] = []
    for tf, d in fails:
        if barrier_groups and barrier_groups[-1][0] == tf:
            barrier_groups[-1][1].append(d)
        else:
            barrier_groups.append((tf, [d]))
    segments = barrier_groups + [(np.inf, [])]
    F = len(fails)
    limit = horizon + drain_cap
    L = len(lanes)
    P = _pow2(n_total_max + 1)
    budget = (4 + 3 * F) * max(n_total_max, 1) + 4 * G + 64
    S = min(_pow2(budget), 256)

    arr_t = np.full((L, P), np.inf, dtype=np.float64)
    arr_m = np.zeros((L, P), dtype=np.int32)
    arr_ew = np.zeros((L, P), dtype=np.float64)
    for li, lane in enumerate(lanes):
        n = len(lane.model)
        arr_t[li, :n] = lane.arrival
        arr_m[li, :n] = lane.model
        if factored:
            arr_ew[li, :n] = np.exp(-lane.arrival / tau_vec[lane.model])

    Q = max_queue or min(_MAX_QUEUE_DEFAULT, _pow2(max(n_qmax, 1)))
    while True:
        key = _ClusterKey(
            num_devices=G, num_models=M, num_exits=E, max_queue=Q,
            pad_len=P, chunk_steps=S, burst=_BURST, max_batch=Bmax,
            ladder=ladder,
            allowed=allowed, fallback_exit=scheds[0]._exits[0],
            clip=cfg.clip, factored=factored, dispatcher=dispatcher,
        )
        chunk_fn = _build_cluster_chunk_fn(key)
        drain_tab = np.zeros((G, M, Q + 1), dtype=np.float64)
        for d, s in enumerate(scheds):
            for m in range(M):
                for q in range(1, Q + 1):
                    drain_tab[d, m, q] = drain_cell(s, m, q)
        parse = [_LaneParse(G, M) for _ in lanes]
        overflowed = False
        with enable_x64():
            shared = (
                jnp.asarray(lat_by_cap), jnp.asarray(exec_lat),
                jnp.asarray(drain_tab), jnp.asarray(b1_final),
                jnp.asarray(tau_vec), jnp.asarray(place_np),
                jnp.asarray(limit, dtype=jnp.float64),
            )
            carry_np = {
                "ai": np.zeros(L, np.int32),
                "qarr": np.zeros((L, G, M, Q), np.float64),
                "qew": np.zeros((L, G, M, Q), np.float64),
                "qhead": np.zeros((L, G, M), np.int32),
                "qlen": np.zeros((L, G, M), np.int32),
                "pend": np.full((L, G), np.inf, np.float64),
                "inq": np.zeros((L, G), bool),
                "alive": np.ones((L, G), bool),
                "done": np.zeros((L, G), bool),
                "clock": np.zeros((L, G), np.float64),
                "busy": np.zeros((L, G), np.float64),
                "rr": np.zeros(L, np.int32),
                "blocked": np.zeros(L, bool),
                "over": np.zeros(L, bool),
            }
            names = ("ai", "qarr", "qew", "qhead", "qlen", "pend", "inq",
                     "alive", "done", "clock", "busy", "rr", "blocked",
                     "over")
            carry = tuple(jnp.asarray(carry_np[n]) for n in names)
            steps_run = 0
            step_cap = budget + (len(segments) + 2) * S
            for bt, dying in segments:
                # fresh segment: clear the barrier-freeze flags
                blocked0 = jnp.zeros(L, bool)
                carry = carry[:12] + (blocked0, carry[13])
                barrier_j = jnp.asarray(bt, dtype=jnp.float64)
                while True:
                    carry, ys = chunk_fn(
                        carry, jnp.asarray(arr_t), jnp.asarray(arr_m),
                        jnp.asarray(arr_ew), *shared, barrier_j)
                    steps_run += S
                    codes, tvals = jax.device_get(ys)
                    for li in range(L):
                        # [S, K+1] slots flatten to the execution-order
                        # event stream the mirror expects
                        _parse_chunk(parse[li],
                                     np.asarray(codes[li]).reshape(-1),
                                     np.asarray(tvals[li]).reshape(-1),
                                     G, M, E, arr_m[li])
                    blocked = np.asarray(carry[12])
                    over = np.asarray(carry[13])
                    if bool(over.any()):
                        overflowed = True
                        break
                    if bool(blocked.all()):
                        break
                    if steps_run > step_cap:
                        raise RuntimeError(
                            f"cluster scan exceeded its step budget "
                            f"({steps_run} events for {n_total_max} "
                            f"arrivals, {F} failures); this indicates a "
                            f"termination bug — please report"
                        )
                if overflowed:
                    break
                if not dying:
                    continue
                host = [np.array(jax.device_get(c)) for c in carry]
                st_all = dict(zip(names, host))
                for li in range(L):
                    st = {k: st_all[k][li] for k in names}
                    # the round-robin counter continues from the compiled
                    # picks; host picks advance it and hand it back
                    st["rr"] = int(st_all["rr"][li])
                    for d_fail in dying:
                        if _host_fail(
                            parse[li], st, d_fail, bt, lanes[li],
                            arr_ew[li], reqids[li], placement, dispatcher,
                            drain_tab, b1_final, Q, M,
                        ):
                            overflowed = True
                            break
                    st_all["rr"][li] = st["rr"]
                    if overflowed:
                        break
                if overflowed:
                    break
                carry = tuple(jnp.asarray(st_all[n]) for n in names)
        if overflowed:
            if Q >= max(n_qmax, 1):
                raise RuntimeError(
                    "cluster scan overflowed a ring already as large as "
                    "the densest per-model arrival count — please report"
                )
            Q *= 2  # retry with a wider ring (sticky-flag overflow)
            continue
        break

    final = [np.asarray(jax.device_get(c)) for c in carry]
    fin = dict(zip(names, final))
    results = []
    for li, lane in enumerate(lanes):
        assert parse[li].ai == len(lane.model), "arrival stream not drained"
        results.append(_rollup(
            lane, parse[li], specs, cfg, exec_lat, reqids[li],
            fin["clock"][li], fin["busy"][li], fin["qlen"][li],
            fin["alive"][li], horizon, warmup_tasks, keep_completions,
        ))
    return results


def simulate_cluster_scan(
    devices: Sequence[DeviceSpec],
    arrivals: Sequence[Request],
    horizon: float,
    **kwargs,
) -> ClusterResult:
    """Compiled twin of ``ClusterSimulator(devices, ...).run(arrivals,
    horizon)`` for one trace: same arguments-to-metrics contract, one
    ``lax.scan`` instead of the Python global event loop. See
    :func:`simulate_cluster_scan_batch` for the supported feature matrix."""
    return simulate_cluster_scan_batch(
        devices, [arrivals], horizon, **kwargs)[0]
