"""Traffic models (paper Sec. VI-A).

Arrivals to each service queue are independent Poisson point processes.
The paper's default rate ratio is ``lambda_50 : lambda_101 : lambda_152
= 3 : 2 : 1`` (lighter models receive heavier traffic); the model-combination
study uses equal rates.

This module is the import-compatible facade over the workload subsystem:
``poisson_arrivals`` now delegates to
:class:`repro.core.workloads.PoissonProcess` (same algorithm, identical
traces per seed); the non-Poisson scenarios — MMPP bursts, diurnal cycles,
flash crowds, trace replay — live in :mod:`repro.core.workloads`.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.request import Request
from repro.core.workloads import PoissonProcess


def poisson_arrivals(
    rates: Sequence[float],
    horizon: float,
    seed: int = 0,
    data_pool: int = 10_000,
    deadlines: Optional[Sequence[float]] = None,
) -> List[Request]:
    """Generate a merged, time-sorted arrival trace.

    Args:
      rates:   per-model arrival rates (req/s); zero-rate models get none.
      horizon: generate arrivals in [0, horizon) seconds.
      seed:    PRNG seed (deterministic traces for reproducible experiments).
      data_pool: data ids are drawn uniformly from [0, data_pool) -- the
        paper draws each request i.i.d. from the CIFAR-100 test set.
      deadlines: optional per-model SLO vector stamped onto each request's
        ``deadline`` (heterogeneous-SLO serving); None = global SLO.
    Returns: list of Requests sorted by arrival time, req_id in that order.
    """
    return PoissonProcess(rates, deadlines=deadlines).generate(
        horizon, seed=seed, data_pool=data_pool
    )


def paper_rate_vector(lambda_152: float, ratio: Sequence[float] = (3, 2, 1)) -> List[float]:
    """Paper default: rates proportional to ``ratio`` with the *last* model
    (ResNet152) pinned to ``lambda_152`` -- i.e. (3x, 2x, x)."""
    unit = lambda_152 / ratio[-1]
    return [unit * r for r in ratio]
