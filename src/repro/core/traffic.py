"""Traffic models (paper Sec. VI-A).

Arrivals to each service queue are independent Poisson point processes.
The paper's default rate ratio is ``lambda_50 : lambda_101 : lambda_152
= 3 : 2 : 1`` (lighter models receive heavier traffic); the model-combination
study uses equal rates.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.request import Request


def poisson_arrivals(
    rates: Sequence[float],
    horizon: float,
    seed: int = 0,
    data_pool: int = 10_000,
) -> List[Request]:
    """Generate a merged, time-sorted arrival trace.

    Args:
      rates:   per-model arrival rates (req/s); zero-rate models get none.
      horizon: generate arrivals in [0, horizon) seconds.
      seed:    PRNG seed (deterministic traces for reproducible experiments).
      data_pool: data ids are drawn uniformly from [0, data_pool) -- the
        paper draws each request i.i.d. from the CIFAR-100 test set.
    Returns: list of Requests sorted by arrival time, req_id in that order.
    """
    rng = np.random.default_rng(seed)
    events = []
    for m, lam in enumerate(rates):
        if lam <= 0:
            continue
        # Expected count + slack, then trim: cheaper than a Python loop.
        n_expect = int(lam * horizon * 1.25 + 50)
        gaps = rng.exponential(1.0 / lam, size=n_expect)
        times = np.cumsum(gaps)
        while times[-1] < horizon:  # extremely unlikely; extend defensively
            extra = rng.exponential(1.0 / lam, size=n_expect)
            times = np.concatenate([times, times[-1] + np.cumsum(extra)])
        times = times[times < horizon]
        data = rng.integers(0, data_pool, size=len(times))
        events.extend(zip(times.tolist(), [m] * len(times), data.tolist()))
    events.sort()
    return [
        Request(req_id=i, model=m, arrival=t, data_id=int(d))
        for i, (t, m, d) in enumerate(events)
    ]


def paper_rate_vector(lambda_152: float, ratio: Sequence[float] = (3, 2, 1)) -> List[float]:
    """Paper default: rates proportional to ``ratio`` with the *last* model
    (ResNet152) pinned to ``lambda_152`` -- i.e. (3x, 2x, x)."""
    unit = lambda_152 / ratio[-1]
    return [unit * r for r in ratio]
