"""Compiled serving simulator: one run = one jitted ``lax.scan``.

The reference event loop (``repro.core.simulator.ServingSimulator``) is pure
Python: the accelerated scoring backends speed up one call inside a slow
interpreter loop, and sweep parallelism is process-level. This module
refactors a whole serving run into fixed-shape array state so it compiles:

  * per-model arrival times become one ``[M, P]`` float64 array, sorted and
    padded with ``+inf``; a FIFO queue is then just the contiguous window
    ``[served_m, served_m + qlen_m)`` of that array, so ingest is a count of
    window entries ``<= t`` and the queue's wait vector is one
    ``dynamic_slice`` of static width ``max_queue``;
  * the profile tables become dense ``[M, E, B_max+1]`` latency arrays
    (scheduler belief and execution ground truth separately, so
    ``sched_table`` / ``model_map`` deployment mixes work unchanged);
  * the batch ladder (Eq. 5 / the lattice generalisation) becomes a static
    ``[B_max+1, R]`` rung table built by calling the *actual* scheduler's
    ``batch_candidates`` for every possible cap — greedy, lattice, custom
    ladders and the bs=1 ablation all compile through one code path;
  * one scheduling round (ingest -> enumerate the (m, e, B) lattice ->
    Eq. 6 exit per candidate -> Sec. V-C / Eq. 4 scoring -> Eq. 7 argmin
    with the reference tiebreak -> pop batch, advance clock) is one
    ``lax.scan`` step; idle rounds are folded into the following dispatch
    (the reference's idle-advance is always followed by an ingest), so the
    scan length is bounded by the dispatch count, not the event count;
    ``jax.vmap`` lays independent traces (seeds x rates) side by side and
    ``jit`` compiles the whole run.

Everything runs in float64 (``jax.experimental.enable_x64``): the clock
evolves by the *identical* IEEE operations as the Python loop (``t + L``,
``nextafter``), so dispatch/finish timestamps are bitwise-equal and
decisions stay equivalent — stability scores differ only at the ~ulp level
(summation order; and the fast scoring path below), which the Eq. 7 argmin
is insensitive to outside exact structural ties, where both engines apply
the identical (score, w_max, candidate order) tiebreak.

Scoring runs in one of two modes, selected automatically:

  * **factored** (the fast path): Eq. 3 urgency obeys
    ``exp((t + L - a)/tau - 1) = exp((t + L)/tau - 1) * exp(-a/tau)``, so
    the per-*task* exponential ``E = exp(-a/tau)`` is precomputed once per
    run outside the loop and each scan step pays only ``[N, M]`` scalar
    exponentials instead of ``[N, M, max_queue]`` — the difference between
    the step being exp-bound and being memory-bound. The factorisation is
    used only when ``max(arrival)/min(tau) <= 700``, where ``E`` stays a
    normal float64 (clips of overflowed products are exact, so late drains
    are safe; an underflowed ``E`` would not be).
  * **direct** (the reference formula ``lattice_stability_scores``, shared
    with the scoring backends): used for long-horizon / tight-deadline runs
    outside the factored range, and forceable via ``factored=False`` for
    A/B testing. Both modes are pinned against the Python engine by
    ``tests/test_simfast.py``.

Deliberately unsupported (rejected loudly, never approximated): schedulers
outside the Algorithm-1 family (Symphony's prune/next_wake, LQF/EDF),
non-default scoring backends, service-time noise, device drift, online
adaptation, and per-request deadlines that vary within a model's queue
(trace replay). The Python loop remains the reference for those; see
docs/simulator.md "Compiled fast path".
"""

from __future__ import annotations

import dataclasses
import functools
import operator
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.baselines import AllFinalDeadlineAwareScheduler, NoBatchingScheduler
from repro.core.metrics import summarize_arrays
from repro.core.profile import ProfileTable
from repro.core.request import Completion, Decision, Request, ServingTrace
from repro.core.workloads import TraceColumns
from repro.core.scheduler import (
    EdgeServingScheduler,
    LatticeEdgeServingScheduler,
    Scheduler,
    VectorizedEdgeServingScheduler,
)
from repro.core.simulator import SimResult
from repro.core.telemetry import DecisionRecord, Tracer
from repro.core.urgency import lattice_stability_scores

__all__ = ["ScanEngineUnsupported", "simulate_scan", "simulate_scan_batch"]


class ScanEngineUnsupported(NotImplementedError):
    """A feature the compiled engine does not reproduce bit-for-bit.

    The scan path refuses rather than approximates: silent semantic drift
    in a compiled rewrite of a discrete-event simulator is exactly what the
    equivalence suite exists to prevent. Fall back to the Python engine
    (``SweepSpec.engine="python"`` / ``ServingSimulator``) for these."""


# The Algorithm-1 family whose decisions the scan step reproduces: shared
# Eq. 5/6 candidate enumeration + stability-score argmin, no prune, no
# next_wake. Exact types, not isinstance: an unknown subclass may override
# decide()/batch_candidates() in ways the compiled step knows nothing about.
_SUPPORTED_SCHEDULERS = (
    EdgeServingScheduler,
    VectorizedEdgeServingScheduler,
    LatticeEdgeServingScheduler,
    AllFinalDeadlineAwareScheduler,
    NoBatchingScheduler,
)

_MAX_QUEUE_DEFAULT = 64  # initial window; doubled (with a recompile) on overflow
_FACTORED_RANGE = 700.0  # max(arrival)/min(tau) bound keeping exp(-a/tau) normal


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class _StaticKey:
    """Everything that shapes the compiled step (hashable jit-cache key)."""

    num_models: int
    num_exits: int
    max_queue: int
    pad_len: int          # P: padded per-model arrival-array length
    chunk_steps: int      # S: lax.scan length per launch
    max_batch: int
    ladder: Tuple[Tuple[int, ...], ...]   # [B_max+1][R] batch rungs (0 = pad)
    allowed: Tuple[bool, ...]             # [E] allowed-exit mask
    fallback_exit: int                    # shallowest allowed exit (Eq. 6)
    clip: float
    factored: bool        # factored-exponential scoring vs direct Eq. 3
    emit_aux: bool        # also record predicted latency + score per round


@functools.lru_cache(maxsize=64)
def _build_chunk_fn(key: _StaticKey):
    """Compile one scan chunk: every lane advances ``chunk_steps`` rounds.
    Returns (carry', ys) with ys stacked step-major."""
    M, E, Q = key.num_models, key.num_exits, key.max_queue
    ladder = jnp.asarray(np.array(key.ladder, dtype=np.int32))      # [B+1, R]
    R = int(ladder.shape[1])
    N = M * R
    allowed = jnp.asarray(np.array(key.allowed, dtype=bool))        # [E]
    e0 = key.fallback_exit
    clip = key.clip
    m_idx = jnp.arange(M)
    n_idx = jnp.arange(N)
    cand_queue = jnp.repeat(m_idx, R)                               # [N]
    pos_q = jnp.arange(Q)[None, :]                                  # [1, Q]

    def run_chunk(carry, arr, lat_by_cap, exec_lat, tau_vec, limit):
        # carry: (t, served[M], busy, done, overflow) for one lane.
        # arr: [M, P, 2] of (arrival time, exp(-arrival/tau)) rows, sorted
        #      by arrival, +inf / 0.0 padded.
        # lat_by_cap: [M, B_max+1, E, R] scheduler-belief latency per
        #      (queue, queue-length cap, exit, ladder rung), prebuilt on the
        #      host so candidate enumeration is one row gather per queue.
        # exec_lat: [M, E, B_max+1] ground-truth execution latency.
        # tau_vec: [M] effective per-model deadline (Eq. 6 + scoring).

        def step(c, _):
            t0, served, busy, done, overflow = c

            # FIFO queue content is the contiguous range [served, served +
            # qlen) of the sorted arrival array, so one width-(Q+1) window
            # holds every queued task plus the next future arrival; counting
            # window entries <= t *is* the reference loop's ingest cursor
            # (t is monotone). A count of Q+1 means the queue outgrew the
            # window and the host must retry wider.
            win = jax.vmap(
                lambda row, s: lax.dynamic_slice(
                    row, (s, jnp.zeros((), jnp.int32)), (Q + 1, 2)
                )
            )(arr, served)                                          # [M, Q+1, 2]
            arr_win = win[:, :, 0]                                  # [M, Q+1]
            qlen0 = jnp.sum(arr_win <= t0, axis=1).astype(jnp.int32)

            # Idle rounds fold into the dispatch that always follows them:
            # when every queue is empty, the reference sleeps to the next
            # arrival with one-ulp strict progress (t = nextafter(max(t,
            # next), inf)), ingests it, and dispatches. No serve happened,
            # so the same window just gets recounted at the advanced clock.
            nxt = jnp.min(jnp.where(arr_win > t0, arr_win, jnp.inf))
            empty0 = ~jnp.any(qlen0 > 0)
            t_idle = jnp.nextafter(jnp.maximum(t0, nxt), jnp.inf)
            halt = empty0 & ~jnp.isfinite(nxt)           # no work ever again
            t = jnp.where(empty0 & ~halt, t_idle, t0)    # halt: break pre-advance
            over_cap = empty0 & (t > limit)              # idle past drain cap
            qlen_raw = jnp.sum(arr_win <= t, axis=1).astype(jnp.int32)
            overflow = overflow | jnp.any(qlen_raw > Q)
            qlen_c = jnp.minimum(qlen_raw, Q)

            mask_b = pos_q < qlen_c[:, None]                        # [M, Q]
            # Oldest wait per queue, zero when empty, exactly like
            # QueueSnapshot.w_max.
            w_max = jnp.where(qlen_c > 0, t - arr_win[:, 0], 0.0)   # [M]

            # Candidate lattice: one rung row per queue from the static
            # ladder (queue asc, batch desc — the reference enumeration
            # order), Eq. 6 deepest-feasible exit per rung.
            cap = jnp.minimum(qlen_c, key.max_batch)                # [M]
            batches = ladder[cap]                                   # [M, R]
            valid = (batches > 0).reshape(-1)                       # [N]
            lat_sel = jnp.take_along_axis(
                lat_by_cap, cap[:, None, None, None], axis=1
            )[:, 0]                                                 # [M, E, R]
            feas = (
                (w_max[:, None, None] + lat_sel <= tau_vec[:, None, None])
                & allowed[None, :, None]
            )
            e_axis = jnp.arange(E)[None, :, None]
            deepest = jnp.max(jnp.where(feas, e_axis, -1), axis=1)  # [M, R]
            e_sel = jnp.where(deepest >= 0, deepest, e0)
            lat_cand = jnp.sum(
                jnp.where(e_sel[:, None, :] == e_axis, lat_sel, 0.0), axis=1
            )                                                       # [M, R]

            cand_batch = batches.reshape(-1)                        # [N]
            cand_lat = lat_cand.reshape(-1)                         # [N]

            if key.factored:
                # Eq. 3/4 + Sec. V-C with the per-task exponential factored
                # out: urgency(w + L) = min(A * E, C) with A = exp((t + L) /
                # tau - 1) per (candidate, queue) and E = exp(-a/tau) per
                # task, precomputed — [N, M] exponentials per round instead
                # of [N, M, max_queue]; the remaining [N, M, Q] work is one
                # fused multiply/min/mask pass (amp=inf on deep drains is
                # benign: the where() masks the inf*0 pad NaNs, real tasks
                # clip to C exactly).
                ew = win[:, :Q, 1]                                  # [M, Q]
                amp = jnp.exp(
                    (t + cand_lat[:, None]) / tau_vec[None, :] - 1.0
                )                                                   # [N, M]
                urg = jnp.where(
                    mask_b[None, :, :],
                    jnp.minimum(amp[:, :, None] * ew[None, :, :], clip),
                    0.0,
                )                                                   # [N, M, Q]
                total = jnp.sum(urg, axis=(1, 2))
                own = urg[n_idx, cand_queue, :]                     # [N, Q]
                removed = jnp.sum(
                    jnp.where(pos_q < cand_batch[:, None], own, 0.0), axis=1
                )
                scores = total - removed
            else:
                w = jnp.where(mask_b, t - arr_win[:, :Q], 0.0)
                mask = mask_b.astype(jnp.float64)
                scores = lattice_stability_scores(
                    w, mask, cand_lat, cand_batch, cand_queue,
                    tau_vec[:, None], clip,
                )

            # Eq. 7 argmin with the reference tiebreak: min score, then max
            # w_max, then first candidate (np.lexsort is stable).
            scores_v = jnp.where(valid, scores, jnp.inf)
            best = jnp.min(scores_v)
            wm_c = jnp.repeat(w_max, R)
            tie = valid & (scores_v == best)
            wm_best = jnp.max(jnp.where(tie, wm_c, -jnp.inf))
            pick = jnp.argmax(tie & (wm_c == wm_best))
            has_work = jnp.any(valid)

            m_star = cand_queue[pick]
            e_star = e_sel.reshape(-1)[pick]
            b_star = cand_batch[pick]
            service = exec_lat[m_star, e_star, b_star]
            t_end = t + service

            active = ~done
            is_disp = active & has_work & ~over_cap
            t_new = jnp.where(is_disp, t_end, jnp.where(active, t, t0))
            pop = jnp.where(is_disp, b_star, 0).astype(jnp.int32)
            served_new = served + jnp.where(m_idx == m_star, pop, 0)
            busy_new = busy + jnp.where(is_disp, service, 0.0)
            # The reference breaks *after* advancing t past horizon +
            # drain_cap in the dispatch branch (the over-cap quantum still
            # counts) and *before* dispatching in the idle branch.
            done_new = done | halt | over_cap | (is_disp & (t_end > limit))
            done_new = done_new | overflow  # window wrong: stop, host retries

            # One int32 codes the whole round: -1 = no dispatch, else
            # m + M*(e + E*b). Finish times and predicted latencies are
            # bitwise-recomputable on the host from (m, e, b) and t0.
            code = jnp.where(
                is_disp, m_star + M * (e_star + E * b_star), -1
            ).astype(jnp.int32)
            if key.emit_aux:
                # Decision margin: runner-up candidate score minus the
                # winner's (inf with a single candidate, 0 on an exact
                # tie) — same definition as telemetry.decision_margin's
                # second-smallest-minus-smallest on the host.
                runner_up = jnp.min(jnp.where(n_idx == pick, jnp.inf,
                                              scores_v))
                ys = (code, t, scores[pick], runner_up - best)
            else:
                ys = (code, t)
            return (t_new, served_new, busy_new, done_new, overflow), ys

        return lax.scan(step, carry, None, length=key.chunk_steps, unroll=4)

    fn = jax.vmap(
        run_chunk, in_axes=((0, 0, 0, 0, 0), 0, None, None, None, None)
    )
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# Host-side packing and validation
# ---------------------------------------------------------------------------


def _validate_scheduler(scheduler: Scheduler) -> None:
    if type(scheduler) not in _SUPPORTED_SCHEDULERS:
        raise ScanEngineUnsupported(
            f"scan engine supports only the Algorithm-1 scheduler family "
            f"{sorted(c.__name__ for c in _SUPPORTED_SCHEDULERS)}; got "
            f"{type(scheduler).__name__!r} (Symphony's prune/next_wake and "
            f"the LQF/EDF baselines need the Python engine)"
        )
    if scheduler.scoring.name != "numpy":
        raise ScanEngineUnsupported(
            f"scan engine compiles its own scoring pass; the "
            f"backend={scheduler.scoring.name!r} knob only applies to the "
            f"Python engine — use the default backend='numpy'"
        )


@dataclasses.dataclass
class _Lane:
    """One arrival trace, unpacked into per-model columnar arrays."""

    requests: Sequence[Request]
    model: np.ndarray      # [n] queue index per request, arrival order
    arrival: np.ndarray    # [n] arrival times, sorted
    by_model: List[np.ndarray]   # per-model index lists into the trace
    tau_vec: np.ndarray    # [M] effective per-model deadline


def _unpack_lane(
    arrivals, num_models: int, slo: float
) -> _Lane:
    n = len(arrivals)
    if isinstance(arrivals, TraceColumns):
        # Columnar lane: already the arrays this function exists to build.
        model = arrivals.model
        arrival = arrivals.arrival
    else:
        # map(attrgetter) keeps attribute extraction in C: this runs once
        # per request per run, so it is the scan engine's host-side hot loop.
        model = np.fromiter(
            map(operator.attrgetter("model"), arrivals),
            dtype=np.int64, count=n,
        )
        arrival = np.fromiter(
            map(operator.attrgetter("arrival"), arrivals),
            dtype=np.float64,
            count=n,
        )
    if n and np.any(np.diff(arrival) < 0):
        raise ValueError("arrivals must be sorted by arrival time")
    if n and (model.min() < 0 or model.max() >= num_models):
        raise ValueError(
            f"arrival trace targets model {model.max()}, but the "
            f"simulation has {num_models} queues"
        )
    tau_vec = np.full(num_models, slo, dtype=np.float64)
    by_model = [np.flatnonzero(model == m) for m in range(num_models)]
    if isinstance(arrivals, TraceColumns):
        deadline = arrivals.deadline          # [n] with NaN = None, or None
    else:
        deadline = None
        distinct = set(map(operator.attrgetter("deadline"), arrivals))
        if distinct and distinct != {None}:
            deadline = np.fromiter(
                (np.nan if r.deadline is None else r.deadline
                 for r in arrivals),
                dtype=np.float64,
                count=n,
            )
    if deadline is not None:
        # Per-request deadlines present: supported iff constant per model.
        for m in range(num_models):
            d = deadline[by_model[m]]
            if len(d) == 0:
                continue
            has = ~np.isnan(d)
            if has.any():
                vals = np.unique(d[has])
                if len(vals) > 1 or not has.all():
                    raise ScanEngineUnsupported(
                        f"model {m} carries per-request deadlines that vary "
                        f"within its queue; the scan engine supports only "
                        f"per-model constant deadlines (trace replay with "
                        f"arbitrary deadline mixes needs the Python engine)"
                    )
                tau_vec[m] = float(vals[0])
    return _Lane(arrivals, model, arrival, by_model, tau_vec)


def _dense_latency(
    table: ProfileTable, rows: Sequence[int], num_exits: int, max_batch: int
) -> np.ndarray:
    """[M, E, B_max+1] lookup array via the table's own clamped ``__call__``
    (slot 0 is never dispatched; fill with batch 1 to stay finite)."""
    out = np.empty((len(rows), num_exits, max_batch + 1), dtype=np.float64)
    for i, row in enumerate(rows):
        for e in range(num_exits):
            out[i, e, 0] = table(row, e, 1)
            for b in range(1, max_batch + 1):
                out[i, e, b] = table(row, e, b)
    return out


def _build_ladder(scheduler: Scheduler, max_batch: int) -> Tuple[Tuple[int, ...], ...]:
    """[B_max+1][R] rung table from the scheduler's own ``batch_candidates``
    (cap -> descending rungs, 0-padded): greedy, lattice, custom ladders and
    the bs=1 ablation all serialise into one static array."""
    rows = [tuple(scheduler.batch_candidates(cap)) for cap in range(max_batch + 1)]
    width = max((len(r) for r in rows), default=1) or 1
    return tuple(r + (0,) * (width - len(r)) for r in rows)


def _pack_lanes(
    lanes: Sequence[_Lane], num_models: int, pad_len: int, factored: bool
) -> np.ndarray:
    """[L, M, P, 2] of (arrival, exp(-arrival/tau)) rows, +inf / 0.0 padded
    (the pad's exponential factor is exactly the +inf arrival's)."""
    out = np.empty((len(lanes), num_models, pad_len, 2), dtype=np.float64)
    out[:, :, :, 0] = np.inf
    out[:, :, :, 1] = 0.0
    for li, lane in enumerate(lanes):
        for m in range(num_models):
            a = lane.arrival[lane.by_model[m]]
            out[li, m, : len(a), 0] = a
            if factored:
                out[li, m, : len(a), 1] = np.exp(-a / lane.tau_vec[m])
    return out


# ---------------------------------------------------------------------------
# Result reconstruction (vectorised numpy, no per-request Python loop)
# ---------------------------------------------------------------------------


def _reconstruct(
    ys: "dict[str, np.ndarray]",
    lane: _Lane,
    table: ProfileTable,
    sched_lat: np.ndarray,
    exec_lat: np.ndarray,
    num_exits: int,
    horizon: float,
    warmup_tasks: int,
    model_map: Optional[Sequence[int]],
    busy: float,
    t_final: float,
    keep_completions: bool,
    keep_traces: bool,
    tracer: Optional[Tracer] = None,
    slo: float = 0.050,
) -> SimResult:
    M = len(lane.tau_vec)
    code = ys["code"]
    disp = code >= 0
    dcode = code[disp]
    dm = dcode % M
    rest = dcode // M
    de = rest % num_exits
    db = rest // num_exits
    dt0 = ys["t0"][disp]
    # t_end = t + L(m, e, B) is the identical IEEE add the scan performed,
    # so recomputing it here is bitwise-faithful to the in-scan clock.
    dt1 = dt0 + exec_lat[dm, de, db]
    n_arr = len(lane.model)
    # Reference completion order is: dispatch rounds in time order, FIFO
    # within each batch. Both coordinates are directly computable -- no
    # sort needed. The k-th dispatch of model m serves the next
    # ``db`` requests of m's arrival-ordered queue, so the per-model
    # position of each completion is (batches m served before this
    # dispatch) + (offset within this batch).
    D = len(dm)
    if D:
        db64 = db.astype(np.int64)
        gidx = np.repeat(np.arange(D), db64)
        starts = np.cumsum(db64) - db64
        off = np.arange(len(gidx)) - starts[gidx]   # 0..b-1, FIFO in batch
        prior = np.empty(D, dtype=np.int64)         # m's served-before count
        for m in range(M):
            sel = dm == m
            bm = np.where(sel, db64, 0)
            prior[sel] = (np.cumsum(bm) - bm)[sel]
        # trace index per completion, via the concatenated per-model lists
        bm_flat = np.concatenate(lane.by_model) if M else np.array([], np.int64)
        bm_off = np.zeros(M, dtype=np.int64)
        np.cumsum([len(ix) for ix in lane.by_model[:-1]], out=bm_off[1:])
        model = dm[gidx]
        ridx = bm_flat[bm_off[model] + prior[gidx] + off]
        exits = de[gidx].astype(np.int64)
        batches = db64[gidx]
        arrival = lane.arrival[ridx]
        dispatch = dt0[gidx]
        finish = dt1[gidx]
        tau = lane.tau_vec[model]
    else:
        model = exits = batches = ridx = np.array([], dtype=np.int64)
        arrival = dispatch = finish = tau = np.array([], dtype=np.float64)

    n_completed = len(model)
    residual = n_arr - n_completed
    span = max(t_final, horizon)
    metrics = summarize_arrays(
        models=model,
        exits=exits,
        batches=batches,
        latencies=finish - arrival,
        queueings=dispatch - arrival,
        taus=tau,
        table=table,
        warmup_tasks=warmup_tasks,
        busy_time=busy,
        span=span,
        residual_queue=residual,
        model_map=model_map,
        dropped=0,
    )

    completions: List[Completion] = []
    if keep_completions and n_completed:
        for i in range(n_completed):
            req = lane.requests[int(ridx[i])]
            completions.append(Completion(
                req_id=req.req_id,
                model=int(model[i]),
                arrival=req.arrival,
                dispatch=float(dispatch[i]),
                finish=float(finish[i]),
                exit_idx=int(exits[i]),
                batch_size=int(batches[i]),
                deadline=req.deadline,
            ))

    traces: List[ServingTrace] = []
    if keep_traces:
        dplat = sched_lat[dm, de, db]
        dscore = ys["score"][disp]
        for i in range(len(dm)):
            traces.append(ServingTrace(
                t_start=float(dt0[i]),
                t_end=float(dt1[i]),
                decision=Decision(
                    model=int(dm[i]),
                    exit_idx=int(de[i]),
                    batch_size=int(db[i]),
                    predicted_latency=float(dplat[i]),
                    stability_score=float(dscore[i]),
                ),
                queue_lengths=(),
            ))

    trace = None
    if tracer is not None:
        # Host-side timeline reconstruction from the packed decision codes.
        # Everything but score/margin is recomputed by the *identical* IEEE
        # ops the Python engine's snapshot performs, so the timeline is
        # bitwise-equal to the reference trace (property-tested):
        #   depth_m  = |arrivals_m <= t| - served_before_m   (ingest rule)
        #   age_m    = t - arrival_of_oldest_queued          (w_max rule)
        D = len(dm)
        db64d = db.astype(np.int64)
        depths = np.zeros((D, M), dtype=np.int64)
        ages = np.zeros((D, M), dtype=np.float64)
        for m in range(M):
            arr_m = lane.arrival[lane.by_model[m]]
            bm = np.where(dm == m, db64d, 0)
            served_before = np.cumsum(bm) - bm
            cnt = np.searchsorted(arr_m, dt0, side="right")
            depth_m = cnt - served_before
            depths[:, m] = depth_m
            if len(arr_m):
                head = np.minimum(served_before, len(arr_m) - 1)
                ages[:, m] = np.where(depth_m > 0, dt0 - arr_m[head], 0.0)
        scores_d = ys["score"][disp]
        margins_d = ys["margin"][disp]
        dplat = sched_lat[dm, de, db]
        for k in range(D):
            tracer.decisions.append(DecisionRecord(
                t=float(dt0[k]), device=0, model=int(dm[k]),
                exit_idx=int(de[k]), batch_size=int(db[k]),
                predicted_latency=float(dplat[k]), t_end=float(dt1[k]),
                score=float(scores_d[k]), margin=float(margins_d[k]),
                queue_depths=tuple(int(x) for x in depths[k]),
                oldest_ages=tuple(float(x) for x in ages[k]),
            ))
        for i in range(n_completed):
            req = lane.requests[int(ridx[i])]
            tracer.record_completion(
                req, float(dispatch[i]), float(finish[i]),
                int(exits[i]), int(batches[i]), slo)
        served_total = np.zeros(M, dtype=np.int64)
        np.add.at(served_total, dm, db64d)
        for m in range(M):
            for j in lane.by_model[m][served_total[m]:]:
                tracer.record_residual(lane.requests[int(j)], slo,
                                       device=-1)
        trace = tracer.freeze(
            engine="scan", num_models=M, num_devices=1, slo=slo,
            horizon=horizon, span=span, warmup_used=metrics.warmup_used,
            n_arrivals=n_arr)
    return SimResult(metrics, completions, traces, span, trace=trace)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def simulate_scan_batch(
    scheduler: Scheduler,
    table: ProfileTable,
    arrival_lanes: Sequence[Sequence[Request]],
    horizon: float,
    num_models: Optional[int] = None,
    warmup_tasks: int = 100,
    model_map: Optional[Sequence[int]] = None,
    drain_cap: float = 600.0,
    max_queue: Optional[int] = None,
    keep_completions: bool = False,
    keep_traces: bool = False,
    factored: Optional[bool] = None,
    tracers: Optional[Sequence[Optional[Tracer]]] = None,
) -> List[SimResult]:
    """Run one serving experiment per arrival lane, all lanes side by side
    in a single jitted, vmapped ``lax.scan`` (seeds x rates in one XLA
    launch). All lanes share the scheduler config and tables; only the
    traces differ. Returns one :class:`SimResult` per lane, in order.

    The scan runs in fixed-size compiled chunks with a host-side
    completion check between launches, so a grid of light lanes does not
    pay the worst-case step bound of its heaviest lane. If any lane's
    queue outgrows the ``max_queue`` window the whole batch retries with
    the window doubled (one recompile; results are never truncated).
    ``factored=None`` auto-selects the factored-exponential scoring path
    whenever its float64 range condition holds (see module docstring).

    ``tracers`` (optional, one ``telemetry.Tracer`` or ``None`` per lane)
    turns on telemetry: the scan emits its score/margin aux and the host
    reconstructs each traced lane's full decision timeline and request
    spans from the packed codes — bitwise-equal to the Python engine's
    trace on everything but score/margin (ulp-level, see telemetry docs).
    Tracing never changes the compiled step's decisions or the metrics.
    """
    _validate_scheduler(scheduler)
    M = num_models or scheduler.table.num_models
    cfg = scheduler.config
    lanes = [_unpack_lane(lane, M, cfg.slo) for lane in arrival_lanes]
    if not lanes:
        return []
    if tracers is None:
        tracers = [None] * len(lanes)
    assert len(tracers) == len(lanes), "one tracer slot per lane"
    for tr in tracers:
        if tr is not None:
            tr.reset()
    any_tracer = any(tr is not None for tr in tracers)
    tau_vec = lanes[0].tau_vec
    for lane in lanes[1:]:
        if not np.array_equal(lane.tau_vec, tau_vec):
            raise ScanEngineUnsupported(
                "all lanes of one scan batch must share the same per-model "
                "deadline vector (split differing lanes into separate calls)"
            )

    n_max = max(
        (max((len(ix) for ix in lane.by_model), default=0) for lane in lanes),
        default=0,
    )
    n_total_max = max((len(lane.model) for lane in lanes), default=0)
    last_arrival = max(
        (lane.arrival[-1] for lane in lanes if len(lane.arrival)),
        default=0.0,
    )
    if factored is None:
        factored = bool(last_arrival / tau_vec.min() <= _FACTORED_RANGE)
    E = scheduler.table.num_exits
    Bmax = cfg.max_batch
    ladder = _build_ladder(scheduler, Bmax)
    allowed = tuple(e in scheduler._exits for e in range(E))
    rows = (
        [model_map[m] for m in range(M)] if model_map is not None
        else list(range(M))
    )
    sched_lat = _dense_latency(scheduler.table, list(range(M)), E, Bmax)
    exec_lat = _dense_latency(table, rows, E, Bmax)
    # [M, cap, E, R]: the candidate lattice's latencies per queue-length
    # cap, so in-scan enumeration is one take_along_axis over cap.
    ladder_np = np.array(ladder, dtype=np.int64)
    lat_by_cap = np.ascontiguousarray(
        sched_lat[:, :, ladder_np].transpose(0, 2, 1, 3)
    )
    limit = horizon + drain_cap
    # Idle rounds fold into dispatches, so rounds <= dispatches + 2 and
    # every dispatch serves >= 1 request.
    budget = n_total_max + 4

    Q = max_queue or min(_MAX_QUEUE_DEFAULT, _pow2(max(n_max, 1)))
    while True:
        P = _pow2(n_max + Q + 2)
        S = min(_pow2(budget), 1024)
        key = _StaticKey(
            num_models=M, num_exits=E, max_queue=Q, pad_len=P,
            chunk_steps=S, max_batch=Bmax, ladder=ladder, allowed=allowed,
            fallback_exit=scheduler._exits[0], clip=cfg.clip,
            factored=factored, emit_aux=keep_traces or any_tracer,
        )
        chunk_fn = _build_chunk_fn(key)
        arr = _pack_lanes(lanes, M, P, factored)
        with enable_x64():
            L = len(lanes)
            carry = (
                jnp.zeros(L, dtype=jnp.float64),
                jnp.zeros((L, M), dtype=jnp.int32),
                jnp.zeros(L, dtype=jnp.float64),
                jnp.zeros(L, dtype=bool),
                jnp.zeros(L, dtype=bool),
            )
            args = (
                jnp.asarray(arr),
                jnp.asarray(lat_by_cap),
                jnp.asarray(exec_lat),
                jnp.asarray(tau_vec),
                jnp.asarray(limit, dtype=jnp.float64),
            )
            ys_chunks = []
            steps_run = 0
            while True:
                carry, ys = chunk_fn(carry, *args)
                ys_chunks.append(jax.device_get(ys))
                steps_run += S
                done = np.asarray(carry[3])
                overflow = np.asarray(carry[4])
                if bool(done.all()) or bool(overflow.any()):
                    break
                if steps_run >= budget + S:
                    raise RuntimeError(
                        f"scan engine exceeded its step budget "
                        f"({steps_run} rounds for {n_total_max} arrivals); "
                        f"this indicates a termination bug — please report"
                    )
        if bool(np.asarray(carry[4]).any()):
            if Q >= max(n_max, 1):
                raise RuntimeError(
                    "scan engine overflowed a max_queue window already as "
                    "large as the densest arrival trace — please report"
                )
            if any_tracer:
                over = np.asarray(carry[4])
                t_over = np.asarray(carry[0])
                for i, tr in enumerate(tracers):
                    if tr is not None and bool(over[i]):
                        tr.record_event(
                            float(t_over[i]), "overflow-retry",
                            max_queue_from=Q, max_queue_to=Q * 2)
            Q = Q * 2  # retry with a wider window (sticky-flag overflow)
            continue
        break

    names = (
        ("code", "t0", "score", "margin") if key.emit_aux
        else ("code", "t0")
    )
    t_fin = np.asarray(carry[0])
    busy_fin = np.asarray(carry[2])
    cat = {
        n: (
            np.concatenate([np.asarray(c[j]) for c in ys_chunks], axis=1)
            if len(ys_chunks) > 1
            else np.asarray(ys_chunks[0][j])
        )
        for j, n in enumerate(names)
    }
    results = []
    for i, lane in enumerate(lanes):
        lane_ys = {n: col[i] for n, col in cat.items()}
        results.append(_reconstruct(
            lane_ys, lane, table, sched_lat, exec_lat, E, horizon,
            warmup_tasks, model_map, float(busy_fin[i]), float(t_fin[i]),
            keep_completions, keep_traces,
            tracer=tracers[i], slo=cfg.slo,
        ))
    return results


def simulate_scan(
    scheduler: Scheduler,
    table: ProfileTable,
    arrivals: Sequence[Request],
    horizon: float,
    num_models: Optional[int] = None,
    warmup_tasks: int = 100,
    model_map: Optional[Sequence[int]] = None,
    drain_cap: float = 600.0,
    max_queue: Optional[int] = None,
    keep_completions: bool = False,
    keep_traces: bool = False,
    factored: Optional[bool] = None,
    tracer: Optional[Tracer] = None,
) -> SimResult:
    """Compiled twin of ``ServingSimulator(...).run(...)`` for one trace:
    same arguments-to-metrics contract, one ``lax.scan`` instead of the
    Python event loop. See the module docstring for the supported feature
    matrix; unsupported configurations raise :class:`ScanEngineUnsupported`.
    """
    return simulate_scan_batch(
        scheduler, table, [arrivals], horizon,
        num_models=num_models, warmup_tasks=warmup_tasks,
        model_map=model_map, drain_cap=drain_cap, max_queue=max_queue,
        keep_completions=keep_completions, keep_traces=keep_traces,
        factored=factored,
        tracers=None if tracer is None else [tracer],
    )[0]
