from repro.data.pipeline import (
    cifar100_like,
    synthetic_lm_batches,
    synthetic_memorization_corpus,
)

__all__ = [
    "cifar100_like",
    "synthetic_lm_batches",
    "synthetic_memorization_corpus",
]
