"""Data pipeline: deterministic synthetic streams (offline container — no
downloads) shaped exactly like the real workloads.

* ``synthetic_lm_batches`` — Zipf-distributed token stream with a Markov
  backbone so a ~100M model has structure to learn; enc-dec and VLM
  variants emit the frontend-stub embeddings.
* ``cifar100_like`` — CIFAR-100-shaped image batches with class-conditional
  structure (the paper's request payloads).
* ``synthetic_memorization_corpus`` — small fixed corpus for convergence
  tests.
"""

from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _zipf_markov_tokens(rng: np.random.Generator, batch: int, seq: int,
                        vocab: int) -> np.ndarray:
    """Tokens with local structure: next ~ 0.7 * f(prev) + 0.3 * Zipf."""
    ranks = np.arange(1, vocab + 1)
    zipf = 1.0 / ranks
    zipf /= zipf.sum()
    # deterministic "grammar": successor table
    succ = rng.permutation(vocab)
    toks = np.empty((batch, seq), dtype=np.int64)
    toks[:, 0] = rng.choice(vocab, size=batch, p=zipf)
    follow = rng.uniform(size=(batch, seq)) < 0.7
    draws = rng.choice(vocab, size=(batch, seq), p=zipf)
    for t in range(1, seq):
        toks[:, t] = np.where(follow[:, t], succ[toks[:, t - 1]],
                              draws[:, t])
    return toks


def synthetic_lm_batches(
    vocab: int,
    batch: int,
    seq: int,
    seed: int = 0,
    encdec: bool = False,
    vision: bool = False,
    d_model: int = 64,
    src_len: int = 16,
) -> Iterator[dict]:
    """Endless iterator of training batches for any LM family."""
    rng = np.random.default_rng(seed)
    while True:
        toks = _zipf_markov_tokens(rng, batch, seq + 1, vocab)
        b = {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        if encdec:
            b["src_embeds"] = jnp.asarray(
                rng.normal(size=(batch, src_len, d_model)), jnp.float32)
        if vision:
            emb = rng.normal(size=(batch, seq, d_model))
            b = {"embeds": jnp.asarray(emb, jnp.float32),
                 "labels": b["labels"]}
        yield b


def cifar100_like(
    batch: int,
    num_classes: int = 100,
    seed: int = 0,
) -> "tuple[jnp.ndarray, jnp.ndarray]":
    """One CIFAR-100-shaped batch with class-conditional colour/frequency
    structure (learnable but synthetic; the container has no dataset)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, num_classes, size=batch)
    base_colour = np.stack([
        np.sin(labels * 0.7), np.cos(labels * 1.3), np.sin(labels * 2.1)
    ], axis=-1)[:, None, None, :]
    imgs = base_colour + 0.25 * rng.normal(size=(batch, 32, 32, 3))
    return (jnp.asarray(imgs, jnp.float32),
            jnp.asarray(labels, jnp.int32))


def synthetic_memorization_corpus(vocab: int, n: int = 8, seq: int = 32,
                                  seed: int = 3) -> dict:
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab, size=(n, seq))
    return {"tokens": jnp.asarray(toks, jnp.int32),
            "labels": jnp.asarray(toks, jnp.int32)}
