"""Pure-JAX optimizers with shardable state (no optax dependency)."""

from repro.optim.optimizers import (
    AdamW,
    Adafactor,
    OptState,
    Optimizer,
    SGD,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    make_optimizer,
)

__all__ = [
    "AdamW",
    "Adafactor",
    "OptState",
    "Optimizer",
    "SGD",
    "clip_by_global_norm",
    "cosine_schedule",
    "global_norm",
    "make_optimizer",
]
