"""Optimizers in pure JAX: AdamW, Adafactor (factored second moments for
671B-scale state), SGD; global-norm clipping; cosine LR schedule.

States are pytrees mirroring the param tree, so they inherit the params'
PartitionSpecs (ZeRO-3 comes free with FSDP rules). Adafactor's factored
moments drop the per-param second moment to O(rows + cols) — the difference
between deepseek-v3 fitting in v5e HBM or not (see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
OptState = Any


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


class Optimizer:
    """init(params) -> state; step(params, grads, state, step_no) ->
    (new_params, new_state)."""

    def init(self, params: PyTree) -> OptState:
        raise NotImplementedError

    def step(self, params, grads, state, step_no):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class SGD(Optimizer):
    lr: Any = 1e-2
    momentum: float = 0.9

    def _lr(self, step_no):
        return self.lr(step_no) if callable(self.lr) else self.lr

    def init(self, params):
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def step(self, params, grads, state, step_no):
        lr = self._lr(step_no)
        mu = jax.tree.map(
            lambda m, g: self.momentum * m + g.astype(m.dtype), state["mu"],
            grads)
        new_params = jax.tree.map(
            lambda p, m: (p - lr * m).astype(p.dtype), params, mu)
        return new_params, {"mu": mu}


@dataclasses.dataclass(frozen=True)
class AdamW(Optimizer):
    lr: Any = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def _lr(self, step_no):
        return self.lr(step_no) if callable(self.lr) else self.lr

    def init(self, params):
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params),
        }

    def step(self, params, grads, state, step_no):
        lr = self._lr(step_no)
        t = jnp.asarray(step_no, jnp.float32) + 1.0
        bc1 = 1.0 - self.b1 ** t
        bc2 = 1.0 - self.b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            update = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            update = update + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}


@dataclasses.dataclass(frozen=True)
class Adafactor(Optimizer):
    """Factored second-moment optimizer (Shazeer & Stern, 2018), the
    standard choice for 100B+ training state. For an [r, c] matrix it keeps
    row/col accumulators instead of the full [r, c] moment; >=3D params are
    factored over their two largest dims; 1D params keep full moments."""

    lr: Any = 1e-2
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    min_dim_size_to_factor: int = 128

    def _lr(self, step_no):
        return self.lr(step_no) if callable(self.lr) else self.lr

    def _factored_dims(self, shape) -> Optional[Tuple[int, int]]:
        if len(shape) < 2:
            return None
        sorted_dims = sorted(range(len(shape)), key=lambda i: shape[i])
        r, c = sorted_dims[-2], sorted_dims[-1]
        if shape[r] < self.min_dim_size_to_factor:
            return None
        return (r, c)

    def init(self, params):
        def one(p):
            f = self._factored_dims(p.shape)
            if f is None:
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            r, c = f
            vr_shape = tuple(d for i, d in enumerate(p.shape) if i != c)
            vc_shape = tuple(d for i, d in enumerate(p.shape) if i != r)
            return {
                "vr": jnp.zeros(vr_shape, jnp.float32),
                "vc": jnp.zeros(vc_shape, jnp.float32),
            }
        return {"v": jax.tree.map(one, params)}

    def step(self, params, grads, state, step_no):
        lr = self._lr(step_no)
        t = jnp.asarray(step_no, jnp.float32) + 1.0
        beta = 1.0 - t ** (-self.decay)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            f = self._factored_dims(p.shape)
            if f is None:
                v = beta * s["v"] + (1 - beta) * g2
                update = g * jax.lax.rsqrt(v + self.eps)
                new_s = {"v": v}
            else:
                r, c = f
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=c)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=r)
                r_factor = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + self.eps)
                c_factor = jax.lax.rsqrt(vc + self.eps)
                update = (
                    g
                    * jnp.expand_dims(r_factor, c)
                    * jnp.expand_dims(c_factor, r)
                )
                new_s = {"vr": vr, "vc": vc}
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(update * update))
            update = update / jnp.maximum(1.0, rms / self.clip_threshold)
            return (p.astype(jnp.float32) - lr * update).astype(p.dtype), new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_v = treedef.unflatten([o[1] for o in out])
        return new_p, {"v": new_v}


def make_optimizer(name: str, lr: Any = None, **kw) -> Optimizer:
    name = name.lower()
    if name == "adamw":
        return AdamW(lr=lr if lr is not None else 3e-4, **kw)
    if name == "adafactor":
        return Adafactor(lr=lr if lr is not None else 1e-2, **kw)
    if name == "sgd":
        return SGD(lr=lr if lr is not None else 1e-2, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
