"""Multi-replica traffic router: the `pod` axis of the serving mesh.

Each pod (or pod slice) runs an independent EdgeServing instance — the
paper's single-accelerator scheduler is the intra-replica brain; this
router is the inter-replica layer that makes it a 1000+-node system:

  * **pluggable dispatch**: replica selection goes through the shared
    ``repro.core.cluster`` :class:`Dispatcher` family (round-robin, JSQ,
    capacity-weighted least-loaded, stability-aware power-of-d) — the same
    implementations the cluster simulator exercises, with the router acting
    as the :class:`DeviceLoadView`. The default remains capacity-weighted
    least-loaded (expected backlog drain time / straggler-scaled capacity),
    which generalises join-shortest-queue to heterogeneous replica speeds;
  * **straggler awareness**: replica capacity weights come from
    ``StragglerPolicy`` EWMA multipliers (observed/expected quantum time),
    so degraded hardware automatically sheds load and detached replicas
    receive none;
  * **locality stickiness**: an optional key (e.g. session id) maps to a
    preferred replica by rendezvous hashing; the router only overrides the
    preference when the preferred replica's backlog exceeds the best one by
    ``spill_factor`` — bounded-load consistent hashing.

The router is deliberately stateless w.r.t. request contents: it reads
only queue backlogs, queue lengths, and capacity weights, all O(replicas)
to maintain.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import (
    DeviceLoadView,
    Dispatcher,
    LeastLoadedDispatcher,
    drain_estimate,
)
from repro.core.profile import ProfileTable
from repro.runtime.fault_tolerance import StragglerPolicy


@dataclasses.dataclass
class ReplicaState:
    """Router-visible state of one serving replica (one pod slice)."""

    backlog_s: float = 0.0        # expected time to drain current queues
    healthy: bool = True
    # Last reported per-model queue lengths; None = never reported (the
    # router then derives a count estimate from the backlog instead).
    qlens: Optional[Tuple[int, ...]] = None
    # Requests routed here since the last queue-length report (the greedy
    # in-flight estimate that lets bursts spread under JSQ dispatch too).
    pending: int = 0


class ReplicaRouter(DeviceLoadView):
    def __init__(
        self,
        num_replicas: int,
        straggler: Optional[StragglerPolicy] = None,
        spill_factor: float = 2.0,
        table: Optional[ProfileTable] = None,
        max_batch: int = 10,
        dispatcher: Optional[Dispatcher] = None,
    ):
        """Args:
          table: the replicas' profile table; when given, backlog bumps and
            completion predictions use real per-item service shares instead
            of a placeholder constant.
          max_batch: the serving policy's batch cap B_max (sets the per-item
            share ``L(m, e_final, B_cap) / B_cap``).
          dispatcher: replica-selection policy; default capacity-weighted
            least-loaded (the router's historical behaviour).
        """
        assert num_replicas >= 1
        self.replicas = [ReplicaState() for _ in range(num_replicas)]
        self.straggler = straggler or StragglerPolicy(num_replicas)
        self.spill_factor = spill_factor
        self.table = table
        self.max_batch = max_batch
        self.dispatcher = dispatcher or LeastLoadedDispatcher()
        # Hermeticity (the Dispatcher contract): a router owns its
        # dispatcher's state; reusing one object across routers must not
        # leak RNG/counter state between experiments.
        self.dispatcher.reset(0)
        # Mean per-item service share at the policy's batch cap, final exit
        # (conservative): the backlog a replica gains per routed request.
        if table is not None:
            cap = min(max_batch, table.max_batch)
            e = table.num_exits - 1
            self._service_share = float(np.mean(
                [table(m, e, cap) / cap for m in range(table.num_models)]
            ))
        else:
            self._service_share = 1e-3  # no table: nominal 1 ms placeholder

    # -- state ingestion ------------------------------------------------------

    def update_backlog(self, replica: int, expected_drain_s: float,
                       qlens: Optional[Sequence[int]] = None) -> None:
        """A fresh replica report supersedes the router's greedy in-flight
        estimates (the routed-but-unreported requests are now part of the
        replica's own numbers). A backlog-only report also invalidates any
        earlier queue-length snapshot — keeping a stale ``qlens`` alongside
        a fresh backlog would make JSQ dispatch read two different eras of
        the same replica."""
        self.replicas[replica].backlog_s = expected_drain_s
        self.replicas[replica].pending = 0
        self.replicas[replica].qlens = (
            tuple(int(n) for n in qlens) if qlens is not None else None
        )

    def observe_quantum(self, replica: int, observed_s: float,
                        expected_s: float) -> None:
        """Feed per-quantum timing into the straggler EWMA."""
        self.straggler.observe(replica, observed_s, expected_s)
        healthy = set(self.straggler.healthy())
        for i, r in enumerate(self.replicas):
            r.healthy = i in healthy

    @staticmethod
    def backlog_from_queues(table: ProfileTable, qlens: Sequence[int],
                            exit_idx: Optional[int] = None,
                            max_batch: int = 10) -> float:
        """Expected drain time of a replica's queues at full batches
        (the router's cheap load signal; final exit = conservative)."""
        e = table.num_exits - 1 if exit_idx is None else exit_idx
        total = 0.0
        for m, n in enumerate(qlens):
            full, rem = divmod(n, max_batch)
            total += full * table(m, e, max_batch)
            if rem:
                total += table(m, e, rem)
        return total

    @staticmethod
    def backlog_from_scheduler(scheduler, qlens: Sequence[int],
                               exit_idx: Optional[int] = None) -> float:
        """Policy-aware drain estimate: derives batch sizes from the
        replica scheduler's own candidate ladder (its ``max_batch`` cap,
        its profile table) instead of caller-supplied constants, so a
        replica running e.g. a bs=1 ablation or a small-B_max deployment
        advertises its true (slower) drain time to the router. Closed form
        over the batch ladder (full-batch quotient + remainder rung); see
        ``repro.core.cluster.drain_estimate``.
        """
        return drain_estimate(scheduler, qlens, exit_idx=exit_idx)

    # -- DeviceLoadView (consumed by the shared dispatchers) ------------------

    def healthy(self, i: int) -> bool:
        return self.replicas[i].healthy

    def effective_backlog(self, i: int) -> float:
        """Backlog scaled by the straggler multiplier (slow replica ->
        its queued work takes proportionally longer to drain)."""
        return self.replicas[i].backlog_s * float(
            self.straggler.multipliers[i])

    def total_queued(self, i: int) -> int:
        """Queued-request count for JSQ-style dispatch: the last reported
        queue lengths plus requests routed here since that report (so a
        ``route_batch`` burst spreads under JSQ too). When a replica has
        never reported queue lengths, fall back to the backlog divided by
        the per-item service share (expected count at mean service time;
        the backlog already carries the per-route bumps) so JSQ degrades
        to backlog ordering instead of dogpiling replica 0."""
        r = self.replicas[i]
        if r.qlens is not None:
            return sum(r.qlens) + r.pending
        return int(round(r.backlog_s / self._service_share))

    def predicted_completion(self, i: int, model: int) -> float:
        mult = float(self.straggler.multipliers[i])
        service = (
            self.table(model, self.table.num_exits - 1, 1)
            if self.table is not None else self._service_share
        )
        return self.effective_backlog(i) + service * mult

    # -- routing ---------------------------------------------------------------

    def route(self, key: Optional[str] = None, model: int = 0) -> int:
        """Pick a replica for one request.

        Without a key: dispatcher policy over healthy replicas (default:
        capacity-weighted least-loaded). With a key: rendezvous-hash
        preference, spilled to the least-loaded replica only when the
        preferred one is ``spill_factor``x worse. The keyed path never
        consults the dispatcher, so stateful dispatchers (round-robin
        counter, power-of-d RNG) advance only for requests they route.
        """
        healthy = [i for i, r in enumerate(self.replicas) if r.healthy]
        if not healthy:  # total failure: degrade to round-robin over all
            healthy = list(range(len(self.replicas)))
        if key is None:
            return self.dispatcher.pick(model, healthy, self)
        preferred = max(
            healthy,
            key=lambda i: hashlib.blake2b(
                f"{key}|{i}".encode(), digest_size=8).digest(),
        )
        best = min(healthy, key=lambda i: (self.effective_backlog(i), i))
        pref_load = self.effective_backlog(preferred)
        best_load = self.effective_backlog(best)
        if pref_load <= self.spill_factor * max(best_load, 1e-9):
            return preferred
        return best

    def route_batch(self, n: int, key_prefix: Optional[str] = None,
                    model: int = 0) -> List[int]:
        """Route n requests, refreshing the load view greedily per request
        (each assignment bumps the chosen replica's backlog estimate by its
        per-item service share — ``mean_m L(m, e_final, B_cap) / B_cap``
        from the profile table when available — so a burst spreads correctly
        even on slow fleets instead of dogpiling)."""
        out = []
        if not any(r.healthy for r in self.replicas):
            return [i % len(self.replicas) for i in range(n)]
        for j in range(n):
            i = self.route(f"{key_prefix}:{j}" if key_prefix else None,
                           model=model)
            out.append(i)
            self.replicas[i].backlog_s += self._service_share
            self.replicas[i].pending += 1
        return out
