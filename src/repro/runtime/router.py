"""Multi-replica traffic router: the `pod` axis of the serving mesh.

Each pod (or pod slice) runs an independent EdgeServing instance — the
paper's single-accelerator scheduler is the intra-replica brain; this
router is the inter-replica layer that makes it a 1000+-node system:

  * **capacity-weighted routing**: requests are routed by weighted
    least-loaded (expected backlog drain time / straggler-scaled capacity),
    which generalises join-shortest-queue to heterogeneous replica speeds;
  * **straggler awareness**: replica capacity weights come from
    ``StragglerPolicy`` EWMA multipliers (observed/expected quantum time),
    so degraded hardware automatically sheds load and detached replicas
    receive none;
  * **locality stickiness**: an optional key (e.g. session id) maps to a
    preferred replica by rendezvous hashing; the router only overrides the
    preference when the preferred replica's backlog exceeds the best one by
    ``spill_factor`` — bounded-load consistent hashing.

The router is deliberately stateless w.r.t. request contents: it reads
only queue backlogs and capacity weights, both O(replicas) to maintain.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Optional, Sequence

import numpy as np

from repro.core.profile import ProfileTable
from repro.runtime.fault_tolerance import StragglerPolicy


@dataclasses.dataclass
class ReplicaState:
    """Router-visible state of one serving replica (one pod slice)."""

    backlog_s: float = 0.0        # expected time to drain current queues
    healthy: bool = True


class ReplicaRouter:
    def __init__(
        self,
        num_replicas: int,
        straggler: Optional[StragglerPolicy] = None,
        spill_factor: float = 2.0,
    ):
        assert num_replicas >= 1
        self.replicas = [ReplicaState() for _ in range(num_replicas)]
        self.straggler = straggler or StragglerPolicy(num_replicas)
        self.spill_factor = spill_factor

    # -- state ingestion ------------------------------------------------------

    def update_backlog(self, replica: int, expected_drain_s: float) -> None:
        self.replicas[replica].backlog_s = expected_drain_s

    def observe_quantum(self, replica: int, observed_s: float,
                        expected_s: float) -> None:
        """Feed per-quantum timing into the straggler EWMA."""
        self.straggler.observe(replica, observed_s, expected_s)
        healthy = set(self.straggler.healthy())
        for i, r in enumerate(self.replicas):
            r.healthy = i in healthy

    @staticmethod
    def backlog_from_queues(table: ProfileTable, qlens: Sequence[int],
                            exit_idx: Optional[int] = None,
                            max_batch: int = 10) -> float:
        """Expected drain time of a replica's queues at full batches
        (the router's cheap load signal; final exit = conservative)."""
        e = table.num_exits - 1 if exit_idx is None else exit_idx
        total = 0.0
        for m, n in enumerate(qlens):
            full, rem = divmod(n, max_batch)
            total += full * table(m, e, max_batch)
            if rem:
                total += table(m, e, rem)
        return total

    @staticmethod
    def backlog_from_scheduler(scheduler, qlens: Sequence[int],
                               exit_idx: Optional[int] = None) -> float:
        """Policy-aware drain estimate: derives batch sizes from the
        replica scheduler's own candidate ladder (its ``max_batch`` cap,
        its profile table) instead of caller-supplied constants, so a
        replica running e.g. a bs=1 ablation or a small-B_max deployment
        advertises its true (slower) drain time to the router."""
        table = scheduler.table
        e = table.num_exits - 1 if exit_idx is None else exit_idx
        total = 0.0
        for m, n in enumerate(qlens):
            while n > 0:
                # the Eq. 5 cap for this queue state under the policy's
                # B_max (subclasses like the bs=1 ablation override it)
                b = scheduler.batch_size(n)
                total += table(m, e, b)
                n -= b
        return total

    # -- routing ---------------------------------------------------------------

    def _effective_backlog(self, i: int) -> float:
        """Backlog scaled by the straggler multiplier (slow replica ->
        its queued work takes proportionally longer to drain)."""
        return self.replicas[i].backlog_s * float(
            self.straggler.multipliers[i])

    def route(self, key: Optional[str] = None) -> int:
        """Pick a replica for one request.

        Without a key: weighted least-loaded among healthy replicas.
        With a key: rendezvous-hash preference, spilled to the least-loaded
        replica only when the preferred one is ``spill_factor``x worse.
        """
        healthy = [i for i, r in enumerate(self.replicas) if r.healthy]
        if not healthy:  # total failure: degrade to round-robin over all
            healthy = list(range(len(self.replicas)))
        best = min(healthy, key=self._effective_backlog)
        if key is None:
            return best
        preferred = max(
            healthy,
            key=lambda i: hashlib.blake2b(
                f"{key}|{i}".encode(), digest_size=8).digest(),
        )
        pref_load = self._effective_backlog(preferred)
        best_load = self._effective_backlog(best)
        if pref_load <= self.spill_factor * max(best_load, 1e-9):
            return preferred
        return best

    def route_batch(self, n: int, key_prefix: Optional[str] = None) -> List[int]:
        """Route n requests, refreshing the load view greedily per request
        (each assignment bumps the chosen replica's backlog estimate by its
        mean service share so a burst spreads instead of dogpiling)."""
        out = []
        if not any(r.healthy for r in self.replicas):
            return [i % len(self.replicas) for i in range(n)]
        mean_quantum = 1e-3
        for j in range(n):
            i = self.route(f"{key_prefix}:{j}" if key_prefix else None)
            out.append(i)
            self.replicas[i].backlog_s += mean_quantum
        return out
