"""Fault tolerance for 1000+ node deployments: preemption handling, elastic
remeshing, and straggler mitigation.

What is *mechanised* here (and exercised by tests on CPU):
  * ``PreemptionGuard`` — SIGTERM/flag-triggered graceful drain: finish the
    in-flight quantum/step, force a checkpoint, exit cleanly.
  * ``ElasticMesh`` — rebuild the largest valid mesh from surviving devices
    and re-lower the step functions; restore re-shards the last committed
    checkpoint onto the new mesh (Checkpointer.restore(shardings=...)).
  * ``StragglerPolicy`` — serving-side mitigation consistent with the
    paper's determinism story: the profile table is scaled by an online
    EWMA of observed/expected latency per replica, so a slow replica's
    queue predictions stay truthful and the stability score automatically
    routes load away from it. (Under time-division there is no intra-step
    collective to desynchronise; stragglers show up as inflated service
    times, which is exactly what the profile multiplier models.)

On real multi-host TPU deployments the failure *detector* is the platform
(GKE/Borg preemption notices, ICI heartbeats); these classes consume a
simple boolean/callback so any detector can drive them.
"""

from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Callable, Iterable, List, Optional

import jax
import numpy as np


class PreemptionGuard:
    """Graceful-drain coordinator.

    Usage:
        guard = PreemptionGuard(install_sigterm=True)
        for step in ...:
            ...train/serve one quantum...
            if guard.should_stop():
                checkpointer.save(step, state); checkpointer.wait(); break
    """

    def __init__(self, install_sigterm: bool = False,
                 deadline_s: Optional[float] = None):
        self._stop = threading.Event()
        self._deadline = (time.monotonic() + deadline_s) if deadline_s else None
        if install_sigterm:
            signal.signal(signal.SIGTERM, self._handler)

    def _handler(self, signum, frame):
        self._stop.set()

    def request_stop(self):
        self._stop.set()

    def should_stop(self) -> bool:
        if self._deadline is not None and time.monotonic() > self._deadline:
            return True
        return self._stop.is_set()


@dataclasses.dataclass
class ElasticMesh:
    """Largest-valid-mesh policy for elastic scaling.

    Given a surviving device count, pick the largest (data, model) grid with
    the model axis preserved (TP degree is fixed by the weight sharding) and
    the data axis shrunk to the largest feasible power-of-two. Training
    semantics are preserved by keeping the *global* batch constant and
    increasing grad-accumulation to cover lost data-parallel rank.
    """

    model_axis: int = 16

    def propose(self, num_devices: int) -> "tuple[int, int, int]":
        """Returns (data_axis, model_axis, grad_accum_multiplier)."""
        assert num_devices >= self.model_axis, (
            "fewer devices than the TP degree: cannot remesh without "
            "re-sharding weights"
        )
        data = num_devices // self.model_axis
        # shrink to a power of two for predictable collectives
        data_pow2 = 1 << (data.bit_length() - 1)
        full_data = 16
        accum = max(1, -(-full_data // data_pow2))
        return data_pow2, self.model_axis, accum

    def build(self, num_devices: Optional[int] = None):
        devices = jax.devices()
        n = num_devices if num_devices is not None else len(devices)
        data, model, accum = self.propose(n)
        mesh = jax.make_mesh((data, model), ("data", "model"),
                             devices=np.asarray(devices[: data * model]))
        return mesh, accum


class StragglerPolicy:
    """Per-replica service-time inflation tracking (EWMA of observed /
    profiled latency). The serving router divides each replica's effective
    throughput by its multiplier; the scheduler's profile lookups are scaled
    so stability-score predictions stay truthful on degraded hardware."""

    def __init__(self, num_replicas: int, alpha: float = 0.2,
                 detach_threshold: float = 3.0):
        self.alpha = alpha
        self.detach_threshold = detach_threshold
        self.multipliers = np.ones(num_replicas)

    def observe(self, replica: int, observed_s: float, expected_s: float):
        ratio = max(observed_s / max(expected_s, 1e-9), 1e-3)
        m = self.multipliers[replica]
        self.multipliers[replica] = (1 - self.alpha) * m + self.alpha * ratio

    def healthy(self) -> List[int]:
        return [i for i, m in enumerate(self.multipliers)
                if m < self.detach_threshold]

    def scale_profile(self, replica: int, table):
        """ProfileTable view with this replica's inflation applied."""
        return table.scaled(float(self.multipliers[replica]),
                            name=f"replica{replica}")
