"""Live serving engine: the paper's "GPU runtime" on a real accelerator.

Time-division execution of M early-exit models behind FIFO queues, driven
by any ``repro.core`` scheduler. The engine shares queues/snapshot/metrics
code with the simulator — the only difference is that service time comes
from executing the jitted ``forward_exit`` on the device instead of the
profile table.

Offline phase  = ``measure_profile`` (wall-clock profile of every
(m, e, B) — one compiled executable per cell, exactly the paper's 120-cell
table), then ``ServingEngine.run`` is the online phase. With an
``OnlineProfiler`` attached (``repro.core.adaptive``), the offline table is
only the *cold start*: measured wall-clock service times feed back into
refreshed scheduler tables while serving, tracking device drift (thermal
throttling, DVFS, contention) the offline profile cannot see. Semantics and
usage: docs/runtime.md.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive import OnlineProfiler
from repro.core.metrics import summarize
from repro.core.profile import ProfileTable
from repro.core.queues import QueueSnapshot, ServiceQueue
from repro.core.request import Completion, Request
from repro.core.scheduler import Scheduler
from repro.core.telemetry import Tracer, decision_margin


@dataclasses.dataclass
class ServedModel:
    """One deployed early-exit model behind its FIFO queue (paper Sec. III).

    Attributes:
      name:       display/profile-row name (e.g. ``"resnet50"``).
      values:     model parameters (pytree) passed to ``forward_fn``.
      forward_fn: ``(values, data, exit_idx) -> outputs`` — one full
                  inference truncated at exit ``exit_idx`` (jit-able; the
                  engine compiles one executable per (m, e, B) cell).
      data_fn:    ``(batch_size) -> input payload batch`` for profiling and
                  serving quanta.
      num_exits:  number of early-exit heads, shallowest -> deepest.
    """

    name: str
    values: Any
    forward_fn: Callable[[Any, Any, int], Any]
    data_fn: Callable[[int], Any]
    num_exits: int


def measure_profile(
    models: Sequence[ServedModel],
    batch_sizes: Sequence[int],
    exit_names: Optional[Sequence[str]] = None,
    accuracy: Optional[np.ndarray] = None,
    repeats: int = 10,
    warmup: int = 2,
    percentile: float = 95.0,
) -> ProfileTable:
    """Offline profiling phase (paper Sec. IV-B) against the live device.

    Compiles one executable per (m, e, B) cell and records the
    ``percentile`` wall-clock latency over ``repeats`` runs after ``warmup``
    discarded runs (``ProfileTable.measure`` underneath) — the paper's
    120-cell table, measured rather than calibrated. The result is the
    scheduler's *cold-start* belief; attach an
    ``repro.core.adaptive.OnlineProfiler`` to :class:`ServingEngine` to keep
    it tracking the device online (docs/runtime.md "Online adaptation").
    """
    compiled: Dict[Tuple[int, int, int], Callable] = {}

    def run_fn(m: int, e: int, b: int):
        key = (m, e, b)
        if key not in compiled:
            mod = models[m]
            fn = jax.jit(
                lambda v, x, _e=e, _mod=mod: _mod.forward_fn(v, x, _e))
            compiled[key] = fn
        mod = models[m]
        out = compiled[key](mod.values, mod.data_fn(b))
        jax.block_until_ready(out)

    n_exits = models[0].num_exits
    return ProfileTable.measure(
        [m.name for m in models],
        exit_names or [f"exit{i}" for i in range(n_exits)],
        list(batch_sizes),
        run_fn,
        accuracy=accuracy,
        repeats=repeats,
        warmup=warmup,
        percentile=percentile,
        meta={"platform": jax.devices()[0].platform},
    )


class ServingEngine:
    """Online serving loop (paper Sec. III "Online Serving Phase").

    The same snapshot -> prune -> decide -> occupy round as the simulator,
    but each quantum executes a jitted forward on the device and service
    time is whatever the wall clock says. ``profiler`` (optional) is an
    ``repro.core.adaptive.OnlineProfiler``: every quantum's measured
    service time is folded into it and the scheduler's table is swapped for
    its refreshed view on the profiler's cadence — online profile
    adaptation over the ``measure_profile`` cold start (docs/runtime.md).
    """

    def __init__(
        self,
        models: Sequence[ServedModel],
        scheduler: Scheduler,
        clock: Callable[[], float] = time.monotonic,
        profiler: Optional[OnlineProfiler] = None,
        tracer: Optional[Tracer] = None,
    ):
        self.models = list(models)
        self.scheduler = scheduler
        self.clock = clock
        self.profiler = profiler
        # Record-only telemetry (repro.core.telemetry): live runs emit the
        # same decision/span/event vocabulary as the simulators, so one
        # tools/tracestats.py invocation reads either. None = zero cost.
        self.tracer = tracer
        self.queues = [ServiceQueue(m) for m in range(len(models))]
        self.completions: List[Completion] = []
        self.dropped = 0
        self._compiled: Dict[Tuple[int, int, int], Callable] = {}
        self._busy_s = 0.0
        self._unsubmitted = 0  # trace tail never ingested (drain-cap exit)
        # Structured engine counters, cumulative across run() calls (like
        # the completion log); "engine-counters" trace events snapshot them
        # at each run() exit. stalls = idle rounds that slept.
        self.counters: Dict[str, int] = {
            "batches_served": 0,
            "requests_served": 0,
            "stalls": 0,
            "profiler_refreshes": 0,
            "dropped": 0,
            "drain_residual": 0,
        }

    # -- ingress ---------------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Enqueue one request (paper: arrivals are never gated on
        accelerator state; they become visible at the next round)."""
        self.queues[req.model].push(req)

    # -- execution ---------------------------------------------------------------

    def _execute(self, m: int, e: int, b: int):
        key = (m, e, b)
        if key not in self._compiled:
            mod = self.models[m]
            self._compiled[key] = jax.jit(
                lambda v, x, _e=e, _mod=mod: _mod.forward_fn(v, x, _e))
        mod = self.models[m]
        out = self._compiled[key](mod.values, mod.data_fn(b))
        jax.block_until_ready(out)
        return out

    def warmup(self, batch_sizes: Optional[Sequence[int]] = None) -> None:
        """Pre-compile every (m, e, B) so online serving never JITs.

        ``batch_sizes=None`` derives the reachable batch set from the
        scheduler itself: the union of its candidate ladders over every
        possible queue length up to B_max (greedy and lattice policies both
        cap batches at ``config.max_batch``, and any smaller batch can occur
        when a queue is short, so this is exactly the dispatchable set).
        """
        if batch_sizes is None:
            reach = set()
            for qlen in range(1, self.scheduler.config.max_batch + 1):
                reach.update(self.scheduler.batch_candidates(qlen))
            batch_sizes = sorted(reach)
        for m, mod in enumerate(self.models):
            for e in range(mod.num_exits):
                for b in batch_sizes:
                    self._execute(m, e, b)

    def run(
        self,
        arrivals: Sequence[Request],
        duration: float,
        drain: bool = True,
        idle_sleep: float = 1e-4,
        drain_cap: float = 600.0,
    ) -> "tuple[list[Completion], float]":
        """Serve a pre-generated arrival trace in real time.

        Arrival times in the trace are relative to loop start; requests are
        enqueued when the wall clock passes them (paper: requests arrive
        continuously, regardless of accelerator state).

        ``drain_cap`` mirrors the simulator's semantics: a hard wall-clock
        cap on post-``duration`` draining. Without it, ``drain=True``
        busy-waits forever whenever a policy leaves queues non-empty while
        ``decide`` keeps returning ``None`` (e.g. a pruning baseline that
        sheds nothing further but never dispatches). Requests stranded at
        the cap stay queued and are surfaced via ``metrics().residual_queue``.

        With a ``profiler`` attached, each quantum's measured wall-clock
        service feeds ``OnlineProfiler.observe`` and the scheduler's table
        is refreshed in place on the profiler's cadence.
        """
        t0 = self.clock()
        next_arr = 0
        n = len(arrivals)
        self._unsubmitted = 0
        tracer = self.tracer
        slo = self.scheduler.config.slo
        while True:
            now = self.clock() - t0
            while next_arr < n and arrivals[next_arr].arrival <= now:
                self.submit(arrivals[next_arr])
                next_arr += 1
            if now > duration + drain_cap:
                # stranded work stays queued; the never-ingested trace tail
                # is counted too so completions + dropped + residual still
                # equals the arrival count (mirrors the simulator).
                self._unsubmitted = n - next_arr
                break
            if now > duration and next_arr >= n:
                if not drain or all(len(q) == 0 for q in self.queues):
                    break
            snapshot = QueueSnapshot.take(self.queues, now)
            for m, cnt in self.scheduler.prune(snapshot):
                popped = self.queues[m].pop_batch(cnt)
                n_shed = len(popped)
                self.dropped += n_shed
                self.counters["dropped"] += n_shed
                if tracer is not None:
                    for req in popped:
                        tracer.record_drop(req, now, slo)
                    if n_shed:
                        tracer.record_event(now, "shed", n=n_shed)
                if self.profiler is not None:
                    self.profiler.observe_dropped(n_shed)
            decision = self.scheduler.decide(snapshot)
            if decision is None:
                self.counters["stalls"] += 1
                time.sleep(idle_sleep)
                continue
            batch = self.queues[decision.model].pop_batch(decision.batch_size)
            t_dispatch = self.clock() - t0
            self._execute(decision.model, decision.exit_idx,
                          decision.batch_size)
            t_done = self.clock() - t0
            self._busy_s += t_done - t_dispatch
            self.counters["batches_served"] += 1
            self.counters["requests_served"] += len(batch)
            if tracer is not None:
                tracer.record_decision(
                    t_dispatch, decision, t_done,
                    tuple(snapshot.qlens()),
                    tuple(snapshot.w_max(m)
                          for m in range(len(self.queues))),
                    margin=decision_margin(self.scheduler, snapshot),
                )
            for req in batch:
                self.completions.append(Completion(
                    req_id=req.req_id, model=req.model, arrival=req.arrival,
                    dispatch=t_dispatch, finish=t_done,
                    exit_idx=decision.exit_idx,
                    batch_size=decision.batch_size,
                    deadline=req.deadline,
                ))
                if tracer is not None:
                    tracer.record_completion(
                        req, t_dispatch, t_done, decision.exit_idx,
                        decision.batch_size, slo)
            if self.profiler is not None:
                refreshed = self.profiler.ingest_quantum(
                    decision.model, decision.exit_idx, decision.batch_size,
                    t_done - t_dispatch, t_done, batch,
                    self.scheduler.config.slo)
                if refreshed is not None:
                    self.scheduler.table = refreshed
                    self.counters["profiler_refreshes"] += 1
                    if tracer is not None:
                        tracer.record_refresh(t_done, self.profiler)
        t_exit = self.clock() - t0
        self.counters["drain_residual"] = (
            sum(len(q) for q in self.queues) + self._unsubmitted)
        if tracer is not None:
            tracer.record_event(t_exit, "engine-counters", **self.counters)
        return self.completions, t_exit

    def metrics(self, table: ProfileTable, slo: float, span: float,
                warmup_tasks: int = 0):
        """Aggregate the completion log (paper Sec. VI metrics): the shared
        ``repro.core.metrics.summarize`` over live completions, with queued
        + never-ingested requests surfaced as ``residual_queue`` so
        completions + dropped + residual always equals the arrival count."""
        return summarize(
            self.completions, table, slo, warmup_tasks=warmup_tasks,
            busy_time=self._busy_s, span=span,
            residual_queue=(sum(len(q) for q in self.queues)
                            + self._unsubmitted),
            dropped=self.dropped,
        )

    def trace(self, **meta):
        """Freeze the attached tracer's timeline as a ``telemetry.Trace``
        (``None`` when no tracer is attached). Unlike the simulators the
        engine is long-lived, so the caller decides when to snapshot;
        residual-span accounting covers whatever is still queued now."""
        if self.tracer is None:
            return None
        slo = self.scheduler.config.slo
        for q in self.queues:
            for req in q.pending():
                self.tracer.record_residual(req, slo, device=-1)
        base = dict(engine="live", num_models=len(self.models),
                    num_devices=1, slo=slo)
        base.update(meta)
        return self.tracer.freeze(**base)
