"""Sharded, asynchronous, atomic checkpointing (fault-tolerance substrate).

Layout (one directory per step):

    <root>/step_000123/
        manifest.json        # treedef, shapes, dtypes, step, mesh, config
        leaf_00000.npy ...   # one file per pytree leaf (full array)
    <root>/step_000123.COMMITTED   # atomic commit marker (written last)

Design points for 1000+ node deployments (documented in DESIGN.md §5):
  * **atomic commit**: readers only consume directories with a COMMITTED
    marker, so a preempted writer never corrupts the restore path;
  * **async save**: the host thread snapshots device arrays (device_get) and
    hands serialisation to a background thread — the training loop resumes
    immediately after the snapshot;
  * **restore with resharding**: arrays are loaded and device_put against
    the *current* mesh's NamedShardings, so a 512-chip checkpoint restores
    onto a 256-chip elastic fallback mesh unchanged (shard shapes are
    re-derived from the specs, not stored);
  * on multi-controller deployments each host writes only the leaves it
    owns (``process_index`` filter); in this single-process container that
    set is all leaves.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_COMMIT_SUFFIX = ".COMMITTED"


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


class Checkpointer:
    def __init__(self, root: str, keep: int = 3, async_save: bool = True):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        os.makedirs(root, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        if async_save:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- write path ---------------------------------------------------------

    def save(self, step: int, tree: PyTree, extra: Optional[dict] = None,
             timestamp: Optional[float] = None):
        """Snapshot + (a)synchronously persist. Returns after the snapshot:
        device buffers may be donated/overwritten immediately.

        ``timestamp`` is caller-injected wall time for the manifest's
        ``time`` field; the default ``None`` omits the field entirely, so
        identical trees produce bytes-identical checkpoints (the manifest
        is part of the repo's determinism contract — see DET002 in
        docs/static-analysis.md)."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self._q.put((step, host_tree, extra or {}, timestamp))
        else:
            self._write(step, host_tree, extra or {}, timestamp)

    def wait(self):
        """Block until all queued saves are durable (tests / shutdown)."""
        self._q.join()
        if self._last_error:
            raise self._last_error

    def _drain(self):
        while True:
            step, tree, extra, timestamp = self._q.get()
            try:
                self._write(step, tree, extra, timestamp)
            except BaseException as e:  # surfaced on wait()
                self._last_error = e
            finally:
                self._q.task_done()

    def _write(self, step: int, host_tree: PyTree, extra: dict,
               timestamp: Optional[float] = None):
        d = _step_dir(self.root, step)
        tmp = d + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = jax.tree.flatten(host_tree)
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(host_tree).serialize_using_proto().hex(),
            "num_leaves": len(leaves),
            "shapes": [list(l.shape) for l in leaves],
            "dtypes": [str(l.dtype) for l in leaves],
            "extra": extra,
        }
        if timestamp is not None:
            manifest["time"] = float(timestamp)
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), leaf,
                    allow_pickle=False)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        with open(d + _COMMIT_SUFFIX, "w") as f:
            f.write(str(step))
        self._gc()

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(_step_dir(self.root, s), ignore_errors=True)
            try:
                os.remove(_step_dir(self.root, s) + _COMMIT_SUFFIX)
            except FileNotFoundError:
                pass

    # -- read path -----------------------------------------------------------

    def committed_steps(self) -> "list[int]":
        out = []
        for name in os.listdir(self.root):
            if name.endswith(_COMMIT_SUFFIX):
                out.append(int(name[len("step_"):-len(_COMMIT_SUFFIX)]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings: PyTree = None,
                template: PyTree = None) -> "tuple[int, PyTree, dict]":
        """Load a committed checkpoint.

        Args:
          step: specific step (default: latest committed).
          shardings: optional NamedSharding tree — arrays are device_put
            against it (resharding onto the current mesh).
          template: optional pytree with the expected structure; used to
            validate the manifest structure matches.
        Returns (step, tree, extra).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {self.root}")
        d = _step_dir(self.root, step)
        if not os.path.exists(d + _COMMIT_SUFFIX):
            raise FileNotFoundError(f"checkpoint step {step} not committed")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        treedef = jax.tree_util.tree_structure_from_proto_bytes(
            bytes.fromhex(manifest["treedef"])
        ) if hasattr(jax.tree_util, "tree_structure_from_proto_bytes") else None
        leaves = [
            np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            for i in range(manifest["num_leaves"])
        ]
        if template is not None:
            _, expect_def = jax.tree.flatten(template)
            tree = jax.tree.unflatten(expect_def, leaves)
        elif treedef is not None:
            tree = jax.tree.unflatten(treedef, leaves)
        else:
            raise ValueError("restore requires a template pytree")
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return step, tree, manifest.get("extra", {})
