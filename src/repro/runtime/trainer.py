"""Training step assembly: value_and_grad + clipping + optimizer, with
gradient accumulation, optional int8 gradient compression (error feedback),
and sharding helpers for optimizer state.

The returned ``train_step(values, opt_state, batch, step_no)`` is a pure
function ready for ``jax.jit`` with donated params/opt-state buffers.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (
    ShardingRules,
    param_shardings,
    spec_for_param,
)
from repro.optim import Adafactor, AdamW, Optimizer, clip_by_global_norm
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    lr: float = 3e-4
    grad_clip: float = 1.0
    grad_accum: int = 1            # microbatches per step (scan-accumulated)
    compress_grads: bool = False   # int8 + error feedback (see collectives)


def make_train_step(
    model,
    optimizer: Optimizer,
    grad_clip: float = 1.0,
    grad_accum: int = 1,
) -> Callable:
    """Build the jit-able train step.

    With ``grad_accum > 1`` the global batch is split along dim 0 into
    microbatches consumed by ``lax.scan`` — activation memory drops by the
    accumulation factor while keeping the same global batch semantics.
    """

    def loss_fn(values, batch):
        loss, metrics = model.train_loss(values, batch)
        return loss, metrics

    def train_step(values, opt_state, batch, step_no):
        if grad_accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(values, batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    values, mb)
                return (
                    jax.tree.map(lambda a, b: a + b, g_acc, g),
                    l_acc + l,
                ), None

            mb_batch = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]),
                batch,
            )
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), values)
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), mb_batch)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = {"loss": loss}

        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_values, new_opt = optimizer.step(values, grads, opt_state, step_no)
        metrics = {**metrics, "grad_norm": gnorm}
        return new_values, new_opt, metrics

    return train_step


# ---------------------------------------------------------------------------
# Optimizer-state sharding
# ---------------------------------------------------------------------------

def opt_state_shardings(
    opt: Optimizer,
    param_shapes: PyTree,
    axes_tree: PyTree,
    rules: ShardingRules,
    mesh: Mesh,
):
    """NamedSharding tree for an optimizer state.

    AdamW moments mirror the params exactly; Adafactor's factored
    accumulators drop one dim — the matching logical axis is dropped from
    the spec by shape alignment.
    """
    state_shapes = jax.eval_shape(opt.init, param_shapes)

    flat_params, _ = jax.tree.flatten(param_shapes)
    flat_axes = jax.tree.leaves(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))

    shape_to_axes = {}
    for p, a in zip(flat_params, flat_axes):
        shape_to_axes.setdefault(p.shape, a)

    def spec_by_shape(s):
        shape = s.shape
        if shape in shape_to_axes:
            axes = shape_to_axes[shape]
            return NamedSharding(mesh,
                                 spec_for_param(shape, axes, rules, mesh))
        # factored accumulator: find a param shape it was reduced from
        for pshape, axes in shape_to_axes.items():
            if len(pshape) != len(shape) + 1:
                continue
            for drop in range(len(pshape)):
                if tuple(d for i, d in enumerate(pshape) if i != drop) == shape:
                    sub_axes = tuple(a for i, a in enumerate(axes) if i != drop)
                    return NamedSharding(
                        mesh, spec_for_param(shape, sub_axes, rules, mesh))
        return NamedSharding(mesh, P())  # scalar counters etc.

    return jax.tree.map(spec_by_shape, state_shapes)


def abstract_opt_state(opt: Optimizer, param_shapes: PyTree) -> PyTree:
    return jax.eval_shape(opt.init, param_shapes)


def pick_optimizer_for(cfg, lr: float = 3e-4) -> Optimizer:
    """Adafactor for >=50B params (factored state is what fits in HBM);
    AdamW otherwise."""
    big = cfg.arch_id in ("deepseek-v3-671b", "jamba-v0.1-52b")
    return Adafactor(lr=lr) if big else AdamW(lr=lr)
