"""Decoder-only LM assembly with scheduler-controlled early exits.

This is the early-exit substrate for the assigned LM architectures: the
decoder stack is split into *segments* at the exit boundaries; each segment
is a stack of identical blocks consumed by ``lax.scan`` (compile time and
HLO size O(#segments), not O(depth) — essential for the 512-device
dry-run). An exit head (per-exit RMSNorm + shared unembedding) sits at each
boundary.

Hardware adaptation of the paper's exit heads (DESIGN.md §2): on ResNets
each exit head is a full pooled classifier; for LMs a per-exit ``[D, V]``
head would add billions of parameters (V up to 200k), so exits share the
unembedding matrix and own only their norm — the latency lever (skipping
the remaining layers) is identical.

Families covered here: dense GQA (qwen3 / smollm / starcoder2 / phi4 /
mistral-llava) and MoE with optional MLA (deepseek-moe-16b, deepseek-v3).
Jamba / RWKV / enc-dec live in sibling modules and share the segment +
exit-head machinery through the same LMConfig.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    AttentionConfig,
    MLAConfig,
    attention,
    init_attention,
    init_mla,
    mla_attention,
    mla_attention_absorbed,
)
from repro.models.common import (
    Param,
    abstract_params,
    cast_floats,
    cross_entropy,
    make_param,
    mask_padded_vocab,
    rms_norm,
    split_params,
    stack_init,
    weighted_exit_loss,
)
from repro.models.moe import MLPConfig, MoEConfig, init_mlp, init_moe, mlp, moe


@dataclasses.dataclass(frozen=True)
class LMConfig:
    """One config type for every assigned LM architecture."""

    arch_id: str
    family: str                    # dense | moe | rwkv | jamba | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    exits: Tuple[int, ...]         # cumulative layer counts; last == num_layers
    head_dim: Optional[int] = None  # defaults to d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    mlp_gated: bool = True         # SwiGLU; starcoder2 uses plain GeLU
    norm_eps: float = 1e-6
    dtype: Any = jnp.float32
    exit_loss_weights: Optional[Tuple[float, ...]] = None  # default: uniform
    remat: str = "none"            # none | dots | full (segment scan body)

    # MoE (family == "moe", or jamba's interleaved MoE)
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_router: str = "softmax"
    dense_prefix: int = 0          # leading dense layers (deepseek: 1 / 3)
    moe_group_size: int = 1024
    moe_capacity_factor: float = 1.25

    # MLA (deepseek-v3)
    mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # rwkv (§Perf: chunked-parallel WKV; 0 = stepwise scan baseline)
    rwkv_chunk: int = 0

    # MLA decode in absorbed-matrix form (§Perf; see attention.py)
    mla_absorbed_decode: bool = False

    # pad vocab so embedding/head shard over the model axis (§Perf;
    # 0 = no padding). Logits at padded slots are masked to -inf.
    vocab_pad_multiple: int = 0

    # hybrid (jamba)
    attn_period: int = 0           # every Nth layer is attention (jamba: 8)
    attn_offset: int = 0           # index within the period
    moe_period: int = 0            # every Nth layer is MoE (jamba: 2)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # enc-dec (seamless)
    num_encoder_layers: int = 0

    # modality frontend stub: "none" | "audio" | "vision"
    frontend: str = "none"
    frontend_seq: int = 0          # frames/patches per example for stubs

    def __post_init__(self):
        assert self.exits, "at least one exit required"
        assert self.exits[-1] == self.num_layers, (
            "deepest exit must be the full stack"
        )
        assert tuple(sorted(set(self.exits))) == tuple(self.exits)
        if self.family == "moe":
            assert all(e > self.dense_prefix for e in self.exits), (
                "exits must land in the MoE region"
            )

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so embedding/head shard cleanly (§Perf)."""
        m = self.vocab_pad_multiple
        if not m:
            return self.vocab_size
        return -(-self.vocab_size // m) * m

    @property
    def num_exits(self) -> int:
        return len(self.exits)

    @property
    def exit_weights_(self) -> Tuple[float, ...]:
        return self.exit_loss_weights or tuple([1.0] * len(self.exits))

    def attn_config(self) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model,
            num_heads=self.num_heads,
            num_kv_heads=self.num_kv_heads,
            head_dim=self.head_dim_,
            rope_theta=self.rope_theta,
            qk_norm=self.qk_norm,
        )

    def mla_config(self) -> MLAConfig:
        return MLAConfig(
            d_model=self.d_model,
            num_heads=self.num_heads,
            q_lora_rank=self.q_lora_rank,
            kv_lora_rank=self.kv_lora_rank,
            qk_nope_head_dim=self.qk_nope_head_dim,
            qk_rope_head_dim=self.qk_rope_head_dim,
            v_head_dim=self.v_head_dim,
            rope_theta=self.rope_theta,
        )

    def mlp_config(self) -> MLPConfig:
        return MLPConfig(d_model=self.d_model, d_ff=self.d_ff,
                         gated=self.mlp_gated)

    def moe_config(self) -> MoEConfig:
        return MoEConfig(
            d_model=self.d_model,
            d_ff_expert=self.d_ff_expert,
            num_experts=self.num_experts,
            top_k=self.top_k,
            num_shared=self.num_shared_experts,
            router_type=self.moe_router,
            group_size=self.moe_group_size,
            capacity_factor=self.moe_capacity_factor,
        )

    # -- segment plan --------------------------------------------------------

    def segments(self) -> List[Tuple[str, int, int]]:
        """[(kind, start_layer, end_layer)] split at exit boundaries and at
        the dense-prefix/MoE boundary. kind in {"dense", "moe"}."""
        bounds = [0]
        if self.dense_prefix:
            bounds.append(self.dense_prefix)
        bounds.extend(self.exits)
        bounds = sorted(set(bounds))
        segs = []
        for a, b in zip(bounds, bounds[1:]):
            kind = "dense" if (self.family != "moe" or b <= self.dense_prefix) \
                else "moe"
            segs.append((kind, a, b))
        return segs

    def exit_segment_index(self, exit_idx: int) -> int:
        """Number of segments to run (inclusive) for a given exit."""
        target = self.exits[exit_idx]
        for i, (_, _, end) in enumerate(self.segments()):
            if end == target:
                return i + 1
        raise ValueError(f"exit {exit_idx} not on a segment boundary")


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _init_block(key: jax.Array, cfg: LMConfig, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "norm1": make_param(ks[0], (cfg.d_model,), ("embed",), init="ones"),
        "norm2": make_param(ks[1], (cfg.d_model,), ("embed",), init="ones"),
    }
    if cfg.mla:
        p["attn"] = init_mla(ks[2], cfg.mla_config())
    else:
        p["attn"] = init_attention(ks[2], cfg.attn_config())
    if kind == "moe":
        p["ffn"] = init_moe(ks[3], cfg.moe_config())
    else:
        p["ffn"] = init_mlp(ks[3], cfg.mlp_config())
    return p


def _block_apply(
    params: dict,
    h: jax.Array,
    cfg: LMConfig,
    kind: str,
    cache: Optional[dict],
    make_cache: bool,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """One pre-norm block. Returns (h, new_cache, aux_loss)."""
    attn_in = rms_norm(h, params["norm1"], cfg.norm_eps)
    pos = jnp.zeros((), jnp.int32) if make_cache else None
    if cfg.mla and cfg.mla_absorbed_decode and cache is not None:
        attn_out, new_cache = mla_attention_absorbed(
            params["attn"], attn_in, cfg.mla_config(), cache=cache
        )
    elif cfg.mla:
        attn_out, new_cache = mla_attention(
            params["attn"], attn_in, cfg.mla_config(), cache=cache, position=pos
        )
    else:
        attn_out, new_cache = attention(
            params["attn"], attn_in, cfg.attn_config(), cache=cache, position=pos
        )
    h = h + attn_out
    ffn_in = rms_norm(h, params["norm2"], cfg.norm_eps)
    if kind == "moe":
        ffn_out, aux = moe(params["ffn"], ffn_in, cfg.moe_config())
    else:
        ffn_out, aux = mlp(params["ffn"], ffn_in, cfg.mlp_config()), jnp.zeros(
            (), jnp.float32
        )
    return h + ffn_out, new_cache, aux


def _remat_wrap(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

class DecoderLM:
    """Early-exit decoder LM (dense & MoE families)."""

    def __init__(self, cfg: LMConfig):
        self.cfg = cfg

    # -- init ------------------------------------------------------------

    def init(self, key: jax.Array):
        """Returns a Param tree (use split_params for values/axes)."""
        cfg = self.cfg
        segs = cfg.segments()
        keys = jax.random.split(key, len(segs) + 3)
        params: Dict[str, Any] = {
            "embed": make_param(
                keys[0], (cfg.vocab_padded, cfg.d_model), ("vocab", "embed"),
                init="embedding",
            ),
            "exit_norms": [
                make_param(keys[1], (cfg.d_model,), ("embed",), init="ones")
                for _ in range(cfg.num_exits)
            ],
            "segments": [
                stack_init(
                    functools.partial(_init_block, cfg=cfg, kind=kind),
                    keys[3 + i],
                    end - start,
                )
                for i, (kind, start, end) in enumerate(segs)
            ],
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = make_param(
                keys[2], (cfg.d_model, cfg.vocab_padded), ("embed", "vocab")
            )
        return params

    def abstract(self, key: jax.Array):
        return abstract_params(self.init, key)

    # -- helpers -----------------------------------------------------------

    def _embed(self, values, batch) -> jax.Array:
        if "embeds" in batch:  # modality frontend stub output (vlm/audio)
            return batch["embeds"].astype(self.cfg.dtype)
        return values["embed"][batch["tokens"]].astype(self.cfg.dtype)

    def _head(self, values, h: jax.Array, exit_idx: int) -> jax.Array:
        cfg = self.cfg
        h = rms_norm(h, values["exit_norms"][exit_idx], cfg.norm_eps)
        w = (
            values["embed"].T
            if cfg.tie_embeddings
            else values["lm_head"]
        )
        logits = (h @ w.astype(h.dtype)).astype(jnp.float32)
        return mask_padded_vocab(logits, cfg.vocab_size)

    def _run_segment(
        self,
        seg_params,
        kind: str,
        h: jax.Array,
        caches: Optional[dict],
        make_cache: bool,
    ):
        """Scan one stacked segment. caches: stacked per-layer cache or None.

        Returns (h, stacked_new_caches_or_None, aux_sum).
        """
        cfg = self.cfg

        def body(carry, xs):
            h, aux = carry
            layer_params, layer_cache = xs
            h, new_cache, aux_i = _block_apply(
                layer_params, h, cfg, kind, layer_cache, make_cache
            )
            return (h, aux + aux_i), new_cache

        body = _remat_wrap(body, cfg.remat)
        (h, aux), new_caches = jax.lax.scan(
            body, (h, jnp.zeros((), jnp.float32)), (seg_params, caches)
        )
        return h, new_caches, aux

    # -- training ----------------------------------------------------------

    def train_loss(self, values, batch) -> Tuple[jax.Array, dict]:
        """Joint early-exit LM loss (weighted per-exit CE + MoE aux)."""
        cfg = self.cfg
        values = cast_floats(values, cfg.dtype)
        h = self._embed(values, batch)
        labels = batch["labels"]
        mask = batch.get("mask")
        segs = cfg.segments()
        exit_bounds = {cfg.exits[i]: i for i in range(cfg.num_exits)}

        aux_total = jnp.zeros((), jnp.float32)
        per_exit_nll = []
        for i, (kind, start, end) in enumerate(segs):
            h, _, aux = self._run_segment(values["segments"][i], kind, h,
                                          None, make_cache=False)
            aux_total = aux_total + aux
            if end in exit_bounds:
                e = exit_bounds[end]
                logits = self._head(values, h, e)
                per_exit_nll.append(cross_entropy(logits, labels, mask))

        loss = weighted_exit_loss(per_exit_nll, cfg.exit_weights_) + aux_total
        metrics = {
            "loss": loss,
            "nll_final": per_exit_nll[-1],
            "moe_aux": aux_total,
            **{f"nll_exit{i}": l for i, l in enumerate(per_exit_nll)},
        }
        return loss, metrics

    # -- serving -----------------------------------------------------------

    def forward_exit(self, values, batch, exit_idx: int) -> jax.Array:
        """Run layers up to ``exits[exit_idx]`` and that exit's head.

        The (m, e, B) unit the paper's profile table measures.
        """
        cfg = self.cfg
        values = cast_floats(values, cfg.dtype)
        h = self._embed(values, batch)
        n_segs = cfg.exit_segment_index(exit_idx)
        segs = cfg.segments()
        for i in range(n_segs):
            kind, _, _ = segs[i]
            h, _, _ = self._run_segment(values["segments"][i], kind, h,
                                        None, make_cache=False)
        return self._head(values, h, exit_idx)

    def prefill(self, values, batch, exit_idx: int):
        """Prefill through exit ``exit_idx``: logits for the last position +
        per-segment stacked KV caches (sized to the prompt)."""
        cfg = self.cfg
        values = cast_floats(values, cfg.dtype)
        h = self._embed(values, batch)
        n_segs = cfg.exit_segment_index(exit_idx)
        segs = cfg.segments()
        caches = []
        for i in range(n_segs):
            kind, _, _ = segs[i]
            h, seg_cache, _ = self._run_segment(
                values["segments"][i], kind, h, None, make_cache=True
            )
            caches.append(seg_cache)
        logits = self._head(values, h[:, -1:, :], exit_idx)
        return logits, {"segments": caches}

    def decode_step(self, values, token: jax.Array, cache: dict, exit_idx: int):
        """One decode step. token [B, 1] int32 (or [B,1,D] embeds).

        cache = {"segments": [stacked per segment]}; lengths live inside the
        per-layer caches. Returns (logits [B,1,V], new cache).
        """
        cfg = self.cfg
        values = cast_floats(values, cfg.dtype)
        if token.ndim == 3:
            h = token.astype(cfg.dtype)
        else:
            h = values["embed"][token].astype(cfg.dtype)
        n_segs = cfg.exit_segment_index(exit_idx)
        segs = cfg.segments()
        new_caches = []
        for i in range(n_segs):
            kind, _, _ = segs[i]
            h, seg_cache, _ = self._run_segment(
                values["segments"][i], kind, h, cache["segments"][i],
                make_cache=False,
            )
            new_caches.append(seg_cache)
        logits = self._head(values, h, exit_idx)
        return logits, {"segments": new_caches}

    def init_cache(self, batch_size: int, max_len: int, exit_idx: int,
                   dtype=None) -> dict:
        """Zero-filled decode cache pytree (also the dry-run ShapeDtypeStruct
        template for ``decode_*`` shapes)."""
        cfg = self.cfg
        dtype = dtype or cfg.dtype
        n_segs = cfg.exit_segment_index(exit_idx)
        segs = cfg.segments()
        caches = []
        for i in range(n_segs):
            _, start, end = segs[i]
            n = end - start
            if cfg.mla:
                c = {
                    "c_kv": jnp.zeros(
                        (n, batch_size, max_len, cfg.kv_lora_rank), dtype),
                    "k_pe": jnp.zeros(
                        (n, batch_size, max_len, cfg.qk_rope_head_dim), dtype),
                    "len": jnp.zeros((n, batch_size), jnp.int32),
                }
            else:
                c = {
                    "k": jnp.zeros(
                        (n, batch_size, max_len, cfg.num_kv_heads,
                         cfg.head_dim_), dtype),
                    "v": jnp.zeros(
                        (n, batch_size, max_len, cfg.num_kv_heads,
                         cfg.head_dim_), dtype),
                    "len": jnp.zeros((n, batch_size), jnp.int32),
                }
            caches.append(c)
        return {"segments": caches}
