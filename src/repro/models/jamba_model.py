"""Jamba-style hybrid LM: Mamba + attention at a 1:7 ratio with interleaved
MoE (arXiv:2403.19887).

Layers are grouped into *superblocks* of ``attn_period`` (8) layers — one
attention layer (at ``attn_offset``) and seven Mamba layers, with MoE on
every ``moe_period``-th (2nd) layer. The stack scans over stacked
superblocks, so HLO size is O(1) in depth and the exit boundaries (multiples
of 8) align with superblock edges.

Early exit interacts with the hybrid structure exactly as the paper's
technique requires: a shallower exit skips the remaining superblocks'
attention KV writes, Mamba state updates, and routed-expert FLOPs alike.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attention, init_attention
from repro.models.common import (
    abstract_params,
    cast_floats,
    cross_entropy,
    make_param,
    mask_padded_vocab,
    rms_norm,
    stack_init,
    weighted_exit_loss,
)
from repro.models.mamba import MambaConfig, init_mamba, mamba
from repro.models.moe import init_mlp, init_moe, mlp, moe
from repro.models.transformer import LMConfig, _remat_wrap


class JambaLM:
    """Early-exit hybrid LM. Uses LMConfig with family == "jamba"."""

    def __init__(self, cfg: LMConfig):
        assert cfg.family == "jamba"
        assert cfg.attn_period > 0 and cfg.num_layers % cfg.attn_period == 0
        for e in cfg.exits:
            assert e % cfg.attn_period == 0, (
                "jamba exits must align to superblock boundaries"
            )
        self.cfg = cfg

    # -- structure ---------------------------------------------------------

    def _mamba_config(self) -> MambaConfig:
        c = self.cfg
        return MambaConfig(
            d_model=c.d_model, d_state=c.mamba_d_state,
            d_conv=c.mamba_d_conv, expand=c.mamba_expand,
        )

    def _sub_kinds(self) -> List[Tuple[str, str]]:
        """Per sublayer within a superblock: (mixer, ffn) kinds."""
        c = self.cfg
        kinds = []
        for j in range(c.attn_period):
            mixer = "attn" if j == c.attn_offset else "mamba"
            ffn = "moe" if (c.moe_period and j % c.moe_period == 1) else "mlp"
            kinds.append((mixer, ffn))
        return kinds

    def _init_superblock(self, key: jax.Array) -> dict:
        c = self.cfg
        kinds = self._sub_kinds()
        keys = jax.random.split(key, 4 * len(kinds))
        p: Dict[str, Any] = {}
        for j, (mixer, ffn) in enumerate(kinds):
            kj = keys[4 * j : 4 * j + 4]
            sub = {
                "norm1": make_param(kj[0], (c.d_model,), ("embed",), init="ones"),
                "norm2": make_param(kj[1], (c.d_model,), ("embed",), init="ones"),
            }
            if mixer == "attn":
                sub["mixer"] = init_attention(kj[2], c.attn_config())
            else:
                sub["mixer"] = init_mamba(kj[2], self._mamba_config())
            if ffn == "moe":
                sub["ffn"] = init_moe(kj[3], c.moe_config())
            else:
                sub["ffn"] = init_mlp(kj[3], c.mlp_config())
            p[f"sub{j}"] = sub
        return p

    def _superblock_apply(self, params, h, cache, make_cache: bool):
        """One superblock (attn_period sublayers, unrolled)."""
        c = self.cfg
        kinds = self._sub_kinds()
        new_cache: Dict[str, Any] = {}
        aux_total = jnp.zeros((), jnp.float32)
        for j, (mixer, ffn) in enumerate(kinds):
            sub = params[f"sub{j}"]
            sub_cache = cache.get(f"sub{j}") if cache is not None else None
            x = rms_norm(h, sub["norm1"], c.norm_eps)
            if mixer == "attn":
                pos = jnp.zeros((), jnp.int32) if make_cache else None
                out, mc = attention(sub["mixer"], x, c.attn_config(),
                                    cache=sub_cache, position=pos)
            else:
                out, mc = mamba(sub["mixer"], x, self._mamba_config(),
                                state=sub_cache)
                if not (make_cache or cache is not None):
                    mc = None  # training: discard states
            h = h + out
            x = rms_norm(h, sub["norm2"], c.norm_eps)
            if ffn == "moe":
                out, aux = moe(sub["ffn"], x, c.moe_config())
                aux_total = aux_total + aux
            else:
                out = mlp(sub["ffn"], x, c.mlp_config())
            h = h + out
            if mc is not None:
                new_cache[f"sub{j}"] = mc
        return h, (new_cache if new_cache else None), aux_total

    # -- init ---------------------------------------------------------------

    def init(self, key: jax.Array):
        c = self.cfg
        n_super = c.num_layers // c.attn_period
        segs = self.segments()
        keys = jax.random.split(key, len(segs) + 3)
        params = {
            "embed": make_param(keys[0], (c.vocab_padded, c.d_model),
                                ("vocab", "embed"), init="embedding"),
            "exit_norms": [
                make_param(keys[1], (c.d_model,), ("embed",), init="ones")
                for _ in range(c.num_exits)
            ],
            "lm_head": make_param(keys[2], (c.d_model, c.vocab_padded),
                                  ("embed", "vocab")),
            "segments": [
                stack_init(self._init_superblock, keys[3 + i], n)
                for i, n in enumerate(segs)
            ],
        }
        return params

    def abstract(self, key: jax.Array):
        return abstract_params(self.init, key)

    def segments(self) -> List[int]:
        """Superblock counts per exit segment."""
        c = self.cfg
        bounds = [0] + [e // c.attn_period for e in c.exits]
        return [b - a for a, b in zip(bounds, bounds[1:])]

    # -- forward ------------------------------------------------------------

    def _run_segment(self, seg_params, h, caches, make_cache: bool):
        cfg = self.cfg

        def body(carry, xs):
            h, aux = carry
            sb_params, sb_cache = xs
            h, new_cache, aux_i = self._superblock_apply(
                sb_params, h, sb_cache, make_cache
            )
            return (h, aux + aux_i), new_cache

        body = _remat_wrap(body, cfg.remat)
        (h, aux), new_caches = jax.lax.scan(
            body, (h, jnp.zeros((), jnp.float32)), (seg_params, caches)
        )
        return h, new_caches, aux

    def _head(self, values, h, exit_idx):
        h = rms_norm(h, values["exit_norms"][exit_idx], self.cfg.norm_eps)
        logits = (h @ values["lm_head"].astype(h.dtype)).astype(jnp.float32)
        return mask_padded_vocab(logits, self.cfg.vocab_size)

    def train_loss(self, values, batch):
        c = self.cfg
        values = cast_floats(values, c.dtype)
        h = values["embed"][batch["tokens"]].astype(c.dtype)
        aux_total = jnp.zeros((), jnp.float32)
        per_exit = []
        for i in range(len(self.segments())):
            h, _, aux = self._run_segment(values["segments"][i], h, None, False)
            aux_total = aux_total + aux
            logits = self._head(values, h, i)
            per_exit.append(
                cross_entropy(logits, batch["labels"], batch.get("mask")))
        loss = weighted_exit_loss(per_exit, c.exit_weights_) + aux_total
        return loss, {"loss": loss, "nll_final": per_exit[-1],
                      "moe_aux": aux_total,
                      **{f"nll_exit{i}": l for i, l in enumerate(per_exit)}}

    def forward_exit(self, values, batch, exit_idx: int):
        c = self.cfg
        values = cast_floats(values, c.dtype)
        h = values["embed"][batch["tokens"]].astype(c.dtype)
        for i in range(exit_idx + 1):
            h, _, _ = self._run_segment(values["segments"][i], h, None, False)
        return self._head(values, h, exit_idx)

    def prefill(self, values, batch, exit_idx: int):
        c = self.cfg
        values = cast_floats(values, c.dtype)
        h = values["embed"][batch["tokens"]].astype(c.dtype)
        caches = []
        for i in range(exit_idx + 1):
            h, seg_cache, _ = self._run_segment(values["segments"][i], h,
                                                None, True)
            caches.append(seg_cache)
        return self._head(values, h[:, -1:, :], exit_idx), {"segments": caches}

    def decode_step(self, values, token, cache, exit_idx: int):
        c = self.cfg
        values = cast_floats(values, c.dtype)
        h = values["embed"][token].astype(c.dtype)
        new_caches = []
        for i in range(exit_idx + 1):
            h, seg_cache, _ = self._run_segment(
                values["segments"][i], h, cache["segments"][i], False)
            new_caches.append(seg_cache)
        return self._head(values, h, exit_idx), {"segments": new_caches}

    def init_cache(self, batch_size: int, max_len: int, exit_idx: int,
                   dtype=None) -> dict:
        c = self.cfg
        dtype = dtype or c.dtype
        mcfg = self._mamba_config()
        kinds = self._sub_kinds()
        segs = self.segments()
        out = []
        for i in range(exit_idx + 1):
            n = segs[i]
            sb: Dict[str, Any] = {}
            for j, (mixer, _) in enumerate(kinds):
                if mixer == "attn":
                    sb[f"sub{j}"] = {
                        "k": jnp.zeros((n, batch_size, max_len,
                                        c.num_kv_heads, c.head_dim_), dtype),
                        "v": jnp.zeros((n, batch_size, max_len,
                                        c.num_kv_heads, c.head_dim_), dtype),
                        "len": jnp.zeros((n, batch_size), jnp.int32),
                    }
                else:
                    sb[f"sub{j}"] = {
                        "h": jnp.zeros((n, batch_size, mcfg.d_inner,
                                        mcfg.d_state), jnp.float32),
                        "conv": jnp.zeros((n, batch_size, mcfg.d_conv - 1,
                                           mcfg.d_inner), dtype),
                    }
            out.append(sb)
        return {"segments": out}
