"""Model substrate: early-exit model zoo in pure JAX.

``build_model(cfg)`` dispatches an LMConfig to its family's model class;
every model exposes the same interface:

    init(key) -> Param tree                   (split with split_params)
    abstract(key) -> (ShapeDtypeStruct tree, axes tree)   (zero-alloc)
    train_loss(values, batch) -> (loss, metrics)
    forward_exit(values, batch_or_x, exit_idx) -> logits
    prefill(values, batch, exit_idx) -> (logits, cache)
    decode_step(values, token, cache, exit_idx) -> (logits, cache)
    init_cache(batch, max_len, exit_idx) -> cache pytree
"""

from repro.models.common import (
    Param,
    abstract_params,
    cross_entropy,
    is_param,
    make_param,
    rms_norm,
    split_params,
    stack_init,
)
from repro.models.encdec import EncDecLM
from repro.models.jamba_model import JambaLM
from repro.models.resnet import EarlyExitResNet, ResNetConfig
from repro.models.rwkv_model import RWKV6LM
from repro.models.transformer import DecoderLM, LMConfig

_FAMILIES = {
    "dense": DecoderLM,
    "moe": DecoderLM,
    "rwkv": RWKV6LM,
    "jamba": JambaLM,
    "encdec": EncDecLM,
}


def build_model(cfg: LMConfig):
    try:
        return _FAMILIES[cfg.family](cfg)
    except KeyError:
        raise ValueError(
            f"unknown family {cfg.family!r}; known: {sorted(_FAMILIES)}"
        ) from None


__all__ = [
    "DecoderLM",
    "EarlyExitResNet",
    "EncDecLM",
    "JambaLM",
    "LMConfig",
    "Param",
    "RWKV6LM",
    "ResNetConfig",
    "abstract_params",
    "build_model",
    "cross_entropy",
    "is_param",
    "make_param",
    "rms_norm",
    "split_params",
    "stack_init",
]
