"""The paper's own models: early-exit ResNet50/101/152 for CIFAR-100 in pure
JAX (paper Sec. IV-A).

Faithful structure: CIFAR stem (3x3 conv — the standard CIFAR adaptation of
the ImageNet 7x7-s2 stem) + four bottleneck stages; a lightweight exit head
(adaptive average pool + single FC) after each of layer1/layer2/layer3, plus
the final head after layer4. When inference exits at point e, only the stem,
stages <= e, and that exit's head execute — exactly the paper's latency
lever.

Adaptation note (DESIGN.md §2): BatchNorm is replaced by GroupNorm(32) to
keep the model purely functional (no running-stats state threading); the
latency profile L(m, e, B) and the exit-head structure are unaffected.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import Param, abstract_params, make_param

STAGE_BLOCKS = {
    "resnet50": (3, 4, 6, 3),
    "resnet101": (3, 4, 23, 3),
    "resnet152": (3, 8, 36, 3),
}
STAGE_WIDTH = (64, 128, 256, 512)   # bottleneck base widths; expansion x4
EXPANSION = 4


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    variant: str = "resnet50"
    num_classes: int = 100
    width_multiplier: float = 1.0   # reduced smoke configs use < 1
    blocks_override: Tuple[int, ...] = ()  # reduced smoke configs
    groups: int = 8                 # GroupNorm groups

    @property
    def blocks(self) -> Tuple[int, ...]:
        return self.blocks_override or STAGE_BLOCKS[self.variant]

    def widths(self) -> List[int]:
        return [max(int(w * self.width_multiplier), 8) for w in STAGE_WIDTH]

    @property
    def num_exits(self) -> int:
        return 4                    # layer1, layer2, layer3, final


def _conv(key, k, cin, cout):
    scale = 1.0 / np.sqrt(k * k * cin)
    return make_param(key, (k, k, cin, cout), (None, None, None, "heads"),
                      scale=scale)


def conv2d(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def group_norm(x, scale, bias, groups: int, eps=1e-5):
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(b, h, w, g, c // g).astype(jnp.float32)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    x = xg.reshape(b, h, w, c)
    return (x * scale + bias).astype(x.dtype)


def _init_norm(key, c):
    return {
        "scale": make_param(key, (c,), (None,), init="ones"),
        "bias": make_param(key, (c,), (None,), init="zeros"),
    }


def _init_bottleneck(key, cin, width, cout, stride):
    ks = jax.random.split(key, 8)
    p = {
        "conv1": _conv(ks[0], 1, cin, width),
        "n1": _init_norm(ks[1], width),
        "conv2": _conv(ks[2], 3, width, width),
        "n2": _init_norm(ks[3], width),
        "conv3": _conv(ks[4], 1, width, cout),
        "n3": _init_norm(ks[5], cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv(ks[6], 1, cin, cout)
        p["nproj"] = _init_norm(ks[7], cout)
    return p, stride


def _bottleneck(params, x, stride, groups):
    h = conv2d(x, params["conv1"])
    h = jax.nn.relu(group_norm(h, params["n1"]["scale"], params["n1"]["bias"],
                               groups))
    h = conv2d(h, params["conv2"], stride=stride)
    h = jax.nn.relu(group_norm(h, params["n2"]["scale"], params["n2"]["bias"],
                               groups))
    h = conv2d(h, params["conv3"])
    h = group_norm(h, params["n3"]["scale"], params["n3"]["bias"], groups)
    if "proj" in params:
        x = conv2d(x, params["proj"], stride=stride)
        x = group_norm(x, params["nproj"]["scale"], params["nproj"]["bias"],
                       groups)
    return jax.nn.relu(x + h)


class EarlyExitResNet:
    """The paper's model family; exits = (layer1, layer2, layer3, final)."""

    def __init__(self, cfg: ResNetConfig):
        self.cfg = cfg

    def init(self, key: jax.Array):
        cfg = self.cfg
        widths = cfg.widths()
        keys = jax.random.split(key, 16)
        params: Dict[str, Any] = {
            "stem": _conv(keys[0], 3, 3, widths[0]),
            "stem_norm": _init_norm(keys[1], widths[0]),
        }
        cin = widths[0]
        strides_meta = []
        for s, (n_blocks, width) in enumerate(zip(cfg.blocks, widths)):
            stage = []
            stage_meta = []
            skeys = jax.random.split(keys[2 + s], n_blocks)
            for b in range(n_blocks):
                stride = 2 if (b == 0 and s > 0) else 1
                cout = width * EXPANSION
                blk, st = _init_bottleneck(skeys[b], cin, width, cout, stride)
                stage.append(blk)
                stage_meta.append(st)
                cin = cout
            params[f"layer{s + 1}"] = stage
            strides_meta.append(tuple(stage_meta))
        self._strides = tuple(strides_meta)
        # exit heads: pool + single FC from each stage's channels
        for s in range(4):
            c_out = widths[s] * EXPANSION
            params[f"exit_head{s}"] = make_param(
                keys[8 + s], (c_out, cfg.num_classes), (None, "vocab"))
        return params

    def _stage_strides(self):
        cfg = self.cfg
        return [
            tuple(2 if (b == 0 and s > 0) else 1 for b in range(n))
            for s, n in enumerate(cfg.blocks)
        ]

    def forward_exit(self, values, x: jax.Array, exit_idx: int) -> jax.Array:
        """x [B, 32, 32, 3] -> logits [B, classes], exiting after stage
        ``exit_idx`` (0..3). Only the included stages execute."""
        cfg = self.cfg
        h = conv2d(x.astype(jnp.float32), values["stem"])
        h = jax.nn.relu(group_norm(h, values["stem_norm"]["scale"],
                                   values["stem_norm"]["bias"], cfg.groups))
        strides = self._stage_strides()
        for s in range(exit_idx + 1):
            for b, blk in enumerate(values[f"layer{s + 1}"]):
                h = _bottleneck(blk, h, strides[s][b], cfg.groups)
        pooled = h.mean(axis=(1, 2))                      # adaptive avg pool
        return pooled @ values[f"exit_head{exit_idx}"]

    def train_loss(self, values, batch, exit_weights=(1.0, 1.0, 1.0, 1.0)):
        """Joint training of all exits (paper Sec. IV-A)."""
        cfg = self.cfg
        x, labels = batch["images"], batch["labels"]
        h = conv2d(x.astype(jnp.float32), values["stem"])
        h = jax.nn.relu(group_norm(h, values["stem_norm"]["scale"],
                                   values["stem_norm"]["bias"], cfg.groups))
        strides = self._stage_strides()
        losses = []
        accs = []
        for s in range(4):
            for b, blk in enumerate(values[f"layer{s + 1}"]):
                h = _bottleneck(blk, h, strides[s][b], cfg.groups)
            logits = h.mean(axis=(1, 2)) @ values[f"exit_head{s}"]
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
            losses.append(nll)
            accs.append(jnp.mean((jnp.argmax(logits, -1) == labels)))
        w = jnp.asarray(exit_weights) / np.sum(exit_weights)
        loss = sum(wi * li for wi, li in zip(w, losses))
        return loss, {
            "loss": loss,
            **{f"nll_exit{i}": l for i, l in enumerate(losses)},
            **{f"acc_exit{i}": a for i, a in enumerate(accs)},
        }

    def abstract(self, key: jax.Array):
        return abstract_params(self.init, key)
