"""RWKV-6 ("Finch") blocks: attention-free time mixing with data-dependent
decay (arXiv:2404.05892), plus the RWKV channel-mix FFN.

The WKV recurrence per head (head dim N):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S in R^{N x N})
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

with per-token, per-channel decay ``w_t = exp(-exp(w0 + lora_w(x_t)))`` —
the data-dependent decay that distinguishes RWKV-6 from RWKV-4/5. Training
and prefill run the recurrence with ``lax.scan`` over time (O(S) sequential,
O(1) memory per step — this is why the arch runs the ``long_500k`` shape);
decode is a single step carrying ``S`` — no KV cache exists at all.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import make_param, rms_norm


@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    num_heads: int                 # head_dim = d_model // num_heads
    d_ff: int
    lora_rank_decay: int = 64
    lora_rank_mix: int = 32
    chunk: int = 0                 # 0 = stepwise scan; >0 = chunked-parallel
                                   # WKV (HBM traffic / chunk, MXU matmuls)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads


_MIX_NAMES = ("w", "k", "v", "r", "g")


def init_time_mix(key: jax.Array, cfg: RWKV6Config) -> dict:
    ks = jax.random.split(key, 16)
    d, rk = cfg.d_model, cfg.lora_rank_mix
    p = {
        # data-dependent interpolation (ddlerp) between x_t and x_{t-1}
        "maa_x": make_param(ks[0], (d,), (None,), init="zeros"),
        "maa": make_param(ks[1], (5, d), (None, None), init="zeros"),
        "mix_a": make_param(ks[2], (d, 5 * rk), ("embed", None), scale=0.01),
        "mix_b": make_param(ks[3], (5, rk, d), (None, None, "embed"), scale=0.01),
        # projections
        "w_r": make_param(ks[4], (d, d), ("embed", "heads")),
        "w_k": make_param(ks[5], (d, d), ("embed", "heads")),
        "w_v": make_param(ks[6], (d, d), ("embed", "heads")),
        "w_g": make_param(ks[7], (d, d), ("embed", "heads")),
        "w_o": make_param(ks[8], (d, d), ("heads", "embed")),
        # data-dependent decay (the Finch mechanism)
        "decay_base": make_param(ks[9], (d,), (None,), init="zeros"),
        "decay_a": make_param(ks[10], (d, cfg.lora_rank_decay), ("embed", None),
                              scale=0.01),
        "decay_b": make_param(ks[11], (cfg.lora_rank_decay, d), (None, "embed"),
                              scale=0.01),
        # per-channel bonus u
        "bonus": make_param(ks[12], (d,), (None,), init="zeros"),
        # output group-norm (per head)
        "ln_out": make_param(ks[13], (d,), (None,), init="ones"),
    }
    return p


def _ddlerp(params, x, sx):
    """RWKV-6 data-dependent token-shift interpolation.

    x, sx: [B, S, D] current and previous token streams. Returns the five
    mixed streams (w, k, v, r, g), each [B, S, D].
    """
    rk = params["mix_b"].shape[1]
    xxx = x + (sx - x) * params["maa_x"]
    lora = jnp.tanh(xxx @ params["mix_a"])            # [B, S, 5*rk]
    lora = lora.reshape(*lora.shape[:-1], 5, rk)
    delta = jnp.einsum("bsfr,frd->bsfd", lora, params["mix_b"])  # [B,S,5,D]
    mixed = []
    for i in range(5):
        maa = params["maa"][i] + delta[..., i, :]
        mixed.append(x + (sx - x) * maa)
    return mixed


def _wkv_scan(r, k, v, w, u, state):
    """Run the WKV recurrence over time.

    r,k,v: [B, S, H, N]; w: [B, S, H, N] decay in (0,1); u: [H, N];
    state: [B, H, N, N] (or None -> zeros). Returns (out [B,S,H,N], state).
    """
    b, s, h, n = r.shape
    if state is None:
        state = jnp.zeros((b, h, n, n), dtype=jnp.float32)

    def step(carry, inputs):
        s_prev = carry
        r_t, k_t, v_t, w_t = inputs            # [B, H, N] each
        kv = k_t[..., :, None] * v_t[..., None, :]          # [B,H,N,N]
        o = jnp.einsum(
            "bhn,bhnm->bhm",
            r_t,
            s_prev + u[None, :, :, None] * kv,
        )
        s_new = w_t[..., :, None] * s_prev + kv
        return s_new, o

    xs = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w)
    )
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1).astype(r.dtype), state


def _wkv_chunked(r, k, v, w, u, state, chunk: int):
    """Chunked-parallel WKV (the GLA/RWKV-6 chunked form).

    Equivalent to the stepwise recurrence, but the per-token [N, N] state
    round-trip to HBM is replaced by: (i) one state read/write per *chunk*
    and (ii) intra-chunk interactions as causal [Tc, Tc] matmuls (MXU work
    instead of HBM traffic). This is the §Perf optimization for the
    rwkv train/prefill cells: HBM traffic drops by ~chunk, FLOPs shift onto
    the MXU.

    Stability: decays are diag per channel; products are kept in log space
    relative to the chunk start and clamped at -60 (contributions decayed
    below e^-60 are zero in fp32 anyway), so no exponent overflows.
    """
    b, s, h, n = r.shape
    tc = min(chunk, s)
    assert s % tc == 0, (s, tc)
    nc = s // tc
    if state is None:
        state = jnp.zeros((b, h, n, n), dtype=jnp.float32)

    f32 = jnp.float32
    rc = jnp.moveaxis(r.astype(f32).reshape(b, nc, tc, h, n), 1, 0)
    kc = jnp.moveaxis(k.astype(f32).reshape(b, nc, tc, h, n), 1, 0)
    vc = jnp.moveaxis(v.astype(f32).reshape(b, nc, tc, h, n), 1, 0)
    wc = jnp.moveaxis(w.astype(f32).reshape(b, nc, tc, h, n), 1, 0)

    def chunk_step(s0, inputs):
        r_, k_, v_, w_ = inputs                    # [B, Tc, H, N]
        logw = jnp.log(jnp.maximum(w_, 1e-38))     # <= 0
        a = jnp.cumsum(logw, axis=1)               # a_t = sum_{i<=t} log w_i
        a_prev = a - logw                          # a_{t-1} (a_0 = 0)
        a_prev = jnp.maximum(a_prev, -60.0)
        a_cl = jnp.maximum(a, -60.0)
        a_end = a[:, -1:, :, :]                    # [B,1,H,N]

        # cross-chunk: o_t += (r_t * exp(a_{t-1})) @ S0
        r_dec = r_ * jnp.exp(a_prev)
        o = jnp.einsum("bthn,bhnm->bthm", r_dec, s0)

        # intra-chunk (strictly causal): scores_ti = sum_n r_t k_i e^{a_{t-1}-a_i}
        k_dec = k_ * jnp.exp(-a_cl)
        scores = jnp.einsum("bthn,bihn->bhti", r_dec, k_dec)
        mask = jnp.tril(jnp.ones((tc, tc), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        o = o + jnp.einsum("bhti,bihm->bthm", scores, v_)

        # diagonal bonus term: r_t (u k_t) v_t
        diag = jnp.sum(r_ * u[None, None] * k_, axis=-1)   # [B,Tc,H]
        o = o + diag[..., None] * v_

        # state to next chunk: S = e^{a_T} S0 + sum_i (k_i e^{a_T - a_i}) v_i
        k_rem = k_ * jnp.exp(jnp.maximum(a_end - a, -60.0))
        s_new = jnp.exp(jnp.maximum(a_end[:, 0], -60.0))[..., None] * s0 \
            + jnp.einsum("bihn,bihm->bhnm", k_rem, v_)
        return s_new, o

    state, out = jax.lax.scan(chunk_step, state, (rc, kc, vc, wc))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, n)
    return out.astype(r.dtype), state


def time_mix(
    params: dict,
    x: jax.Array,
    cfg: RWKV6Config,
    state: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """RWKV-6 time mixing. state = {"shift": [B,D], "wkv": [B,H,N,N]} for
    decode; None for train/prefill (shift starts at zeros)."""
    b, s, d = x.shape
    h, n = cfg.num_heads, cfg.head_dim

    if state is None:
        sx = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]     # previous token
        wkv_state = None
    else:
        sx = state["shift"][:, None, :]
        wkv_state = state["wkv"]

    xw, xk, xv, xr, xg = _ddlerp(params, x, sx)
    r = (xr @ params["w_r"]).reshape(b, s, h, n)
    k = (xk @ params["w_k"]).reshape(b, s, h, n)
    v = (xv @ params["w_v"]).reshape(b, s, h, n)
    g = jax.nn.silu(xg @ params["w_g"])

    # data-dependent decay in (0, 1): exp(-exp(.)) (Finch Eq. section 3)
    decay_logit = params["decay_base"] + jnp.tanh(
        xw @ params["decay_a"]
    ) @ params["decay_b"]
    w = jnp.exp(-jnp.exp(decay_logit.astype(jnp.float32)))
    w = w.reshape(b, s, h, n)
    u = params["bonus"].reshape(h, n)

    if cfg.chunk > 0 and s > 1 and s % min(cfg.chunk, s) == 0:
        out, wkv_state = _wkv_chunked(r, k, v, w, u, wkv_state,
                                      chunk=cfg.chunk)
    else:
        out, wkv_state = _wkv_scan(r, k, v, w, u, wkv_state)
    out = out.reshape(b, s, d)
    # per-head group norm
    out = out.reshape(b, s, h, n)
    out = rms_norm(out, jnp.ones((n,), out.dtype))
    out = out.reshape(b, s, d) * params["ln_out"]
    out = (out * g) @ params["w_o"]

    new_state = None
    if state is not None or s >= 1:
        new_state = {"shift": x[:, -1, :], "wkv": wkv_state}
    return out, new_state


def init_channel_mix(key: jax.Array, cfg: RWKV6Config) -> dict:
    ks = jax.random.split(key, 5)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "maa_k": make_param(ks[0], (d,), (None,), init="zeros"),
        "maa_r": make_param(ks[1], (d,), (None,), init="zeros"),
        "w_k": make_param(ks[2], (d, f), ("embed", "mlp")),
        "w_v": make_param(ks[3], (f, d), ("mlp", "embed")),
        "w_r": make_param(ks[4], (d, d), ("embed", "embed_out")),
    }


def channel_mix(
    params: dict,
    x: jax.Array,
    cfg: RWKV6Config,
    state: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """RWKV channel mixing (squared-ReLU FFN with token shift + r gate).
    state = {"shift": [B, D]} for decode."""
    if state is None:
        sx = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        sx = state["shift"][:, None, :]
    xk = x + (sx - x) * params["maa_k"]
    xr = x + (sx - x) * params["maa_r"]
    k = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    out = jax.nn.sigmoid(xr @ params["w_r"]) * (k @ params["w_v"])
    return out, {"shift": x[:, -1, :]}
