"""Feed-forward layers: dense SwiGLU MLP and fine-grained Mixture-of-Experts.

The MoE uses the TPU-standard dispatch/combine einsum formulation
(Mesh-TensorFlow / Switch / MaxText style): tokens are grouped, each group
assigns its tokens to per-expert capacity slots via one-hot dispatch
tensors, expert FFNs run as a single batched einsum sharded over the
``expert`` logical axis (EP), and results are combined with the routing
weights. This is dropless up to the capacity factor and — crucially for the
dry-run — fully expressible as einsums the SPMD partitioner can shard.

DeepSeek specifics implemented: shared experts always active alongside
routed top-k; optional sigmoid routing with normalised top-k weights
(DeepSeek-V3) vs softmax routing (DeepSeek-MoE 16B); load-balance auxiliary
loss (Switch-style, returned for the trainer to add).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import make_param, swiglu


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    gated: bool = True             # SwiGLU (llama family) vs GeLU


def init_mlp(key: jax.Array, cfg: MLPConfig) -> dict:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    p = {
        "w_up": make_param(ks[0], (d, f), ("embed", "mlp")),
        "w_down": make_param(ks[1], (f, d), ("mlp", "embed")),
    }
    if cfg.gated:
        p["w_gate"] = make_param(ks[2], (d, f), ("embed", "mlp"))
    return p


def mlp(params: dict, x: jax.Array, cfg: MLPConfig) -> jax.Array:
    if cfg.gated:
        h = swiglu(x @ params["w_gate"], x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff_expert: int               # per-expert hidden (fine-grained: small)
    num_experts: int               # routed experts
    top_k: int
    num_shared: int = 0            # always-active shared experts
    d_ff_shared: Optional[int] = None  # defaults to num_shared * d_ff_expert
    capacity_factor: float = 1.25
    router_type: str = "softmax"   # "softmax" (dsmoe) | "sigmoid" (dsv3)
    aux_loss_weight: float = 0.001
    group_size: int = 1024         # tokens per dispatch group (bounds the
                                   # [G, Tg, E, C] dispatch tensor footprint)

    @property
    def shared_ff(self) -> int:
        return self.d_ff_shared or self.num_shared * self.d_ff_expert


def init_moe(key: jax.Array, cfg: MoEConfig) -> dict:
    ks = jax.random.split(key, 8)
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.num_experts
    p = {
        "router": make_param(ks[0], (d, e), ("embed", "expert"), scale=0.02),
        # stacked expert FFNs: leading `expert` axis shards over EP
        "we_gate": make_param(ks[1], (e, d, f), ("expert", "embed", "mlp")),
        "we_up": make_param(ks[2], (e, d, f), ("expert", "embed", "mlp")),
        "we_down": make_param(ks[3], (e, f, d), ("expert", "mlp", "embed")),
    }
    if cfg.num_shared > 0:
        fs = cfg.shared_ff
        p["shared"] = {
            "w_gate": make_param(ks[4], (d, fs), ("embed", "mlp")),
            "w_up": make_param(ks[5], (d, fs), ("embed", "mlp")),
            "w_down": make_param(ks[6], (fs, d), ("mlp", "embed")),
        }
    return p


def _routing(params, x3d: jax.Array, cfg: MoEConfig):
    """Grouped token->expert assignment.

    x3d [G, Tg, D] -> (weights [G, Tg, k], idx [G, Tg, k], aux scalar).
    """
    logits = (x3d @ params["router"]).astype(jnp.float32)     # [G, Tg, E]
    if cfg.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, cfg.top_k)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
    # Switch-style load-balance loss over the full softmax distribution.
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=(0, 1))                         # [E]
    one_hot = jax.nn.one_hot(idx[..., 0], cfg.num_experts)    # primary route
    ce = jnp.mean(one_hot, axis=(0, 1))
    aux = cfg.num_experts * jnp.sum(me * ce) * cfg.aux_loss_weight
    return w.astype(x3d.dtype), idx, aux


def moe(
    params: dict, x: jax.Array, cfg: MoEConfig
) -> Tuple[jax.Array, jax.Array]:
    """MoE forward. x [B, S, D] (or [T, D]); returns (out, aux_loss).

    Tokens are processed in groups of ``cfg.group_size`` with per-group,
    per-expert capacity ``C = Tg * k / E * capacity_factor`` (>= 1). Tokens
    above an expert's capacity within their group are dropped (combine
    weight zero) — standard Switch semantics; the default capacity factor
    keeps drops rare. The dispatch tensor is [G, Tg, E, C]: bounded by the
    group size regardless of global batch, and shardable as
    (data, -, expert, -) by the SPMD partitioner.
    """
    orig_shape = x.shape
    x2d = x.reshape(-1, cfg.d_model)
    t = x2d.shape[0]
    e, k = cfg.num_experts, cfg.top_k

    tg = min(cfg.group_size, t)
    g = -(-t // tg)                                           # ceil
    pad = g * tg - t
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    x3d = x2d.reshape(g, tg, cfg.d_model)

    weights, idx, aux = _routing(params, x3d, cfg)

    cap = max(int(tg * k / e * cfg.capacity_factor), 1)
    # Position of each (token, slot) within its expert's per-group buffer:
    # cumulative count of prior assignments to the same expert in the group.
    expert_onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)   # [G, Tg, k, E]
    flat = expert_onehot.reshape(g, tg * k, e)
    pos = (jnp.cumsum(flat, axis=1) * flat - 1).reshape(g, tg, k, e)

    # Accumulate dispatch/combine over the k routing slots (Python loop over
    # k avoids materialising the [G, Tg, k, E, C] intermediate).
    dispatch = jnp.zeros((g, tg, e, cap), dtype=x2d.dtype)
    combine = jnp.zeros((g, tg, e, cap), dtype=x2d.dtype)
    for slot in range(k):
        p_s = jnp.sum(pos[:, :, slot, :] * expert_onehot[:, :, slot, :], axis=-1)
        ok = (p_s >= 0) & (p_s < cap)                          # [G, Tg]
        oh = (
            jax.nn.one_hot(jnp.clip(p_s, 0, cap - 1), cap, dtype=x2d.dtype)
            * ok[..., None].astype(x2d.dtype)
        )                                                      # [G, Tg, C]
        eh = expert_onehot[:, :, slot, :].astype(x2d.dtype)    # [G, Tg, E]
        dispatch = dispatch + eh[..., None] * oh[..., None, :]
        combine = combine + (
            eh[..., None] * oh[..., None, :] * weights[:, :, slot, None, None]
        )

    xe = jnp.einsum("gtd,gtec->gecd", x3d, dispatch)          # [G, E, C, D]
    h = swiglu(
        jnp.einsum("gecd,edf->gecf", xe, params["we_gate"]),
        jnp.einsum("gecd,edf->gecf", xe, params["we_up"]),
    )
    ye = jnp.einsum("gecf,efd->gecd", h, params["we_down"])   # [G, E, C, D]
    out = jnp.einsum("gecd,gtec->gtd", ye, combine)           # [G, Tg, D]
    out = out.reshape(g * tg, cfg.d_model)
    if pad:
        out = out[:t]

    if cfg.num_shared > 0:
        sh = params["shared"]
        x2d_real = x2d[:t] if pad else x2d
        out = out + swiglu(
            x2d_real @ sh["w_gate"], x2d_real @ sh["w_up"]
        ) @ sh["w_down"]

    return out.reshape(orig_shape), aux
