"""RWKV-6 early-exit LM (attention-free; family == "rwkv").

No KV cache exists: the per-layer state is O(1) in sequence length
(token-shift vector + WKV matrix state), which is why this arch runs the
``long_500k`` shape. Early exit truncates the stack — remaining layers'
state updates are skipped entirely (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    abstract_params,
    cast_floats,
    cross_entropy,
    make_param,
    mask_padded_vocab,
    rms_norm,
    stack_init,
    weighted_exit_loss,
)
from repro.models.rwkv6 import (
    RWKV6Config,
    channel_mix,
    init_channel_mix,
    init_time_mix,
    time_mix,
)
from repro.models.transformer import LMConfig, _remat_wrap


class RWKV6LM:
    def __init__(self, cfg: LMConfig):
        assert cfg.family == "rwkv"
        self.cfg = cfg

    def _rwkv_config(self) -> RWKV6Config:
        c = self.cfg
        return RWKV6Config(d_model=c.d_model, num_heads=c.num_heads,
                           d_ff=c.d_ff, chunk=c.rwkv_chunk)

    def _init_block(self, key: jax.Array) -> dict:
        c = self.cfg
        ks = jax.random.split(key, 4)
        return {
            "norm1": make_param(ks[0], (c.d_model,), ("embed",), init="ones"),
            "norm2": make_param(ks[1], (c.d_model,), ("embed",), init="ones"),
            "tm": init_time_mix(ks[2], self._rwkv_config()),
            "cm": init_channel_mix(ks[3], self._rwkv_config()),
        }

    def _block_apply(self, params, h, state, keep_state: bool):
        c = self.cfg
        rcfg = self._rwkv_config()
        tm_state = state.get("tm") if state is not None else None
        cm_state = state.get("cm") if state is not None else None
        out, tm_new = time_mix(params["tm"], rms_norm(h, params["norm1"],
                                                      c.norm_eps),
                               rcfg, state=tm_state)
        h = h + out
        out, cm_new = channel_mix(params["cm"], rms_norm(h, params["norm2"],
                                                         c.norm_eps),
                                  rcfg, state=cm_state)
        h = h + out
        if keep_state:
            return h, {"tm": tm_new, "cm": cm_new}
        return h, None

    # -- init ----------------------------------------------------------------

    def init(self, key: jax.Array):
        c = self.cfg
        segs = self.segments()
        keys = jax.random.split(key, len(segs) + 3)
        return {
            "embed": make_param(keys[0], (c.vocab_padded, c.d_model),
                                ("vocab", "embed"), init="embedding"),
            "exit_norms": [
                make_param(keys[1], (c.d_model,), ("embed",), init="ones")
                for _ in range(c.num_exits)
            ],
            "lm_head": make_param(keys[2], (c.d_model, c.vocab_padded),
                                  ("embed", "vocab")),
            "segments": [
                stack_init(self._init_block, keys[3 + i], n)
                for i, n in enumerate(segs)
            ],
        }

    def abstract(self, key: jax.Array):
        return abstract_params(self.init, key)

    def segments(self) -> List[int]:
        bounds = [0] + list(self.cfg.exits)
        return [b - a for a, b in zip(bounds, bounds[1:])]

    # -- forward ---------------------------------------------------------------

    def _run_segment(self, seg_params, h, states, keep_state: bool):
        def body(carry, xs):
            layer_params, layer_state = xs
            h, new_state = self._block_apply(layer_params, carry, layer_state,
                                             keep_state)
            return h, new_state

        body = _remat_wrap(body, self.cfg.remat)
        h, new_states = jax.lax.scan(body, h, (seg_params, states))
        return h, new_states

    def _head(self, values, h, exit_idx):
        h = rms_norm(h, values["exit_norms"][exit_idx], self.cfg.norm_eps)
        logits = (h @ values["lm_head"].astype(h.dtype)).astype(jnp.float32)
        return mask_padded_vocab(logits, self.cfg.vocab_size)

    def train_loss(self, values, batch):
        c = self.cfg
        values = cast_floats(values, c.dtype)
        h = values["embed"][batch["tokens"]].astype(c.dtype)
        per_exit = []
        for i in range(len(self.segments())):
            h, _ = self._run_segment(values["segments"][i], h, None, False)
            per_exit.append(cross_entropy(self._head(values, h, i),
                                          batch["labels"], batch.get("mask")))
        loss = weighted_exit_loss(per_exit, c.exit_weights_)
        return loss, {"loss": loss, "nll_final": per_exit[-1],
                      **{f"nll_exit{i}": l for i, l in enumerate(per_exit)}}

    def forward_exit(self, values, batch, exit_idx: int):
        c = self.cfg
        values = cast_floats(values, c.dtype)
        h = values["embed"][batch["tokens"]].astype(c.dtype)
        for i in range(exit_idx + 1):
            h, _ = self._run_segment(values["segments"][i], h, None, False)
        return self._head(values, h, exit_idx)

    def prefill(self, values, batch, exit_idx: int):
        c = self.cfg
        values = cast_floats(values, c.dtype)
        h = values["embed"][batch["tokens"]].astype(c.dtype)
        states = []
        for i in range(exit_idx + 1):
            h, st = self._run_segment(values["segments"][i], h, None, True)
            states.append(st)
        return self._head(values, h[:, -1:, :], exit_idx), {"segments": states}

    def decode_step(self, values, token, cache, exit_idx: int):
        c = self.cfg
        values = cast_floats(values, c.dtype)
        h = values["embed"][token].astype(c.dtype)
        new_states = []
        for i in range(exit_idx + 1):
            h, st = self._run_segment(values["segments"][i], h,
                                      cache["segments"][i], True)
            new_states.append(st)
        return self._head(values, h, exit_idx), {"segments": new_states}

    def init_cache(self, batch_size: int, max_len: int, exit_idx: int,
                   dtype=None) -> dict:
        """State template. ``max_len`` is ignored — RWKV state is O(1)."""
        c = self.cfg
        dtype = dtype or c.dtype
        rcfg = self._rwkv_config()
        out = []
        for n_layers in self.segments()[: exit_idx + 1]:
            out.append({
                "tm": {
                    "shift": jnp.zeros((n_layers, batch_size, c.d_model), dtype),
                    "wkv": jnp.zeros((n_layers, batch_size, c.num_heads,
                                      rcfg.head_dim, rcfg.head_dim),
                                     jnp.float32),
                },
                "cm": {
                    "shift": jnp.zeros((n_layers, batch_size, c.d_model), dtype),
                },
            })
        return {"segments": out}
