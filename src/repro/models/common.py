"""Shared model-substrate primitives: parameters with logical sharding axes,
norms, embeddings, RoPE, and losses.

Parameter convention
--------------------
Every parameter is created through :func:`make_param` and carried as a
:class:`Param` leaf — ``(value, logical_axes)``. Model code works on *value*
pytrees (plain ``jax.Array`` leaves); the axes pytree is split off once at
init and mapped to mesh axes by ``repro.distributed.sharding`` rules. This
keeps the forward code framework-free while giving the dry-run exact
per-parameter PartitionSpecs.

Scan-over-layers convention
---------------------------
Repeated blocks are *stacked*: each leaf gains a leading ``layers`` axis and
the stack is consumed by ``jax.lax.scan``. This keeps HLO size and compile
time O(1) in depth (critical for the 512-device dry-run) and is reflected in
the axes tuples by a leading ``"layers"`` entry (never sharded).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class Param:
    """A parameter leaf: array value + logical axis names (len == ndim)."""

    value: jax.Array
    axes: Axes


def is_param(x) -> bool:
    return isinstance(x, Param)


def make_param(
    key: jax.Array,
    shape: Sequence[int],
    axes: Axes,
    dtype=jnp.float32,
    init: str = "normal",
    scale: Optional[float] = None,
) -> Param:
    """Create a Param with the given initializer.

    init: "normal" (trunc-normal, fan-in scaled unless ``scale`` given),
          "zeros", "ones", "embedding" (normal(1.0/sqrt(d))).
    """
    shape = tuple(int(s) for s in shape)
    assert len(axes) == len(shape), (axes, shape)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        if scale is None:
            fan_in = shape[0] if len(shape) >= 1 else 1
            if init == "embedding":
                fan_in = shape[-1]
            scale = 1.0 / np.sqrt(max(fan_in, 1))
        v = (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)
    return Param(v, tuple(axes))


def split_params(tree: PyTree) -> Tuple[PyTree, PyTree]:
    """Param tree -> (values tree, axes tree) with identical structure."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def abstract_params(init_fn: Callable[[jax.Array], PyTree], key: jax.Array):
    """Shape-only init: (ShapeDtypeStruct values tree, axes tree).

    Runs ``init_fn`` under ``jax.eval_shape`` — zero FLOPs, zero allocation —
    capturing the static axes tuples through a side channel. This is how the
    dry-run builds 671B-parameter input specs on a CPU host.
    """
    captured = []

    def value_only(k):
        params = init_fn(k)
        captured.append(jax.tree.map(lambda p: p.axes, params, is_leaf=is_param))
        return jax.tree.map(lambda p: p.value, params, is_leaf=is_param)

    shapes = jax.eval_shape(value_only, key)
    return shapes, captured[0]


def stack_init(block_init: Callable[[jax.Array], PyTree], key: jax.Array, n: int):
    """Initialise ``n`` stacked copies of a block (scan-over-layers).

    Returns a Param tree whose leaves have a leading ``n`` axis and a
    prepended ``"layers"`` logical axis.
    """
    keys = jax.random.split(key, n)
    stacked_values = jax.vmap(
        lambda k: jax.tree.map(lambda p: p.value, block_init(k), is_leaf=is_param)
    )(keys)
    axes_tree = jax.tree.map(
        lambda p: ("layers",) + p.axes, block_init(key), is_leaf=is_param
    )
    # Re-wrap into Params: leaf positions follow stacked_values (array
    # leaves); flatten_up_to semantics hand each one its whole axes tuple.
    return jax.tree.map(lambda v, a: Param(v, a), stacked_values, axes_tree)


def cast_floats(tree: PyTree, dtype) -> PyTree:
    """Cast float leaves to the compute dtype (mixed precision: the master
    copy stays fp32 in the optimizer; forward casts at entry)."""

    def one(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in fp32 accumulation, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """[head_dim/2] inverse frequencies."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotate ``x [..., S, H, D]`` by ``positions [..., S]`` (broadcastable).

    Pairs (x[2i], x[2i+1]) are rotated — the interleaved convention.
    """
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)                          # [D/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # [...,S,1,D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token-level CE. logits [..., V] fp32-accumulated, labels int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def mask_padded_vocab(logits: jax.Array, vocab: int) -> jax.Array:
    """Mask sharding-padding vocab slots to -inf (no-op when unpadded)."""
    if logits.shape[-1] == vocab:
        return logits
    keep = jnp.arange(logits.shape[-1]) < vocab
    return jnp.where(keep, logits, -1e30)


def weighted_exit_loss(per_exit_nll: Sequence[jax.Array],
                       weights: Sequence[float]) -> jax.Array:
    """Early-exit training objective: weighted sum of per-exit CE losses.

    The paper trains every exit head jointly; the standard weighting puts
    full weight on the final head and smaller weight on early heads.
    """
    w = jnp.asarray(weights, dtype=jnp.float32)
    w = w / jnp.sum(w)
    return sum(wi * li for wi, li in zip(w, per_exit_nll))
