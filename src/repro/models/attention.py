"""Attention variants: GQA (with optional QK-norm) and DeepSeek-style MLA.

Shapes: activations are ``[B, S, D]``; query heads ``H``, kv heads ``K``
(GQA groups ``G = H // K``), head dim ``Dh``. KV caches are per layer
``{"k": [B, Smax, K, Dh], "v": [B, Smax, K, Dh]}`` (MLA caches the
compressed latent instead — its whole point is an ``O(d_c)`` cache).

The jnp attention here is the reference path (and the dry-run path — see
DESIGN.md: Pallas kernels are validated separately in interpret mode and
swapped in on real TPU via ``use_flash_kernel``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Param, apply_rope, make_param, rms_norm


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False          # qwen3-style per-head RMS norm on q and k
    causal: bool = True
    use_flash_kernel: bool = False  # swap in the Pallas kernel (TPU path)
    attn_bias: bool = False


def init_attention(key: jax.Array, cfg: AttentionConfig) -> dict:
    ks = jax.random.split(key, 6)
    d, h, k_h, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    params = {
        "wq": make_param(ks[0], (d, h * dh), ("embed", "heads")),
        "wk": make_param(ks[1], (d, k_h * dh), ("embed", "heads")),
        "wv": make_param(ks[2], (d, k_h * dh), ("embed", "heads")),
        "wo": make_param(ks[3], (h * dh, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        params["q_norm"] = make_param(ks[4], (dh,), (None,), init="ones")
        params["k_norm"] = make_param(ks[5], (dh,), (None,), init="ones")
    return params


def _sdpa(q, k, v, causal: bool, q_offset=0, kv_len: Optional[jax.Array] = None):
    """Scaled dot-product attention with GQA via kv-head broadcasting.

    q [B, Sq, H, Dh]; k, v [B, Skv, K, Dh]. fp32 softmax accumulation.
    ``q_offset`` is the absolute position of q[0] (decode: cache length).
    ``kv_len`` optionally masks the cache tail (positions >= kv_len).
    """
    b, sq, h, dh = q.shape
    _, skv, kh, _ = k.shape
    g = h // kh
    qg = q.reshape(b, sq, kh, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores *= 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    if causal and sq > 1:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(skv)
        mask = kpos[None, :] <= qpos[:, None]                # [Sq, Skv]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    if kv_len is not None:
        valid = jnp.arange(skv)[None, :] < kv_len[:, None]   # [B, Skv]
        scores = jnp.where(valid[:, None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, v.shape[-1])  # v head dim may differ (MLA)


def attention(
    params: dict,
    x: jax.Array,
    cfg: AttentionConfig,
    cache: Optional[dict] = None,
    position: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Self-attention forward.

    Modes:
      * train/prefill: ``cache is None`` -> full causal attention over x.
        When ``position`` is given (prefill), a fresh cache dict is returned.
      * decode: ``cache`` holds {"k","v","len"}; x is ``[B, 1, D]``; returns
        updated cache (functional, donate-friendly).
    """
    b, s, d = x.shape
    h, kh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    k = (x @ params["wk"]).reshape(b, s, kh, dh)
    v = (x @ params["wv"]).reshape(b, s, kh, dh)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])

    if cache is None:
        pos = jnp.arange(s)[None, :]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        out = _sdpa(q, k, v, cfg.causal)
        new_cache = None
        if position is not None:  # prefill: hand the KV back for decode
            new_cache = {"k": k, "v": v, "len": jnp.full((b,), s, jnp.int32)}
    else:
        cache_len = cache["len"]                              # [B]
        pos = cache_len[:, None]                              # x is the next token
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        # Scatter the new token at its position. All rows share the same
        # length in this serving runtime, so use row 0's length.
        idx = cache_len[0]
        k_all = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0)
        )
        v_all = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0)
        )
        out = _sdpa(q, k_all, v_all, causal=False, kv_len=cache_len + 1)
        new_cache = {"k": k_all, "v": v_all, "len": cache_len + 1}

    return out.reshape(b, s, h * dh) @ params["wo"], new_cache


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    num_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    causal: bool = True

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


def init_mla(key: jax.Array, cfg: MLAConfig) -> dict:
    ks = jax.random.split(key, 8)
    d, h = cfg.d_model, cfg.num_heads
    return {
        # low-rank query path: d -> q_lora -> heads*(nope+rope)
        "wq_a": make_param(ks[0], (d, cfg.q_lora_rank), ("embed", None)),
        "q_a_norm": make_param(ks[1], (cfg.q_lora_rank,), (None,), init="ones"),
        "wq_b": make_param(ks[2], (cfg.q_lora_rank, h * cfg.qk_head_dim),
                           (None, "heads")),
        # compressed kv path: d -> kv_lora (+ shared rope key)
        "wkv_a": make_param(ks[3], (d, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
                            ("embed", None)),
        "kv_a_norm": make_param(ks[4], (cfg.kv_lora_rank,), (None,), init="ones"),
        "wkv_b": make_param(
            ks[5],
            (cfg.kv_lora_rank, h * (cfg.qk_nope_head_dim + cfg.v_head_dim)),
            (None, "heads"),
        ),
        "wo": make_param(ks[6], (h * cfg.v_head_dim, d), ("heads", "embed")),
    }


def _mla_qkv(params, x, cfg: MLAConfig, positions):
    """Project x into per-head q, k, v (+ return the compressed latent)."""
    b, s, _ = x.shape
    h = cfg.num_heads
    q = rms_norm(x @ params["wq_a"], params["q_a_norm"]) @ params["wq_b"]
    q = q.reshape(b, s, h, cfg.qk_head_dim)
    q_nope, q_pe = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    kv_a = x @ params["wkv_a"]                                 # [B,S,dc+rope]
    c_kv, k_pe = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_a_norm"])
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)  # [B,S,1,r]

    kv = (c_kv @ params["wkv_b"]).reshape(
        b, s, h, cfg.qk_nope_head_dim + cfg.v_head_dim
    )
    k_nope, v = jnp.split(kv, [cfg.qk_nope_head_dim], axis=-1)
    k_pe_bcast = jnp.broadcast_to(k_pe, (b, s, h, cfg.qk_rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    k_full = jnp.concatenate([k_nope, k_pe_bcast], axis=-1)
    return q_full, k_full, v, c_kv, k_pe[:, :, 0, :]


def mla_attention(
    params: dict,
    x: jax.Array,
    cfg: MLAConfig,
    cache: Optional[dict] = None,
    position: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """MLA forward. The decode cache stores the *compressed* latent
    ``c_kv [B, Smax, d_c]`` + rope key ``k_pe [B, Smax, r]`` — the O(d_c)
    per-token cache that makes MLA serve long contexts cheaply."""
    b, s, _ = x.shape
    h = cfg.num_heads

    if cache is None:
        pos = jnp.arange(s)[None, :]
        q, k, v, c_kv, k_pe = _mla_qkv(params, x, cfg, pos)
        out = _sdpa(q, k, v, cfg.causal)
        new_cache = None
        if position is not None:
            new_cache = {
                "c_kv": c_kv, "k_pe": k_pe,
                "len": jnp.full((b,), s, jnp.int32),
            }
    else:
        cache_len = cache["len"]
        pos = cache_len[:, None]
        q, k_new, v_new, c_kv_new, k_pe_new = _mla_qkv(params, x, cfg, pos)
        idx = cache_len[0]
        c_all = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, idx, 0))
        pe_all = jax.lax.dynamic_update_slice(
            cache["k_pe"], k_pe_new.astype(cache["k_pe"].dtype), (0, idx, 0))
        # Expand latent -> per-head K/V for the attention itself.
        s_kv = c_all.shape[1]
        kv = (c_all @ params["wkv_b"]).reshape(
            b, s_kv, h, cfg.qk_nope_head_dim + cfg.v_head_dim)
        k_nope, v = jnp.split(kv, [cfg.qk_nope_head_dim], axis=-1)
        k_pe_b = jnp.broadcast_to(
            pe_all[:, :, None, :], (b, s_kv, h, cfg.qk_rope_head_dim))
        k = jnp.concatenate([k_nope, k_pe_b], axis=-1)
        out = _sdpa(q, k, v, causal=False, kv_len=cache_len + 1)
        new_cache = {"c_kv": c_all, "k_pe": pe_all, "len": cache_len + 1}

    out = out.reshape(b, s, h * cfg.v_head_dim) @ params["wo"]
    return out, new_cache


def mla_attention_absorbed(
    params: dict,
    x: jax.Array,
    cfg: MLAConfig,
    cache: dict,
) -> Tuple[jax.Array, dict]:
    """Absorbed-matrix MLA decode (DeepSeek-V2/V3 inference form).

    Mathematically identical to expanding the latent into per-head K/V, but
    attention runs *in latent space*: the nope-query is projected through
    W_k into the latent (``q_eff = q_nope @ W_k``), scores are taken against
    the cached latent directly, and the context is re-expanded through W_v
    only for the single output token.

    Per decode step this reads the cache once — O(S * d_c) — instead of
    materialising K/V at O(S * H * (d_nope + d_v)): a 64x HBM-traffic
    reduction for V3's 128 heads (see EXPERIMENTS.md §Perf).
    """
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dv, dc = cfg.qk_nope_head_dim, cfg.v_head_dim, cfg.kv_lora_rank
    cache_len = cache["len"]
    pos = cache_len[:, None]

    q = rms_norm(x @ params["wq_a"], params["q_a_norm"]) @ params["wq_b"]
    q = q.reshape(b, s, h, cfg.qk_head_dim)
    q_nope, q_pe = jnp.split(q, [dn], axis=-1)
    q_pe = apply_rope(q_pe, pos, cfg.rope_theta)

    kv_a = x @ params["wkv_a"]
    c_new, k_pe_new = jnp.split(kv_a, [dc], axis=-1)
    c_new = rms_norm(c_new, params["kv_a_norm"])
    k_pe_new = apply_rope(k_pe_new[:, :, None, :], pos,
                          cfg.rope_theta)[:, :, 0, :]

    idx = cache_len[0]
    c_all = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, idx, 0))
    pe_all = jax.lax.dynamic_update_slice(
        cache["k_pe"], k_pe_new.astype(cache["k_pe"].dtype), (0, idx, 0))

    # absorbed weights: wkv_b [dc, H*(dn+dv)] -> W_k [dc,H,dn], W_v [dc,H,dv]
    wkv_b = params["wkv_b"].reshape(dc, h, dn + dv)
    w_k, w_v = wkv_b[..., :dn], wkv_b[..., dn:]

    q_eff = jnp.einsum("bshd,chd->bshc", q_nope, w_k)        # [B,1,H,dc]
    scores = (
        jnp.einsum("bshc,btc->bhst", q_eff, c_all)
        + jnp.einsum("bshr,btr->bhst", q_pe, pe_all)
    ).astype(jnp.float32)
    scores *= 1.0 / jnp.sqrt(cfg.qk_head_dim).astype(jnp.float32)
    s_kv = c_all.shape[1]
    valid = jnp.arange(s_kv)[None, :] < (cache_len + 1)[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)

    o_latent = jnp.einsum("bhst,btc->bshc", probs, c_all)    # [B,1,H,dc]
    out = jnp.einsum("bshc,chd->bshd", o_latent, w_v)        # [B,1,H,dv]
    out = out.reshape(b, s, h * dv) @ params["wo"]
    return out, {"c_kv": c_all, "k_pe": pe_all, "len": cache_len + 1}
