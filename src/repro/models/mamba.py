"""Mamba-1 selective SSM block (for the Jamba hybrid, arXiv:2403.19887).

Selective state space: per token t,

    h_t = exp(A * dt_t) ⊙ h_{t-1} + dt_t * B_t * x_t     (h in R^{d_in x N})
    y_t = C_t · h_t + D ⊙ x_t

with input-dependent (selective) dt, B, C. Train/prefill run ``lax.scan``
over time; decode carries ``h`` and the depthwise-conv window — O(1) state,
which is why Jamba runs the ``long_500k`` shape with only its sparse
attention layers holding a KV cache.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import Param, make_param


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # defaults to ceil(d_model / 16)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def init_mamba(key: jax.Array, cfg: MambaConfig) -> dict:
    ks = jax.random.split(key, 8)
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.rank
    # S4D-real initialisation for A (negative real spectrum).
    a_init = jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                      (di, n)))
    return {
        "w_in": make_param(ks[0], (d, 2 * di), ("embed", "mlp")),
        "conv_w": make_param(ks[1], (cfg.d_conv, di), (None, "mlp"),
                             scale=1.0 / np.sqrt(cfg.d_conv)),
        "conv_b": make_param(ks[2], (di,), ("mlp",), init="zeros"),
        "w_x_dbc": make_param(ks[3], (di, r + 2 * n), ("mlp", None)),
        "w_dt": make_param(ks[4], (r, di), (None, "mlp")),
        "dt_bias": make_param(ks[5], (di,), ("mlp",), init="zeros"),
        "a_log": Param(a_init, ("mlp", None)),
        "d_skip": make_param(ks[6], (di,), ("mlp",), init="ones"),
        "w_out": make_param(ks[7], (di, d), ("mlp", "embed")),
    }


def _selective_scan(x, dt, b_t, c_t, a, d_skip, h0):
    """x, dt: [B, S, Di]; b_t, c_t: [B, S, N]; a: [Di, N]; h0: [B, Di, N]."""
    bsz, s, di = x.shape
    n = b_t.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), dtype=jnp.float32)

    def step(h, inputs):
        x_t, dt_t, bt, ct = inputs            # [B,Di], [B,Di], [B,N], [B,N]
        da = jnp.exp(dt_t[..., None] * a[None])               # [B, Di, N]
        h_new = da * h + (dt_t * x_t)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h_new, ct)
        return h_new, y

    xs = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0)
        for t in (x, dt, b_t, c_t)
    )
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * d_skip
    return y, h_final


def _causal_conv(x, w, b, window: Optional[jax.Array] = None):
    """Depthwise causal conv1d. x [B,S,Di], w [K,Di]. window [B,K-1,Di] is the
    carried left context for decode; None -> zero padding (train/prefill)."""
    k = w.shape[0]
    if window is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = window.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                    # [B, S+K-1, Di]
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return out + b, xp[:, -(k - 1):, :]


def mamba(
    params: dict,
    x: jax.Array,
    cfg: MambaConfig,
    state: Optional[dict] = None,
) -> Tuple[jax.Array, dict]:
    """Mamba block forward. state = {"h": [B,Di,N], "conv": [B,K-1,Di]}."""
    xz = x @ params["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)                         # [B,S,Di] each
    conv_state = state["conv"] if state is not None else None
    h0 = state["h"] if state is not None else None

    xs, new_conv = _causal_conv(xs, params["conv_w"], params["conv_b"],
                                conv_state)
    xs = jax.nn.silu(xs)

    dbc = xs @ params["w_x_dbc"]
    r, n = cfg.rank, cfg.d_state
    dt_r, b_t, c_t = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["w_dt"] + params["dt_bias"])
    a = -jnp.exp(params["a_log"].astype(jnp.float32))         # [Di, N]

    y, h_final = _selective_scan(xs, dt, b_t, c_t, a, params["d_skip"], h0)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["w_out"]
    return out, {"h": h_final, "conv": new_conv}
