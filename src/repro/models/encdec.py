"""Encoder-decoder early-exit LM (SeamlessM4T backbone; family == "encdec").

The audio frontend is a STUB per the assignment spec: ``input_specs()``
provides precomputed frame embeddings ``[B, S_src, D]`` directly to the
encoder. Early exits attach to the **decoder** stack only — the encoder
always runs fully, because every exit's cross-attention consumes the full
encoder output (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import _sdpa, attention, init_attention
from repro.models.common import (
    abstract_params,
    cast_floats,
    cross_entropy,
    make_param,
    mask_padded_vocab,
    rms_norm,
    stack_init,
    weighted_exit_loss,
)
from repro.models.moe import init_mlp, mlp
from repro.models.transformer import LMConfig, _remat_wrap


def init_cross_attention(key: jax.Array, cfg) -> dict:
    ks = jax.random.split(key, 4)
    d, h, kh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": make_param(ks[0], (d, h * dh), ("embed", "heads")),
        "wk": make_param(ks[1], (d, kh * dh), ("embed", "heads")),
        "wv": make_param(ks[2], (d, kh * dh), ("embed", "heads")),
        "wo": make_param(ks[3], (h * dh, d), ("heads", "embed")),
    }


def cross_attention(params, x, enc_kv, cfg):
    """x [B, St, D] attends to precomputed encoder K/V (no positions)."""
    b, s, _ = x.shape
    h, dh = cfg.num_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    out = _sdpa(q, enc_kv["k"], enc_kv["v"], causal=False)
    return out.reshape(b, s, h * dh) @ params["wo"]


def encode_kv(params, enc_out, cfg):
    """Project encoder output once per session into cross-attn K/V."""
    b, s, _ = enc_out.shape
    kh, dh = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": (enc_out @ params["wk"]).reshape(b, s, kh, dh),
        "v": (enc_out @ params["wv"]).reshape(b, s, kh, dh),
    }


class EncDecLM:
    def __init__(self, cfg: LMConfig):
        assert cfg.family == "encdec" and cfg.num_encoder_layers > 0
        self.cfg = cfg

    # -- blocks ----------------------------------------------------------------

    def _init_enc_block(self, key: jax.Array) -> dict:
        c = self.cfg
        ks = jax.random.split(key, 4)
        return {
            "norm1": make_param(ks[0], (c.d_model,), ("embed",), init="ones"),
            "norm2": make_param(ks[1], (c.d_model,), ("embed",), init="ones"),
            "attn": init_attention(ks[2], c.attn_config()),
            "ffn": init_mlp(ks[3], c.mlp_config()),
        }

    def _init_dec_block(self, key: jax.Array) -> dict:
        c = self.cfg
        ks = jax.random.split(key, 6)
        return {
            "norm1": make_param(ks[0], (c.d_model,), ("embed",), init="ones"),
            "norm2": make_param(ks[1], (c.d_model,), ("embed",), init="ones"),
            "norm3": make_param(ks[2], (c.d_model,), ("embed",), init="ones"),
            "attn": init_attention(ks[3], c.attn_config()),
            "xattn": init_cross_attention(ks[4], c.attn_config()),
            "ffn": init_mlp(ks[5], c.mlp_config()),
        }

    # -- init --------------------------------------------------------------------

    def init(self, key: jax.Array):
        c = self.cfg
        segs = self.segments()
        keys = jax.random.split(key, len(segs) + 5)
        return {
            "embed": make_param(keys[0], (c.vocab_padded, c.d_model),
                                ("vocab", "embed"), init="embedding"),
            "enc_norm": make_param(keys[1], (c.d_model,), ("embed",),
                                   init="ones"),
            "exit_norms": [
                make_param(keys[2], (c.d_model,), ("embed",), init="ones")
                for _ in range(c.num_exits)
            ],
            "lm_head": make_param(keys[3], (c.d_model, c.vocab_padded),
                                  ("embed", "vocab")),
            "encoder": stack_init(self._init_enc_block, keys[4],
                                  c.num_encoder_layers),
            "segments": [
                stack_init(self._init_dec_block, keys[5 + i], n)
                for i, n in enumerate(segs)
            ],
        }

    def abstract(self, key: jax.Array):
        return abstract_params(self.init, key)

    def segments(self) -> List[int]:
        bounds = [0] + list(self.cfg.exits)
        return [b - a for a, b in zip(bounds, bounds[1:])]

    # -- encoder -------------------------------------------------------------------

    def encode(self, values, src_embeds: jax.Array) -> jax.Array:
        """Full (bidirectional) encoder over frontend-stub embeddings."""
        c = self.cfg
        acfg = functools.partial  # readability only
        cfg_attn = c.attn_config()
        cfg_attn = type(cfg_attn)(**{**cfg_attn.__dict__, "causal": False})
        h = src_embeds.astype(c.dtype)

        def body(h, layer_params):
            x = rms_norm(h, layer_params["norm1"], c.norm_eps)
            out, _ = attention(layer_params["attn"], x, cfg_attn)
            h = h + out
            x = rms_norm(h, layer_params["norm2"], c.norm_eps)
            return h + mlp(layer_params["ffn"], x, c.mlp_config()), None

        body = _remat_wrap(body, c.remat)
        h, _ = jax.lax.scan(body, h, values["encoder"])
        return rms_norm(h, values["enc_norm"], c.norm_eps)

    # -- decoder --------------------------------------------------------------------

    def _run_segment(self, seg_params, h, enc_out, caches, make_cache: bool):
        c = self.cfg

        def body(carry, xs):
            h = carry
            layer_params, layer_cache = xs
            x = rms_norm(h, layer_params["norm1"], c.norm_eps)
            pos = jnp.zeros((), jnp.int32) if make_cache else None
            self_cache = layer_cache.get("self") if layer_cache else None
            out, new_self = attention(layer_params["attn"], x, c.attn_config(),
                                      cache=self_cache, position=pos)
            h = h + out
            x = rms_norm(h, layer_params["norm2"], c.norm_eps)
            enc_kv = (layer_cache.get("enc_kv") if layer_cache else None)
            if enc_kv is None:
                enc_kv = encode_kv(layer_params["xattn"], enc_out,
                                   c.attn_config())
            h = h + cross_attention(layer_params["xattn"], x, enc_kv,
                                    c.attn_config())
            x = rms_norm(h, layer_params["norm3"], c.norm_eps)
            h = h + mlp(layer_params["ffn"], x, c.mlp_config())
            new_cache = None
            if make_cache:
                new_cache = {"self": new_self, "enc_kv": enc_kv}
            elif layer_cache is not None:
                new_cache = {"self": new_self, "enc_kv": enc_kv}
            return h, new_cache

        body = _remat_wrap(body, c.remat)
        h, new_caches = jax.lax.scan(body, h, (seg_params, caches))
        return h, new_caches

    def _head(self, values, h, exit_idx):
        h = rms_norm(h, values["exit_norms"][exit_idx], self.cfg.norm_eps)
        logits = (h @ values["lm_head"].astype(h.dtype)).astype(jnp.float32)
        return mask_padded_vocab(logits, self.cfg.vocab_size)

    # -- public API --------------------------------------------------------------------

    def train_loss(self, values, batch):
        """batch: {"src_embeds": [B,Ss,D], "tokens": [B,St], "labels"}."""
        c = self.cfg
        values = cast_floats(values, c.dtype)
        enc_out = self.encode(values, batch["src_embeds"])
        h = values["embed"][batch["tokens"]].astype(c.dtype)
        per_exit = []
        for i in range(len(self.segments())):
            h, _ = self._run_segment(values["segments"][i], h, enc_out,
                                     None, False)
            per_exit.append(cross_entropy(self._head(values, h, i),
                                          batch["labels"], batch.get("mask")))
        loss = weighted_exit_loss(per_exit, c.exit_weights_)
        return loss, {"loss": loss, "nll_final": per_exit[-1],
                      **{f"nll_exit{i}": l for i, l in enumerate(per_exit)}}

    def forward_exit(self, values, batch, exit_idx: int):
        c = self.cfg
        values = cast_floats(values, c.dtype)
        enc_out = self.encode(values, batch["src_embeds"])
        h = values["embed"][batch["tokens"]].astype(c.dtype)
        for i in range(exit_idx + 1):
            h, _ = self._run_segment(values["segments"][i], h, enc_out,
                                     None, False)
        return self._head(values, h, exit_idx)

    def prefill(self, values, batch, exit_idx: int):
        c = self.cfg
        values = cast_floats(values, c.dtype)
        enc_out = self.encode(values, batch["src_embeds"])
        h = values["embed"][batch["tokens"]].astype(c.dtype)
        caches = []
        for i in range(exit_idx + 1):
            h, seg_cache = self._run_segment(values["segments"][i], h,
                                             enc_out, None, True)
            caches.append(seg_cache)
        return self._head(values, h[:, -1:, :], exit_idx), {"segments": caches}

    def decode_step(self, values, token, cache, exit_idx: int):
        """Decode with self-attn KV cache + fixed cross-attn K/V."""
        c = self.cfg
        values = cast_floats(values, c.dtype)
        h = values["embed"][token].astype(c.dtype)
        dummy_enc = None  # enc_kv comes from the cache
        new_caches = []
        for i in range(exit_idx + 1):
            h, seg_cache = self._run_segment(
                values["segments"][i], h, dummy_enc, cache["segments"][i],
                False)
            new_caches.append(seg_cache)
        return self._head(values, h, exit_idx), {"segments": new_caches}

    def prepare_decode_cache(self, values, src_embeds, batch_size: int,
                             max_len: int, exit_idx: int) -> dict:
        """Fresh decode cache with the cross-attn K/V precomputed from the
        encoder output (run once per serving session)."""
        enc_out = self.encode(values, src_embeds)
        cache = self.init_cache(batch_size, max_len, exit_idx,
                                src_len=src_embeds.shape[1])
        acfg = self.cfg.attn_config()
        for i, seg in enumerate(cache["segments"]):
            seg["enc_kv"] = jax.vmap(
                lambda p: encode_kv(p, enc_out, acfg)
            )(values["segments"][i]["xattn"])
        return cache

    def init_cache(self, batch_size: int, max_len: int, exit_idx: int,
                   src_len: int = 0, dtype=None) -> dict:
        c = self.cfg
        dtype = dtype or c.dtype
        src_len = src_len or max(c.frontend_seq, 1)
        out = []
        for n in self.segments()[: exit_idx + 1]:
            out.append({
                "self": {
                    "k": jnp.zeros((n, batch_size, max_len, c.num_kv_heads,
                                    c.head_dim_), dtype),
                    "v": jnp.zeros((n, batch_size, max_len, c.num_kv_heads,
                                    c.head_dim_), dtype),
                    "len": jnp.zeros((n, batch_size), jnp.int32),
                },
                "enc_kv": {
                    "k": jnp.zeros((n, batch_size, src_len, c.num_kv_heads,
                                    c.head_dim_), dtype),
                    "v": jnp.zeros((n, batch_size, src_len, c.num_kv_heads,
                                    c.head_dim_), dtype),
                },
            })
        return {"segments": out}
