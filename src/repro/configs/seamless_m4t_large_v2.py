"""seamless-m4t-large-v2 [audio]: enc-dec, 24L(+24 enc) d_model=1024 16H
(kv=16) d_ff=8192 vocab=256206 — multimodal. [arXiv:2308.11596; hf]

The audio frontend is a STUB per the assignment spec: ``input_specs()``
provides precomputed frame embeddings to the encoder. Early exits attach to
the decoder only; the encoder always runs fully (every exit's
cross-attention reads the full encoder output).
"""

import jax.numpy as jnp

from repro.models.transformer import LMConfig

FULL = LMConfig(
    arch_id="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,                 # decoder layers (exit-bearing)
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    exits=(6, 12, 18, 24),
    frontend="audio",
    frontend_seq=1024,
    dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    arch_id="seamless-m4t-large-v2-smoke",
    family="encdec",
    num_layers=4,
    num_encoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    exits=(1, 2, 3, 4),
    frontend="audio",
    frontend_seq=16,
    dtype=jnp.float32,
)
