"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
Mamba+attention 1:7 interleave, MoE 16e top-2 every other layer.
[arXiv:2403.19887; hf]

Only 4 of 32 layers hold a KV cache -> runs long_500k (DESIGN.md
§Arch-applicability). Exits align to superblock (8-layer) boundaries.
"""

import jax.numpy as jnp

from repro.models.transformer import LMConfig

FULL = LMConfig(
    arch_id="jamba-v0.1-52b",
    family="jamba",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    exits=(8, 16, 24, 32),
    attn_period=8,
    attn_offset=3,                 # one attention layer per 8 (1:7)
    moe_period=2,                  # MoE every other layer
    num_experts=16,
    top_k=2,
    d_ff_expert=14336,
    moe_router="softmax",
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    rope_theta=10_000.0,
    dtype=jnp.bfloat16,
    remat="dots",
)

SMOKE = LMConfig(
    arch_id="jamba-v0.1-52b-smoke",
    family="jamba",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    exits=(4, 8),
    attn_period=4,
    attn_offset=3,
    moe_period=2,
    num_experts=4,
    top_k=2,
    d_ff_expert=64,
    moe_group_size=16,
    mamba_d_state=8,
    mamba_d_conv=3,
    mamba_expand=2,
    dtype=jnp.float32,
)
