"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168 vocab=65536
— Finch, data-dependent decay. [arXiv:2404.05892; unverified]

Attention-free: no KV cache exists; per-layer state is O(1) in context, so
this arch runs the long_500k shape (DESIGN.md §Arch-applicability).
"""

import jax.numpy as jnp

from repro.models.transformer import LMConfig

FULL = LMConfig(
    arch_id="rwkv6-1.6b",
    family="rwkv",
    num_layers=24,
    d_model=2048,
    num_heads=32,                  # RWKV-6 head size 64
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    exits=(6, 12, 18, 24),
    dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    arch_id="rwkv6-1.6b-smoke",
    family="rwkv",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    exits=(1, 2, 3, 4),
    dtype=jnp.float32,
)
