"""The paper's own deployment: early-exit ResNet50/101/152 on CIFAR-100
(paper Sec. IV). FULL configs match the standard bottleneck stage plans;
SMOKE configs shrink width/depth for CPU tests."""

from repro.models.resnet import ResNetConfig

FULL = {
    "resnet50": ResNetConfig(variant="resnet50", num_classes=100),
    "resnet101": ResNetConfig(variant="resnet101", num_classes=100),
    "resnet152": ResNetConfig(variant="resnet152", num_classes=100),
}

SMOKE = {
    "resnet50": ResNetConfig(variant="resnet50", num_classes=100,
                             width_multiplier=0.125,
                             blocks_override=(1, 1, 1, 1)),
    "resnet101": ResNetConfig(variant="resnet101", num_classes=100,
                              width_multiplier=0.125,
                              blocks_override=(1, 1, 2, 1)),
    "resnet152": ResNetConfig(variant="resnet152", num_classes=100,
                              width_multiplier=0.125,
                              blocks_override=(1, 2, 2, 1)),
}
