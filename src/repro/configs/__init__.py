"""Architecture registry: the 10 assigned architectures (+ the paper's own
ResNet trio) as selectable configs (``--arch <id>``)."""

from __future__ import annotations

from typing import Dict, List

from repro.configs import (
    deepseek_moe_16b,
    deepseek_v3_671b,
    edgeserving_resnets,
    jamba_v0_1_52b,
    llava_next_mistral_7b,
    phi4_mini_3_8b,
    qwen3_8b,
    rwkv6_1_6b,
    seamless_m4t_large_v2,
    smollm_135m,
    starcoder2_7b,
)
from repro.configs.shapes import (
    SHAPES,
    ShapeSpec,
    applicable,
    input_specs,
    skip_reason,
)
from repro.models.transformer import LMConfig

_MODULES = {
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "qwen3-8b": qwen3_8b,
    "smollm-135m": smollm_135m,
    "starcoder2-7b": starcoder2_7b,
    "phi4-mini-3.8b": phi4_mini_3_8b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "rwkv6-1.6b": rwkv6_1_6b,
    "jamba-v0.1-52b": jamba_v0_1_52b,
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str, smoke: bool = False) -> LMConfig:
    try:
        mod = _MODULES[arch_id]
    except KeyError:
        raise ValueError(
            f"unknown arch {arch_id!r}; available: {ARCH_IDS}"
        ) from None
    return mod.SMOKE if smoke else mod.FULL


def all_configs(smoke: bool = False) -> Dict[str, LMConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}


def resnet_configs(smoke: bool = False):
    return edgeserving_resnets.SMOKE if smoke else edgeserving_resnets.FULL


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ShapeSpec",
    "all_configs",
    "applicable",
    "get_config",
    "input_specs",
    "resnet_configs",
    "skip_reason",
]
