"""llava-next-mistral-7b [vlm]: Mistral-7B backbone — 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000 — anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Per the assignment spec, the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (anyres tiling is absorbed into the
stub's sequence length). The transformer backbone is what this config
exercises.
"""

import jax.numpy as jnp

from repro.models.transformer import LMConfig

FULL = LMConfig(
    arch_id="llava-next-mistral-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    exits=(8, 16, 24, 32),
    rope_theta=1_000_000.0,
    dtype=jnp.bfloat16,
    frontend="vision",
    frontend_seq=2880,             # anyres: up to 5 tiles x 576 patches
)

SMOKE = LMConfig(
    arch_id="llava-next-mistral-7b-smoke",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    exits=(1, 2, 3, 4),
    dtype=jnp.float32,
    frontend="vision",
    frontend_seq=16,
)
