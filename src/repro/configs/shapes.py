"""Assigned input shapes and per-(arch x shape) applicability.

LM shapes are (seq_len, global_batch):
  train_4k     4,096 x 256    -> lowers train_step
  prefill_32k  32,768 x 32    -> lowers prefill (inference)
  decode_32k   32,768 x 128   -> lowers serve_step: ONE new token against a
                                  KV cache of seq_len
  long_500k    524,288 x 1    -> serve_step; sub-quadratic archs only
                                  (SSM / hybrid) — full-attention archs skip
                                  it (DESIGN.md §Arch-applicability)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import build_model
from repro.models.transformer import LMConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Families whose serve-time state is sub-quadratic in context length.
_SUBQUADRATIC = ("rwkv", "jamba")


def applicable(cfg: LMConfig, shape_name: str) -> bool:
    """Whether an (arch x shape) cell is part of the assignment."""
    if shape_name == "long_500k":
        return cfg.family in _SUBQUADRATIC
    return True


def skip_reason(cfg: LMConfig, shape_name: str) -> Optional[str]:
    if applicable(cfg, shape_name):
        return None
    return (
        f"{cfg.arch_id} is pure full-attention; long_500k requires "
        "sub-quadratic attention (run only for SSM/hybrid archs)"
    )


def _src_len(cfg: LMConfig, seq_len: int, kind: str) -> int:
    """Frontend-stub source length for enc-dec (audio frames, ~4x
    downsampled from the target length; fixed 1k context for decode)."""
    return 1024 if kind == "decode" else max(seq_len // 4, 8)


def input_specs(cfg: LMConfig, shape_name: str, exit_idx: Optional[int] = None):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns (kind, kwargs) where kwargs match the corresponding step fn:
      train   -> {"batch": {...}}
      prefill -> {"batch": {...}, "exit_idx": e}
      decode  -> {"token": ..., "cache": ..., "exit_idx": e}
    No device memory is allocated.
    """
    spec = SHAPES[shape_name]
    if not applicable(cfg, shape_name):
        raise ValueError(skip_reason(cfg, shape_name))
    e = cfg.num_exits - 1 if exit_idx is None else exit_idx
    b, s = spec.global_batch, spec.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    f32 = cfg.dtype

    if spec.kind == "train":
        batch = {"tokens": tok, "labels": tok}
        if cfg.frontend == "vision":
            # VLM stub: patch embeddings replace the token embedding input.
            batch = {
                "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                "labels": tok,
            }
        if cfg.family == "encdec":
            batch["src_embeds"] = jax.ShapeDtypeStruct(
                (b, _src_len(cfg, s, "train"), cfg.d_model), f32)
        return "train", {"batch": batch}

    if spec.kind == "prefill":
        batch = {"tokens": tok}
        if cfg.frontend == "vision":
            batch = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32)}
        if cfg.family == "encdec":
            batch["src_embeds"] = jax.ShapeDtypeStruct(
                (b, _src_len(cfg, s, "prefill"), cfg.d_model), f32)
        return "prefill", {"batch": batch, "exit_idx": e}

    # decode: one new token against a cache of seq_len
    model = build_model(cfg)
    if cfg.family == "encdec":
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(b, s, e, src_len=_src_len(cfg, s, "decode"))
        )
    else:
        cache_shapes = jax.eval_shape(lambda: model.init_cache(b, s, e))
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return "decode", {"token": token, "cache": cache_shapes, "exit_idx": e}
