"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE. [arXiv:2402.19173; hf]"""

import jax.numpy as jnp

from repro.models.transformer import LMConfig

FULL = LMConfig(
    arch_id="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    exits=(8, 16, 24, 32),
    rope_theta=100_000.0,
    mlp_gated=False,               # starcoder2: plain GeLU FFN
    dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    arch_id="starcoder2-7b-smoke",
    family="dense",
    num_layers=4,
    d_model=72,
    num_heads=6,
    num_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=256,
    exits=(1, 2, 3, 4),
    mlp_gated=False,
    dtype=jnp.float32,
)
