"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
— llama-arch small, tied embeddings. [hf:HuggingFaceTB/SmolLM-135M; hf]"""

import jax.numpy as jnp

from repro.models.transformer import LMConfig

FULL = LMConfig(
    arch_id="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    exits=(8, 15, 23, 30),
    tie_embeddings=True,
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    arch_id="smollm-135m-smoke",
    family="dense",
    num_layers=4,
    d_model=48,
    num_heads=3,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    exits=(1, 2, 3, 4),
    tie_embeddings=True,
    dtype=jnp.float32,
)
