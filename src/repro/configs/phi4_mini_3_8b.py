"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA. [arXiv:2412.08905; hf]

Adaptation note: phi-4-mini uses partial-rotary long-rope; we apply standard
full RoPE (DESIGN.md §2 — positional flavour does not change latency/FLOPs).
"""

import jax.numpy as jnp

from repro.models.transformer import LMConfig

FULL = LMConfig(
    arch_id="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    exits=(8, 16, 24, 32),
    rope_theta=10_000.0,
    dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    arch_id="phi4-mini-3.8b-smoke",
    family="dense",
    num_layers=4,
    d_model=48,
    num_heads=3,
    num_kv_heads=1,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    exits=(1, 2, 3, 4),
    dtype=jnp.float32,
)
