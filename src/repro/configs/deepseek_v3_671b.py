"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048(expert)
vocab=129280, MLA, 1 shared + 256 routed top-8 (sigmoid router), first 3
layers dense (d_ff 18432). [arXiv:2412.19437; hf]

Adaptation notes (DESIGN.md §2): MTP (multi-token prediction) is a training
add-on head, not exercised by the assigned shapes; the MLA decode cache
stores the compressed latent (512 + 64 per token) — the reason this arch's
decode_32k cell is far lighter on HBM than its head count suggests.
"""

import jax.numpy as jnp

from repro.models.transformer import LMConfig

FULL = LMConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,                    # dense prefix FFN
    vocab_size=129280,
    exits=(15, 30, 45, 61),
    num_experts=256,
    top_k=8,
    num_shared_experts=1,
    d_ff_expert=2048,
    moe_router="sigmoid",
    dense_prefix=3,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
    dtype=jnp.bfloat16,
    remat="dots",                  # 671B training wants activation remat
)

SMOKE = LMConfig(
    arch_id="deepseek-v3-671b-smoke",
    family="moe",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=160,
    vocab_size=256,
    exits=(2, 3, 4, 5),
    num_experts=8,
    top_k=2,
    num_shared_experts=1,
    d_ff_expert=32,
    moe_router="sigmoid",
    dense_prefix=1,
    mla=True,
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_nope_head_dim=16,
    qk_rope_head_dim=8,
    v_head_dim=16,
    moe_group_size=16,
    dtype=jnp.float32,
)
