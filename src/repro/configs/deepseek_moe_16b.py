"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) d_ff=1408(expert)
vocab=102400, 2 shared + 64 routed top-6, fine-grained experts, first layer
dense (d_ff 10944). [arXiv:2401.06066; hf]"""

import jax.numpy as jnp

from repro.models.transformer import LMConfig

FULL = LMConfig(
    arch_id="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,                    # the dense first layer's FFN
    vocab_size=102400,
    exits=(7, 14, 21, 28),
    num_experts=64,
    top_k=6,
    num_shared_experts=2,
    d_ff_expert=1408,
    moe_router="softmax",
    dense_prefix=1,
    rope_theta=10_000.0,
    dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    arch_id="deepseek-moe-16b-smoke",
    family="moe",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=160,
    vocab_size=256,
    exits=(2, 3, 4, 5),
    num_experts=8,
    top_k=2,
    num_shared_experts=2,
    d_ff_expert=32,
    moe_router="softmax",
    dense_prefix=1,
    moe_group_size=16,
    dtype=jnp.float32,
)
