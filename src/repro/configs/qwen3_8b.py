"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936
— qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""

import jax.numpy as jnp

from repro.models.transformer import LMConfig

FULL = LMConfig(
    arch_id="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    exits=(9, 18, 27, 36),
    qk_norm=True,
    rope_theta=1_000_000.0,
    dtype=jnp.bfloat16,
)

SMOKE = LMConfig(
    arch_id="qwen3-8b-smoke",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    exits=(1, 2, 3, 4),
    qk_norm=True,
    dtype=jnp.float32,
)
